"""Serve a small model with batched requests through the DES engine.

The continuous-batching control plane is the paper's DES scheduler:
request arrivals/prefills/decodes are events; runs of decode events in
the lookahead window execute as pre-composed fused k-step programs.

    PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import numpy as np

from repro.configs import get_config
from repro.models import LM
from repro.serving.engine import ServingEngine


def main():
    cfg = get_config("phi4-mini-3.8b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_slots=4, max_len=128,
                           max_batch_len=6, arrival_lookahead=7.0)

    rng = np.random.default_rng(1)
    t = 0.0
    for rid in range(8):
        prompt = rng.integers(0, cfg.vocab_size, rng.integers(4, 12)).tolist()
        engine.submit(rid, prompt, max_new_tokens=10, at=t)
        t += 7.0 + float(rng.random() * 2)
    engine.schedule_decode_grid(1.0, t + 80.0)

    stats = engine.run()
    print(f"requests served: "
          f"{sum(r.done for r in engine.requests.values())}/8")
    print(f"decode events {stats.decode_events}; "
          f"fused batches {stats.fused_batches} "
          f"(mean run length {stats.mean_fused_length:.2f}); "
          f"single-step fallbacks {stats.singles}")
    print(f"composed programs: {sorted(stats.compiled_programs)}")
    for rid, r in sorted(engine.requests.items()):
        print(f"  req {rid}: {len(r.output)} tokens, "
              f"latency {(r.finish_time - r.arrival):.1f} sim-steps")


if __name__ == "__main__":
    main()
