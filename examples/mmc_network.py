"""A tandem M/M/c queueing network, written once, every runtime.

``K`` stations in series, each with ``c`` servers.  Customers enter at
station 0 (a self-scheduling arrival source), receive service (queueing
when all ``c`` servers are busy), and are routed to the next station on
departure.  A third, *entity-parallel* event type — TALLY — samples the
per-station queue length on a fixed grid: all K tallies share one
timestamp, so the extracted window is a single-type run and the device
engine dispatches it as ONE ``vmap`` over the stations
(``@prog.entity_handler``) instead of a sequential switch branch.

Like examples/phold.py, service/interarrival times are counter-based
hashes on the 0.25 time grid, so every backend — host conservative /
speculative / unbatched and device tiered / flat / reference — produces
bit-identical final state; the example asserts it.

    PYTHONPATH=src python examples/mmc_network.py [--stations 4] [--tiny]
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.api import ARG_WIDTH, Config, SimProgram

ARRIVE, DEPART, TALLY = 0, 1, 2  # registration-order type ids
C_SERVERS = 2

BACKENDS = {
    "host/conservative": dict(backend="host", scheduler="conservative"),
    "host/speculative": dict(backend="host", scheduler="speculative"),
    "host/unbatched": dict(backend="host", scheduler="unbatched"),
    "device/tiered": dict(backend="device", queue_mode="tiered"),
    "device/flat": dict(backend="device", queue_mode="flat"),
    "device/reference": dict(backend="device", queue_mode="reference"),
}


def _mix(t, station, salt: int):
    """Counter-based hash of (time, station, stream): exact on the 0.25
    time grid, identical across backends."""
    t4 = (t * 4.0).astype(jnp.uint32)
    h = (t4 * jnp.uint32(2654435761)
         + station.astype(jnp.uint32) * jnp.uint32(40503)
         + jnp.uint32(salt) * jnp.uint32(97))
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0x5BD1E995)
    return h ^ (h >> 15)


def _delay(h, lo: float = 0.5, steps: int = 8):
    """Grid-exact pseudo-exponential delay in {lo, lo+0.25, ...}."""
    return lo + (h % steps).astype(jnp.float32) * 0.25


def _row(cond, delay, type_id, a0, a1=None):
    """One portable emit row (delay, type, arg...); ν when cond is
    False."""
    zero = jnp.float32(0.0)
    ty = jnp.where(cond, jnp.float32(type_id), jnp.float32(-1.0))
    a1 = zero if a1 is None else a1
    pad = [zero] * (ARG_WIDTH - 2)
    return jnp.stack([delay.astype(jnp.float32), ty,
                      a0.astype(jnp.float32), a1] + pad)


def build_program(num_stations: int = 4, t_open: float = 30.0,
                  tally_every: float = 5.0, max_batch_len: int | None = None,
                  capacity: int = 512) -> SimProgram:
    """The network model.  ``max_batch_len`` defaults to the station
    count so a tally grid point fills exactly one vmapped window."""
    K = num_stations
    max_batch_len = K if max_batch_len is None else max_batch_len
    prog = SimProgram(
        "mmc_network",
        config=Config(max_batch_len=max_batch_len, capacity=capacity,
                      max_emit=2),
    )

    @prog.handler("ARRIVE", lookahead=0.5, emits=True)
    def arrive(state, t, arg):
        s = arg[0].astype(jnp.int32)
        is_source = arg[1] > 0.5  # the self-scheduling external stream
        service = _delay(_mix(t, s, 17))
        free = state["busy"][s] < C_SERVERS
        state = {
            **state,
            "busy": state["busy"].at[s].add(jnp.where(free, 1, 0)),
            "qlen": state["qlen"].at[s].add(jnp.where(free, 0, 1)),
            "arrived": state["arrived"].at[s].add(1),
        }
        next_gap = _delay(_mix(t, s, 23), lo=0.5, steps=6)
        emits = jnp.stack([
            # free server: begin service now, schedule the departure
            _row(free, service, DEPART, s.astype(jnp.float32)),
            # external source keeps itself alive while the doors are open
            _row(is_source & (t < t_open), next_gap, ARRIVE,
                 jnp.float32(0.0), jnp.float32(1.0)),
        ])
        return state, emits

    @prog.handler("DEPART", lookahead=0.5, emits=True)
    def depart(state, t, arg):
        s = arg[0].astype(jnp.int32)
        service = _delay(_mix(t, s, 29))
        waiting = state["qlen"][s] > 0
        state = {
            **state,
            "qlen": state["qlen"].at[s].add(jnp.where(waiting, -1, 0)),
            "busy": state["busy"].at[s].add(jnp.where(waiting, 0, -1)),
            "served": state["served"].at[s].add(1),
        }
        route = s < K - 1
        emits = jnp.stack([
            # a waiting customer takes the freed server immediately
            _row(waiting, service, DEPART, s.astype(jnp.float32)),
            # the finished customer hops to the next station in series
            _row(route, jnp.float32(0.5), ARRIVE,
                 (s + 1).astype(jnp.float32)),
        ])
        return state, emits

    @prog.entity_handler("TALLY", lookahead=1.0)
    def tally(entity_state, t, arg):
        # Entity-local: `entity_state` is one station's slice of every
        # state leaf.  Integrates queue length over the sample grid.
        return {
            **entity_state,
            "area": entity_state["area"] + entity_state["qlen"],
            "samples": entity_state["samples"] + 1,
        }

    prog.schedule(0.0, "ARRIVE", arg=[0.0, 1.0])
    g = tally_every
    while g < t_open + 10.0:
        for s in range(K):
            prog.schedule(g, "TALLY", arg=[float(s)])
        g += tally_every
    return prog


def initial_state(num_stations: int):
    z = jnp.zeros((num_stations,), jnp.int32)
    return {"qlen": z, "busy": z, "served": z, "arrived": z,
            "area": z, "samples": z}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--stations", type=int, default=4)
    ap.add_argument("--t-open", type=float, default=30.0)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (3 stations, short horizon)")
    args = ap.parse_args()
    K = 3 if args.tiny else args.stations
    t_open = 10.0 if args.tiny else args.t_open

    results = {}
    for label, build_kw in BACKENDS.items():
        prog = build_program(num_stations=K, t_open=t_open)
        res = prog.build(**build_kw).run(initial_state(K))
        results[label] = res
        print(f"{label:20s} events={res.events:5d} batches={res.batches:5d} "
              f"(mean len {res.mean_batch_length:4.2f}) "
              f"rollbacks={res.rollbacks:3d} served={np.asarray(res.state['served'])}")

    base = results["host/unbatched"]
    for label, res in results.items():
        for leaf in ("qlen", "busy", "served", "arrived", "area", "samples"):
            assert (np.asarray(res.state[leaf])
                    == np.asarray(base.state[leaf])).all(), (label, leaf)
        assert res.events == base.events and res.dropped == base.dropped, label

    st = base.state
    # conservation: everyone who arrived is served, queued, or in service
    assert (np.asarray(st["arrived"])
            == np.asarray(st["served"]) + np.asarray(st["qlen"])
            + np.asarray(st["busy"])).all()
    mean_q = np.asarray(st["area"]) / np.maximum(np.asarray(st["samples"]), 1)
    print(f"\nall {len(results)} runtimes agree bit-for-bit; "
          f"mean queue length per station: {np.round(mean_q, 2)}")


if __name__ == "__main__":
    main()
