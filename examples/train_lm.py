"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the production substrates exactly as the full-scale launcher does:
deterministic data pipeline, microbatched train step, async atomic
checkpoints, crash-recovery supervisor — on a llama-family config sized
to ~100M params so it runs on this CPU container.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_batch
from repro.models import LM
from repro.runtime.supervisor import FailureInjector, TrainSupervisor
from repro.training.optim import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def config_100m():
    """stablelm family scaled to ~100M params."""
    base = get_config("stablelm-12b")
    return dataclasses.replace(
        base, name="stablelm-100m", num_layers=6, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=1536,
        vocab_size=32768, attn_q_block=128, attn_kv_block=128)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = p.parse_args(argv)

    cfg = config_100m()
    model = LM(cfg)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.0f}M params")

    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.batch)

    state = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, opt_cfg, num_microbatches=2,
                                      remat=True))
    losses = []

    def logged(state, batch):
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        s = int(state["opt"]["step"])
        if s % 25 == 0:
            print(f"step {s:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(m['lr']):.2e}", flush=True)
        return state, m

    sup = TrainSupervisor(
        make_step=lambda n: logged, make_batch=lambda s: make_batch(dc, s),
        init_state=state, ckpt=CheckpointManager(args.ckpt_dir),
        ckpt_every=100, injector=FailureInjector([]))
    report = sup.run(args.steps)
    first, last = sum(losses[:10]) / 10, sum(losses[-10:]) / 10
    print(f"\n{report.steps_run} steps; loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'}); "
          f"{report.checkpoints_saved} checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
