"""Open-system serving: stream a request trace into the admission model.

The closed scenarios (examples/serve_lm.py, the admission program) bake
their arrival process into the model.  This example runs the OPEN
variant (DESIGN.md §10): requests come from a host-side arrival stream
— a synthetic Poisson source or an on-disk trace from
``scripts/gen_trace.py`` — fed block-by-block into the running device
engine with double-buffered host→device staging, while the admission
fence keeps execution bit-identical to pre-seeding the whole trace.

The example is the equivalence proof in miniature:

1. stream the trace:  ``sim.run(state0, arrivals=source)``
2. pre-seed the same trace and run the closed system
3. assert final state / events / final_time are bit-equal
4. report sustained ingest throughput (requests per wall-second)

    PYTHONPATH=src python examples/streaming_serving.py [--tiny]
        [--shards N] [--requests N] [--trace PATH] [--spill]
"""

import argparse
import time

import numpy as np

from repro.core.program import Config
from repro.serving.scenarios import (
    build_open_admission_program,
    initial_state,
)
from repro.stream import PoissonSource, TraceReader, source_events


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true",
                    help="small sizes for CI smoke")
    ap.add_argument("--requests", type=int, default=None,
                    help="trace length (default 400, or 48 with --tiny)")
    ap.add_argument("--shards", type=int, default=0,
                    help="run the sharded device engine with N shards")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--rate", type=float, default=2.0)
    ap.add_argument("--trace", default=None,
                    help="replay an on-disk trace (scripts/gen_trace.py) "
                         "instead of the synthetic source; must be "
                         "grid=0.25, type 0")
    ap.add_argument("--spill", action="store_true",
                    help="stream through a queue smaller than the trace "
                         "(overflow='spill' parks the excess host-side)")
    args = ap.parse_args()

    if args.trace is not None:
        source = TraceReader(args.trace)
        n_req = len(source)
    else:
        n_req = args.requests or (48 if args.tiny else 400)
        source = PoissonSource(args.rate, n_req, seed=7, grid=0.25,
                               type_id=0, block_size=64)

    # without --spill the device queue must hold the worst-case backlog
    # (every request waiting on an ADMIT retry at once); --spill shows
    # the bounded-memory shape instead, parking the excess host-side
    capacity = 48 if args.spill else max(1024, n_req + 64)
    cfg = Config(max_batch_len=3, capacity=capacity, max_emit=2)

    def build():
        return build_open_admission_program(
            num_slots=args.slots, num_requests=n_req, config=cfg)

    kw = dict(backend="device")
    if args.shards:
        kw["shards"] = args.shards
    if args.spill:
        kw["overflow"] = "spill"

    sim = build().build(**kw)
    state0 = initial_state(args.slots)
    sim.run(state0, arrivals=source)  # warm the jit caches
    source.seek(0)
    wall = time.perf_counter()
    streamed = sim.run(state0, arrivals=source)
    wall = time.perf_counter() - wall
    rps = streamed.ingested / wall
    print(f"streamed : {streamed.ingested} requests ingested, "
          f"{streamed.events} events, served="
          f"{int(streamed.state['served'])}, "
          f"final_time={streamed.final_time:.2f}")
    print(f"           {wall * 1e3:.1f} ms wall -> {rps:,.0f} sustained RPS")

    # closed-system reference: seeds first, then the trace (the seq
    # discipline the streamed run reserves for)
    closed_cfg = Config(max_batch_len=3, capacity=max(1024, n_req + 64),
                        max_emit=2)

    def build_closed():
        return build_open_admission_program(
            num_slots=args.slots, num_requests=n_req, config=closed_cfg)

    events = [(1.0, "TICK")] + [
        (t, ty, list(arg)) for (t, ty, arg) in source_events(source)
    ]
    closed = build_closed().build(backend="device").run(
        state0, events=events)
    print(f"closed   : {closed.events} events, "
          f"served={int(closed.state['served'])}, "
          f"final_time={closed.final_time:.2f}")

    for k, v in closed.state.items():
        np.testing.assert_array_equal(
            np.asarray(streamed.state[k]), np.asarray(v), err_msg=k)
    assert streamed.events == closed.events
    assert streamed.dropped == closed.dropped == 0
    assert np.float32(streamed.final_time) == np.float32(closed.final_time)
    print("equivalence: streamed run is bit-identical to pre-seeding "
          "the trace")


if __name__ == "__main__":
    main()
