"""Quickstart: the paper's Increment/Set model in 40 lines.

Shows the whole method end to end: register event handlers, compose
batches at compile time, run with the lookahead-window scheduler, and
verify the cross-event optimization (XLA removing the dead Increment
loop) plus the speedup over one-by-one execution.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import numpy as np

from repro import poc
from repro.core import Simulator, compose_word_fn

ITERS = 300_000
EVENTS = 200


def main():
    # 1. The event alphabet: Increment (heavy loop) and Set (constant).
    registry = poc.build_registry(iters=ITERS)

    # 2. Compile-time cross-event optimization, observed directly:
    import jax.numpy as jnp
    batch = compose_word_fn(registry, [poc.INCREMENT, poc.SET])
    hlo = jax.jit(batch).lower(
        jax.ShapeDtypeStruct((), jnp.uint32),
        [jax.ShapeDtypeStruct((), jnp.float32)] * 2,
        [None, None]).compile().as_text()
    print("batch [Increment, Set]: increment loop removed by XLA:",
          " while(" not in hlo)

    # 3. Run a simulation: one event per time step, 50% Set.
    rng = np.random.default_rng(0)
    types = [int(x) for x in (rng.random(EVENTS) < 0.5)]

    def simulate(mode, n=4, composer=None):
        sim = Simulator(registry, max_batch_len=n)
        if composer is not None:
            sim.composer = composer
        for t, ty in enumerate(types):
            sim.queue.push(float(t), ty)
        t0 = time.perf_counter()
        state, stats = sim.run(poc.initial_state(), mode=mode)
        jax.block_until_ready(state)
        return time.perf_counter() - t0, int(state), stats, sim.composer

    _, _, _, composer = simulate("conservative")       # warm-up/compile
    simulate("unbatched")
    t_batched, s_b, stats, _ = simulate("conservative", composer=composer)
    t_single, s_u, _, _ = simulate("unbatched")
    assert s_b == s_u == poc.reference_final_sum(types, ITERS)
    print(f"events={EVENTS}  batches={stats.batches_executed} "
          f"(mean length {stats.mean_batch_length:.1f})")
    print(f"one-by-one: {t_single*1e3:.1f} ms   "
          f"batched: {t_batched*1e3:.1f} ms   "
          f"speedup: {t_single/t_batched:.2f}x "
          f"(analytic bound {poc.s_max(4, 0.5):.2f}x)")


if __name__ == "__main__":
    main()
