"""Quickstart: the paper's Increment/Set model in 40 lines.

Shows the whole method end to end with the `repro.api` surface: define
the model once on a SimProgram, observe the cross-event optimization
(XLA removing the dead Increment loop) on a composed batch, then compile
THE SAME definition to the batched lookahead-window scheduler and to the
one-by-one baseline and measure the speedup.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import numpy as np

from repro import poc
from repro.core import compose_word_fn

ITERS = 300_000
EVENTS = 200


def main():
    # 1. The event alphabet: Increment (heavy loop) and Set (constant),
    #    declared once on a SimProgram.
    prog = poc.build_program(iters=ITERS)

    # 2. Compile-time cross-event optimization, observed directly:
    import jax.numpy as jnp
    batch = compose_word_fn(prog.host_registry(), [poc.INCREMENT, poc.SET])
    hlo = jax.jit(batch).lower(
        jax.ShapeDtypeStruct((), jnp.uint32),
        [jax.ShapeDtypeStruct((), jnp.float32)] * 2,
        [None, None]).compile().as_text()
    print("batch [Increment, Set]: increment loop removed by XLA:",
          " while(" not in hlo)

    # 3. Run a simulation: one event per time step, 50% Set.
    rng = np.random.default_rng(0)
    types = [int(x) for x in (rng.random(EVENTS) < 0.5)]
    for t, ty in enumerate(types):
        prog.schedule(float(t), ("Increment", "Set")[ty])

    # Two runtimes from the same definition; CompiledSim handles are
    # re-runnable, so the second run of each is warm (compiled).
    batched = prog.build(backend="host", scheduler="conservative")
    unbatched = prog.build(backend="host", scheduler="unbatched")

    def timed(sim):
        t0 = time.perf_counter()
        res = sim.run(poc.initial_state())
        jax.block_until_ready(res.state)
        return time.perf_counter() - t0, res

    timed(batched)          # warm-up (composes + compiles)
    timed(unbatched)
    t_batched, res_b = timed(batched)
    t_single, res_u = timed(unbatched)
    assert int(res_b.state) == int(res_u.state) \
        == poc.reference_final_sum(types, ITERS)
    print(f"events={EVENTS}  batches={res_b.batches} "
          f"(mean length {res_b.mean_batch_length:.1f})")
    print(f"one-by-one: {t_single*1e3:.1f} ms   "
          f"batched: {t_batched*1e3:.1f} ms   "
          f"speedup: {t_single/t_batched:.2f}x "
          f"(analytic bound {poc.s_max(4, 0.5):.2f}x)")


if __name__ == "__main__":
    main()
