"""PHOLD, written once, runnable on every runtime.

PHOLD is the standard synthetic PDES benchmark (Fujimoto, 1990): a
constant population of messages hops between logical processes; each
executed hop schedules exactly one future hop at a pseudo-random LP
with a pseudo-random delay.  It stresses the part the PoC model leaves
out — a hot emit/insert path with data-dependent routing.

The model is defined ONCE on a :class:`repro.api.SimProgram` and then
compiled to every runtime (host conservative / speculative /
unbatched; device tiered3 / tiered / flat / reference queues; add
``--shards N`` for the sharded engine).  Every run must
produce the same final state bit-for-bit, including the
order-sensitive ``checksum`` — the randomness is a counter-based hash
of ``(time, lp)`` and every delay is a multiple of 0.5, so f32 device
arithmetic and the host heap agree exactly.

    PYTHONPATH=src python examples/phold.py [--lps 8] [--t-stop 40] [--tiny]
                                            [--shards N]

``--shards N`` adds the sharded device engine (N per-shard tiered3
queues under the lookahead-synchronized super-step, DESIGN.md §5.1) to
the matrix — LPs route to shards by their index, and the run must stay
bit-identical to every single-queue backend.
"""

import argparse

import jax.numpy as jnp
import numpy as np

from repro.api import ARG_WIDTH, Config, SimProgram

HOP = 0  # single-type alphabet: registration order id

BACKENDS = {
    "host/conservative": dict(backend="host", scheduler="conservative"),
    "host/speculative": dict(backend="host", scheduler="speculative"),
    "host/unbatched": dict(backend="host", scheduler="unbatched"),
    "device/tiered3": dict(backend="device", queue_mode="tiered3"),
    "device/tiered": dict(backend="device", queue_mode="tiered"),
    "device/flat": dict(backend="device", queue_mode="flat"),
    "device/reference": dict(backend="device", queue_mode="reference"),
}


def _mix(t, src):
    """Counter-based hash of (time, lp): deterministic 'randomness'
    that is identical on every backend.  Times stay on the 0.5 grid,
    so ``2t`` is an exact integer in f32."""
    t2 = (t * 2.0).astype(jnp.uint32)
    h = (t2 * jnp.uint32(2654435761)
         + src.astype(jnp.uint32) * jnp.uint32(40503)
         + jnp.uint32(12345))
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0x5BD1E995)
    return h ^ (h >> 15)


def build_program(num_lps: int = 8, t_stop: float = 40.0,
                  max_batch_len: int = 4, capacity: int = 256) -> SimProgram:
    """The PHOLD model: one emitting HOP type, one initial hop per LP."""
    prog = SimProgram(
        "phold",
        config=Config(max_batch_len=max_batch_len, capacity=capacity,
                      max_emit=1),
    )

    @prog.handler("HOP", lookahead=1.0, emits=True)
    def hop(state, t, arg):
        src = arg[0].astype(jnp.int32)
        h = _mix(t, src)
        # delay in {1.0, 1.5, ..., 4.5} >= the declared lookahead;
        # destination is any OTHER lp — both pure functions of (t, src).
        delay = 1.0 + (h % 8).astype(jnp.float32) * 0.5
        dst = (src + 1 + ((h // 8) % (num_lps - 1)).astype(jnp.int32)) \
            % num_lps
        counts = state["counts"].at[src].add(1)
        checksum = state["checksum"] * jnp.uint32(31) + h
        emit = jnp.zeros((1, 2 + ARG_WIDTH), jnp.float32)
        emit = (emit.at[0, 0].set(delay)
                    .at[0, 1].set(jnp.where(t < t_stop, 0.0, -1.0))
                    .at[0, 2].set(dst.astype(jnp.float32)))
        return {"counts": counts, "checksum": checksum}, emit

    for lp in range(num_lps):
        prog.schedule(0.5 * lp, "HOP", arg=[float(lp)])
    return prog


def initial_state(num_lps: int):
    return {
        "counts": jnp.zeros((num_lps,), jnp.int32),
        "checksum": jnp.uint32(1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lps", type=int, default=8)
    ap.add_argument("--t-stop", type=float, default=40.0)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (4 LPs, short horizon)")
    ap.add_argument("--shards", type=int, default=None,
                    help="also run the sharded device engine with this "
                         "many per-shard queues (bit-parity asserted)")
    args = ap.parse_args()
    num_lps = 4 if args.tiny else args.lps
    t_stop = 8.0 if args.tiny else args.t_stop
    backends = dict(BACKENDS)
    if args.shards:
        backends[f"device/{args.shards}shard"] = dict(
            backend="device", shards=args.shards)

    results = {}
    for label, build_kw in backends.items():
        prog = build_program(num_lps=num_lps, t_stop=t_stop)
        sim = prog.build(**build_kw)
        res = sim.run(initial_state(num_lps))
        results[label] = res
        print(f"{label:20s} events={res.events:5d} batches={res.batches:5d} "
              f"(mean len {res.mean_batch_length:4.2f}) "
              f"rollbacks={res.rollbacks:3d} dropped={res.dropped} "
              f"checksum={int(res.state['checksum']):>10d}")

    base = results["host/unbatched"]
    for label, res in results.items():
        assert int(res.state["checksum"]) == int(base.state["checksum"]), label
        assert (np.asarray(res.state["counts"])
                == np.asarray(base.state["counts"])).all(), label
        assert res.events == base.events and res.dropped == base.dropped, label
    print(f"\nall {len(results)} runtimes agree bit-for-bit: "
          f"counts={np.asarray(base.state['counts'])}")


if __name__ == "__main__":
    main()
