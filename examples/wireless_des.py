"""The paper's §IV.A wireless-broadcast sketch, made concrete.

"Suppose a node in a simulated network periodically broadcasts messages
to nearby receivers.  The successful reception depends on whether the
receiver is in a power-saving state.  If none of the nearby nodes is
ready to receive, the computations involved in the creation of the
message could be avoided entirely."

Events:
* SleepAll     — every receiver enters power saving (awake = 0)
* WakeAll      — every receiver wakes (awake = 1)
* Broadcast    — sender builds an expensive message (a long mixing
                 loop) and delivers it to awake receivers.

In the batch [SleepAll, Broadcast], the delivery mask is all-zero — XLA's
cross-event DCE removes the message-construction loop, exactly the
paper's motivating scenario.  Verified on the optimized HLO below.

The model is defined ONCE on a :class:`repro.api.SimProgram` and then
compiled to the host scheduler and to the on-device engine in both
queue modes — same definition, every runtime, identical inboxes.

    PYTHONPATH=src python examples/wireless_des.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Config, SimProgram
from repro.core import compose_word_fn

N_RECEIVERS = 4
MSG_WORK = 100_000
SLEEP, WAKE, BCAST = 0, 1, 2  # registration-order type ids


def build_program() -> SimProgram:
    prog = SimProgram(
        "wireless",
        config=Config(max_batch_len=2, capacity=64),
    )

    @prog.handler("SleepAll")
    def sleep_all(state, t, arg):
        return {**state, "awake": jnp.zeros_like(state["awake"])}

    @prog.handler("WakeAll")
    def wake_all(state, t, arg):
        return {**state, "awake": jnp.ones_like(state["awake"])}

    @prog.handler("Broadcast")
    def broadcast(state, t, arg):
        # expensive message construction (mixing loop)
        msg = jax.lax.fori_loop(
            0, MSG_WORK,
            lambda i, m: m * jnp.uint32(1664525) + jnp.uint32(1013904223),
            jnp.uint32(12345))
        # delivery gated by receiver power state
        delivered = state["inbox"] + state["awake"] * msg
        return {**state, "inbox": delivered.astype(jnp.uint32)}

    # day/night duty cycle with periodic broadcasts
    for day in range(8):
        base = day * 10.0
        prog.schedule(base + 0.0, "SleepAll")
        prog.schedule(base + 1.0, "Broadcast")
        prog.schedule(base + 2.0, "Broadcast")
        prog.schedule(base + 5.0, "WakeAll")
        prog.schedule(base + 6.0, "Broadcast")
    return prog


def initial_state():
    return {
        "awake": jnp.ones((N_RECEIVERS,), jnp.uint32),
        "inbox": jnp.zeros((N_RECEIVERS,), jnp.uint32),
    }


def main():
    prog = build_program()

    # cross-event DCE check: [SleepAll, Broadcast, WakeAll] -> no one can
    # receive, so the message-construction loop must disappear.  The
    # composed word programs come from the program's host registry.
    state_spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), initial_state())
    t_spec = [jax.ShapeDtypeStruct((), jnp.float32)] * 3

    reg = prog.host_registry()
    dead = compose_word_fn(reg, [SLEEP, BCAST, WAKE])
    live = compose_word_fn(reg, [WAKE, BCAST, SLEEP])
    hlo_dead = jax.jit(dead).lower(state_spec, t_spec,
                                   [None] * 3).compile().as_text()
    hlo_live = jax.jit(live).lower(state_spec, t_spec,
                                   [None] * 3).compile().as_text()
    print("message loop removed when all receivers sleep:",
          " while(" not in hlo_dead)
    print("message loop present when receivers awake:   ",
          " while(" in hlo_live)

    # host runtime
    host = prog.build(backend="host", scheduler="conservative")
    res = host.run(initial_state())
    print(f"host run: batches executed: {res.batches} "
          f"(mean len {res.mean_batch_length:.1f}); "
          f"final inbox: {np.asarray(res.state['inbox'])}")

    # SAME definition compiled to ONE on-device program: queue, window
    # selection, and dispatch all run inside a single lax.while_loop —
    # zero host round-trips during the run.  The default pending-event
    # set is the two-tier queue (DESIGN.md §4), so the engine can be
    # provisioned with deep capacity headroom at no per-batch cost.
    # CompiledSim.run rebuilds the donated device queue each call, so
    # the handle is freely re-runnable.
    for queue_mode, capacity in (("tiered", 4096), ("flat", 64)):
        dev = prog.build(backend="device", queue_mode=queue_mode,
                         capacity=capacity)
        dres = dev.run(initial_state())
        same = bool((np.asarray(dres.state["inbox"])
                     == np.asarray(res.state["inbox"])).all())
        print(f"on-device engine [{queue_mode:6s} queue, "
              f"capacity {capacity:4d}]: batches={dres.batches} "
              f"events={dres.events} "
              f"dropped={dres.dropped}; matches host run: {same}")


if __name__ == "__main__":
    main()
