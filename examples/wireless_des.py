"""The paper's §IV.A wireless-broadcast sketch, made concrete.

"Suppose a node in a simulated network periodically broadcasts messages
to nearby receivers.  The successful reception depends on whether the
receiver is in a power-saving state.  If none of the nearby nodes is
ready to receive, the computations involved in the creation of the
message could be avoided entirely."

Events:
* Sleep(i)     — receiver i enters power saving (awake[i] = 0)
* Wake(i)      — receiver i wakes (awake[i] = 1)
* Broadcast    — sender builds an expensive message (a long mixing
                 loop) and delivers it to awake receivers.

In the batch [Sleep(all), Broadcast], the delivery mask is all-zero —
XLA's cross-event DCE removes the message-construction loop, exactly
the paper's motivating scenario.  Verified on the optimized HLO below.

    PYTHONPATH=src python examples/wireless_des.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ARG_WIDTH, EventRegistry, Simulator, compose_word_fn

N_RECEIVERS = 4
MSG_WORK = 100_000


def build_registry():
    reg = EventRegistry()

    def sleep_all(state, t, arg):
        return {**state, "awake": jnp.zeros_like(state["awake"])}

    def wake_all(state, t, arg):
        return {**state, "awake": jnp.ones_like(state["awake"])}

    def broadcast(state, t, arg):
        # expensive message construction (mixing loop)
        msg = jax.lax.fori_loop(
            0, MSG_WORK,
            lambda i, m: m * jnp.uint32(1664525) + jnp.uint32(1013904223),
            jnp.uint32(12345))
        # delivery gated by receiver power state
        delivered = state["inbox"] + state["awake"] * msg
        return {**state, "inbox": delivered.astype(jnp.uint32)}

    reg.register("SleepAll", sleep_all, lookahead=np.inf)
    reg.register("WakeAll", wake_all, lookahead=np.inf)
    reg.register("Broadcast", broadcast, lookahead=np.inf)
    return reg.freeze()


def initial_state():
    return {
        "awake": jnp.ones((N_RECEIVERS,), jnp.uint32),
        "inbox": jnp.zeros((N_RECEIVERS,), jnp.uint32),
    }


def main():
    reg = build_registry()
    SLEEP, WAKE, BCAST = 0, 1, 2

    # cross-event DCE check: [SleepAll, Broadcast, WakeAll] -> no one can
    # receive, so the message-construction loop must disappear.
    state_spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), initial_state())
    t_spec = [jax.ShapeDtypeStruct((), jnp.float32)] * 3

    dead = compose_word_fn(reg, [SLEEP, BCAST, WAKE])
    live = compose_word_fn(reg, [WAKE, BCAST, SLEEP])
    hlo_dead = jax.jit(dead).lower(state_spec, t_spec,
                                   [None] * 3).compile().as_text()
    hlo_live = jax.jit(live).lower(state_spec, t_spec,
                                   [None] * 3).compile().as_text()
    print("message loop removed when all receivers sleep:",
          " while(" not in hlo_dead)
    print("message loop present when receivers awake:   ",
          " while(" in hlo_live)

    # run a simulation: day/night duty cycle with periodic broadcasts
    sim = Simulator(reg, max_batch_len=4)
    for day in range(8):
        base = day * 10.0
        sim.schedule(base + 0.0, "SleepAll")
        sim.schedule(base + 1.0, "Broadcast")
        sim.schedule(base + 2.0, "Broadcast")
        sim.schedule(base + 5.0, "WakeAll")
        sim.schedule(base + 6.0, "Broadcast")
    state, stats = sim.run(initial_state(), mode="conservative")
    print(f"batches executed: {stats.batches_executed} "
          f"(mean len {stats.mean_batch_length:.1f}); "
          f"final inbox: {np.asarray(state['inbox'])}")

    # same model compiled to ONE on-device program: queue, window
    # selection, and dispatch all run inside a single lax.while_loop —
    # zero host round-trips during the run.  The default pending-event
    # set is the two-tier queue (DESIGN.md §4): per-batch scheduling
    # touches only the small front/staging tiers, so the engine can be
    # provisioned with deep capacity headroom for emission bursts at no
    # per-batch cost.  A run consumes its input queue (the buffers are
    # donated); build a fresh one per run via eng.initial_queue.
    from repro.core import DeviceEngine

    events = []
    for day in range(8):
        base = day * 10.0
        events += [(base + 0.0, 0, None), (base + 1.0, 2, None),
                   (base + 2.0, 2, None), (base + 5.0, 1, None),
                   (base + 6.0, 2, None)]
    for queue_mode, capacity in (("tiered", 4096), ("flat", 64)):
        eng = DeviceEngine(reg, max_batch_len=2, capacity=capacity,
                           queue_mode=queue_mode)
        dstate, _q, dstats = eng.run(initial_state(),
                                     eng.initial_queue(events))
        same = bool((np.asarray(dstate["inbox"])
                     == np.asarray(state["inbox"])).all())
        print(f"on-device engine [{queue_mode:6s} queue, "
              f"capacity {capacity:4d}]: batches={int(dstats['batches'])} "
              f"events={int(dstats['events'])} "
              f"dropped={int(dstats['dropped'])}; matches host run: {same}")


if __name__ == "__main__":
    main()
