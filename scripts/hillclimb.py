import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: compile one (arch × shape) cell under a named
variant and print the roofline terms.

    PYTHONPATH=src python scripts/hillclimb.py --arch llama3-405b \
        --shape train_4k --variant bf16_proj

Variants (composable with '+'):
    base          — paper-faithful baseline config
    bf16_proj     — projection matmuls emit bf16 (bf16 TP all-reduces)
    prevent_cse   — jax.checkpoint(prevent_cse=True)
    no_remat      — disable activation rematerialization
    microK        — K gradient-accumulation microbatches (e.g. micro8)
    qblkN/kvblkN  — attention block sizes (e.g. qblk1024)
    ssmchunkN     — mamba chunk size
    no_fsdp       — replicate params over data axis (pure DP+TP)
"""

import argparse
import dataclasses
import json
import time

import jax

from repro.configs import SHAPES, get_config
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell


def apply_variant(cfg, variant):
    model_kwargs = {}
    kwargs = {}
    for v in variant.split("+"):
        if v == "base" or not v:
            continue
        elif v == "bf16_proj":
            from repro.models.layers import set_matmul_precision
            set_matmul_precision(False)
        elif v == "prevent_cse":
            model_kwargs["remat_prevent_cse"] = True
        elif v == "seqpar":
            model_kwargs["seq_parallel"] = True
        elif v == "no_remat":
            kwargs["no_remat"] = True
        elif v.startswith("micro"):
            kwargs["num_microbatches"] = int(v[5:])
        elif v.startswith("qblk"):
            cfg = dataclasses.replace(cfg, attn_q_block=int(v[4:]))
        elif v.startswith("kvblk"):
            cfg = dataclasses.replace(cfg, attn_kv_block=int(v[5:]))
        elif v.startswith("ssmchunk"):
            cfg = dataclasses.replace(
                cfg, mamba=dataclasses.replace(cfg.mamba, chunk=int(v[8:])))
        elif v == "no_fsdp":
            kwargs["fsdp"] = False
        else:
            raise SystemExit(f"unknown variant {v}")
    return cfg, model_kwargs, kwargs


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--mesh", default="single")
    p.add_argument("--mesh-shape", default=None,
                   help="override mesh, e.g. 32x8 (data x model)")
    p.add_argument("--variant", default="base")
    p.add_argument("--log", default="/root/repo/perf_iterations.jsonl")
    args = p.parse_args()

    cfg = get_config(args.arch)
    cfg, model_kwargs, kwargs = apply_variant(cfg, args.variant)
    no_remat = kwargs.pop("no_remat", False)
    if args.mesh_shape:
        import jax as _jax
        d, m = (int(t) for t in args.mesh_shape.split("x"))
        mesh = _jax.make_mesh((d, m), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    cell = build_cell(cfg, args.shape, mesh, model_kwargs=model_kwargs,
                      **kwargs)
    if no_remat and cell.kind == "train":
        # rebuild the step without remat
        from repro.launch.specs import pick_microbatches
        from repro.models import LM
        from repro.training.optim import AdamWConfig
        from repro.training.train_step import make_train_step
        from repro.launch.mesh import dp_size
        model = LM(cfg, **(model_kwargs or {}))
        nm = kwargs.get("num_microbatches") or pick_microbatches(
            SHAPES[args.shape]["global_batch"], dp_size(mesh))
        cell.fn = make_train_step(model, AdamWConfig(),
                                  num_microbatches=nm, remat=False)
    t0 = time.time()
    with mesh:
        compiled = jax.jit(
            cell.fn, in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums).lower(
                *cell.arg_specs).compile()
    t_compile = time.time() - t0
    shape = SHAPES[args.shape]
    mf = rl.model_flops_for(cfg, cell.kind, cell.static_info["tokens"],
                            shape["seq_len"])
    roof = rl.analyze(compiled, arch=args.arch, shape=args.shape,
                      mesh_name=args.mesh, chips=mesh.size, model_flops=mf)
    ms = roof.memory_stats
    rec = {
        "arch": args.arch, "shape": args.shape,
        "variant": args.variant + (f"@{args.mesh_shape}"
                                   if args.mesh_shape else ""),
        "compute_s": roof.compute_seconds, "memory_s": roof.memory_seconds,
        "collective_s": roof.collective_seconds,
        "dominant": roof.dominant, "mfu_at_bound": roof.mfu,
        "useful_fraction": roof.useful_flops_fraction,
        "temp_gb": ms["temp_bytes"] / 1e9,
        "coll_by_op": {k: round(v["bytes"] / 1e9, 2)
                       for k, v in roof.collective_detail.items()
                       if v["count"]},
        "compile_s": round(t_compile, 1),
    }
    print(json.dumps(rec, indent=1))
    with open(args.log, "a") as f:
        f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
