"""Generate an on-disk arrival trace in bounded memory.

Streams a synthetic :mod:`repro.stream` source block-at-a-time through
:class:`~repro.stream.source.TraceWriter` — peak host memory is ONE
block regardless of ``--n``, so million-row serving traces are cheap:

    PYTHONPATH=src python scripts/gen_trace.py \
        --kind poisson --rate 4.0 --n 1000000 --grid 0.25 \
        --out /tmp/serving.trace

The written file replays with ``TraceReader(path)`` as a
``run(arrivals=...)`` source; metadata (kind, parameters, seed) rides
the header so a trace is self-describing.
"""

import argparse
import sys
import time

from repro.stream import (
    BurstySource,
    DiurnalSource,
    PoissonSource,
    TraceWriter,
)


def build_source(args):
    kw = dict(seed=args.seed, t0=args.t0, type_id=args.type_id,
              block_size=args.block, grid=args.grid)
    if args.kind == "poisson":
        return PoissonSource(args.rate, args.n, **kw)
    if args.kind == "bursty":
        return BurstySource(args.burst_rate, args.idle_rate,
                            args.burst_len, args.n, **kw)
    return DiurnalSource(args.rate, args.n, amplitude=args.amplitude,
                         period=args.period, **kw)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--kind", choices=["poisson", "bursty", "diurnal"],
                    default="poisson")
    ap.add_argument("--n", type=int, default=100_000,
                    help="number of arrival rows")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="poisson/diurnal base rate (events per unit time)")
    ap.add_argument("--burst-rate", type=float, default=32.0)
    ap.add_argument("--idle-rate", type=float, default=0.5)
    ap.add_argument("--burst-len", type=int, default=16)
    ap.add_argument("--amplitude", type=float, default=0.5)
    ap.add_argument("--period", type=float, default=256.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--t0", type=float, default=0.0)
    ap.add_argument("--type-id", type=int, default=0,
                    help="event type id carried by every arrival row")
    ap.add_argument("--grid", type=float, default=None,
                    help="snap times to this f32-exact grid (e.g. 0.25)")
    ap.add_argument("--block", type=int, default=4096,
                    help="rows generated/written per block")
    ap.add_argument("--out", required=True, help="output trace path")
    args = ap.parse_args(argv)

    src = build_source(args)
    meta = {"kind": args.kind, "seed": args.seed, "n": args.n,
            "grid": args.grid, "type_id": args.type_id}
    wall = time.perf_counter()
    written = 0
    with TraceWriter(args.out, meta=meta) as w:
        for block in src.blocks():
            written += w.write_block(block)
            if written % (args.block * 64) == 0:
                print(f"  {written}/{args.n} rows", file=sys.stderr)
    wall = time.perf_counter() - wall
    print(f"wrote {written} rows to {args.out} in {wall:.2f}s "
          f"({written / max(wall, 1e-9):,.0f} rows/s)")


if __name__ == "__main__":
    main()
