"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from
dryrun_results.json (so the document regenerates from artifacts).

    PYTHONPATH=src python scripts/render_experiments.py > /tmp/tables.md
"""

import json
import sys


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.1f}s"
    return f"{x*1e3:.1f}ms"


def main(path="dryrun_results.json"):
    rs = json.load(open(path))
    ok = [r for r in rs if r["status"] == "ok"]
    sk = [r for r in rs if r["status"] == "skipped"]

    print("### Dry-run matrix (compile success, per-device memory)\n")
    print("| arch | shape | mesh | chips | compile | args/dev | temp/dev |"
          " collectives (counts) |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        ro = r["roofline"]
        ms = ro["memory_stats"]
        coll = ro["collective_detail"]
        cstr = " ".join(f"{k.split('-')[-1]}:{int(v['count'])}"
                        for k, v in sorted(coll.items()) if v["count"])
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {ro['chips']} "
              f"| {r['compile_seconds']:.1f}s "
              f"| {ms['argument_bytes']/1e9:.2f}GB "
              f"| {ms['temp_bytes']/1e9:.2f}GB | {cstr} |")
    print(f"\nSkipped cells ({len(sk)}):\n")
    for r in sorted(sk, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(f"* {r['arch']} × {r['shape']} × {r['mesh']} — {r['reason']}")

    print("\n### Roofline terms (single-pod 16×16 = 256 chips)\n")
    print("memory columns: as-lowered on the CPU backend / assuming "
          "TPU-native bf16 dots (no f32 legalization converts) / with "
          "the Pallas flash-attention kernel (scores stay in VMEM).\n")
    print("| arch | shape | compute | memory | mem(bf16-native) |"
          " mem(pallas-adj) | collective | dominant | useful-FLOP frac |"
          " MFU@bound |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "single":
            continue
        ro = r["roofline"]
        adj = r.get("memory_seconds_pallas_adj", ro["memory_seconds"])
        nb = ro["memory_stats"].get("memory_seconds_native_bf16",
                                    ro["memory_seconds"])
        print(f"| {r['arch']} | {r['shape']} "
              f"| {fmt_s(ro['compute_seconds'])} "
              f"| {fmt_s(ro['memory_seconds'])} "
              f"| {fmt_s(nb)} "
              f"| {fmt_s(adj)} "
              f"| {fmt_s(ro['collective_seconds'])} "
              f"| {ro['dominant']} "
              f"| {ro['useful_flops_fraction']:.3f} "
              f"| {ro['mfu_at_bound']:.4f} |")

    print("\n### Multi-pod (2×16×16 = 512 chips) deltas vs single-pod\n")
    print("| arch | shape | compute ratio | memory ratio | collective"
          " ratio |")
    print("|---|---|---|---|---|")
    single = {(r["arch"], r["shape"]): r["roofline"] for r in ok
              if r["mesh"] == "single"}
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "multi":
            continue
        s = single.get((r["arch"], r["shape"]))
        if not s:
            continue
        ro = r["roofline"]
        def ratio(a, b):
            return f"{a/b:.2f}" if b else "-"
        print(f"| {r['arch']} | {r['shape']} "
              f"| {ratio(ro['compute_seconds'], s['compute_seconds'])} "
              f"| {ratio(ro['memory_seconds'], s['memory_seconds'])} "
              f"| {ratio(ro['collective_seconds'], s['collective_seconds'])}"
              f" |")


if __name__ == "__main__":
    main(*sys.argv[1:])
