"""Paper §IV.B: runtime overhead of batch selection ≈ 5 %.

Workload: m Set events only (no Increment), so handler work is
negligible and the measurement isolates the scheduler.  Compared:
one-by-one execution vs batch selection at mean batch length 2
(max_batch_len=2), exactly the paper's setup.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro import poc
from repro.core import Simulator


def run(quick: bool = False, *, repeats: int = 5):
    m = 512 if quick else 2048
    reg = poc.build_registry(iters=8)

    def once(mode, max_len, composer=None):
        sim = Simulator(reg, max_batch_len=max_len)
        if composer is not None:
            sim.composer = composer
        for t in range(m):
            sim.queue.push(float(t), poc.SET)
        t0 = time.perf_counter()
        state, stats = sim.run(poc.initial_state(), mode=mode)
        jax.block_until_ready(state)
        return time.perf_counter() - t0, stats, sim.composer

    # warm-up (compilation)
    _, _, comp = once("conservative", 2)
    once("unbatched", 1)

    t_b = min(once("conservative", 2, comp)[0] for _ in range(repeats))
    t_u = min(once("unbatched", 1)[0] for _ in range(repeats))
    _, stats, _ = once("conservative", 2, comp)
    return {
        "events": m,
        "unbatched_seconds": t_u,
        "batched_seconds": t_b,
        "overhead_pct": (t_b - t_u) / t_u * 100.0,
        "mean_batch_length": stats.mean_batch_length,
    }


def main(quick: bool = False):
    r = run(quick=quick)
    print("events,unbatched_s,batched_s,overhead_pct,mean_batch_len")
    print(f"{r['events']},{r['unbatched_seconds']:.4f},"
          f"{r['batched_seconds']:.4f},{r['overhead_pct']:.1f},"
          f"{r['mean_batch_length']:.2f}")
    return r


if __name__ == "__main__":
    main()
