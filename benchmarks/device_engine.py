"""Beyond-paper: fully on-device DES vs host-driven dispatch, plus the
per-batch scheduling-overhead split (extract / dispatch / insert).

Two measurements:

* ``run``  — events/second of the on-device engine against the
  host-driven batched scheduler on the PoC model (as in the seed).

* ``scheduling_overhead`` — the cost of the queue machinery itself, on
  a trivial-handler workload (each event bumps a counter and emits one
  far-future event, so per-batch time is almost pure scheduling).
  Two measurements:

  - **anchor** (capacity 4096, max_batch_len 16, the PR-1 reference
    point): whole-run per-batch and per-op split for all three queue
    modes (tiered / flat / reference).

  - **capacity sweep** (1k/4k/16k/64k × {tiered, flat}) at a FIXED
    pending-set size, so what scales is only the allocated capacity:
    whole-run per-batch cost plus a chained insert-op loop.  The
    recorded ``insert_op_ratio_16k_over_1k`` is the capacity-
    independence claim as a number: per-batch insert cost at 16384
    must stay within 2x of its capacity-1024 cost under
    ``queue_mode="tiered"``.

* ``near_full`` — the ROADMAP follow-up baseline: the tiered queue held
  at >=90% occupancy with emissions alternating between near-head
  landings (front merges + tail evictions into staging) and far-future
  landings (staging appends with no ring headroom), so the rare
  O(capacity) flush/merge/compaction paths fire continuously.  This is
  the workload a third (log-structured) tier or in-ring compaction with
  slack reserve must beat; ``--near-full-only`` refreshes just this
  section of the JSON.

  Results land in ``BENCH_device_engine.json`` at the repo root so
  future PRs have a perf trajectory to track.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import poc
from repro.core import DeviceEngine, EventRegistry, Simulator, emits_events
from repro.core.events import ARG_WIDTH
from repro.core.queue import (
    device_queue_extract,
    device_queue_extract_ref,
    device_queue_fill_rows,
    device_queue_push_rows,
    tiered_queue_extract,
    tiered_queue_fill_rows,
)

JSON_PATH = Path(__file__).resolve().parents[1] / "BENCH_device_engine.json"


def run(quick: bool = False):
    iters = 2_000 if quick else 20_000
    num_events = 128 if quick else 384
    n = 4
    rng = np.random.default_rng(0)
    types = [int(x) for x in (rng.random(num_events) < 0.5)]

    # host engine
    reg = poc.build_registry(iters=iters)
    sim = Simulator(reg, max_batch_len=n)
    for t, ty in enumerate(types):
        sim.queue.push(float(t), ty)
    state, _ = sim.run(poc.initial_state(), mode="conservative")  # warm
    sim2 = Simulator(reg, max_batch_len=n)
    sim2.composer = sim.composer
    for t, ty in enumerate(types):
        sim2.queue.push(float(t), ty)
    t0 = time.perf_counter()
    state_h, _ = sim2.run(poc.initial_state(), mode="conservative")
    jax.block_until_ready(state_h)
    t_host = time.perf_counter() - t0

    # on-device engine
    eng = DeviceEngine(reg, max_batch_len=n, capacity=num_events + 8)
    queue = eng.initial_queue([(float(t), ty, None)
                               for t, ty in enumerate(types)])
    eng.run(poc.initial_state(), queue)  # warm (compiles)
    queue = eng.initial_queue([(float(t), ty, None)
                               for t, ty in enumerate(types)])
    t0 = time.perf_counter()
    state_d, _q, stats = eng.run(poc.initial_state(), queue)
    jax.block_until_ready(state_d)
    t_dev = time.perf_counter() - t0

    assert int(state_h) == int(state_d) == poc.reference_final_sum(
        types, iters)
    return {
        "events": num_events,
        "host_us_per_event": t_host / num_events * 1e6,
        "device_us_per_event": t_dev / num_events * 1e6,
        "device_speedup": t_host / t_dev,
    }


def _trivial_registry():
    """One trivial emitting type: bump a counter, emit one event far in
    the future (keeps the queue at steady occupancy, so every batch
    pays full-queue scheduling cost)."""
    reg = EventRegistry()

    @emits_events
    def tick(state, t, arg):
        emit = jnp.zeros((1, 2 + ARG_WIDTH), jnp.float32)
        emit = emit.at[0, 0].set(t + 1e6).at[0, 1].set(0.0)
        return state + 1, emit

    reg.register("Tick", tick, lookahead=1e6)
    return reg.freeze()


def _bench_op_loop(step, init, iters):
    """µs per application of ``step``, chained in one jitted fori_loop
    (matches how the ops run inside the engine — per-call dispatch
    overhead would otherwise dominate and invert the comparison).

    Short chains (small ``iters``) are re-launched enough times per
    timing sample to keep each sample above ~1k steps; min over 5
    samples filters scheduler noise.
    """
    looped = jax.jit(
        lambda init: jax.lax.fori_loop(0, iters, lambda i, c: step(c), init)
    )
    jax.block_until_ready(looped(init))
    launches = max(1, -(-1024 // iters))
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _ in range(launches):
            out = looped(init)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / (iters * launches))
    return best * 1e6


def _time_engine_run(eng, events, max_batches):
    q = eng.initial_queue(events)
    eng.run(jnp.int32(0), q, max_batches=max_batches)  # warm
    best = float("inf")
    for _ in range(3):
        q = eng.initial_queue(events)
        t0 = time.perf_counter()
        s, _q, stats = eng.run(jnp.int32(0), q, max_batches=max_batches)
        jax.block_until_ready(s)
        best = min(best, time.perf_counter() - t0)
    return best / int(stats["batches"]) * 1e6


def _advancing_rows(max_len):
    """One full emit block per iteration, timestamps marching forward
    (the common DES shape — keeps the tiered staging on its append
    path, as a real emitting workload would)."""
    rows = np.full((max_len, 2 + ARG_WIDTH), -1.0, np.float32)
    rows[:, 0] = np.arange(max_len, dtype=np.float32)
    rows[:, 1] = 0.0
    return jnp.asarray(rows)


def _insert_op_us(eng, mode, events, max_len, base_t, in_iters):
    """µs per chained emit-block insert starting from ``events`` pending.

    ``in_iters`` must keep ``len(events) + in_iters * max_len`` within
    capacity; callers pass the SAME count across a capacity sweep so
    fixed loop overhead cancels out of the comparison.
    """
    q0 = eng.initial_queue(events)
    rows = _advancing_rows(max_len)
    fill = {"tiered": tiered_queue_fill_rows,
            "flat": device_queue_fill_rows,
            "reference": device_queue_push_rows}[mode]

    def step(carry):
        i, q = carry
        block = rows.at[:, 0].add(base_t + i * max_len)
        return i + 1, fill(q, block)

    return _bench_op_loop(step, (jnp.int32(0), q0), in_iters)


def scheduling_overhead(quick: bool = False):
    max_len = 16
    max_batches = 128 if quick else 512

    # -- anchor: the PR-1 reference point, all three queue modes -------
    capacity = 1024 if quick else 4096
    num_events = capacity - 2 * max_len
    events = [(float(t), 0, None) for t in range(num_events)]

    per_batch = {}
    engines = {}
    for mode in ("tiered", "flat", "reference"):
        eng = DeviceEngine(_trivial_registry(), max_batch_len=max_len,
                           capacity=capacity, max_emit=1, queue_mode=mode)
        engines[mode] = eng
        per_batch[mode] = _time_engine_run(eng, events, max_batches)

    # Per-op split: each op chained in its own fused loop, from a
    # representative steady state.
    eng = engines["flat"]
    la = eng._lookaheads
    q_full = eng.initial_queue(events)
    tq_full = engines["tiered"].initial_queue(events)
    _, ts, tys, args, length = device_queue_extract(q_full, max_len, la)
    code = eng.codec.encode_jnp(tys, length)
    half = events[: num_events // 2]

    # Iteration counts keep the extract loops from draining the queues
    # and the insert loops from overflowing them.
    ex_iters = max(1, (num_events - max_len) // max_len)
    phase = {
        "extract": {
            "tiered": _bench_op_loop(
                lambda q: tiered_queue_extract(q, max_len, la)[0],
                tq_full, ex_iters),
            "flat": _bench_op_loop(
                lambda q: device_queue_extract(q, max_len, la)[0],
                q_full, ex_iters),
            "reference": _bench_op_loop(
                lambda q: device_queue_extract_ref(q, max_len, la)[0],
                q_full, ex_iters),
        },
        "insert": {
            mode: _insert_op_us(
                engines[mode], mode, half, max_len, float(num_events),
                max(1, (capacity - num_events // 2 - max_len) // max_len))
            for mode in ("tiered", "flat", "reference")
        },
        "dispatch": {
            "shared": _bench_op_loop(
                lambda s: eng.dispatch(code, s, ts, tys, args)[0],
                jnp.int32(0), 256),
        },
    }

    anchor = {
        "capacity": capacity,
        "max_batch_len": max_len,
        "num_seed_events": num_events,
        "batches_timed": max_batches,
        "per_batch_us": {
            **per_batch,
            "speedup_tiered_vs_reference":
                per_batch["reference"] / per_batch["tiered"],
            "speedup_tiered_vs_flat":
                per_batch["flat"] / per_batch["tiered"],
        },
        "per_op_us": phase,
    }

    # -- capacity sweep: fixed pending-set size, growing capacity ------
    sweep_caps = [1024, 4096] if quick else [1024, 4096, 16384, 65536]
    sweep_events = [(float(t), 0, None) for t in range(1000)]
    insert_base = sweep_events[:256]
    # Identical iteration count at every capacity (sized so the
    # SMALLEST capacity cannot overflow): fixed loop overhead cancels.
    sweep_iters = (min(sweep_caps) - len(insert_base) - max_len) // max_len
    sweep = {}
    for cap in sweep_caps:
        row = {}
        for mode in ("tiered", "flat"):
            eng = DeviceEngine(_trivial_registry(), max_batch_len=max_len,
                               capacity=cap, max_emit=1, queue_mode=mode)
            row[mode] = {
                "per_batch_us": _time_engine_run(
                    eng, sweep_events, max_batches),
                "insert_op_us": _insert_op_us(
                    eng, mode, insert_base, max_len, 1000.0, sweep_iters),
            }
        sweep[str(cap)] = row

    def ratio(hi, lo):
        if str(hi) in sweep and str(lo) in sweep:
            return (sweep[str(hi)]["tiered"]["insert_op_us"]
                    / sweep[str(lo)]["tiered"]["insert_op_us"])
        return None

    result = {
        "workload": {
            "description": "trivial emitting handler (counter + 1 far-future"
                           " emit); per-batch time ~= scheduling overhead",
            "max_batch_len": max_len,
            "max_emit": 1,
            "batches_timed": max_batches,
        },
        "anchor": anchor,
        "capacity_sweep": {
            "fixed_pending_events": 1000,
            "insert_loop": {"base_pending": len(insert_base),
                            "iters": sweep_iters},
            "capacities": sweep,
            "insert_op_ratio_16k_over_1k": ratio(16384, 1024),
            "insert_op_ratio_64k_over_1k": ratio(65536, 1024),
        },
    }
    return result


def _churn_registry(near_delay: float):
    """Emitting type for the near-full stress: each event re-emits with
    a timestamp alternating (by 16-event stripe) between *just past the
    current window* — lands in the front tier, forcing merges and tail
    evictions — and *far future* — lands in staging/main with no ring
    headroom left.  Both legs push the tiered queue onto its rare
    O(capacity) flush/merge paths every few batches."""
    reg = EventRegistry()

    @emits_events
    def churn(state, t, arg):
        far = jnp.floor(t / 16.0) % 2.0 == 0.0
        delay = jnp.where(far, jnp.float32(1e6), jnp.float32(near_delay))
        emit = jnp.zeros((1, 2 + ARG_WIDTH), jnp.float32)
        emit = emit.at[0, 0].set(t + delay).at[0, 1].set(0.0)
        return state + 1, emit

    reg.register("Churn", churn, lookahead=1e6)
    return reg.freeze()


def near_full(quick: bool = False):
    """Tiered queue at >=90% occupancy under sustained flush pressure.

    Occupancy is stationary (each batch pops ``max_len`` events and
    inserts ``max_len`` emissions), so the whole timed run sits at the
    seeded fraction.  Recorded against the same-capacity anchor so the
    planned third tier has a ratio to beat, plus a low-occupancy control
    run of the identical workload (the penalty is the pressure, not the
    handler).
    """
    max_len = 16
    capacity = 1024 if quick else 4096
    max_batches = 128 if quick else 512
    occupancy = 0.92
    seed_n = int(capacity * occupancy)
    seed_lo = int(capacity * 0.25)
    events_hi = [(float(t), 0, None) for t in range(seed_n)]
    events_lo = [(float(t), 0, None) for t in range(seed_lo)]

    per_batch = {}
    engines = {}
    for mode in ("tiered", "flat"):
        engines[mode] = DeviceEngine(_churn_registry(near_delay=17.0),
                                     max_batch_len=max_len,
                                     capacity=capacity, max_emit=1,
                                     queue_mode=mode)
        per_batch[mode] = _time_engine_run(engines[mode], events_hi,
                                           max_batches)
    # Low-occupancy control on the SAME compiled engine (engines are
    # re-runnable; only the seeded queue differs).
    low = _time_engine_run(engines["tiered"], events_lo, max_batches)

    return {
        "description": "alternating near-head/far-future re-emits at "
                       "stationary >=90% occupancy; sustains the tiered "
                       "queue's O(capacity) flush/merge/compaction paths",
        "capacity": capacity,
        "max_batch_len": max_len,
        "max_emit": 1,
        "batches_timed": max_batches,
        "occupancy_fraction": seed_n / capacity,
        "per_batch_us": per_batch,
        "tiered_low_occupancy_us": low,
        "low_occupancy_fraction": seed_lo / capacity,
        "tiered_pressure_ratio_vs_low_occupancy":
            per_batch["tiered"] / low,
    }


def _merge_near_full_into_json(nf):
    """Refresh only the near_full section, keeping the recorded
    anchor/sweep baselines intact."""
    payload = json.loads(JSON_PATH.read_text()) if JSON_PATH.exists() \
        else {}
    payload.setdefault("scheduling_overhead", {})["near_full"] = nf
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")


def _print_near_full(nf):
    pb = nf["per_batch_us"]
    print(f"near-full (occupancy {nf['occupancy_fraction']:.0%}, "
          f"cap={nf['capacity']}): tiered={pb['tiered']:.1f}us/batch "
          f"flat={pb['flat']:.1f}us/batch | tiered at "
          f"{nf['low_occupancy_fraction']:.0%} occupancy: "
          f"{nf['tiered_low_occupancy_us']:.1f}us "
          f"(pressure ratio "
          f"{nf['tiered_pressure_ratio_vs_low_occupancy']:.2f}x)")


def main(quick: bool = False, out: str | None = None):
    sched = scheduling_overhead(quick=quick)
    sched["near_full"] = near_full(quick=quick)
    r = run(quick=quick)
    payload = {"host_vs_device": r, "scheduling_overhead": sched}
    if out:
        Path(out).write_text(json.dumps(payload, indent=2) + "\n")
        print("wrote", out)
    if quick:
        # Quick mode uses a smaller workload — don't clobber the
        # recorded full-run perf baseline future PRs track.
        print("quick mode: not overwriting", JSON_PATH.name)
    else:
        JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    print("events,host_us_per_event,device_us_per_event,device_speedup")
    print(f"{r['events']},{r['host_us_per_event']:.1f},"
          f"{r['device_us_per_event']:.1f},{r['device_speedup']:.2f}")
    pb = sched["anchor"]["per_batch_us"]
    print(f"scheduling us/batch @ cap={sched['anchor']['capacity']} "
          f"k={sched['anchor']['max_batch_len']}: "
          f"tiered={pb['tiered']:.1f} flat={pb['flat']:.1f} "
          f"reference={pb['reference']:.1f} "
          f"(tiered vs ref {pb['speedup_tiered_vs_reference']:.2f}x)")
    for cap, row in sched["capacity_sweep"]["capacities"].items():
        print(f"  cap={cap:>6}: tiered per_batch="
              f"{row['tiered']['per_batch_us']:.1f}us insert="
              f"{row['tiered']['insert_op_us']:.1f}us | flat per_batch="
              f"{row['flat']['per_batch_us']:.1f}us insert="
              f"{row['flat']['insert_op_us']:.1f}us")
    ratio = sched["capacity_sweep"]["insert_op_ratio_16k_over_1k"]
    if ratio is not None:
        print(f"capacity-independence: tiered insert 16k/1k = {ratio:.2f}x")
    _print_near_full(sched["near_full"])
    if not quick:
        print(f"wrote {JSON_PATH}")
    r = dict(r)
    r["sched_speedup"] = pb["speedup_tiered_vs_reference"]
    return r


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--near-full-only", action="store_true",
                    help="run just the near-full stress and merge it "
                         "into the recorded JSON baseline")
    ap.add_argument("--out", default=None,
                    help="also write results to this path (CI artifact)")
    args = ap.parse_args()
    if args.near_full_only:
        nf = near_full(quick=args.quick)
        _print_near_full(nf)
        if args.quick:
            print("quick mode: not merging into", JSON_PATH.name)
        else:
            _merge_near_full_into_json(nf)
            print("merged near_full into", JSON_PATH.name)
        if args.out:
            Path(args.out).write_text(json.dumps({"near_full": nf},
                                                 indent=2) + "\n")
    else:
        main(quick=args.quick, out=args.out)
