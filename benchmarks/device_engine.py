"""Beyond-paper: fully on-device DES vs host-driven dispatch, plus the
per-batch scheduling-overhead split (extract / dispatch / insert).

Two measurements:

* ``run``  — events/second of the on-device engine against the
  host-driven batched scheduler on the PoC model (as in the seed).

* ``scheduling_overhead`` — the cost of the queue machinery itself, on
  a trivial-handler workload (each event bumps a counter and emits one
  far-future event, so per-batch time is almost pure scheduling).
  Two measurements:

  - **anchor** (capacity 4096, max_batch_len 16, the PR-1 reference
    point): whole-run per-batch and per-op split for all four queue
    modes (tiered3 / tiered / flat / reference).

  - **capacity sweep** (1k/4k/16k/64k × {tiered3, tiered, flat}) at a
    FIXED pending-set size, so what scales is only the allocated
    capacity:
    whole-run per-batch cost plus a chained insert-op loop.  The
    recorded ``insert_op_ratio_16k_over_1k`` is the capacity-
    independence claim as a number: per-batch insert cost at 16384
    must stay within 2x of its capacity-1024 cost under
    ``queue_mode="tiered"``.

* ``near_full`` — the worst-case stress: the queue held at >=90%
  occupancy with emissions alternating between near-head landings
  (front merges + tail evictions into staging) and far-future landings
  (staging appends with no ring headroom), so the two-tier queue's
  O(capacity) flush/merge/compaction paths fire continuously.  This is
  the workload the log-structured ``tiered3`` mode exists for; the
  section records all of tiered3/tiered/flat at the anchor capacity
  plus a tiered3-vs-tiered CAPACITY SWEEP of the same workload (the
  "worst-case path no longer scales with capacity" claim as numbers).
  ``--near-full-only`` refreshes just this section of the JSON, and
  ``--check-baseline R`` instead compares the fresh tiered3 median
  against the recorded baseline, failing (exit 1) on a >R× regression
  — the CI perf gate.

* ``fused_dispatch`` (``--fused-only``) — the composition-specialized
  dispatch (DESIGN.md §7): whole-run per-batch cost AND a chained
  per-dispatch microbenchmark on the hottest observed word (profiled
  via ``RunResult.word_counts``) for all three dispatch modes, on the
  PoC model and the serving admission scenario.  The claim the section
  records is *hot-word fused dispatch <= the generic masked path* —
  the bounded W+1-way switch plus straight-line super-procedures must
  not cost more than the per-lane type switches they replace.
  ``--fused-only --check-baseline R`` gates the fused/masked
  per-dispatch ratio against the recorded baseline (same
  machine-independence reasoning as the near-full gate).

* ``streaming`` (``--streaming-only``) — the open-system serving axis
  (DESIGN.md §10): sustained requests/second streaming a Poisson trace
  through ``run(arrivals=...)`` on the admission scenario, against the
  pre-seeded closed reference (bit-identity checked), plus the
  double-buffer A/B (prefetch vs ``_stream_prefetch=False`` on a
  decode-bound source) and a bounded-memory ``overflow='spill'``
  variant.  ``--check-streaming R`` gates bit-identity and the
  streamed/pre-seeded wall ratio (absolute ceiling, both sides fresh);
  ``--trace PATH`` replays a ``scripts/gen_trace.py`` file at
  acceptance scale into the ``trace_replay`` subsection.

* ``shards_sweep`` (``--shards-only``) — the sharded engine
  (DESIGN.md §5.1) against the bit-identical single tiered3 queue on
  the 92%-occupancy ROUTED churn (re-emits hop entities, so a constant
  fraction crosses shard boundaries): per-super-step cost for shards
  ∈ {1, 2, 4} at each capacity, interleaved A/B rounds.  Since every
  super-step executes exactly the single-queue window, the recorded
  ratio IS the merge/exchange overhead of the sharded machinery.

Whole-run timings are median-of-N (``--repeats``, default 5) with the
raw samples recorded next to every median: single-shot numbers on
shared CPU runners are ±30% noisy, which is exactly the band a
near-full regression has to clear.  Per-op microbenchmarks keep their
min-of-5 chained-loop form.  NOTE (PR 4): the ``reference`` insert
column times :func:`device_queue_push_rows`, now a one-pass scatter
that is bit-identical to — but much faster than — the serial seed
chain it replaced, so pre-PR-4 ``reference`` insert numbers are not
comparable; ``reference`` extraction is unchanged (the serial spec).

  Results land in ``BENCH_device_engine.json`` at the repo root so
  future PRs have a perf trajectory to track.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import poc
from repro.core import (
    DeviceEngine,
    EventRegistry,
    ShardedDeviceEngine,
    Simulator,
    emits_events,
)
from repro.core.events import ARG_WIDTH
from repro.core.queue import (
    device_queue_extract,
    device_queue_extract_ref,
    device_queue_fill_rows,
    device_queue_push_rows,
    tiered3_queue_extract,
    tiered3_queue_fill_rows,
    tiered_queue_extract,
    tiered_queue_fill_rows,
)

JSON_PATH = Path(__file__).resolve().parents[1] / "BENCH_device_engine.json"


def run(quick: bool = False):
    iters = 2_000 if quick else 20_000
    num_events = 128 if quick else 384
    n = 4
    rng = np.random.default_rng(0)
    types = [int(x) for x in (rng.random(num_events) < 0.5)]

    # host engine
    reg = poc.build_registry(iters=iters)
    sim = Simulator(reg, max_batch_len=n)
    for t, ty in enumerate(types):
        sim.queue.push(float(t), ty)
    state, _ = sim.run(poc.initial_state(), mode="conservative")  # warm
    sim2 = Simulator(reg, max_batch_len=n)
    sim2.composer = sim.composer
    for t, ty in enumerate(types):
        sim2.queue.push(float(t), ty)
    t0 = time.perf_counter()
    state_h, _ = sim2.run(poc.initial_state(), mode="conservative")
    jax.block_until_ready(state_h)
    t_host = time.perf_counter() - t0

    # on-device engine
    eng = DeviceEngine(reg, max_batch_len=n, capacity=num_events + 8)
    queue = eng.initial_queue([(float(t), ty, None)
                               for t, ty in enumerate(types)])
    eng.run(poc.initial_state(), queue)  # warm (compiles)
    queue = eng.initial_queue([(float(t), ty, None)
                               for t, ty in enumerate(types)])
    t0 = time.perf_counter()
    state_d, _q, stats = eng.run(poc.initial_state(), queue)
    jax.block_until_ready(state_d)
    t_dev = time.perf_counter() - t0

    assert int(state_h) == int(state_d) == poc.reference_final_sum(
        types, iters)
    return {
        "events": num_events,
        "host_us_per_event": t_host / num_events * 1e6,
        "device_us_per_event": t_dev / num_events * 1e6,
        "device_speedup": t_host / t_dev,
    }


def _trivial_registry():
    """One trivial emitting type: bump a counter, emit one event far in
    the future (keeps the queue at steady occupancy, so every batch
    pays full-queue scheduling cost)."""
    reg = EventRegistry()

    @emits_events
    def tick(state, t, arg):
        emit = jnp.zeros((1, 2 + ARG_WIDTH), jnp.float32)
        emit = emit.at[0, 0].set(t + 1e6).at[0, 1].set(0.0)
        return state + 1, emit

    reg.register("Tick", tick, lookahead=1e6)
    return reg.freeze()


def _bench_op_loop(step, init, iters):
    """µs per application of ``step``, chained in one jitted fori_loop
    (matches how the ops run inside the engine — per-call dispatch
    overhead would otherwise dominate and invert the comparison).

    Short chains (small ``iters``) are re-launched enough times per
    timing sample to keep each sample above ~1k steps; min over 5
    samples filters scheduler noise.
    """
    looped = jax.jit(
        lambda init: jax.lax.fori_loop(0, iters, lambda i, c: step(c), init)
    )
    jax.block_until_ready(looped(init))
    launches = max(1, -(-1024 // iters))
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _ in range(launches):
            out = looped(init)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / (iters * launches))
    return best * 1e6


def _bench_ops_interleaved(steps, init, iters, rounds=7):
    """_bench_op_loop over several candidate step fns at once, timed
    round-robin (one sample each per round) so host-load drift hits
    every candidate equally — the gates compare the RATIOS
    (DESIGN.md §6.4), and sequential blocks would let a load spike
    land entirely on one candidate."""
    looped = {
        name: jax.jit(lambda init, f=f: jax.lax.fori_loop(
            0, iters, lambda i, c: f(c), init))
        for name, f in steps.items()
    }
    for fn in looped.values():
        jax.block_until_ready(fn(init))
    launches = max(1, -(-1024 // iters))
    best = {name: float("inf") for name in steps}
    for _ in range(rounds):
        for name, fn in looped.items():
            t0 = time.perf_counter()
            for _ in range(launches):
                out = fn(init)
            jax.block_until_ready(out)
            best[name] = min(
                best[name], (time.perf_counter() - t0) / (iters * launches))
    return {name: v * 1e6 for name, v in best.items()}


def _time_engines_interleaved(runs, max_batches, repeats=5):
    """Round-robin median-of-``repeats`` µs/batch for several engines.

    ``runs`` maps label -> (engine, events).  One sample per engine per
    round, cycling through the engines, so slow phases of a shared/
    noisy host hit every mode roughly equally — the A/B comparison
    stays trustworthy even when absolute numbers drift between rounds.
    Two warm runs per engine first: one covers compilation, the second
    the allocator/cache warm-up that otherwise penalizes whichever
    engine is timed first.  Returns label -> (median, samples).
    """
    for eng, events in runs.values():
        for _ in range(2):  # compile + allocator warm-up
            q = eng.initial_queue(events)
            eng.run(jnp.int32(0), q, max_batches=max_batches)
    samples = {label: [] for label in runs}
    for _ in range(max(1, repeats)):
        for label, (eng, events) in runs.items():
            q = eng.initial_queue(events)
            t0 = time.perf_counter()
            s, _q, stats = eng.run(jnp.int32(0), q,
                                   max_batches=max_batches)
            jax.block_until_ready(s)
            samples[label].append((time.perf_counter() - t0)
                                  / int(stats["batches"]) * 1e6)
    return {label: (float(np.median(v)), v)
            for label, v in samples.items()}


def _time_engine_run(eng, events, max_batches, repeats=5):
    """Median-of-``repeats`` µs/batch for a whole engine run, plus the
    raw per-sample values (kept in the JSON so the medians can be
    re-judged against the run-to-run noise they were taken in).
    The single-engine case of :func:`_time_engines_interleaved` — one
    warm-up/sampling protocol, defined once."""
    return _time_engines_interleaved(
        {"only": (eng, events)}, max_batches, repeats)["only"]


def _advancing_rows(max_len):
    """One full emit block per iteration, timestamps marching forward
    (the common DES shape — keeps the tiered staging on its append
    path, as a real emitting workload would)."""
    rows = np.full((max_len, 2 + ARG_WIDTH), -1.0, np.float32)
    rows[:, 0] = np.arange(max_len, dtype=np.float32)
    rows[:, 1] = 0.0
    return jnp.asarray(rows)


def _insert_op_us(eng, mode, events, max_len, base_t, in_iters):
    """µs per chained emit-block insert starting from ``events`` pending.

    ``in_iters`` must keep ``len(events) + in_iters * max_len`` within
    capacity; callers pass the SAME count across a capacity sweep so
    fixed loop overhead cancels out of the comparison.
    """
    q0 = eng.initial_queue(events)
    rows = _advancing_rows(max_len)
    fill = {"tiered": tiered_queue_fill_rows,
            "tiered3": tiered3_queue_fill_rows,
            "flat": device_queue_fill_rows,
            "reference": device_queue_push_rows}[mode]

    def step(carry):
        i, q = carry
        block = rows.at[:, 0].add(base_t + i * max_len)
        return i + 1, fill(q, block)

    return _bench_op_loop(step, (jnp.int32(0), q0), in_iters)


def scheduling_overhead(quick: bool = False, repeats: int = 5):
    max_len = 16
    max_batches = 128 if quick else 512

    # -- anchor: the PR-1 reference point, all four queue modes --------
    capacity = 1024 if quick else 4096
    num_events = capacity - 2 * max_len
    events = [(float(t), 0, None) for t in range(num_events)]

    per_batch = {}
    samples = {}
    engines = {}
    for mode in ("tiered3", "tiered", "flat", "reference"):
        eng = DeviceEngine(_trivial_registry(), max_batch_len=max_len,
                           capacity=capacity, max_emit=1, queue_mode=mode)
        engines[mode] = eng
        per_batch[mode], samples[mode] = _time_engine_run(
            eng, events, max_batches, repeats)

    # Per-op split: each op chained in its own fused loop, from a
    # representative steady state.
    eng = engines["flat"]
    la = eng._lookaheads
    q_full = eng.initial_queue(events)
    tq_full = engines["tiered"].initial_queue(events)
    t3q_full = engines["tiered3"].initial_queue(events)
    _, ts, tys, args, length = device_queue_extract(q_full, max_len, la)
    code = eng.codec.encode_jnp(tys, length)
    half = events[: num_events // 2]

    # Iteration counts keep the extract loops from draining the queues
    # and the insert loops from overflowing them.
    ex_iters = max(1, (num_events - max_len) // max_len)
    phase = {
        "extract": {
            "tiered3": _bench_op_loop(
                lambda q: tiered3_queue_extract(q, max_len, la)[0],
                t3q_full, ex_iters),
            "tiered": _bench_op_loop(
                lambda q: tiered_queue_extract(q, max_len, la)[0],
                tq_full, ex_iters),
            "flat": _bench_op_loop(
                lambda q: device_queue_extract(q, max_len, la)[0],
                q_full, ex_iters),
            "reference": _bench_op_loop(
                lambda q: device_queue_extract_ref(q, max_len, la)[0],
                q_full, ex_iters),
        },
        "insert": {
            mode: _insert_op_us(
                engines[mode], mode, half, max_len, float(num_events),
                max(1, (capacity - num_events // 2 - max_len) // max_len))
            for mode in ("tiered3", "tiered", "flat", "reference")
        },
        "dispatch": {
            "shared": _bench_op_loop(
                lambda s: eng.dispatch(code, s, ts, tys, args)[0],
                jnp.int32(0), 256),
        },
    }

    anchor = {
        "capacity": capacity,
        "max_batch_len": max_len,
        "num_seed_events": num_events,
        "batches_timed": max_batches,
        "repeats": repeats,
        "per_batch_us": {
            **per_batch,
            "speedup_tiered_vs_reference":
                per_batch["reference"] / per_batch["tiered"],
            "speedup_tiered_vs_flat":
                per_batch["flat"] / per_batch["tiered"],
            "speedup_tiered3_vs_reference":
                per_batch["reference"] / per_batch["tiered3"],
        },
        "per_batch_samples_us": samples,
        "per_op_us": phase,
    }

    # -- capacity sweep: fixed pending-set size, growing capacity ------
    sweep_caps = [1024, 4096] if quick else [1024, 4096, 16384, 65536]
    sweep_events = [(float(t), 0, None) for t in range(1000)]
    insert_base = sweep_events[:256]
    # Identical iteration count at every capacity (sized so the
    # SMALLEST capacity cannot overflow): fixed loop overhead cancels.
    sweep_iters = (min(sweep_caps) - len(insert_base) - max_len) // max_len
    sweep = {}
    for cap in sweep_caps:
        row = {}
        for mode in ("tiered3", "tiered", "flat"):
            eng = DeviceEngine(_trivial_registry(), max_batch_len=max_len,
                               capacity=cap, max_emit=1, queue_mode=mode)
            med, raw = _time_engine_run(eng, sweep_events, max_batches,
                                        repeats)
            row[mode] = {
                "per_batch_us": med,
                "per_batch_samples_us": raw,
                "insert_op_us": _insert_op_us(
                    eng, mode, insert_base, max_len, 1000.0, sweep_iters),
            }
        sweep[str(cap)] = row

    def ratio(mode, hi, lo):
        if str(hi) in sweep and str(lo) in sweep:
            return (sweep[str(hi)][mode]["insert_op_us"]
                    / sweep[str(lo)][mode]["insert_op_us"])
        return None

    result = {
        "workload": {
            "description": "trivial emitting handler (counter + 1 far-future"
                           " emit); per-batch time ~= scheduling overhead",
            "max_batch_len": max_len,
            "max_emit": 1,
            "batches_timed": max_batches,
            "repeats": repeats,
        },
        "anchor": anchor,
        "capacity_sweep": {
            "fixed_pending_events": 1000,
            "insert_loop": {"base_pending": len(insert_base),
                            "iters": sweep_iters},
            "capacities": sweep,
            "insert_op_ratio_16k_over_1k": ratio("tiered", 16384, 1024),
            "insert_op_ratio_64k_over_1k": ratio("tiered", 65536, 1024),
            "tiered3_insert_op_ratio_16k_over_1k":
                ratio("tiered3", 16384, 1024),
            "tiered3_insert_op_ratio_64k_over_1k":
                ratio("tiered3", 65536, 1024),
        },
    }
    return result


def _churn_registry(near_delay: float):
    """Emitting type for the near-full stress: each event re-emits with
    a timestamp alternating (by 16-event stripe) between *just past the
    current window* — lands in the front tier, forcing merges and tail
    evictions — and *far future* — lands in staging/main with no ring
    headroom left.  Both legs push the tiered queue onto its rare
    O(capacity) flush/merge paths every few batches."""
    reg = EventRegistry()

    @emits_events
    def churn(state, t, arg):
        far = jnp.floor(t / 16.0) % 2.0 == 0.0
        delay = jnp.where(far, jnp.float32(1e6), jnp.float32(near_delay))
        emit = jnp.zeros((1, 2 + ARG_WIDTH), jnp.float32)
        emit = emit.at[0, 0].set(t + delay).at[0, 1].set(0.0)
        return state + 1, emit

    reg.register("Churn", churn, lookahead=1e6)
    return reg.freeze()


def near_full(quick: bool = False, repeats: int = 5, sweep: bool = True,
              controls: bool = True):
    """The queue at >=90% occupancy under sustained flush pressure.

    Occupancy is stationary (each batch pops ``max_len`` events and
    inserts ``max_len`` emissions), so the whole timed run sits at the
    seeded fraction.  Anchor capacity: tiered3/tiered/flat medians plus
    a low-occupancy control of the identical workload (the penalty is
    the pressure, not the handler).  Capacity sweep (tiered3 vs
    tiered): the same 92%-occupancy workload at every capacity — the
    number that must stay flat for tiered3 and grows for the two-tier
    flush merge.  ``sweep=False`` skips it (the CI gate reads only the
    anchor, and every sweep capacity costs fresh compiles + timed
    runs); ``controls=False`` likewise skips the low-occupancy
    control runs the gate never reads.
    """
    max_len = 16
    capacity = 1024 if quick else 4096
    max_batches = 128 if quick else 512
    occupancy = 0.92

    def seeded(cap, frac):
        return [(float(t), 0, None) for t in range(int(cap * frac))]

    def engine(mode, cap):
        return DeviceEngine(_churn_registry(near_delay=17.0),
                            max_batch_len=max_len, capacity=cap,
                            max_emit=1, queue_mode=mode)

    engines = {mode: engine(mode, capacity)
               for mode in ("tiered3", "tiered", "flat")}
    # Interleaved rounds: host-load drift hits every mode equally, so
    # the mode-vs-mode comparison survives a noisy box.
    timed = _time_engines_interleaved(
        {m: (engines[m], seeded(capacity, occupancy)) for m in engines},
        max_batches, repeats)
    per_batch = {m: t[0] for m, t in timed.items()}
    samples = {m: t[1] for m, t in timed.items()}
    # Low-occupancy controls on the SAME compiled engines (engines are
    # re-runnable; only the seeded queue differs).
    low = None
    if controls:
        low = {
            m: t[0]
            for m, t in _time_engines_interleaved(
                {m: (engines[m], seeded(capacity, 0.25))
                 for m in ("tiered3", "tiered")},
                max_batches, repeats).items()
        }

    sweep_caps = [1024, 4096] if quick else [1024, 4096, 16384, 65536]
    rows = {}
    if sweep:
        for cap in sweep_caps:
            timed = _time_engines_interleaved(
                {m: (engines[m] if cap == capacity else engine(m, cap),
                     seeded(cap, occupancy))
                 for m in ("tiered3", "tiered")},
                max_batches, repeats)
            rows[str(cap)] = {
                m: {"per_batch_us": t[0], "per_batch_samples_us": t[1]}
                for m, t in timed.items()
            }

    def ratio(mode, hi, lo):
        if str(hi) in rows and str(lo) in rows:
            return (rows[str(hi)][mode]["per_batch_us"]
                    / rows[str(lo)][mode]["per_batch_us"])
        return None

    return {
        "description": "alternating near-head/far-future re-emits at "
                       "stationary >=90% occupancy; sustains the two-tier "
                       "queue's O(capacity) flush/merge/compaction paths "
                       "(the tiered3 run tier bounds them)",
        "capacity": capacity,
        "max_batch_len": max_len,
        "max_emit": 1,
        "batches_timed": max_batches,
        "repeats": repeats,
        "occupancy_fraction": int(capacity * occupancy) / capacity,
        "per_batch_us": per_batch,
        "per_batch_samples_us": samples,
        "low_occupancy_us": low,
        "low_occupancy_fraction": 0.25,
        "tiered_pressure_ratio_vs_low_occupancy":
            per_batch["tiered"] / low["tiered"] if low else None,
        "tiered3_pressure_ratio_vs_low_occupancy":
            per_batch["tiered3"] / low["tiered3"] if low else None,
        "capacity_sweep": {
            "occupancy_fraction": occupancy,
            "capacities": rows,
            "tiered3_ratio_64k_over_1k": ratio("tiered3", 65536, 1024),
            "tiered_ratio_64k_over_1k": ratio("tiered", 65536, 1024),
        } if sweep else None,
    }


def validate_overhead(quick: bool = False, repeats: int = 5):
    """Cost of the on-device invariant auditor: ``validate='cheap'``
    (O(front) fault bits folded into the while-loop carry every
    super-step) vs ``validate='off'`` on the IDENTICAL churn workload.

    The two engines run in interleaved rounds, so the recorded
    ``cheap_over_off`` ratio is host-drift-free — that ratio is the
    CI-gated quantity (``--check-validate``): the auditor's contract is
    "always-on-able", i.e. a small constant factor, not a new scaling
    term.
    """
    max_len = 16
    capacity = 1024 if quick else 4096
    max_batches = 128 if quick else 512

    # An HONEST variant of the churn model: same near/far re-emit
    # shape, but the declared lookahead (17) really bounds every emit
    # delay.  (_churn_registry declares 1e6 while emitting at t+17 — a
    # fine perf stressor, but the clock-regression bit would correctly
    # flag it, so it cannot A/B the validator.)
    def _honest_churn():
        reg = EventRegistry()

        @emits_events
        def churn(state, t, arg):
            far = jnp.floor(t / 16.0) % 2.0 == 0.0
            delay = jnp.where(far, jnp.float32(1e6), jnp.float32(17.0))
            emit = jnp.zeros((1, 2 + ARG_WIDTH), jnp.float32)
            emit = emit.at[0, 0].set(t + delay).at[0, 1].set(0.0)
            return state + 1, emit

        reg.register("Churn", churn, lookahead=17.0)
        return reg.freeze()

    def engine(validate):
        return DeviceEngine(_honest_churn(),
                            max_batch_len=max_len, capacity=capacity,
                            max_emit=1, queue_mode="tiered3",
                            validate=validate)

    events = [(float(t), 0, None) for t in range(capacity // 2)]
    timed = _time_engines_interleaved(
        {"off": (engine("off"), events),
         "cheap": (engine("cheap"), events)},
        max_batches, repeats)
    # The gated ratio uses min-of-samples, not the median: host noise
    # on a shared box only ever ADDS time, so each side's minimum is
    # its best floor estimate, and the min/min ratio tracks the actual
    # kernel-count overhead instead of whichever round caught a noise
    # spike (the raw samples are kept alongside for re-judging).
    per_batch = {m: float(np.min(t[1])) for m, t in timed.items()}
    return {
        "description": "validate='cheap' per-super-step fault bits vs "
                       "validate='off', identical tiered3 churn workload "
                       "in interleaved rounds (min-of-samples ratio is "
                       "the gated value)",
        "capacity": capacity,
        "max_batch_len": max_len,
        "batches_timed": max_batches,
        "repeats": repeats,
        "per_batch_us": per_batch,
        "per_batch_samples_us": {m: t[1] for m, t in timed.items()},
        "cheap_over_off": per_batch["cheap"] / per_batch["off"],
    }


def _print_validate(vo):
    pb = vo["per_batch_us"]
    print(f"validate overhead @ cap={vo['capacity']}: "
          f"off={pb['off']:.1f}us/batch cheap={pb['cheap']:.1f}us/batch "
          f"(cheap/off {vo['cheap_over_off']:.3f}x)")


def _merge_validate_into_json(vo):
    payload = json.loads(JSON_PATH.read_text()) if JSON_PATH.exists() \
        else {}
    payload["validate_overhead"] = vo
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")


def _check_validate_overhead(vo, max_ratio: float) -> int:
    """CI gate: the cheap auditor must stay within ``max_ratio``x of
    validate='off' on the same box (an absolute ceiling — both sides
    of the ratio are measured fresh in the same interleaved rounds, so
    there is no recorded baseline to drift against).  Returns a process
    exit code."""
    fresh = vo["cheap_over_off"]
    print(f"validate gate: cheap/off {fresh:.3f}x (ceiling "
          f"{max_ratio:.2f}x)")
    if fresh > max_ratio:
        print(f"validate gate: FAIL — cheap validation costs "
              f"{fresh:.3f}x, above the {max_ratio:.2f}x ceiling")
        return 1
    print("validate gate: OK")
    return 0


class _DecodeBoundSource:
    """Arrival-source wrapper that sleeps per block, emulating a trace
    whose blocks cost real host time to produce (disk decode, feature
    hydration).  Sleeping — not spinning — so the hidden work truly
    overlaps the device segment instead of stealing its CPU."""

    def __init__(self, inner, delay_s: float):
        self.inner = inner
        self.delay_s = delay_s
        self.block_size = inner.block_size

    def __len__(self):
        return len(self.inner)

    def seek(self, cursor: int) -> None:
        self.inner.seek(cursor)

    def blocks(self):
        for block in self.inner.blocks():
            time.sleep(self.delay_s)
            yield block


def _stream_bit_equal(streamed, closed) -> bool:
    if streamed.events != closed.events or \
            streamed.dropped != closed.dropped or \
            np.float32(streamed.final_time) != np.float32(closed.final_time):
        return False
    return all(
        np.array_equal(np.asarray(streamed.state[k]), np.asarray(v))
        for k, v in closed.state.items())


def streaming(quick: bool = False, repeats: int = 5,
              trace: str | None = None):
    """Open-system ingestion (DESIGN.md §10): sustained host→device
    arrival throughput on the serving admission scenario.

    Four measurements on the SAME Poisson trace, interleaved rounds:

    - ``preseeded`` — the closed reference: the whole trace pushed into
      the queue up front.  The wall-time denominator of the gated
      ``streamed_over_preseeded`` ratio (both sides fresh each run, so
      the gate is an absolute overhead ceiling, machine-independent).
    - ``streamed`` — ``run(arrivals=...)`` with the double-buffered
      prefetch feeder; ``streaming_rps`` = requests / wall is the
      recorded serving axis.
    - ``sync_feed`` — the same run with ``_stream_prefetch=False``
      (block built + staged inline at each segment boundary).
    - ``decode_bound`` — both feed modes again on a source that sleeps
      per block (~half the streamed wall in total): the recorded
      ``sync_over_prefetch`` shows the double buffer actually hiding
      host block cost behind device segments, which the cheap synthetic
      source is too fast to expose.

    A bounded-memory variant (device queue ~1/4 the trace length,
    ``overflow='spill'``) re-runs the streamed side and is bit-compared
    against the SAME closed reference — the serving shape where the
    backlog never fits on device.  With ``trace=`` (``--trace``), a
    trace file from ``scripts/gen_trace.py`` replays through the
    bounded config at scale (the >=1M-request acceptance run) and its
    ``streaming_rps`` + bit-equality land in a ``trace_replay``
    subsection; sized so the closed reference still fits in one queue.
    """
    from repro.core.program import Config
    from repro.serving.scenarios import build_open_admission_program
    from repro.serving.scenarios import initial_state as admission_state
    from repro.stream import PoissonSource, TraceReader, source_events

    # slots sized so service (~slots / 3.5 ticks mean decode) outruns
    # the arrival rate — an underprovisioned admission system melts
    # into an ADMIT retry storm, which stresses the queue, not the
    # ingestion path this section measures.  max_batch_len stays at 3
    # like every serving workload here: scenario compile time grows
    # steeply with lane count (~10s at 3, minutes at 5+).
    n_req = 1_500 if quick else 8_000
    num_slots = 64
    max_len = 3
    src = PoissonSource(16.0, n_req, seed=11, grid=0.25, type_id=0,
                        block_size=256)
    bounded_cap = max(512, n_req // 4)

    def build(capacity, n=n_req, slots=num_slots, mbl=max_len):
        return build_open_admission_program(
            num_slots=slots, num_requests=n, max_decode=6,
            config=Config(max_batch_len=mbl, capacity=capacity,
                          max_emit=2))

    state0 = admission_state(num_slots)
    events = [(1.0, "TICK")] + [
        (t, ty, list(a)) for (t, ty, a) in source_events(src)]
    sim_closed = build(n_req + 2048).build(backend="device")
    sim_open = build(n_req + 2048).build(backend="device")
    sim_bounded = build(bounded_cap).build(backend="device",
                                           overflow="spill")

    # warm every jit cache once
    closed = sim_closed.run(state0, events=events)
    src.seek(0)
    streamed = sim_open.run(state0, arrivals=src)
    src.seek(0)
    bounded = sim_bounded.run(state0, arrivals=src)
    # a post-warm streamed wall sizes the decode-bound sleep (total
    # sleep ~= half the streamed wall — sizing off the FIRST run would
    # fold jit compile into the delay and swamp the segments it is
    # supposed to hide behind)
    src.seek(0)
    t0 = time.perf_counter()
    streamed = sim_open.run(state0, arrivals=src)
    warm_wall = time.perf_counter() - t0
    bit = _stream_bit_equal(streamed, closed) and \
        _stream_bit_equal(bounded, closed)
    assert streamed.ingested == n_req and bounded.ingested == n_req
    n_blocks = -(-n_req // src.block_size)
    delay_s = 0.5 * warm_wall / n_blocks
    slow = _DecodeBoundSource(src, delay_s)

    def timed_closed():
        t = time.perf_counter()
        sim_closed.run(state0, events=events)
        return time.perf_counter() - t

    def timed_stream(sim, source, **kw):
        source.seek(0)
        t = time.perf_counter()
        sim.run(state0, arrivals=source, **kw)
        return time.perf_counter() - t

    rounds = {
        "preseeded": timed_closed,
        "streamed": lambda: timed_stream(sim_open, src),
        "sync_feed": lambda: timed_stream(sim_open, src,
                                          _stream_prefetch=False),
        "decode_bound_prefetch": lambda: timed_stream(sim_open, slow),
        "decode_bound_sync": lambda: timed_stream(
            sim_open, slow, _stream_prefetch=False),
        "bounded_spill": lambda: timed_stream(sim_bounded, src),
    }
    samples = {m: [] for m in rounds}
    for _ in range(repeats):
        for m, fn in rounds.items():
            samples[m].append(fn())
    med = {m: float(np.median(s)) for m, s in samples.items()}
    best = {m: float(np.min(s)) for m, s in samples.items()}
    return {
        "description": "open-system ingestion on the serving admission "
                       "scenario: streamed run(arrivals=...) vs the "
                       "pre-seeded closed reference, interleaved "
                       "rounds; streaming_rps = requests / median "
                       "streamed wall; the gated streamed_over_"
                       "preseeded ratio uses min-of-samples",
        "n_requests": n_req,
        "num_slots": num_slots,
        "max_batch_len": max_len,
        "events": int(closed.events),
        "bounded_capacity": bounded_cap,
        "repeats": repeats,
        "wall_s": med,
        "wall_samples_s": samples,
        "streaming_rps": n_req / med["streamed"],
        "bounded_streaming_rps": n_req / med["bounded_spill"],
        "streamed_over_preseeded": best["streamed"] / best["preseeded"],
        "decode_bound": {
            "delay_per_block_s": delay_s,
            "blocks": n_blocks,
            "sync_over_prefetch": best["decode_bound_sync"]
            / best["decode_bound_prefetch"],
        },
        "bit_identical": bool(bit),
        **({"trace_replay": _trace_replay(trace, build, admission_state,
                                          TraceReader, source_events)}
           if trace is not None else {}),
    }


def _trace_replay(trace, build, admission_state, TraceReader,
                  source_events):
    """The acceptance-scale run: replay an on-disk trace through the
    bounded-memory streamed config and bit-compare against the closed
    pre-seeded reference.  One shot each — at >=1M requests the walls
    are seconds-to-minutes and the quantity of interest is sustained
    RPS, not a noise-grade median."""
    reader = TraceReader(trace)
    n = len(reader)
    slots = 1024
    mbl = 3
    state0 = admission_state(slots)
    sim_b = build(32_768, n=n, slots=slots,
                  mbl=mbl).build(backend="device", overflow="spill")
    res = sim_b.run(state0, arrivals=reader)
    reader.seek(0)
    t0 = time.perf_counter()
    res = sim_b.run(state0, arrivals=reader)
    wall = time.perf_counter() - t0
    assert res.ingested == n, (res.ingested, n)

    events = [(1.0, "TICK")] + [
        (t, ty, list(a)) for (t, ty, a) in source_events(reader)]
    sim_c = build(n + 4096, n=n, slots=slots,
                  mbl=mbl).build(backend="device")
    t0 = time.perf_counter()
    closed = sim_c.run(state0, events=events)
    closed_wall = time.perf_counter() - t0
    return {
        "trace": str(trace),
        "n_requests": n,
        "num_slots": slots,
        "max_batch_len": mbl,
        "bounded_capacity": 32_768,
        "events": int(closed.events),
        "streamed_wall_s": wall,
        "preseeded_wall_s": closed_wall,
        "streaming_rps": n / wall,
        "bit_identical": _stream_bit_equal(res, closed),
    }


def _print_streaming(st):
    w = st["wall_s"]
    print(f"streaming @ n={st['n_requests']}: "
          f"{st['streaming_rps']:,.0f} RPS sustained "
          f"(bounded cap={st['bounded_capacity']}: "
          f"{st['bounded_streaming_rps']:,.0f} RPS); "
          f"streamed/preseeded {st['streamed_over_preseeded']:.3f}x "
          f"(walls {w['streamed'] * 1e3:.0f}ms / "
          f"{w['preseeded'] * 1e3:.0f}ms)")
    db = st["decode_bound"]
    print(f"  decode-bound source ({db['delay_per_block_s'] * 1e3:.1f}"
          f"ms x {db['blocks']} blocks): sync/prefetch "
          f"{db['sync_over_prefetch']:.3f}x (double-buffer overlap)")
    print(f"  streamed == preseeded bit-identical: "
          f"{st['bit_identical']}")
    tr = st.get("trace_replay")
    if tr:
        print(f"  trace replay {tr['trace']}: n={tr['n_requests']:,} "
              f"{tr['streaming_rps']:,.0f} RPS "
              f"(wall {tr['streamed_wall_s']:.1f}s, closed ref "
              f"{tr['preseeded_wall_s']:.1f}s), bit_identical="
              f"{tr['bit_identical']}")


def _merge_streaming_into_json(st):
    payload = json.loads(JSON_PATH.read_text()) if JSON_PATH.exists() \
        else {}
    prev = payload.get("streaming", {})
    if "trace_replay" not in st and "trace_replay" in prev:
        # a quick/CI refresh must not erase the recorded acceptance run
        st = dict(st, trace_replay=prev["trace_replay"])
    payload["streaming"] = st
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")


def _check_streaming(st, max_ratio: float) -> int:
    """CI gate: streamed execution must stay bit-identical to the
    pre-seeded closed reference AND within ``max_ratio``x of its wall
    time (both sides fresh in the same interleaved rounds — an
    absolute ceiling, nothing recorded to drift against).  The
    decode-bound overlap is printed, not gated: it quantifies the
    double buffer but is scheduler-noise-sensitive on shared runners.
    Returns a process exit code."""
    fresh = st["streamed_over_preseeded"]
    print(f"streaming gate: bit_identical={st['bit_identical']} "
          f"streamed/preseeded {fresh:.3f}x (ceiling {max_ratio:.2f}x)")
    if not st["bit_identical"]:
        print("streaming gate: FAIL — streamed run diverged from the "
              "pre-seeded closed reference")
        return 1
    if fresh > max_ratio:
        print(f"streaming gate: FAIL — streamed ingestion costs "
              f"{fresh:.3f}x the pre-seeded run, above the "
              f"{max_ratio:.2f}x ceiling")
        return 1
    print("streaming gate: OK")
    return 0


def _routed_churn_registry(near_delay: float, num_entities: int):
    """The near-full churn shape WITH entity routing: each re-emit
    targets the next entity (mod ``num_entities``), so under the
    sharded engine a constant fraction of emissions cross shard
    boundaries and exercise the exchange merge, while the single-queue
    engines see the identical event stream (they ignore ``arg[0]``)."""
    reg = EventRegistry()

    @emits_events
    def churn(state, t, arg):
        far = jnp.floor(t / 16.0) % 2.0 == 0.0
        delay = jnp.where(far, jnp.float32(1e6), jnp.float32(near_delay))
        emit = jnp.zeros((1, 2 + ARG_WIDTH), jnp.float32)
        emit = emit.at[0, 0].set(t + delay).at[0, 1].set(0.0)
        emit = emit.at[0, 2].set(
            jnp.mod(arg[0] + 1.0, float(num_entities)))
        return state + 1, emit

    reg.register("Churn", churn, lookahead=1e6)
    return reg.freeze()


def shards_sweep(quick: bool = False, repeats: int = 5):
    """`--shards`: the sharded engine vs the single tiered3 queue.

    The 92%-occupancy routed churn (near-head/far-future re-emits, one
    event per entity hop) runs on shards ∈ {1, 2, 4} at each capacity
    — shards=1 is the plain ``DeviceEngine(queue_mode="tiered3")``
    baseline the sharded runs are bit-identical to.  Interleaved A/B
    rounds (``_time_engines_interleaved``), so host-load drift hits
    every engine equally.  What this records is the COST of the
    lookahead-synchronized merge/exchange machinery per super-step
    (each super-step executes exactly the single-queue window, so
    per-batch numbers are directly comparable); per-shard queue work
    stays bounded, so the overhead ratio should stay flat in capacity.
    """
    max_len = 16
    num_entities = 64
    max_batches = 128 if quick else 512
    occupancy = 0.92
    caps = [1024] if quick else [4096, 65536]
    shard_counts = (1, 2, 4)

    def engine(n_shards, cap):
        reg = _routed_churn_registry(17.0, num_entities)
        kw = dict(max_batch_len=max_len, capacity=cap, max_emit=1)
        if n_shards == 1:
            return DeviceEngine(reg, queue_mode="tiered3", **kw)
        return ShardedDeviceEngine(reg, shards=n_shards, **kw)

    def seeded(cap):
        return [(float(t), 0,
                 np.asarray([t % num_entities, 0, 0, 0], np.float32))
                for t in range(int(cap * occupancy))]

    rows = {}
    for cap in caps:
        timed = _time_engines_interleaved(
            {f"shards={n}": (engine(n, cap), seeded(cap))
             for n in shard_counts},
            max_batches, repeats)
        rows[str(cap)] = {
            label: {"per_batch_us": t[0], "per_batch_samples_us": t[1]}
            for label, t in timed.items()
        }

    def ratio(cap, n):
        row = rows.get(str(cap))
        if not row:
            return None
        return (row[f"shards={n}"]["per_batch_us"]
                / row["shards=1"]["per_batch_us"])

    big = caps[-1]
    return {
        "description": "routed near-full churn (92% occupancy, "
                       "cross-entity re-emits); sharded engine vs the "
                       "bit-identical single tiered3 queue, interleaved "
                       "rounds",
        "max_batch_len": max_len,
        "max_emit": 1,
        "num_entities": num_entities,
        "batches_timed": max_batches,
        "repeats": repeats,
        "occupancy_fraction": occupancy,
        "capacities": rows,
        f"shards2_over_single_at_{big}": ratio(big, 2),
        f"shards4_over_single_at_{big}": ratio(big, 4),
    }


def _fused_workload_builders(quick: bool):
    """label -> (build(**kw) -> CompiledSim, state0_fn) for the two
    fused-dispatch workloads: the PoC model (2 types, the paper's
    motivating example) and the serving admission scenario (5 types —
    a word space where the default hot set really is a subset)."""
    from repro.core.program import Config
    from repro.serving.scenarios import build_admission_program
    from repro.serving.scenarios import initial_state as admission_state

    num_events = 192 if quick else 768
    rng = np.random.default_rng(0)
    types = (rng.random(num_events) < 0.5).astype(int)

    def build_poc(**kw):
        # p_set = 0.5 and max_batch_len = 6: most windows contain a
        # Set, and in a straight-line branch (switch/fused) everything
        # before the last Set is dead code and everything after it
        # runs on a compile-time constant — the paper's §I motivating
        # optimization.  The masked per-lane path executes every
        # Increment loop live, so the hot-word comparison measures
        # exactly the cross-event scope fused dispatch preserves.
        prog = poc.build_program(
            iters=32,
            config=Config(max_batch_len=6, capacity=num_events + 8),
        )
        for t, ty in enumerate(types):
            prog.schedule(float(t), ("Increment", "Set")[int(ty)])
        return prog.build(backend="device", **kw)

    num_requests = 24 if quick else 96

    def build_serving(**kw):
        prog = build_admission_program(
            num_slots=8, num_requests=num_requests, max_decode=5,
            config=Config(max_batch_len=3, capacity=1024, max_emit=2),
        )
        return prog.build(backend="device", **kw)

    return {
        "poc": (build_poc, poc.initial_state),
        "serving": (build_serving, lambda: admission_state(8)),
    }


def _time_sims_interleaved(sims, state0_fn, repeats):
    """The `_time_engines_interleaved` protocol at the CompiledSim
    level (dict states, re-runnable handles): label -> (median µs per
    batch, samples)."""
    for sim in sims.values():
        for _ in range(2):  # compile + allocator warm-up
            jax.block_until_ready(sim.run(state0_fn()).state)
    samples = {label: [] for label in sims}
    for _ in range(max(1, repeats)):
        for label, sim in sims.items():
            s0 = state0_fn()
            t0 = time.perf_counter()
            r = sim.run(s0)
            jax.block_until_ready(r.state)
            samples[label].append(
                (time.perf_counter() - t0) / r.batches * 1e6)
    return {label: (float(np.median(v)), v)
            for label, v in samples.items()}


def fused_dispatch(quick: bool = False, repeats: int = 5):
    """Composition-specialized dispatch vs the masked and full-switch
    paths — whole-run and per-dispatch (see module docstring)."""
    from repro.core.composer import hot_words_from_counts

    out = {}
    for wl, (build, state0_fn) in _fused_workload_builders(quick).items():
        sims = {mode: build(dispatch_mode=mode)
                for mode in ("switch", "masked")}

        # Profile pass on the generic modes, then specialize: the
        # fused sim gets the top-W PROFILED words (the intended
        # profile -> hot_words workflow), not the default dense-code
        # prefix — the observed hot words need not be the short ones.
        profiles = {m: sims[m].run(state0_fn()) for m in sims}
        base = profiles["switch"]
        hot = hot_words_from_counts(base.word_counts,
                                    sims["switch"].engine.codec, 8)
        sims["fused"] = build(dispatch_mode="fused", hot_words=hot)
        profiles["fused"] = sims["fused"].run(state0_fn())
        for m, r in profiles.items():
            np.testing.assert_array_equal(r.word_counts,
                                          base.word_counts, err_msg=m)
        hot_code = int(np.argmax(base.word_counts))

        timed = _time_sims_interleaved(sims, state0_fn, repeats)
        per_batch = {m: t[0] for m, t in timed.items()}

        # Per-dispatch microbenchmark on the hottest word, chained on
        # the state (the same _bench_op_loop shape as the per-op split).
        eng = sims["switch"].engine
        word = tuple(eng.codec.decode(hot_code))
        k = eng.max_batch_len
        tys_np = np.zeros((k,), np.int32)
        tys_np[: len(word)] = word
        ts = jnp.asarray(np.arange(k, dtype=np.float32))
        tys = jnp.asarray(tys_np)
        args = jnp.zeros((k, ARG_WIDTH), jnp.float32)
        length = jnp.int32(len(word))
        code = jnp.int32(hot_code)
        s0 = state0_fn()
        eng_f = sims["fused"].engine
        eng_m = sims["masked"].engine
        # The window rides in the loop carry: closed-over arrays embed
        # as jaxpr constants, XLA folds the dispatch switch on a
        # constant index, and the "dispatch" loop would time only the
        # branch body.
        def _carried(fn):
            def step(c):
                s, code, ts, tys, args, length = c
                return ((fn(s, code, ts, tys, args, length),)
                        + c[1:])
            return step

        op_us = _bench_ops_interleaved({
            "switch": _carried(
                lambda s, c, ts, tys, args, n:
                eng.dispatch(c, s, ts, tys, args)[0]),
            "masked": _carried(
                lambda s, c, ts, tys, args, n:
                eng_m._dispatch_masked(s, ts, tys, args, n)[0]),
            "fused": _carried(
                lambda s, c, ts, tys, args, n:
                eng_f._dispatch_fused(c, s, ts, tys, args, n)[0]),
        }, (s0, code, ts, tys, args, length), 256)

        out[wl] = {
            "batches": base.batches,
            "events": base.events,
            "hot_word": list(word),
            "hot_word_share": float(
                base.word_counts[hot_code] / base.word_counts.sum()),
            "num_hot_words": eng_f._dispatch_fused.num_hot,
            "num_batch_words": eng.codec.num_batches,
            "repeats": repeats,
            "per_batch_us": per_batch,
            "per_batch_samples_us": {m: t[1] for m, t in timed.items()},
            "run_fused_over_masked":
                per_batch["fused"] / per_batch["masked"],
            "dispatch_op_us": op_us,
            "dispatch_fused_over_masked": op_us["fused"] / op_us["masked"],
        }
    return {
        "description": "dispatch modes on identical workloads: full "
                       "switch over all words / generic per-lane masked "
                       "path / top-W fused super-procedures with masked "
                       "fallback; dispatch_op_us times the hottest "
                       "profiled word per dispatch call",
        "workloads": out,
    }


def _print_fused(fd):
    for wl, row in fd["workloads"].items():
        pb = row["per_batch_us"]
        op = row["dispatch_op_us"]
        print(f"  fused dispatch [{wl}] hot={row['hot_word']} "
              f"({row['num_hot_words']}/{row['num_batch_words']} words "
              f"hot): per-batch switch={pb['switch']:.1f}us "
              f"masked={pb['masked']:.1f}us fused={pb['fused']:.1f}us | "
              f"per-dispatch switch={op['switch']:.2f}us "
              f"masked={op['masked']:.2f}us fused={op['fused']:.2f}us "
              f"(fused/masked {row['dispatch_fused_over_masked']:.2f}x)")


def _merge_fused_into_json(fd):
    payload = json.loads(JSON_PATH.read_text()) if JSON_PATH.exists() \
        else {}
    payload["fused_dispatch"] = fd
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")


def _check_fused_baseline(fd, max_ratio: float) -> int:
    """CI perf gate for the dispatch specialization: per workload, the
    fused/masked per-dispatch ratio — host speed cancels, a fused-path
    regression does not — must stay within ``max_ratio``× the recorded
    ratio.  Returns a process exit code."""
    if not JSON_PATH.exists():
        print(f"baseline check: no {JSON_PATH.name}; nothing to compare")
        return 1
    base = json.loads(JSON_PATH.read_text()).get("fused_dispatch")
    if not base:
        print("baseline check: no recorded fused_dispatch section")
        return 1
    code = 0
    for wl, row in fd["workloads"].items():
        rec = base.get("workloads", {}).get(wl)
        if not rec:
            print(f"baseline check [{wl}]: not in recorded baseline; "
                  "skipping")
            continue
        recorded = rec.get("dispatch_fused_over_masked")
        if recorded is None:
            # A hand-edited or pre-dispatch-gate baseline: fail with
            # instructions instead of a bare KeyError traceback.
            print(f"baseline check [{wl}]: recorded entry lacks "
                  "'dispatch_fused_over_masked' — stale baseline "
                  "format; re-record with --fused-only (no --quick)")
            code = 1
            continue
        fresh = row["dispatch_fused_over_masked"]
        limit = recorded * max_ratio
        print(f"baseline check [{wl}]: fresh fused/masked {fresh:.2f}x "
              f"vs recorded {recorded:.2f}x (limit {limit:.2f}x)")
        if fresh > limit:
            print(f"baseline check [{wl}]: FAIL — fused dispatch "
                  f"regressed {fresh / recorded:.2f}x vs baseline")
            code = 1
    if code == 0:
        print("baseline check: OK")
    return code


def _print_shards(sh):
    for cap, row in sh["capacities"].items():
        parts = " ".join(
            f"{label}={vals['per_batch_us']:.1f}us"
            for label, vals in row.items())
        print(f"  shards sweep cap={cap:>6}: {parts}")


def _merge_shards_into_json(sh):
    payload = json.loads(JSON_PATH.read_text()) if JSON_PATH.exists() \
        else {}
    payload.setdefault("scheduling_overhead", {})["shards_sweep"] = sh
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")


def _merge_near_full_into_json(nf):
    """Refresh only the near_full section, keeping the recorded
    anchor/sweep baselines intact."""
    payload = json.loads(JSON_PATH.read_text()) if JSON_PATH.exists() \
        else {}
    payload.setdefault("scheduling_overhead", {})["near_full"] = nf
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")


def _print_near_full(nf):
    pb = nf["per_batch_us"]
    line = (f"near-full (occupancy {nf['occupancy_fraction']:.0%}, "
            f"cap={nf['capacity']}, median of {nf['repeats']}): "
            f"tiered3={pb['tiered3']:.1f}us/batch "
            f"tiered={pb['tiered']:.1f}us/batch "
            f"flat={pb['flat']:.1f}us/batch")
    if nf.get("low_occupancy_us"):
        line += (f" | at {nf['low_occupancy_fraction']:.0%} occupancy: "
                 f"tiered3={nf['low_occupancy_us']['tiered3']:.1f}us "
                 f"(pressure ratio "
                 f"{nf['tiered3_pressure_ratio_vs_low_occupancy']:.2f}x; "
                 f"two-tier "
                 f"{nf['tiered_pressure_ratio_vs_low_occupancy']:.2f}x)")
    print(line)
    if not nf.get("capacity_sweep"):
        return
    for cap, row in nf["capacity_sweep"]["capacities"].items():
        print(f"  near-full cap={cap:>6}: "
              f"tiered3={row['tiered3']['per_batch_us']:.1f}us "
              f"tiered={row['tiered']['per_batch_us']:.1f}us")
    r3 = nf["capacity_sweep"]["tiered3_ratio_64k_over_1k"]
    r2 = nf["capacity_sweep"]["tiered_ratio_64k_over_1k"]
    if r3 is not None:
        print(f"  worst-case capacity scaling 64k/1k: tiered3 {r3:.2f}x "
              f"vs two-tier {r2:.2f}x")


def _check_near_full_baseline(nf, max_ratio: float) -> int:
    """CI perf gate: fail when tiered3's near-full cost regresses more
    than ``max_ratio``× the recorded baseline.

    Absolute microseconds do not transfer between the recording
    machine and a CI runner (DESIGN.md §6.4), so the gated quantity is
    the tiered3/flat per-batch RATIO — both sides measured in the same
    interleaved rounds, so host speed cancels while a tiered3-specific
    regression does not.  Falls back to the absolute tiered3 (or
    pre-tiered3 two-tier) median only when the recorded baseline
    predates the flat column.  Returns a process exit code.
    """
    if not JSON_PATH.exists():
        print(f"baseline check: no {JSON_PATH.name}; nothing to compare")
        return 1
    payload = json.loads(JSON_PATH.read_text())
    base = payload.get("scheduling_overhead", {}).get("near_full")
    if not base:
        print("baseline check: no recorded near_full section")
        return 1
    base_pb = base.get("per_batch_us")
    if not base_pb or not ("tiered3" in base_pb or "tiered" in base_pb):
        # Guard against a hand-edited / truncated baseline file: the
        # gate should say what to re-record, not dump a KeyError.
        print("baseline check: recorded near_full section lacks "
              "'per_batch_us' medians — stale or truncated baseline; "
              "re-record with --near-full-only (no --quick)")
        return 1
    fresh_pb = nf["per_batch_us"]
    if "tiered3" in base_pb and "flat" in base_pb:
        recorded = base_pb["tiered3"] / base_pb["flat"]
        fresh = fresh_pb["tiered3"] / fresh_pb["flat"]
        what = "tiered3/flat per-batch ratio"
        units = "x"
    else:
        recorded = base_pb.get("tiered3", base_pb.get("tiered"))
        fresh = fresh_pb["tiered3"]
        what = "tiered3 per-batch (absolute — old baseline, machine-"
        what += "dependent)"
        units = "us"
    if base.get("capacity") != nf["capacity"]:
        # Neither comparison transfers across capacities: flat's cost
        # is O(capacity), so the tiered3/flat ratio shifts with it.
        print(f"baseline check: FAIL — recorded baseline is at capacity "
              f"{base.get('capacity')}, this run at {nf['capacity']}; "
              "run the gate at the recorded capacity (no --quick)")
        return 1
    limit = recorded * max_ratio
    print(f"baseline check: fresh {what} {fresh:.2f}{units} vs recorded "
          f"{recorded:.2f}{units} (limit {max_ratio:.1f}x = "
          f"{limit:.2f}{units})")
    if fresh > limit:
        print("baseline check: FAIL — near-full regressed "
              f"{fresh / recorded:.2f}x vs baseline")
        return 1
    print("baseline check: OK")
    return 0


def main(quick: bool = False, out: str | None = None, repeats: int = 5):
    sched = scheduling_overhead(quick=quick, repeats=repeats)
    sched["near_full"] = near_full(quick=quick, repeats=repeats)
    sched["shards_sweep"] = shards_sweep(quick=quick, repeats=repeats)
    fd = fused_dispatch(quick=quick, repeats=repeats)
    vo = validate_overhead(quick=quick, repeats=repeats)
    st = streaming(quick=quick, repeats=repeats)
    r = run(quick=quick)
    payload = {"host_vs_device": r, "scheduling_overhead": sched,
               "fused_dispatch": fd, "validate_overhead": vo,
               "streaming": st}
    if out:
        Path(out).write_text(json.dumps(payload, indent=2) + "\n")
        print("wrote", out)
    if quick:
        # Quick mode uses a smaller workload — don't clobber the
        # recorded full-run perf baseline future PRs track.
        print("quick mode: not overwriting", JSON_PATH.name)
    else:
        # Merge, don't overwrite: sections recorded by other suites
        # (e.g. serving_fusion) live in the same file.
        recorded = json.loads(JSON_PATH.read_text()) \
            if JSON_PATH.exists() else {}
        prev_tr = recorded.get("streaming", {}).get("trace_replay")
        if prev_tr and "trace_replay" not in payload["streaming"]:
            # keep the recorded acceptance-scale trace replay
            payload["streaming"] = dict(payload["streaming"],
                                        trace_replay=prev_tr)
        recorded.update(payload)
        JSON_PATH.write_text(json.dumps(recorded, indent=2) + "\n")
    print("events,host_us_per_event,device_us_per_event,device_speedup")
    print(f"{r['events']},{r['host_us_per_event']:.1f},"
          f"{r['device_us_per_event']:.1f},{r['device_speedup']:.2f}")
    pb = sched["anchor"]["per_batch_us"]
    print(f"scheduling us/batch @ cap={sched['anchor']['capacity']} "
          f"k={sched['anchor']['max_batch_len']}: "
          f"tiered3={pb['tiered3']:.1f} tiered={pb['tiered']:.1f} "
          f"flat={pb['flat']:.1f} reference={pb['reference']:.1f} "
          f"(tiered vs ref {pb['speedup_tiered_vs_reference']:.2f}x)")
    for cap, row in sched["capacity_sweep"]["capacities"].items():
        print(f"  cap={cap:>6}: tiered3 per_batch="
              f"{row['tiered3']['per_batch_us']:.1f}us insert="
              f"{row['tiered3']['insert_op_us']:.1f}us | tiered per_batch="
              f"{row['tiered']['per_batch_us']:.1f}us insert="
              f"{row['tiered']['insert_op_us']:.1f}us | flat per_batch="
              f"{row['flat']['per_batch_us']:.1f}us insert="
              f"{row['flat']['insert_op_us']:.1f}us")
    ratio = sched["capacity_sweep"]["insert_op_ratio_16k_over_1k"]
    r3 = sched["capacity_sweep"]["tiered3_insert_op_ratio_16k_over_1k"]
    if ratio is not None:
        print(f"capacity-independence: insert 16k/1k tiered={ratio:.2f}x "
              f"tiered3={r3:.2f}x")
    _print_near_full(sched["near_full"])
    _print_shards(sched["shards_sweep"])
    _print_fused(fd)
    _print_validate(vo)
    _print_streaming(st)
    if not quick:
        print(f"wrote {JSON_PATH}")
    r = dict(r)
    r["sched_speedup"] = pb["speedup_tiered_vs_reference"]
    return r


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--near-full-only", action="store_true",
                    help="run just the near-full stress and merge it "
                         "into the recorded JSON baseline")
    ap.add_argument("--shards-only", action="store_true",
                    help="run just the sharded-engine sweep (shards "
                         "1/2/4, interleaved rounds) and merge it into "
                         "the recorded JSON baseline")
    ap.add_argument("--fused-only", action="store_true",
                    help="run just the dispatch-specialization "
                         "comparison (switch/masked/fused) and merge it "
                         "into the recorded JSON baseline")
    ap.add_argument("--validate-only", action="store_true",
                    help="run just the validate='cheap' vs 'off' "
                         "interleaved A/B and merge it into the "
                         "recorded JSON baseline")
    ap.add_argument("--streaming-only", action="store_true",
                    help="run just the open-system ingestion section "
                         "(streamed vs pre-seeded, sync vs prefetch "
                         "feed, bounded-memory spill) and merge it "
                         "into the recorded JSON baseline")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="with --streaming-only: also replay this "
                         "on-disk trace (scripts/gen_trace.py) through "
                         "the bounded streamed config and record the "
                         "acceptance-scale trace_replay subsection")
    ap.add_argument("--check-streaming", type=float, default=None,
                    metavar="RATIO",
                    help="with --streaming-only: exit 1 unless the "
                         "streamed run is bit-identical to the "
                         "pre-seeded reference and within RATIO x of "
                         "its wall time (absolute ceiling; CI gate "
                         "for the ingestion path)")
    ap.add_argument("--check-validate", type=float, default=None,
                    metavar="RATIO",
                    help="with --validate-only: exit 1 if the fresh "
                         "cheap/off per-batch ratio exceeds RATIO "
                         "(absolute ceiling; CI gate for the on-device "
                         "invariant auditor)")
    ap.add_argument("--repeats", type=int, default=5,
                    help="whole-run timing samples per measurement; the "
                         "recorded value is the median (raw samples are "
                         "kept alongside)")
    ap.add_argument("--check-baseline", type=float, default=None,
                    metavar="RATIO",
                    help="with --near-full-only / --fused-only: compare "
                         "the fresh medians (tiered3 near-full ratio / "
                         "fused-over-masked dispatch ratio) against the "
                         "recorded baseline instead of merging; exit 1 "
                         "on a >RATIO x regression (CI perf gate)")
    ap.add_argument("--out", default=None,
                    help="also write results to this path (CI artifact)")
    args = ap.parse_args()
    if args.shards_only:
        sh = shards_sweep(quick=args.quick, repeats=args.repeats)
        _print_shards(sh)
        if args.out:
            Path(args.out).write_text(json.dumps({"shards_sweep": sh},
                                                 indent=2) + "\n")
        if args.quick:
            print("quick mode: not merging into", JSON_PATH.name)
        else:
            _merge_shards_into_json(sh)
            print("merged shards_sweep into", JSON_PATH.name)
    elif args.fused_only:
        fd = fused_dispatch(quick=args.quick, repeats=args.repeats)
        _print_fused(fd)
        if args.out:
            Path(args.out).write_text(json.dumps({"fused_dispatch": fd},
                                                 indent=2) + "\n")
        if args.check_baseline is not None:
            raise SystemExit(_check_fused_baseline(
                fd, args.check_baseline))
        if args.quick:
            print("quick mode: not merging into", JSON_PATH.name)
        else:
            _merge_fused_into_json(fd)
            print("merged fused_dispatch into", JSON_PATH.name)
    elif args.streaming_only:
        st = streaming(quick=args.quick, repeats=args.repeats,
                       trace=args.trace)
        _print_streaming(st)
        if args.out:
            Path(args.out).write_text(
                json.dumps({"streaming": st}, indent=2) + "\n")
        if args.check_streaming is not None:
            raise SystemExit(_check_streaming(st, args.check_streaming))
        if args.quick:
            print("quick mode: not merging into", JSON_PATH.name)
        else:
            _merge_streaming_into_json(st)
            print("merged streaming into", JSON_PATH.name)
    elif args.validate_only:
        vo = validate_overhead(quick=args.quick, repeats=args.repeats)
        _print_validate(vo)
        if args.out:
            Path(args.out).write_text(
                json.dumps({"validate_overhead": vo}, indent=2) + "\n")
        if args.check_validate is not None:
            raise SystemExit(_check_validate_overhead(
                vo, args.check_validate))
        if args.quick:
            print("quick mode: not merging into", JSON_PATH.name)
        else:
            _merge_validate_into_json(vo)
            print("merged validate_overhead into", JSON_PATH.name)
    elif args.near_full_only:
        # The gate reads only the anchor — skip the capacity sweep.
        nf = near_full(quick=args.quick, repeats=args.repeats,
                       sweep=args.check_baseline is None,
                       controls=args.check_baseline is None)
        _print_near_full(nf)
        if args.out:
            Path(args.out).write_text(json.dumps({"near_full": nf},
                                                 indent=2) + "\n")
        if args.check_baseline is not None:
            raise SystemExit(_check_near_full_baseline(
                nf, args.check_baseline))
        if args.quick:
            print("quick mode: not merging into", JSON_PATH.name)
        else:
            _merge_near_full_into_json(nf)
            print("merged near_full into", JSON_PATH.name)
    else:
        main(quick=args.quick, out=args.out, repeats=args.repeats)
