"""Beyond-paper: fully on-device DES vs host-driven dispatch.

The TPU-native adaptation (DESIGN.md §2) compiles the WHOLE simulation
— queue, lookahead window, Horner encode, lax.switch dispatch — into one
XLA program.  This benchmark measures events/second of the on-device
engine against the host-driven batched scheduler on the PoC model.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro import poc
from repro.core import DeviceEngine, Simulator


def run(quick: bool = False):
    iters = 2_000 if quick else 20_000
    num_events = 128 if quick else 384
    n = 4
    rng = np.random.default_rng(0)
    types = [int(x) for x in (rng.random(num_events) < 0.5)]

    # host engine
    reg = poc.build_registry(iters=iters)
    sim = Simulator(reg, max_batch_len=n)
    for t, ty in enumerate(types):
        sim.queue.push(float(t), ty)
    state, _ = sim.run(poc.initial_state(), mode="conservative")  # warm
    sim2 = Simulator(reg, max_batch_len=n)
    sim2.composer = sim.composer
    for t, ty in enumerate(types):
        sim2.queue.push(float(t), ty)
    t0 = time.perf_counter()
    state_h, _ = sim2.run(poc.initial_state(), mode="conservative")
    jax.block_until_ready(state_h)
    t_host = time.perf_counter() - t0

    # on-device engine
    eng = DeviceEngine(reg, max_batch_len=n, capacity=num_events + 8)
    queue = eng.initial_queue([(float(t), ty, None)
                               for t, ty in enumerate(types)])
    eng.run(poc.initial_state(), queue)  # warm (compiles)
    queue = eng.initial_queue([(float(t), ty, None)
                               for t, ty in enumerate(types)])
    t0 = time.perf_counter()
    state_d, _q, stats = eng.run(poc.initial_state(), queue)
    jax.block_until_ready(state_d)
    t_dev = time.perf_counter() - t0

    assert int(state_h) == int(state_d) == poc.reference_final_sum(
        types, iters)
    return {
        "events": num_events,
        "host_us_per_event": t_host / num_events * 1e6,
        "device_us_per_event": t_dev / num_events * 1e6,
        "device_speedup": t_host / t_dev,
    }


def main(quick: bool = False):
    r = run(quick=quick)
    print("events,host_us_per_event,device_us_per_event,device_speedup")
    print(f"{r['events']},{r['host_us_per_event']:.1f},"
          f"{r['device_us_per_event']:.1f},{r['device_speedup']:.2f}")
    return r


if __name__ == "__main__":
    main()
