"""Beyond-paper: fully on-device DES vs host-driven dispatch, plus the
per-batch scheduling-overhead split (extract / dispatch / insert).

Two measurements:

* ``run``  — events/second of the on-device engine against the
  host-driven batched scheduler on the PoC model (as in the seed).

* ``scheduling_overhead`` — the cost of the queue machinery itself, on
  a trivial-handler workload (each event bumps a counter and emits one
  far-future event, so per-batch time is almost pure scheduling): the
  vectorized single-pass queue ops (sorted-prefix extract + counting
  merge insert) against the seed per-event reference ops
  (serial peek/pop argmin chains + one-at-a-time pushes), whole-run
  per-batch and per-op.  Results land in ``BENCH_device_engine.json``
  at the repo root so future PRs have a perf trajectory to track.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import poc
from repro.core import DeviceEngine, EventRegistry, Simulator, emits_events
from repro.core.events import ARG_WIDTH
from repro.core.queue import (
    device_queue_extract,
    device_queue_extract_ref,
    device_queue_fill_rows,
    device_queue_push_rows,
)

JSON_PATH = Path(__file__).resolve().parents[1] / "BENCH_device_engine.json"


def run(quick: bool = False):
    iters = 2_000 if quick else 20_000
    num_events = 128 if quick else 384
    n = 4
    rng = np.random.default_rng(0)
    types = [int(x) for x in (rng.random(num_events) < 0.5)]

    # host engine
    reg = poc.build_registry(iters=iters)
    sim = Simulator(reg, max_batch_len=n)
    for t, ty in enumerate(types):
        sim.queue.push(float(t), ty)
    state, _ = sim.run(poc.initial_state(), mode="conservative")  # warm
    sim2 = Simulator(reg, max_batch_len=n)
    sim2.composer = sim.composer
    for t, ty in enumerate(types):
        sim2.queue.push(float(t), ty)
    t0 = time.perf_counter()
    state_h, _ = sim2.run(poc.initial_state(), mode="conservative")
    jax.block_until_ready(state_h)
    t_host = time.perf_counter() - t0

    # on-device engine
    eng = DeviceEngine(reg, max_batch_len=n, capacity=num_events + 8)
    queue = eng.initial_queue([(float(t), ty, None)
                               for t, ty in enumerate(types)])
    eng.run(poc.initial_state(), queue)  # warm (compiles)
    queue = eng.initial_queue([(float(t), ty, None)
                               for t, ty in enumerate(types)])
    t0 = time.perf_counter()
    state_d, _q, stats = eng.run(poc.initial_state(), queue)
    jax.block_until_ready(state_d)
    t_dev = time.perf_counter() - t0

    assert int(state_h) == int(state_d) == poc.reference_final_sum(
        types, iters)
    return {
        "events": num_events,
        "host_us_per_event": t_host / num_events * 1e6,
        "device_us_per_event": t_dev / num_events * 1e6,
        "device_speedup": t_host / t_dev,
    }


def _trivial_registry():
    """One trivial emitting type: bump a counter, emit one event far in
    the future (keeps the queue at steady occupancy, so every batch
    pays full-queue scheduling cost)."""
    reg = EventRegistry()

    @emits_events
    def tick(state, t, arg):
        emit = jnp.zeros((1, 2 + ARG_WIDTH), jnp.float32)
        emit = emit.at[0, 0].set(t + 1e6).at[0, 1].set(0.0)
        return state + 1, emit

    reg.register("Tick", tick, lookahead=1e6)
    return reg.freeze()


def _bench_op_loop(step, init, iters):
    """µs per application of ``step``, chained in one jitted fori_loop
    (matches how the ops run inside the engine — per-call dispatch
    overhead would otherwise dominate and invert the comparison)."""
    looped = jax.jit(
        lambda init: jax.lax.fori_loop(0, iters, lambda i, c: step(c), init)
    )
    jax.block_until_ready(looped(init))
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        out = looped(init)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best * 1e6


def scheduling_overhead(quick: bool = False):
    capacity = 1024 if quick else 4096
    max_len = 16
    max_batches = 128 if quick else 512
    num_events = capacity - 2 * max_len
    events = [(float(t), 0, None) for t in range(num_events)]

    per_batch = {}
    engines = {}
    for name, vec in (("vectorized", True), ("reference", False)):
        reg = _trivial_registry()
        eng = DeviceEngine(reg, max_batch_len=max_len, capacity=capacity,
                           max_emit=1, use_vectorized_queue=vec)
        engines[name] = eng
        q = eng.initial_queue(events)
        eng.run(jnp.int32(0), q, max_batches=max_batches)  # warm
        best = float("inf")
        for _ in range(3):
            q = eng.initial_queue(events)
            t0 = time.perf_counter()
            s, _q, stats = eng.run(jnp.int32(0), q, max_batches=max_batches)
            jax.block_until_ready(s)
            best = min(best, time.perf_counter() - t0)
        per_batch[name] = best / int(stats["batches"]) * 1e6

    # Per-op split: each op chained in its own fused loop, from a
    # representative steady state.
    eng = engines["vectorized"]
    la = eng._lookaheads
    q_full = eng.initial_queue(events)
    q_half = eng.initial_queue(events[: num_events // 2])
    rows = np.full((max_len, 2 + ARG_WIDTH), -1.0, np.float32)
    rows[:, 0] = np.arange(max_len) + float(num_events)
    rows[:, 1] = 0.0
    rows = jnp.asarray(rows)
    _, ts, tys, args, length = device_queue_extract(q_full, max_len, la)
    code = eng.codec.encode_jnp(tys, length)
    state0 = jnp.int32(0)

    # Iteration counts keep the extract loop from draining the queue and
    # the insert loop from overflowing it.
    ex_iters = max(1, (num_events - max_len) // max_len)
    in_iters = max(1, (capacity - num_events // 2 - max_len) // max_len)
    phase = {
        "extract": {
            "vectorized": _bench_op_loop(
                lambda q: device_queue_extract(q, max_len, la)[0],
                q_full, ex_iters),
            "reference": _bench_op_loop(
                lambda q: device_queue_extract_ref(q, max_len, la)[0],
                q_full, ex_iters),
        },
        "insert": {
            "vectorized": _bench_op_loop(
                lambda q: device_queue_fill_rows(q, rows), q_half, in_iters),
            "reference": _bench_op_loop(
                lambda q: device_queue_push_rows(q, rows), q_half, in_iters),
        },
        "dispatch": {
            "shared": _bench_op_loop(
                lambda s: eng.dispatch(code, s, ts, tys, args)[0],
                state0, 256),
        },
    }

    result = {
        "workload": {
            "description": "trivial emitting handler (counter + 1 far-future"
                           " emit); per-batch time ~= scheduling overhead",
            "capacity": capacity,
            "max_batch_len": max_len,
            "max_emit": 1,
            "num_seed_events": num_events,
            "batches_timed": max_batches,
        },
        "per_batch_us": {
            **per_batch,
            "speedup": per_batch["reference"] / per_batch["vectorized"],
        },
        "per_op_us": phase,
    }
    return result


def main(quick: bool = False):
    sched = scheduling_overhead(quick=quick)
    r = run(quick=quick)
    payload = {"host_vs_device": r, "scheduling_overhead": sched}
    if quick:
        # Quick mode uses a smaller workload — don't clobber the
        # recorded full-run perf baseline future PRs track.
        print("quick mode: not overwriting", JSON_PATH.name)
    else:
        JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    print("events,host_us_per_event,device_us_per_event,device_speedup")
    print(f"{r['events']},{r['host_us_per_event']:.1f},"
          f"{r['device_us_per_event']:.1f},{r['device_speedup']:.2f}")
    pb = sched["per_batch_us"]
    print(f"scheduling us/batch: vectorized={pb['vectorized']:.1f} "
          f"reference={pb['reference']:.1f} speedup={pb['speedup']:.2f}x "
          f"(capacity={sched['workload']['capacity']}, "
          f"k={sched['workload']['max_batch_len']})")
    if not quick:
        print(f"wrote {JSON_PATH}")
    r = dict(r)
    r["sched_speedup"] = pb["speedup"]
    return r


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
