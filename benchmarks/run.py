"""Benchmark harness: one module per paper table/figure + beyond-paper.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints ``name,us_per_call,derived`` CSV summary lines at the end.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="smaller workloads (CI mode)")
    p.add_argument("--only", default=None)
    args = p.parse_args()

    from benchmarks import (
        batch_counts,
        compile_times,
        device_engine,
        poc_speedup,
        selection_overhead,
        serving_fusion,
    )

    suites = {
        "poc_speedup(Fig3)": poc_speedup,
        "compile_times(Fig4)": compile_times,
        "selection_overhead(SIV.B)": selection_overhead,
        "batch_counts(SIV.C)": batch_counts,
        "serving_fusion(beyond)": serving_fusion,
        "device_engine(beyond)": device_engine,
    }
    summary = []
    for name, mod in suites.items():
        if args.only and args.only not in name:
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.perf_counter()
        result = mod.main(quick=args.quick)
        dt = time.perf_counter() - t0
        derived = ""
        if name.startswith("poc_speedup") and result:
            best = max(r["speedup"] for r in result)
            derived = f"max_speedup={best:.2f}"
        elif name.startswith("selection") and result:
            derived = f"overhead={result['overhead_pct']:.1f}%"
        elif name.startswith("serving") and result:
            derived = f"fusion_speedup_k8={result[-1]['speedup_vs_k1']:.2f}"
        elif name.startswith("device_engine") and result:
            derived = (f"device_speedup={result['device_speedup']:.2f};"
                       f"sched_speedup={result['sched_speedup']:.2f}")
        summary.append((name, dt * 1e6, derived))
    print("\n===== summary =====")
    print("name,us_per_call,derived")
    for name, us, derived in summary:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
