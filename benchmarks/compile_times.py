"""Paper Fig. 4: compile-time growth with event-type count × batch length.

The paper's C++ template instantiation grows exponentially and exceeds
240 s at 10 event types × length 5.  Here the analogue is AOT
``jit(...).lower().compile()`` of every composed batch (EagerComposer).
We reproduce the exponential growth AND measure the two beyond-paper
mitigations:

* dense codec (no ν-redundant programs) vs the paper codec's count;
* lazy composition (compile only observed batches) — reported as the
  compile cost of a realistic run that observes a fraction of Σ*.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import EagerComposer, LazyComposer, EventRegistry
from repro.core.codec import (
    DenseCodec,
    PaperCodec,
    dense_batch_count,
    paper_batch_count,
)

BUDGET_SECONDS = 120.0  # paper used 240 s on a 3.5 GHz desktop


def _registry(num_types: int) -> EventRegistry:
    reg = EventRegistry()
    for i in range(num_types):
        # distinct bodies so XLA cannot collapse programs
        reg.register(f"E{i}",
                     (lambda k: lambda s, t, a: s * jnp.uint32(2 + k)
                      + jnp.uint32(k))(i))
    return reg.freeze()


def run(quick: bool = False):
    type_counts = (2, 3) if quick else (2, 3, 5)
    lengths = (1, 2, 3) if quick else (1, 2, 3, 4, 5)
    rows = []
    for nt in type_counts:
        for n in lengths:
            dense_n = dense_batch_count(nt, n)
            if dense_n > 4000:
                rows.append({"types": nt, "n": n, "programs": dense_n,
                             "seconds": None, "status": "over budget"})
                continue
            reg = _registry(nt)
            codec = DenseCodec(nt, n)
            t0 = time.perf_counter()
            comp = EagerComposer(
                reg, codec,
                state_spec=jax.ShapeDtypeStruct((), jnp.uint32),
                arg_spec=None)
            dt = time.perf_counter() - t0
            rows.append({
                "types": nt, "n": n, "programs": comp.num_composed,
                "paper_codec_programs": paper_batch_count(nt, n),
                "seconds": dt,
                "status": "ok" if dt < BUDGET_SECONDS else "over budget",
            })
            if dt > BUDGET_SECONDS:
                break
    return rows


def run_codec_comparison(quick: bool = False):
    """Eager-compile the SAME alphabet under both codecs: the dense
    codec's time saving is the measured value of the paper's §IV.D
    'refined enumeration scheme'."""
    nt, n = (2, 3) if quick else (3, 4)
    out = {}
    for kind, codec_cls in (("dense", DenseCodec), ("paper", PaperCodec)):
        reg = _registry(nt)
        t0 = time.perf_counter()
        comp = EagerComposer(
            reg, codec_cls(nt, n),
            state_spec=jax.ShapeDtypeStruct((), jnp.uint32),
            arg_spec=None)
        out[kind] = {"seconds": time.perf_counter() - t0,
                     "programs": comp.num_composed}
    out["speedup"] = out["paper"]["seconds"] / out["dense"]["seconds"]
    return out


def run_fused_compile_scaling(quick: bool = False):
    """Compile-cost guard for fused dispatch (DESIGN.md §7): engine
    compile time as a function of the hot-set size W must grow
    LINEARLY in W (each hot word adds one straight-line branch) on top
    of the constant masked fallback — not with the |Σ|^n full-switch
    word count.  Reports seconds per W and the scaling ratio vs the
    hot-word ratio; ``linear_ok`` flags time growing no faster than
    2× the W growth (the slack absorbs constant per-compile overhead,
    which makes the measured ratio UNDERestimate linearity)."""
    import numpy as np

    from repro.core.codec import DenseCodec as _DC
    from repro.core.engine import DeviceEngine

    nt, n = (3, 3)
    codec = _DC(nt, n)
    ws = (2, 8) if quick else (2, 8, 32)
    reg_words = [tuple(codec.decode(c)) for c in range(codec.num_batches)]
    rows = []
    for w in ws:
        reg = _registry(nt)
        eng = DeviceEngine(reg, max_batch_len=n, capacity=128,
                           dispatch_mode="fused",
                           hot_words=reg_words[:w])
        queue = eng.initial_queue(
            [(float(t), t % nt, None) for t in range(32)])
        t0 = time.perf_counter()
        eng.run(jnp.uint32(0), queue)  # first call = trace + compile
        rows.append({"hot_words": w,
                     "seconds": time.perf_counter() - t0})
    t_lo, t_hi = rows[0]["seconds"], rows[-1]["seconds"]
    w_lo, w_hi = rows[0]["hot_words"], rows[-1]["hot_words"]
    time_ratio = t_hi / t_lo
    w_ratio = w_hi / w_lo
    return {
        "types": nt, "n": n, "rows": rows,
        "time_ratio": time_ratio, "hot_word_ratio": w_ratio,
        "seconds_per_hot_word": (t_hi - t_lo) / (w_hi - w_lo),
        "linear_ok": bool(time_ratio <= 2.0 * w_ratio),
    }


def run_lazy_fraction(quick: bool = False):
    """Lazy composition on a realistic workload: how many of the Σ*
    programs does a 1000-event run actually touch?"""
    import numpy as np

    from repro import poc
    from repro.core import Simulator

    n = 4 if quick else 6
    reg = poc.build_registry(iters=64)
    sim = Simulator(reg, max_batch_len=n, composer="lazy")
    rng = np.random.default_rng(0)
    events = 256 if quick else 1024
    for t, ty in enumerate((rng.random(events) < 0.5).astype(int)):
        sim.queue.push(float(t), int(ty))
    sim.run(poc.initial_state(), mode="conservative")
    total = dense_batch_count(2, n)
    return {
        "n": n, "possible_programs": total,
        "compiled_programs": sim.composer.num_composed,
        "fraction": sim.composer.num_composed / total,
    }


def main(quick: bool = False):
    rows = run(quick=quick)
    print("types,n,programs,paper_codec_programs,seconds,status")
    for r in rows:
        sec = f"{r['seconds']:.2f}" if r["seconds"] is not None else "-"
        print(f"{r['types']},{r['n']},{r['programs']},"
              f"{r.get('paper_codec_programs', '-')},{sec},{r['status']}")
    cc = run_codec_comparison(quick=quick)
    print(f"codec comparison: paper {cc['paper']['programs']} programs "
          f"{cc['paper']['seconds']:.1f}s vs dense "
          f"{cc['dense']['programs']} programs "
          f"{cc['dense']['seconds']:.1f}s -> dense codec compiles "
          f"{cc['speedup']:.2f}x faster")
    lz = run_lazy_fraction(quick=quick)
    print(f"lazy: {lz['compiled_programs']}/{lz['possible_programs']} "
          f"programs compiled ({lz['fraction']:.1%}) at n={lz['n']}")
    fs = run_fused_compile_scaling(quick=quick)
    ws = " ".join(f"W={r['hot_words']}:{r['seconds']:.2f}s"
                  for r in fs["rows"])
    print(f"fused dispatch compile scaling ({fs['types']} types, "
          f"n={fs['n']}): {ws} -> time x{fs['time_ratio']:.2f} for "
          f"hot-words x{fs['hot_word_ratio']:.0f} "
          f"({fs['seconds_per_hot_word'] * 1e3:.0f}ms/word, "
          f"linear_ok={fs['linear_ok']})")
    if not fs["linear_ok"]:
        raise SystemExit(
            "fused dispatch compile cost grew superlinearly in W")
    return rows


if __name__ == "__main__":
    main()
