"""Paper §IV.C: composed-batch counts and ν-redundancy.

Closed forms, checked exhaustively for small alphabets.  Reproduces the
paper's |Σ|=5, n=5 example (58 % redundant; the paper's prose quotes
"9331", which its own formula shows is the total-including-ε — the
formula value is 5425 = 58.1 % of 9330, matching the quoted percentage).
"""

from __future__ import annotations

from repro.core.codec import (
    DenseCodec,
    dense_batch_count,
    paper_batch_count,
    redundant_batch_count,
)


def run(quick: bool = False):
    rows = []
    cases = [(2, 2), (2, 5), (5, 5), (10, 5)] if not quick else [(2, 2),
                                                                 (5, 5)]
    for nt, n in cases:
        total = paper_batch_count(nt, n)
        red = redundant_batch_count(nt, n)
        rows.append({
            "types": nt, "n": n,
            "paper_codec_batches": total,
            "redundant": red,
            "redundant_pct": red / total * 100.0,
            "dense_codec_batches": dense_batch_count(nt, n),
        })
    return rows


def main(quick: bool = False):
    rows = run(quick=quick)
    print("types,n,paper_batches,redundant,redundant_pct,dense_batches")
    for r in rows:
        print(f"{r['types']},{r['n']},{r['paper_codec_batches']},"
              f"{r['redundant']},{r['redundant_pct']:.1f},"
              f"{r['dense_codec_batches']}")
    return rows


if __name__ == "__main__":
    main()
