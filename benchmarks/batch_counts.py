"""Paper §IV.C: composed-batch counts and ν-redundancy.

Closed forms, checked exhaustively for small alphabets.  Reproduces the
paper's |Σ|=5, n=5 example (58 % redundant; the paper's prose quotes
"9331", which its own formula shows is the total-including-ε — the
formula value is 5425 = 58.1 % of 9330, matching the quoted percentage).

``run_measured`` complements the closed forms with OBSERVED word
frequencies: the per-word histogram the device engine now records in
``RunResult.word_counts`` (the profile input to fused dispatch,
DESIGN.md §7), measured on the Fig-3 PoC workload across p_s — how
concentrated the word distribution actually is, i.e. how few hot words
a top-W fused dispatcher needs to cover most batches.
"""

from __future__ import annotations

import numpy as np

from repro.core.codec import (
    DenseCodec,
    dense_batch_count,
    paper_batch_count,
    redundant_batch_count,
)


def run(quick: bool = False):
    rows = []
    cases = [(2, 2), (2, 5), (5, 5), (10, 5)] if not quick else [(2, 2),
                                                                 (5, 5)]
    for nt, n in cases:
        total = paper_batch_count(nt, n)
        red = redundant_batch_count(nt, n)
        rows.append({
            "types": nt, "n": n,
            "paper_codec_batches": total,
            "redundant": red,
            "redundant_pct": red / total * 100.0,
            "dense_codec_batches": dense_batch_count(nt, n),
        })
    return rows


def run_measured(quick: bool = False):
    """Observed word histograms from device runs of the PoC workload:
    per p_s, the number of distinct words seen, and the share of
    batches the top-1 / top-4 words cover (``RunResult.word_counts``
    ranked by :func:`repro.core.composer.hot_words_from_counts`)."""
    from repro import poc
    from repro.core.composer import hot_words_from_counts
    from repro.core.program import Config

    n = 4
    num_events = 64 if quick else 256
    ps_values = (0.25,) if quick else (0.05, 0.25, 0.5)
    rows = []
    for p_s in ps_values:
        rng = np.random.default_rng(0)
        types = [int(x) for x in (rng.random(num_events) < p_s)]
        prog = poc.build_program(
            iters=16, config=Config(max_batch_len=n,
                                    capacity=num_events + 8))
        for t, ty in enumerate(types):
            prog.schedule(float(t), ("Increment", "Set")[ty])
        sim = prog.build(backend="device")
        r = sim.run(poc.initial_state())
        wc = r.word_counts
        total = int(wc.sum())
        assert total == r.batches
        ranked = np.sort(wc[wc > 0])[::-1]
        hot = hot_words_from_counts(wc, sim.engine.codec, 4)
        rows.append({
            "p_s": p_s, "n": n, "batches": total,
            "possible_words": int(wc.shape[0]),
            "observed_words": int((wc > 0).sum()),
            "top1_share": float(ranked[0] / total),
            "top4_share": float(ranked[:4].sum() / total),
            "top4_words": [list(w) for w in hot],
        })
    return rows


def main(quick: bool = False):
    rows = run(quick=quick)
    print("types,n,paper_batches,redundant,redundant_pct,dense_batches")
    for r in rows:
        print(f"{r['types']},{r['n']},{r['paper_codec_batches']},"
              f"{r['redundant']},{r['redundant_pct']:.1f},"
              f"{r['dense_codec_batches']}")
    meas = run_measured(quick=quick)
    print("p_s,n,batches,observed/possible_words,top1_share,top4_share")
    for m in meas:
        print(f"{m['p_s']},{m['n']},{m['batches']},"
              f"{m['observed_words']}/{m['possible_words']},"
              f"{m['top1_share']:.2f},{m['top4_share']:.2f}")
    return rows


if __name__ == "__main__":
    main()
