"""Beyond-paper: cross-event fusion in the serving engine.

Measures the paper's mechanism applied to LM decoding: a fused k-step
decode program (one composed batch) vs k single-step dispatches, on the
reduced stablelm config.  The win is per-event dispatch + host-sync
elimination plus XLA cross-step optimization — the serving analogue of
Fig 3.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import LM
from repro.serving.engine import ServingEngine

JSON_PATH = Path(__file__).resolve().parents[1] / "BENCH_device_engine.json"


def run(quick: bool = False):
    cfg = get_config("stablelm-12b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    slots = 4
    steps = 32 if quick else 64
    eng = ServingEngine(model, params, max_slots=slots, max_len=256,
                        max_batch_len=8)
    # occupy all slots manually
    for rid in range(slots):
        eng.submit(rid, [3 + rid, 5, 7], max_new_tokens=10 ** 9, at=0.0)
    eng.queue = type(eng.queue)()   # drop events; we drive decode directly
    for rid in range(slots):
        eng.waiting.append(eng.requests[rid])
        eng._h_prefill(None, 0.0, None)

    results = {}
    for k in (1, 2, 4, 8):
        prog = eng._decode_k(k)
        tokens = eng._pending_tokens_default()
        active = eng._active_mask()
        cache, toks = prog(params, eng.cache, tokens, active)  # compile
        jax.block_until_ready(toks)
        reps = max(1, steps // k)
        t0 = time.perf_counter()
        cache = eng.cache
        for _ in range(reps):
            cache, toks = prog(params, cache, tokens, active)
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        results[k] = dt / (reps * k)   # seconds per decoded event
    base = results[1]
    return [{"k": k, "us_per_event": v * 1e6, "speedup_vs_k1": base / v}
            for k, v in sorted(results.items())]


def _merge_into_json(rows):
    """Record the fusion curve next to the engine perf trajectory in
    BENCH_device_engine.json (the one perf file future PRs track)."""
    payload = json.loads(JSON_PATH.read_text()) if JSON_PATH.exists() \
        else {}
    payload["serving_fusion"] = {
        "description": "fused k-step decode program vs k single-step "
                       "dispatches (reduced stablelm config); the "
                       "serving analogue of Fig 3",
        "rows": rows,
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")


def main(quick: bool = False, out: str | None = None):
    rows = run(quick=quick)
    print("fused_k,us_per_decode_event,speedup_vs_single")
    for r in rows:
        print(f"{r['k']},{r['us_per_event']:.1f},{r['speedup_vs_k1']:.2f}")
    if out:
        Path(out).write_text(
            json.dumps({"serving_fusion": rows}, indent=2) + "\n")
        print("wrote", out)
    if quick:
        print("quick mode: not merging into", JSON_PATH.name)
    else:
        _merge_into_json(rows)
        print("merged serving_fusion into", JSON_PATH.name)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=None,
                    help="also write results to this path (CI artifact)")
    args = ap.parse_args()
    main(quick=args.quick, out=args.out)
