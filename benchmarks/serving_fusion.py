"""Beyond-paper: cross-event fusion in the serving engine.

Measures the paper's mechanism applied to LM decoding: a fused k-step
decode program (one composed batch) vs k single-step dispatches, on the
reduced stablelm config.  The win is per-event dispatch + host-sync
elimination plus XLA cross-step optimization — the serving analogue of
Fig 3.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import LM
from repro.serving.engine import ServingEngine


def run(quick: bool = False):
    cfg = get_config("stablelm-12b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    slots = 4
    steps = 32 if quick else 64
    eng = ServingEngine(model, params, max_slots=slots, max_len=256,
                        max_batch_len=8)
    # occupy all slots manually
    for rid in range(slots):
        eng.submit(rid, [3 + rid, 5, 7], max_new_tokens=10 ** 9, at=0.0)
    eng.queue = type(eng.queue)()   # drop events; we drive decode directly
    for rid in range(slots):
        eng.waiting.append(eng.requests[rid])
        eng._h_prefill(None, 0.0, None)

    results = {}
    for k in (1, 2, 4, 8):
        prog = eng._decode_k(k)
        tokens = eng._pending_tokens_default()
        active = eng._active_mask()
        cache, toks = prog(params, eng.cache, tokens, active)  # compile
        jax.block_until_ready(toks)
        reps = max(1, steps // k)
        t0 = time.perf_counter()
        cache = eng.cache
        for _ in range(reps):
            cache, toks = prog(params, cache, tokens, active)
        jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        results[k] = dt / (reps * k)   # seconds per decoded event
    base = results[1]
    return [{"k": k, "us_per_event": v * 1e6, "speedup_vs_k1": base / v}
            for k, v in sorted(results.items())]


def main(quick: bool = False):
    rows = run(quick=quick)
    print("fused_k,us_per_decode_event,speedup_vs_single")
    for r in rows:
        print(f"{r['k']},{r['us_per_event']:.1f},{r['speedup_vs_k1']:.2f}")
    return rows


if __name__ == "__main__":
    main()
