"""Paper Fig. 3: speedup of event batching on the Increment/Set model.

24 configurations in the paper: max batch length × p_s ∈ {5,25,50,75}%.
Here: n ∈ {2, 4, 8} × the four p_s values (the container is a single
CPU core; DESIGN.md §6.4 — ratios are scale-invariant).  Also plots the
analytic bound s_max = n(1-p_I)/(1-p_I^n) (Corollary 1) and reports
measured/s_max.

Compilation is excluded from the timed region (the paper's measurements
are post-compilation runtimes; compile cost is the subject of the
separate compile_times benchmark).

``--device`` runs the same grid on the on-device engine instead of the
host scheduler, with ``--dispatch-mode`` selecting the dispatch path
(DESIGN.md §7) — ``both`` (default) runs masked AND fused on identical
event streams, so the recorded rows are a direct fused-vs-masked
comparison on the Fig-3 workload.  Results merge into
``BENCH_device_engine.json`` under ``poc_speedup_device``.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import numpy as np

from repro import poc
from repro.core import Simulator

JSON_PATH = Path(__file__).resolve().parents[1] / "BENCH_device_engine.json"

# Paper values: 1e6-iteration Increment loops, so handler compute
# dominates per-event dispatch (~60us here) and the measured speedup is
# comparable against the compute-only bound s_max.  quick mode uses a
# smaller loop and reports dispatch-amortization-inflated numbers.
ITERS = 1_000_000
NUM_EVENTS = 256
SEEDS = (0,)


def _run_once(types, mode, max_len, composer_cache=None):
    # NB: registry rebuilt per call so ITERS (global) is honored
    reg = poc.build_registry(iters=ITERS)
    sim = Simulator(reg, max_batch_len=max_len)
    if composer_cache is not None and mode != "unbatched":
        sim.composer = composer_cache.setdefault(
            max_len, sim.composer)
    for t, ty in enumerate(types):
        sim.queue.push(float(t), ty)
    t0 = time.perf_counter()
    state, stats = sim.run(poc.initial_state(), mode=mode)
    jax.block_until_ready(state)
    return time.perf_counter() - t0, int(state), stats


def run(quick: bool = False):
    global ITERS
    lengths = (2, 4) if quick else (2, 4, 8)
    ps_values = (0.25, 0.5) if quick else (0.05, 0.25, 0.5, 0.75)
    num_events = 64 if quick else NUM_EVENTS
    seeds = SEEDS
    iters_saved = ITERS
    if quick:
        ITERS = 100_000
    rows = []
    composer_cache: dict = {}
    for p_s in ps_values:
        for n in lengths:
            speeds = []
            for seed in seeds:
                rng = np.random.default_rng(seed)
                types = [int(x) for x in (rng.random(num_events) < p_s)]
                # warm-up pass compiles every batch program seen
                _run_once(types, "conservative", n, composer_cache)
                _run_once(types, "unbatched", 1)
                t_b, s_b, stats = _run_once(types, "conservative", n,
                                            composer_cache)
                t_u, s_u, _ = _run_once(types, "unbatched", 1)
                assert s_b == s_u == poc.reference_final_sum(types, ITERS)
                speeds.append(t_u / t_b)
            smax = poc.s_max(n, 1.0 - p_s)
            meas = float(np.median(speeds))
            rows.append({
                "p_s": p_s, "n": n, "speedup": meas, "s_max": smax,
                "fraction_of_bound": meas / smax,
            })
    ITERS = iters_saved
    return rows


def run_device(quick: bool = False, dispatch_modes=("masked", "fused"),
               repeats: int = 3):
    """The Fig-3 grid on the on-device engine, per dispatch mode.

    Speedup is batched (max_batch_len = n) over unbatched
    (max_batch_len = 1) with the SAME dispatch mode on the SAME event
    stream, so the batching win is isolated from the dispatch-path
    choice; across modes the batched runtimes themselves compare fused
    vs masked on identical workloads (``fused_over_masked_runtime``).
    """
    from repro.core.program import Config

    # Smaller than the host Fig-3 grid on purpose: the unbatched
    # (n = 1) leg pays one device dispatch per event, so the full host
    # sizes would run for hours; the fused-vs-masked ratio this grid
    # exists for is size-stable well below that.
    iters = 20_000 if quick else 50_000
    lengths = (2, 4) if quick else (2, 4, 8)
    ps_values = (0.25, 0.5) if quick else (0.05, 0.5)
    num_events = 64

    def build(types, n, mode):
        prog = poc.build_program(
            iters=iters,
            config=Config(max_batch_len=n, capacity=num_events + 8),
        )
        for t, ty in enumerate(types):
            prog.schedule(float(t), ("Increment", "Set")[int(ty)])
        return prog.build(backend="device", dispatch_mode=mode)

    def timed(sim, state0):
        sim.run(state0)  # compile
        best = float("inf")
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            r = sim.run(state0)
            jax.block_until_ready(r.state)
            best = min(best, time.perf_counter() - t0)
        return best, r

    rows = []
    for p_s in ps_values:
        rng = np.random.default_rng(SEEDS[0])
        types = [int(x) for x in (rng.random(num_events) < p_s)]
        oracle = poc.reference_final_sum(types, iters)
        for n in lengths:
            batched_t = {}
            for mode in dispatch_modes:
                t1, r1 = timed(build(types, 1, mode),
                               poc.initial_state())
                tn, rn = timed(build(types, n, mode),
                               poc.initial_state())
                assert int(r1.state) == int(rn.state) == oracle
                batched_t[mode] = tn
                smax = poc.s_max(n, 1.0 - p_s)
                rows.append({
                    "dispatch_mode": mode, "p_s": p_s, "n": n,
                    "speedup": t1 / tn, "s_max": smax,
                    "fraction_of_bound": (t1 / tn) / smax,
                    "batched_seconds": tn,
                })
            if "masked" in batched_t and "fused" in batched_t:
                rows[-1]["fused_over_masked_runtime"] = (
                    batched_t["fused"] / batched_t["masked"])
    ratios = [r["fused_over_masked_runtime"] for r in rows
              if "fused_over_masked_runtime" in r]
    return {
        "iters": iters,
        "num_events": num_events,
        "repeats": repeats,
        "rows": rows,
        "median_fused_over_masked_runtime":
            float(np.median(ratios)) if ratios else None,
    }


def _merge_device_into_json(dev):
    payload = json.loads(JSON_PATH.read_text()) if JSON_PATH.exists() \
        else {}
    payload["poc_speedup_device"] = dev
    JSON_PATH.write_text(json.dumps(payload, indent=2) + "\n")


def _print_device(dev):
    print("dispatch_mode,p_s,n,measured_speedup,s_max,fused_over_masked")
    for r in dev["rows"]:
        fm = r.get("fused_over_masked_runtime")
        tail = f"{fm:.3f}" if fm is not None else "-"
        print(f"{r['dispatch_mode']},{r['p_s']},{r['n']},"
              f"{r['speedup']:.3f},{r['s_max']:.3f},{tail}")
    med = dev["median_fused_over_masked_runtime"]
    if med is not None:
        print(f"median fused/masked batched runtime: {med:.3f}x")


def main(quick: bool = False):
    rows = run(quick=quick)
    print("p_s,n,measured_speedup,s_max,fraction_of_bound")
    for r in rows:
        print(f"{r['p_s']},{r['n']},{r['speedup']:.3f},{r['s_max']:.3f},"
              f"{r['fraction_of_bound']:.3f}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--device", action="store_true",
                    help="run the grid on the on-device engine instead "
                         "of the host scheduler")
    ap.add_argument("--dispatch-mode", default="both",
                    choices=["switch", "masked", "fused", "both"],
                    help="device dispatch path; 'both' = masked AND "
                         "fused on identical streams (the recorded "
                         "comparison)")
    ap.add_argument("--out", default=None,
                    help="also write device results to this path")
    args = ap.parse_args()
    if args.device:
        modes = (("masked", "fused") if args.dispatch_mode == "both"
                 else (args.dispatch_mode,))
        dev = run_device(quick=args.quick, dispatch_modes=modes)
        _print_device(dev)
        if args.out:
            Path(args.out).write_text(
                json.dumps({"poc_speedup_device": dev}, indent=2) + "\n")
        if args.quick:
            print("quick mode: not merging into", JSON_PATH.name)
        else:
            _merge_device_into_json(dev)
            print("merged poc_speedup_device into", JSON_PATH.name)
    else:
        main(quick=args.quick)
