"""Paper Fig. 3: speedup of event batching on the Increment/Set model.

24 configurations in the paper: max batch length × p_s ∈ {5,25,50,75}%.
Here: n ∈ {2, 4, 8} × the four p_s values (the container is a single
CPU core; DESIGN.md §6.4 — ratios are scale-invariant).  Also plots the
analytic bound s_max = n(1-p_I)/(1-p_I^n) (Corollary 1) and reports
measured/s_max.

Compilation is excluded from the timed region (the paper's measurements
are post-compilation runtimes; compile cost is the subject of the
separate compile_times benchmark).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro import poc
from repro.core import Simulator

# Paper values: 1e6-iteration Increment loops, so handler compute
# dominates per-event dispatch (~60us here) and the measured speedup is
# comparable against the compute-only bound s_max.  quick mode uses a
# smaller loop and reports dispatch-amortization-inflated numbers.
ITERS = 1_000_000
NUM_EVENTS = 256
SEEDS = (0,)


def _run_once(types, mode, max_len, composer_cache=None):
    # NB: registry rebuilt per call so ITERS (global) is honored
    reg = poc.build_registry(iters=ITERS)
    sim = Simulator(reg, max_batch_len=max_len)
    if composer_cache is not None and mode != "unbatched":
        sim.composer = composer_cache.setdefault(
            max_len, sim.composer)
    for t, ty in enumerate(types):
        sim.queue.push(float(t), ty)
    t0 = time.perf_counter()
    state, stats = sim.run(poc.initial_state(), mode=mode)
    jax.block_until_ready(state)
    return time.perf_counter() - t0, int(state), stats


def run(quick: bool = False):
    global ITERS
    lengths = (2, 4) if quick else (2, 4, 8)
    ps_values = (0.25, 0.5) if quick else (0.05, 0.25, 0.5, 0.75)
    num_events = 64 if quick else NUM_EVENTS
    seeds = SEEDS
    iters_saved = ITERS
    if quick:
        ITERS = 100_000
    rows = []
    composer_cache: dict = {}
    for p_s in ps_values:
        for n in lengths:
            speeds = []
            for seed in seeds:
                rng = np.random.default_rng(seed)
                types = [int(x) for x in (rng.random(num_events) < p_s)]
                # warm-up pass compiles every batch program seen
                _run_once(types, "conservative", n, composer_cache)
                _run_once(types, "unbatched", 1)
                t_b, s_b, stats = _run_once(types, "conservative", n,
                                            composer_cache)
                t_u, s_u, _ = _run_once(types, "unbatched", 1)
                assert s_b == s_u == poc.reference_final_sum(types, ITERS)
                speeds.append(t_u / t_b)
            smax = poc.s_max(n, 1.0 - p_s)
            meas = float(np.median(speeds))
            rows.append({
                "p_s": p_s, "n": n, "speedup": meas, "s_max": smax,
                "fraction_of_bound": meas / smax,
            })
    ITERS = iters_saved
    return rows


def main(quick: bool = False):
    rows = run(quick=quick)
    print("p_s,n,measured_speedup,s_max,fraction_of_bound")
    for r in rows:
        print(f"{r['p_s']},{r['n']},{r['speedup']:.3f},{r['s_max']:.3f},"
              f"{r['fraction_of_bound']:.3f}")
    return rows


if __name__ == "__main__":
    main()
