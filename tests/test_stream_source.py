"""Arrival sources: determinism, seek, grid exactness, trace round-trip.

The streaming determinism contract (DESIGN.md §10) rests entirely on
sources being bit-reproducible: checkpoint/resume stores only a row
CURSOR, and the closed-vs-open equivalence proof pre-seeds the same
rows the stream delivers.  These tests pin that contract at the source
layer, before any engine is involved.
"""

import numpy as np
import pytest

from repro.stream import (
    ArrivalSource,
    BurstySource,
    DiurnalSource,
    PoissonSource,
    TraceReader,
    TraceWriter,
    source_events,
)
from repro.stream.source import EMIT_WIDTH


def _materialize(source):
    """All real rows of a source, concatenated (row-exact view)."""
    out = [b[b[:, 1] >= 0] for b in source.blocks()]
    return (np.concatenate(out) if out
            else np.zeros((0, EMIT_WIDTH), np.float32))


SOURCES = {
    "poisson": lambda n, **kw: PoissonSource(2.0, n, **kw),
    "bursty": lambda n, **kw: BurstySource(8.0, 0.5, 5, n, **kw),
    "diurnal": lambda n, **kw: DiurnalSource(2.0, n, period=16.0, **kw),
}


@pytest.mark.parametrize("kind", sorted(SOURCES))
def test_source_protocol_and_shape(kind):
    src = SOURCES[kind](37, seed=3, block_size=8)
    assert isinstance(src, ArrivalSource)
    assert len(src) == 37
    blocks = list(src.blocks())
    assert len(blocks) == 5  # ceil(37 / 8)
    for b in blocks:
        assert b.shape == (8, EMIT_WIDTH)
        assert b.dtype == np.float32
    rows = _materialize(src)
    assert rows.shape == (37, EMIT_WIDTH)
    # padding only in the final block, as a suffix
    tail = blocks[-1]
    real = tail[:, 1] >= 0
    assert real.sum() == 37 - 4 * 8
    assert not real[int(real.sum()):].any()
    # default arg0 is the global row index (the shard-routing slot)
    np.testing.assert_array_equal(rows[:, 2], np.arange(37, dtype=np.float32))


@pytest.mark.parametrize("kind", sorted(SOURCES))
def test_source_deterministic_and_block_size_invariant(kind):
    """Same seed -> bit-identical rows, twice over AND across different
    block sizes (chunked-identically-from-row-0 generation makes the
    block size a packaging detail, not part of the stream identity)."""
    a = _materialize(SOURCES[kind](50, seed=7, block_size=8))
    b = _materialize(SOURCES[kind](50, seed=7, block_size=8))
    c = _materialize(SOURCES[kind](50, seed=7, block_size=17))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)
    d = _materialize(SOURCES[kind](50, seed=8, block_size=8))
    assert not np.array_equal(a, d)


@pytest.mark.parametrize("kind", sorted(SOURCES))
@pytest.mark.parametrize("cursor", [0, 1, 8, 13, 49, 50])
def test_seek_equals_slice(kind, cursor):
    """blocks() after seek(c) delivers exactly rows c.. of the full
    stream — the checkpoint-resume identity."""
    full = _materialize(SOURCES[kind](50, seed=5, block_size=8))
    src = SOURCES[kind](50, seed=5, block_size=8)
    src.seek(cursor)
    rest = _materialize(src)
    np.testing.assert_array_equal(rest, full[cursor:])


def test_seek_validation():
    src = PoissonSource(1.0, 10)
    with pytest.raises(ValueError):
        src.seek(-1)
    with pytest.raises(ValueError):
        src.seek(11)


@pytest.mark.parametrize("kind", sorted(SOURCES))
def test_times_nondecreasing(kind):
    rows = _materialize(SOURCES[kind](200, seed=11, block_size=32))
    t = rows[:, 0]
    assert (np.diff(t) >= 0).all()


@pytest.mark.parametrize("kind", sorted(SOURCES))
def test_grid_times_exact_and_strictly_increasing(kind):
    """grid= snaps every time to an exact f32 multiple of the step and
    keeps the stream strictly increasing (each gap rounds to >= 1
    step) — the property the serving scenario's cross-backend f32
    parity relies on."""
    rows = _materialize(SOURCES[kind](200, seed=11, grid=0.25,
                                      block_size=32))
    t = rows[:, 0].astype(np.float64)
    steps = t / 0.25
    np.testing.assert_array_equal(steps, np.round(steps))
    assert (np.diff(t) > 0).all()


def test_bursty_gap_structure():
    """Burst members are tightly spaced; burst boundaries carry the
    idle gap (in expectation — check medians, not tails)."""
    rows = _materialize(BurstySource(100.0, 0.1, 10, 400, seed=1,
                                     block_size=64))
    gaps = np.diff(rows[:, 0].astype(np.float64))
    idx = np.arange(1, 400)
    boundary = (idx % 10) == 0
    assert np.median(gaps[boundary]) > 10 * np.median(gaps[~boundary])


def test_ctor_validation():
    with pytest.raises(ValueError):
        PoissonSource(0.0, 10)
    with pytest.raises(ValueError):
        PoissonSource(1.0, -1)
    with pytest.raises(ValueError):
        PoissonSource(1.0, 10, block_size=0)
    with pytest.raises(ValueError):
        PoissonSource(1.0, 10, grid=-0.5)
    with pytest.raises(ValueError):
        BurstySource(1.0, 1.0, 0, 10)
    with pytest.raises(ValueError):
        DiurnalSource(1.0, 10, amplitude=1.0)


def test_arg_fn_shape_enforced():
    src = PoissonSource(1.0, 10, block_size=4,
                        arg_fn=lambda g: np.ones((len(g), 2)))
    with pytest.raises(ValueError, match="arg_fn"):
        list(src.blocks())


def test_trace_round_trip(tmp_path):
    """writer -> reader is row-exact, including partial final blocks,
    mismatched writer/reader block sizes, and metadata."""
    path = str(tmp_path / "t.trace")
    src = BurstySource(8.0, 0.5, 5, 43, seed=9, block_size=8)
    with TraceWriter(path, meta={"kind": "bursty", "seed": 9}) as w:
        for b in src.blocks():
            w.write_block(b)
    rd = TraceReader(path, block_size=16)
    assert isinstance(rd, ArrivalSource)
    assert len(rd) == 43
    assert rd.meta["kind"] == "bursty"
    np.testing.assert_array_equal(_materialize(rd), _materialize(src))
    # seek on the reader too
    rd.seek(20)
    np.testing.assert_array_equal(_materialize(rd), _materialize(src)[20:])


def test_trace_reader_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.trace"
    bad.write_bytes(b"not a trace at all" + b"\x00" * 300)
    with pytest.raises(ValueError, match="not a repro trace"):
        TraceReader(str(bad))


def test_trace_reader_rejects_truncation(tmp_path):
    path = str(tmp_path / "t.trace")
    src = PoissonSource(2.0, 20, seed=1, block_size=8)
    with TraceWriter(path) as w:
        for b in src.blocks():
            w.write_block(b)
    data = open(path, "rb").read()
    open(path, "wb").write(data[:-8])  # chop the last rows
    with pytest.raises(ValueError, match="truncated"):
        TraceReader(str(path))


def test_source_events_matches_blocks():
    src = PoissonSource(2.0, 15, seed=4, grid=0.25, block_size=4)
    evs = source_events(src)
    rows = _materialize(src)
    assert len(evs) == 15
    for ev, row in zip(evs, rows):
        assert ev[0] == float(row[0])
        assert ev[1] == int(row[1])
        assert ev[2] == tuple(float(x) for x in row[2:])
