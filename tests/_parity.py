"""Shared cross-backend parity harness.

One SimProgram definition must run unmodified on every runtime with
bit-identical final state and identical normalized stats — the
executable contract of `repro.api`.  This module is the ONE place that
knows the backend matrix and the assertion set; parity suites
(`test_simprogram_parity.py`, `test_serving_scenarios.py`,
`test_sharded_engine.py`) pick backends from it instead of copying the
assertions, and a new backend (e.g. the sharded device engine) joins
every suite by registering one entry here.

Groups:

* ``ALL_BACKENDS`` — label -> ``SimProgram.build`` kwargs for every
  runtime, including the sharded device engine at 2 and 4 shards.
* ``BATCHED`` — the runtimes sharing the §III-B window rule, which
  must therefore agree on the BATCH COUNT too.  The sharded engine
  belongs here: each super-step reconstructs the exact single-queue
  window (DESIGN.md §5.1), so even its batch grouping is identical.
  ``unbatched``/``speculative`` group differently and stay out.

The ``device/masked`` and ``device/fused*`` entries pin the dispatch
specialization contract (DESIGN.md §7): all three dispatch modes run
the identical handler sequence with the identical emit layout, so they
are full BATCHED members — bit-identical state AND batch counts, with
the sharded entry exercising fused dispatch under the split window.
"""

import numpy as np

ALL_BACKENDS = {
    "host/conservative": dict(backend="host", scheduler="conservative"),
    "host/speculative": dict(backend="host", scheduler="speculative"),
    "host/unbatched": dict(backend="host", scheduler="unbatched"),
    "device/tiered3": dict(backend="device", queue_mode="tiered3"),
    "device/tiered": dict(backend="device", queue_mode="tiered"),
    "device/flat": dict(backend="device", queue_mode="flat"),
    "device/reference": dict(backend="device", queue_mode="reference"),
    "device/tiered3-2shard": dict(backend="device", shards=2),
    "device/tiered3-4shard": dict(backend="device", shards=4),
    "device/masked": dict(backend="device", dispatch_mode="masked"),
    "device/fused": dict(backend="device", dispatch_mode="fused"),
    "device/fused-2shard": dict(
        backend="device", shards=2, dispatch_mode="fused"),
}

BATCHED = (
    "host/conservative",
    "device/tiered3",
    "device/tiered",
    "device/flat",
    "device/reference",
    "device/tiered3-2shard",
    "device/tiered3-4shard",
    "device/masked",
    "device/fused",
    "device/fused-2shard",
)

# The streaming axis (DESIGN.md §10): runtimes that accept
# ``run(arrivals=...)``.  A streamed run must be bit-identical to
# pre-seeding the same trace (seq reservation makes the absorbed
# arrivals occupy the exact (time, seq) lex rank the pre-seeded events
# would), so these labels join `assert_parity` against a CLOSED
# ``host/unbatched`` base — but NOT the batch-count check: absorption
# happens at segment boundaries, so batch grouping may differ from the
# closed run even though the executed event sequence is identical.
# Device streaming requires tiered3 (the only queue family with a
# fence-bounded extract); the single-queue entries rely on the default
# ``queue_kernels="xla"`` (the pallas extract has no lex bound).
STREAM_BACKENDS = {
    "host/unbatched+stream": dict(backend="host", scheduler="unbatched"),
    "host/conservative+stream": dict(
        backend="host", scheduler="conservative"),
    "device/tiered3+stream": dict(backend="device", queue_mode="tiered3"),
    "device/masked+stream": dict(backend="device", dispatch_mode="masked"),
    "device/fused+stream": dict(backend="device", dispatch_mode="fused"),
    "device/tiered3-2shard+stream": dict(backend="device", shards=2),
    "device/fused-2shard+stream": dict(
        backend="device", shards=2, dispatch_mode="fused"),
}

# The resume axis: device runtimes whose interrupted-then-resumed runs
# must be bit-identical to a straight run (segmented execution carries
# the whole loop state through the checkpoint, so this holds by
# construction — these labels prove it across queue mode × dispatch
# mode × shard count).  Host backends have no checkpoint driver.
RESUME_BACKENDS = (
    "device/tiered3",
    "device/flat",
    "device/masked",
    "device/fused",
    "device/tiered3-2shard",
    "device/fused-2shard",
)


def run_all(build_program, state0, *, backends=None, run_kw=None):
    """Build the program per backend and run it; label -> RunResult.

    ``build_program`` is a zero-arg callable returning a fresh
    SimProgram (a program freezes on first build, so each backend gets
    its own instance).  ``backends`` restricts/overrides the matrix
    (label -> build kwargs); ``run_kw`` is forwarded to every run.
    """
    backends = ALL_BACKENDS if backends is None else backends
    run_kw = run_kw or {}
    return {
        label: build_program().build(**kw).run(state0, **run_kw)
        for label, kw in backends.items()
    }


def assert_parity(results, *, base="host/unbatched", batched=None,
                  expect_dropped=0):
    """Every backend agrees with ``base`` on final state (bit-exact,
    every pytree leaf), executed-event count, ``dropped``, and
    ``final_time`` (as f32 — the cross-backend grid contract); the
    batched runtimes additionally agree on the batch count.

    ``batched`` defaults to the ``BATCHED`` members present in
    ``results``; ``expect_dropped=None`` skips the exact-drop check
    (overflow scenarios assert equality only).
    """
    import jax

    base_res = results[base]
    for label, res in results.items():
        for leaf_base, leaf in zip(
            jax.tree_util.tree_leaves(base_res.state),
            jax.tree_util.tree_leaves(res.state),
        ):
            np.testing.assert_array_equal(
                np.asarray(leaf), np.asarray(leaf_base), err_msg=label
            )
        assert res.events == base_res.events, label
        assert res.dropped == base_res.dropped, label
        if expect_dropped is not None:
            assert res.dropped == expect_dropped, label
        assert np.float32(res.final_time) == np.float32(
            base_res.final_time), label
    if batched is None:
        batched = [k for k in BATCHED if k in results]
    batch_counts = {results[k].batches for k in batched}
    assert len(batch_counts) <= 1, batch_counts


def queue_flat_view(result):
    """``(times, types, seqs)`` of the residual pending set, as numpy.

    Normalizes every device queue family (flat, tiered, tiered3,
    sharded) to the LIVE entries in ``(time, seq)`` order, so residual
    queues compare bit-exactly across resume boundaries and across
    physical layouts (a 2-shard queue and a single queue holding the
    same pending set produce identical views).
    """
    q = result.raw["final_queue"]
    name = type(q).__name__
    if name == "ShardedQueue":
        from repro.core.sharded import sharded_queue_to_flat
        q = sharded_queue_to_flat(q)
    elif name == "Tiered3DeviceQueue":
        from repro.core.queue import tiered3_queue_to_flat
        q = tiered3_queue_to_flat(q)
    elif name == "TieredDeviceQueue":
        from repro.core.queue import tiered_queue_to_flat
        q = tiered_queue_to_flat(q)
    times = np.asarray(q.times)
    types = np.asarray(q.types)
    seqs = np.asarray(q.seqs)
    live = types >= 0
    times, types, seqs = times[live], types[live], seqs[live]
    order = np.lexsort((seqs, times))
    return (times[order], types[order], seqs[order])


def assert_resume_parity(straight, resumed, *, label=""):
    """An interrupted-then-resumed run must be BIT-IDENTICAL to the
    straight run: state leaves, executed/batch/drop counters, final
    time, and the residual pending set (times, types, seqs)."""
    import jax

    for leaf_s, leaf_r in zip(
        jax.tree_util.tree_leaves(straight.state),
        jax.tree_util.tree_leaves(resumed.state),
    ):
        np.testing.assert_array_equal(
            np.asarray(leaf_r), np.asarray(leaf_s), err_msg=label
        )
    assert resumed.events == straight.events, label
    assert resumed.batches == straight.batches, label
    assert resumed.dropped == straight.dropped, label
    assert np.float32(resumed.final_time) == np.float32(
        straight.final_time), label
    for got, want in zip(queue_flat_view(resumed),
                         queue_flat_view(straight)):
        np.testing.assert_array_equal(got, want, err_msg=label)


def run_interrupted_then_resumed(sim, state0, *, tmpdir,
                                 max_batches, checkpoint_every,
                                 crash_at_segment, run_kw=None):
    """Drive ``sim`` segmented, crash it at ``crash_at_segment`` via the
    injection seam, then resume from the latest checkpoint; returns the
    resumed RunResult.  Raises if the crash never fires (the run ended
    before the target segment — a miswired scenario, not a pass)."""
    from repro.testing.faults import SimulatedCrash

    run_kw = run_kw or {}
    fired = []

    def hook(seg, state, queue, stats):
        if seg == crash_at_segment:
            fired.append(seg)
            raise SimulatedCrash(f"injected crash at segment {seg}")
        return None

    try:
        sim.run(state0, max_batches=max_batches,
                checkpoint_every=checkpoint_every, checkpoint_dir=tmpdir,
                _segment_hook=hook, **run_kw)
    except SimulatedCrash:
        pass
    assert fired, (
        f"crash segment {crash_at_segment} never reached "
        f"(run finished early — lower crash_at_segment)"
    )
    return sim.run(state0, max_batches=max_batches,
                   checkpoint_every=checkpoint_every, checkpoint_dir=tmpdir,
                   resume_from="latest", **run_kw)
