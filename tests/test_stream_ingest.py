"""Streaming ingest: absorb correctness under near-full occupancy,
feeder mechanics, and the backpressure axis.

Three layers:

* **Queue ops** — differential churn driving
  ``tiered3_queue_absorb_rows(insert=)`` and
  ``tiered3_queue_fill_rows_tagged`` with bursty arrival blocks against
  a numpy ``(time, seq)``-sorted model at >= 90% occupancy, interleaved
  with (optionally fence-bounded) extraction — the exact shapes the
  streamed admission path produces, including prefix ``[lo, hi)``
  partial-block absorption and spill-style masked rows.
* **Feeder** — block delivery, seek/cursor mechanics, producer-side
  validation (nondecreasing times, shape), prefetch-off equivalence.
* **Engine** — the backpressure trio on a wedged topology (capacity
  full of far-future events): ``shed`` counts and completes, ``error``
  raises ``ingest_stall`` immediately, ``block`` stalls into
  ``FAULT_INGEST`` after the idle-round detector fires.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.api import Config, EngineFaultError, SimProgram
from repro.core.queue import (
    tiered3_queue_absorb_rows,
    tiered3_queue_extract,
    tiered3_queue_fill_rows,
    tiered3_queue_fill_rows_tagged,
    tiered3_queue_init,
    tiered3_queue_occupancy,
    tiered3_queue_to_flat,
)
from repro.core.validate import FAULT_INGEST
from repro.stream import BurstySource, PoissonSource, StreamFeeder
from repro.stream.source import EMIT_WIDTH

CAP = 32
SEQ0 = 1000  # arrival seq reservation base (past every seeded seq)

@jax.jit
def _extract_plain(q, la):
    return tiered3_queue_extract(q, 4, la)


@jax.jit
def _extract_bound(q, la, bound):
    return tiered3_queue_extract(q, 4, la, bound=bound)


def _canonical(q):
    flat = tiered3_queue_to_flat(q)
    times = np.asarray(flat.times)
    types = np.asarray(flat.types)
    args = np.asarray(flat.args)
    seqs = np.asarray(flat.seqs)
    occ = types >= 0
    order = np.lexsort((seqs[occ], times[occ]))
    return (times[occ][order], types[occ][order], args[occ][order],
            seqs[occ][order])


def _model_sorted(model):
    """model rows sorted by (time, seq); returns (times, types, args,
    seqs) arrays."""
    if not model:
        z = np.zeros(0, np.float32)
        return z, z.astype(np.int32), np.zeros((0, EMIT_WIDTH - 2),
                                               np.float32), \
            np.zeros(0, np.int32)
    arr = sorted(model, key=lambda r: (r[0], r[1]))
    times = np.array([r[0] for r in arr], np.float32)
    seqs = np.array([r[1] for r in arr], np.int32)
    types = np.array([r[2] for r in arr], np.int32)
    args = np.stack([r[3] for r in arr]).astype(np.float32)
    return times, types, args, seqs


def _assert_matches(q, model, expect_next_seq, msg):
    times, types, args, seqs = _model_sorted(model)
    qt, qy, qa, qs = _canonical(q)
    np.testing.assert_array_equal(qt, times, err_msg=msg)
    np.testing.assert_array_equal(qy, types, err_msg=msg)
    np.testing.assert_array_equal(qa, args, err_msg=msg)
    np.testing.assert_array_equal(qs, seqs, err_msg=msg)
    assert int(q.size) == len(model), msg
    assert int(q.dropped) == 0, msg
    assert int(q.next_seq) == expect_next_seq, msg


def _seed_queue(front_cap, stage_cap, num_runs, n_seed=29):
    """A near-full queue (n_seed of CAP slots) with grid-timed seeds."""
    q = tiered3_queue_init(CAP, front_cap=front_cap, stage_cap=stage_cap,
                           num_runs=num_runs)
    seed_src = PoissonSource(2.0, n_seed, seed=99, grid=0.25, block_size=64)
    rows = np.concatenate([b for b in seed_src.blocks()])[:n_seed]
    for s in range(0, n_seed, stage_cap):  # fill_rows takes <= stage_cap
        q = tiered3_queue_fill_rows(q, jnp.asarray(rows[s:s + stage_cap]))
    model = [(float(r[0]), i, int(r[1]), np.array(r[2:], np.float32))
             for i, r in enumerate(rows)]
    return q, model


def _absorb_churn(seed, front_cap, stage_cap, num_runs, steps=40):
    rng = np.random.default_rng(seed)
    q, model = _seed_queue(front_cap, stage_cap, num_runs)
    next_seq = len(model)
    src = BurstySource(8.0, 0.5, 5, 400, seed=seed, grid=0.25,
                       block_size=16)
    blocks = src.blocks()
    block = next(blocks)
    block_start, off = 0, 0
    la = jnp.asarray([0.5], jnp.float32)
    peak = len(model)

    def absorb(q, rows, seqs, lo, hi):
        idx = jnp.arange(rows.shape[0], dtype=jnp.int32)
        return tiered3_queue_absorb_rows(
            q, rows, seqs, insert=(idx >= lo) & (idx < hi))

    absorb = jax.jit(absorb)
    for step in range(steps):
        msg = f"seed {seed} step {step}"
        real_n = int((np.asarray(block)[:, 1] >= 0).sum())
        free = CAP - len(model)
        k = min(real_n - off, free, int(rng.integers(1, 7)))
        if k > 0:
            seqs = SEQ0 + block_start + np.arange(16, dtype=np.int32)
            q = absorb(q, jnp.asarray(block), jnp.asarray(seqs),
                       jnp.int32(off), jnp.int32(off + k))
            for j in range(off, off + k):
                r = np.asarray(block)[j]
                model.append((float(r[0]), SEQ0 + block_start + j,
                              int(r[1]), np.array(r[2:], np.float32)))
            next_seq = max(next_seq, SEQ0 + block_start + off + k)
            off += k
            if off == real_n:
                try:
                    block = next(blocks)
                    block_start += 16
                    off = 0
                except StopIteration:
                    block = None
        peak = max(peak, len(model))
        if rng.random() < 0.6 and model:
            bound = None
            mt, _, _, ms = _model_sorted(model)
            if rng.random() < 0.5 and len(model) > 3:
                j = int(rng.integers(1, len(model)))
                bound = (jnp.float32(mt[j]), jnp.int32(ms[j]))
            if bound is None:
                q, ts, tys, args, n_pop = _extract_plain(q, la)
            else:
                q, ts, tys, args, n_pop = _extract_bound(q, la, bound)
            n_pop = int(n_pop)
            ts, tys = np.asarray(ts)[:n_pop], np.asarray(tys)[:n_pop]
            # popped batch is the lex prefix of the pending set
            np.testing.assert_array_equal(ts, mt[:n_pop], err_msg=msg)
            if bound is not None:
                bt, bs = float(bound[0]), int(bound[1])
                for t, s in zip(ts, ms[:n_pop]):
                    assert (float(t), int(s)) < (bt, bs), msg
            model = sorted(model, key=lambda r: (r[0], r[1]))[n_pop:]
        _assert_matches(q, model, next_seq, msg)
        assert int(tiered3_queue_occupancy(q)) == len(model), msg
        if block is None:
            break
    assert peak >= int(0.9 * CAP), f"seed {seed}: churn never got near-full"


def test_absorb_churn_smoke():
    """One tiny-tier churn in the fast lane; the full config sweep and
    the hypothesis property run in the slow/full jobs."""
    _absorb_churn(0, 6, 4, 1, steps=25)


# Tiny tiers force the rare paths (run-pool exhaustion, staged flush)
# under absorbed-arrival keys OLDER than already-queued seqs.
@pytest.mark.slow
@pytest.mark.parametrize("front_cap,stage_cap,num_runs", [
    (4, 5, 2), (8, 16, 2),
])
@pytest.mark.parametrize("seed", [0, 1])
def test_absorb_churn_fixed_cases(seed, front_cap, stage_cap, num_runs):
    _absorb_churn(seed, front_cap, stage_cap, num_runs)


@given(
    seed=st.integers(0, 10_000),
    front_cap=st.integers(4, 12),
    stage_cap=st.integers(4, 16),
    num_runs=st.integers(1, 3),
)
@settings(max_examples=15, deadline=None)
def test_property_absorb_churn(seed, front_cap, stage_cap, num_runs):
    """Property form of the near-full absorb churn (bursty blocks,
    prefix masks, fence-bounded extraction) across random tier shapes."""
    _absorb_churn(seed, front_cap, stage_cap, num_runs)


def test_fill_rows_tagged_masked_rows_ignored():
    """fill_rows_tagged with an insert mask (the sharded routing/spill
    shape): masked rows leave content AND counters untouched."""
    q, model = _seed_queue(6, 8, 2, n_seed=20)
    src = BurstySource(8.0, 0.5, 5, 16, seed=3, grid=0.25, block_size=16)
    rows = next(src.blocks())
    seqs = SEQ0 + np.arange(16, dtype=np.int32)
    insert = np.zeros(16, bool)
    insert[2:9] = True
    for s in range(0, 16, 8):  # tagged fill takes <= stage_cap rows
        q = tiered3_queue_fill_rows_tagged(
            q, jnp.asarray(rows[s:s + 8]), jnp.asarray(seqs[s:s + 8]),
            jnp.asarray(insert[s:s + 8]))
    for j in range(2, 9):
        r = np.asarray(rows)[j]
        model.append((float(r[0]), SEQ0 + j, int(r[1]),
                      np.array(r[2:], np.float32)))
    _assert_matches(q, model, SEQ0 + 9, "tagged masked")


# -- feeder -------------------------------------------------------------------

class _ListSource:
    """Minimal ArrivalSource over explicit blocks (adversarial inputs)."""

    def __init__(self, blocks, n=None):
        self._blocks = [np.asarray(b, np.float32) for b in blocks]
        self.block_size = self._blocks[0].shape[0] if blocks else 0
        self.n = (sum(int((b[:, 1] >= 0).sum()) for b in self._blocks)
                  if n is None else n)
        self._cursor = 0

    def __len__(self):
        return self.n

    def seek(self, cursor):
        self._cursor = cursor

    def blocks(self):
        # honor seek only block-aligned: the feeder always seeks to a
        # cursor it reached by consuming, which this test respects
        skip = self._cursor
        for b in self._blocks:
            real = int((b[:, 1] >= 0).sum())
            if skip >= real:
                skip -= real
                continue
            yield b[skip:] if skip == 0 else np.concatenate(
                [b[skip:], np.full((skip, EMIT_WIDTH), -1.0, np.float32)])
            skip = 0


def _block(times, bs=4):
    b = np.zeros((bs, EMIT_WIDTH), np.float32)
    b[:, 1] = -1.0
    for i, t in enumerate(times):
        b[i, 0] = t
        b[i, 1] = 0.0
        b[i, 2] = i
    return b


def test_feeder_keys_and_advance():
    src = _ListSource([_block([1.0, 2.0, 3.0, 4.0]), _block([5.0, 6.0])])
    f = StreamFeeder(src, 10, prefetch=False)
    try:
        assert f.has_pending()
        assert f.next_key() == (1.0, 10)
        assert f.admissible(3.0) == 3   # times <= t_end, active block
        rows, seqs, off = f.device_block()
        assert off == 0
        np.testing.assert_array_equal(np.asarray(seqs), 10 + np.arange(4))
        f.advance(2)
        assert f.next_key() == (3.0, 12)
        f.advance(2)
        # crossed into block 2
        assert f.next_key() == (5.0, 14)
        assert f.admissible(np.inf) == 2
        f.advance(2)
        assert not f.has_pending()
        assert f.next_key() == (float("inf"), 2**31 - 1)
        assert f.admissible(np.inf) == 0
    finally:
        f.close()


def test_feeder_host_slice():
    src = _ListSource([_block([1.0, 2.0, 3.0, 4.0])])
    f = StreamFeeder(src, 5, prefetch=False, to_device=False)
    try:
        f.next_key()  # load the block before committing consumption
        f.advance(1)
        rows, seqs = f.host_slice(2)
        np.testing.assert_array_equal(rows[:, 0], [2.0, 3.0])
        np.testing.assert_array_equal(seqs, [6, 7])
    finally:
        f.close()


def test_feeder_rejects_decreasing_times():
    src = _ListSource([_block([1.0, 2.0, 3.0, 4.0]), _block([3.5, 6.0])])
    f = StreamFeeder(src, 0, prefetch=False, to_device=False)
    try:
        f.next_key()
        f.advance(4)
        with pytest.raises(ValueError, match="nondecreasing"):
            f.next_key()
    finally:
        f.close()


def test_feeder_rejects_bad_shape():
    src = _ListSource([np.zeros((4, 3), np.float32)], n=4)
    f = StreamFeeder(src, 0, prefetch=False, to_device=False)
    try:
        with pytest.raises(ValueError):
            f.next_key()
    finally:
        f.close()


def test_feeder_rejects_real_row_past_declared_n():
    src = _ListSource([_block([1.0, 2.0, 3.0, 4.0])], n=2)
    f = StreamFeeder(src, 0, prefetch=False, to_device=False)
    try:
        with pytest.raises(ValueError, match="real row"):
            f.next_key()
    finally:
        f.close()


def test_feeder_prefetch_thread_surfaces_errors():
    src = _ListSource([_block([1.0, 2.0, 3.0, 4.0]), _block([3.5, 6.0])])
    f = StreamFeeder(src, 0, prefetch=True, to_device=False)
    try:
        f.next_key()
        f.advance(4)
        with pytest.raises(ValueError, match="nondecreasing"):
            f.next_key()
    finally:
        f.close()


# -- engine backpressure ------------------------------------------------------

def _wedged_prog(cap=8):
    """Queue pre-filled to capacity with far-future events: no arrival
    can ever be absorbed, and (under the fence) no event can run."""
    p = SimProgram("wedge", config=Config(
        max_batch_len=4, capacity=cap, max_emit=1))

    @p.handler("EV", lookahead=0.25)
    def ev(state, t, arg):
        return state + 1

    for i in range(cap):
        p.schedule(1000.0 + 0.25 * i, "EV")
    return p


def _arrivals(n=4):
    return PoissonSource(4.0, n, grid=0.25, type_id=0, block_size=4)


def test_backpressure_shed_completes():
    sim = _wedged_prog().build(backend="device", validate="cheap")
    res = sim.run(jnp.int32(0), arrivals=_arrivals(), backpressure="shed",
                  max_batches=20)
    assert res.shed == 4
    assert res.ingested == 4       # consumed from the source, then shed
    assert res.events == 8         # the fence lifts once the stream dries
    assert int(res.state) == 8


def test_backpressure_error_raises_ingest_stall():
    sim = _wedged_prog().build(backend="device", validate="cheap")
    with pytest.raises(EngineFaultError, match="ingest_stall") as ei:
        sim.run(jnp.int32(0), arrivals=_arrivals(), backpressure="error",
                max_batches=20)
    assert ei.value.fault_word & FAULT_INGEST


def test_backpressure_block_stalls_into_fault():
    """block: the run waits for capacity that can never free (the fence
    holds every far-future event behind the unabsorbed arrival), so the
    idle-round detector converts the wedge into FAULT_INGEST instead of
    spinning forever."""
    sim = _wedged_prog().build(backend="device", validate="cheap")
    with pytest.raises(EngineFaultError, match="ingest_stall"):
        sim.run(jnp.int32(0), arrivals=_arrivals(), backpressure="block",
                max_batches=20)


def test_backpressure_block_waits_for_capacity():
    """block with a drainable queue: arrivals wait, capacity frees, and
    every arrival is eventually absorbed (nothing shed or lost)."""
    p = SimProgram("drain", config=Config(
        max_batch_len=2, capacity=4, max_emit=1))

    @p.handler("EV", lookahead=0.25)
    def ev(state, t, arg):
        return state + 1

    for i in range(4):
        p.schedule(0.25 * i, "EV")
    sim = p.build(backend="device", validate="cheap")
    src = PoissonSource(1.0, 6, grid=0.25, t0=0.25, type_id=0,
                        block_size=4)
    res = sim.run(jnp.int32(0), arrivals=src, max_batches=100)
    assert res.shed == 0
    assert res.ingested == 6
    assert res.events == 10
    assert res.pending == 0


def test_sync_feed_matches_prefetch():
    """_stream_prefetch=False (synchronous staging) is bit-identical —
    prefetch is a latency optimization, never a semantic one."""
    from repro.testing.faults import tiny_phold

    def go(prefetch):
        src = PoissonSource(2.0, 24, grid=0.25, type_id=0, block_size=8)
        sim = tiny_phold(capacity=64).build(backend="device")
        return sim.run(jnp.int32(0), max_batches=40, arrivals=src,
                       _stream_prefetch=prefetch)

    a, b = go(True), go(False)
    assert int(a.state) == int(b.state)
    assert a.events == b.events
    assert a.ingested == b.ingested
    assert np.float32(a.final_time) == np.float32(b.final_time)
