"""Differential suites for the Pallas front-tier queue kernels.

The ``queue_kernels="pallas"`` paths must be BIT-IDENTICAL to the XLA
tiered3 paths (which the reference-queue suites already pin), so every
assertion here is ``assert_array_equal`` on every queue field — no
tolerances.  Kernels run in interpret mode on CPU (the repo-wide
Pallas idiom, see repro/kernels/ops.py), so these are exact semantics
tests of the kernel bodies; the fast cases run in the CI fast lane,
the full-capacity sweeps are ``slow``.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.queue import (
    tiered3_queue_extract,
    tiered3_queue_fill_rows,
    tiered3_queue_fill_rows_tagged,
    tiered3_queue_init,
    tiered3_queue_peek_front,
    window_prefix_mask,
)
from repro.kernels.queue_front import front_merge, window_extract

from repro import poc
from repro.core.program import Config


def _assert_queues_equal(qa, qb, msg=""):
    for f in qa._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(qa, f)), np.asarray(getattr(qb, f)),
            err_msg=f"{msg} field {f}",
        )


def _rand_rows(rng, n, num_types, arg_width, t_hi=10.0):
    t = rng.uniform(0, t_hi, n).astype(np.float32)
    ty = rng.integers(-1, num_types, n).astype(np.float32)
    a = rng.uniform(0, 1, (n, arg_width)).astype(np.float32)
    return jnp.asarray(np.concatenate([t[:, None], ty[:, None], a], axis=1))


def _run_differential(front_cap, stage_cap, capacity, *, steps, R, k,
                      seed, t_cap=8.0):
    """Drive identical random fill/extract streams through the XLA and
    Pallas paths and assert bit-equality after every operation."""
    rng = np.random.default_rng(seed)
    la = jnp.asarray([0.5, 1.0, 0.25], jnp.float32)
    W = 6
    qx = qp = tiered3_queue_init(
        capacity, front_cap=front_cap, stage_cap=stage_cap, arg_width=W
    )
    for step in range(steps):
        rows = _rand_rows(rng, R, la.shape[0], W)
        qx = tiered3_queue_fill_rows(qx, rows)
        qp = tiered3_queue_fill_rows(qp, rows, kernels="pallas")
        _assert_queues_equal(qx, qp, f"fill step {step}")
        if step % 3 == 2:
            cap = None if step % 2 else t_cap
            qx, ts1, ty1, a1, l1 = tiered3_queue_extract(qx, k, la, cap)
            qp, ts2, ty2, a2, l2 = tiered3_queue_extract(
                qp, k, la, cap, kernels="pallas"
            )
            np.testing.assert_array_equal(np.asarray(ts1), np.asarray(ts2))
            np.testing.assert_array_equal(np.asarray(ty1), np.asarray(ty2))
            np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
            assert int(l1) == int(l2)
            _assert_queues_equal(qx, qp, f"extract step {step}")


def test_fill_extract_differential_small():
    _run_differential(16, 8, 64, steps=30, R=6, k=4, seed=0)


def test_fill_extract_differential_tiny_front():
    # front_cap == k: every extract drains the front, exercising the
    # refill + shift edge where length == front occupancy.
    _run_differential(4, 8, 64, steps=24, R=4, k=4, seed=1)


def test_tagged_fill_differential():
    """The sharded insert path (caller-supplied seqs + survive mask)."""
    rng = np.random.default_rng(2)
    la = jnp.asarray([0.5, 1.0], jnp.float32)
    W = 6
    qx = qp = tiered3_queue_init(64, front_cap=16, stage_cap=8, arg_width=W)
    next_seq = 0
    for step in range(20):
        rows = _rand_rows(rng, 5, la.shape[0], W)
        seqs = jnp.asarray(
            next_seq + np.arange(5, dtype=np.int32), jnp.int32
        )
        next_seq += 5
        insert = jnp.asarray(rng.random(5) < 0.8)
        qx = tiered3_queue_fill_rows_tagged(qx, rows, seqs, insert)
        qp = tiered3_queue_fill_rows_tagged(
            qp, rows, seqs, insert, kernels="pallas"
        )
        _assert_queues_equal(qx, qp, f"tagged step {step}")


def test_window_extract_matches_reference_rule():
    """window_extract's take rule vs the shared window_prefix_mask spec
    applied to the same peeked front."""
    rng = np.random.default_rng(3)
    la = jnp.asarray([0.5, 1.0, 0.25], jnp.float32)
    W, k = 6, 4
    q = tiered3_queue_init(64, front_cap=16, stage_cap=8, arg_width=W)
    for _ in range(6):
        q = tiered3_queue_fill_rows(q, _rand_rows(rng, 6, 3, W))
    q, ts_c, tys_c, args_c, _ = tiered3_queue_peek_front(q, k)

    valid = tys_c >= 0
    lavec = la[jnp.clip(tys_c, 0, 2)]
    wins = jnp.where(valid, ts_c + lavec, jnp.inf)
    take = window_prefix_mask(ts_c, wins, valid, 5.0)

    ts, tys, args, length, *_ = window_extract(
        q.f_times, q.f_types, q.f_args, q.f_seqs, la, 5.0, k=k
    )
    np.testing.assert_array_equal(
        np.asarray(ts), np.asarray(jnp.where(take, ts_c, 0.0))
    )
    np.testing.assert_array_equal(
        np.asarray(tys), np.asarray(jnp.where(take, tys_c, 0))
    )
    np.testing.assert_array_equal(
        np.asarray(args), np.asarray(jnp.where(take[:, None], args_c, 0.0))
    )
    assert int(length) == int(jnp.sum(take))


def test_front_merge_empty_and_full_masks():
    """Degenerate masks: no row bound for the front, and all rows."""
    W, F, R = 6, 8, 4
    q = tiered3_queue_init(32, front_cap=F, stage_cap=8, arg_width=W)
    rng = np.random.default_rng(4)
    q = tiered3_queue_fill_rows(q, _rand_rows(rng, 4, 2, W, t_hi=4.0))

    t_r = jnp.asarray([1.0, 2.0, 3.0, 4.0], jnp.float32)
    ty_r = jnp.asarray([0, 1, 0, 1], jnp.int32)
    arg_r = jnp.zeros((R, W), jnp.float32)
    seq_r = jnp.asarray([100, 101, 102, 103], jnp.int32)

    for mask in (jnp.zeros((R,), bool), jnp.ones((R,), bool)):
        got = front_merge(
            q.f_times, q.f_types, q.f_args, q.f_seqs, q.front_n,
            t_r, ty_r, arg_r, seq_r, mask,
        )
        # XLA oracle: the _tiered_fill_finish front-merge block.
        from repro.core.queue import _I32_MAX, _small_lex_perm

        perm = _small_lex_perm(
            jnp.where(mask, t_r, jnp.inf),
            jnp.where(mask, seq_r, _I32_MAX),
        )
        rt = jnp.where(mask, t_r, jnp.inf)[perm]
        older = jnp.minimum(
            jnp.searchsorted(q.f_times, rt, side="right").astype(jnp.int32),
            q.front_n,
        )
        FE = F + R
        pos = jnp.where(
            mask[perm], older + jnp.arange(R, dtype=jnp.int32), FE + R
        )
        i_idx = jnp.arange(FE, dtype=jnp.int32)
        ins_before = jnp.searchsorted(pos, i_idx, side="left").astype(
            jnp.int32
        )
        is_ins = (
            jnp.searchsorted(pos, i_idx, side="right").astype(jnp.int32)
            > ins_before
        )
        src = jnp.where(
            is_ins, FE + jnp.clip(ins_before, 0, R - 1),
            jnp.clip(i_idx - ins_before, 0, FE - 1),
        )

        def fmerge(col, rcol, fill):
            ext = jnp.concatenate(
                [col, jnp.full((R,) + col.shape[1:], fill, col.dtype),
                 rcol]
            )
            return jnp.take(ext, src, axis=0)

        np.testing.assert_array_equal(
            np.asarray(got[0]), np.asarray(fmerge(q.f_times, rt, jnp.inf))
        )
        np.testing.assert_array_equal(
            np.asarray(got[1]),
            np.asarray(fmerge(q.f_types, ty_r[perm], -1)),
        )
        np.testing.assert_array_equal(
            np.asarray(got[2]),
            np.asarray(fmerge(q.f_args, arg_r[perm], 0.0)),
        )
        np.testing.assert_array_equal(
            np.asarray(got[3]),
            np.asarray(fmerge(q.f_seqs, seq_r[perm], 2**31 - 1)),
        )


def test_engine_pallas_parity_poc():
    """Whole-run parity: DeviceEngine(queue_kernels='pallas') vs XLA."""
    types = [0, 1, 0, 0, 1, 1, 0, 0, 1]

    def build():
        prog = poc.build_program(iters=64, config=Config(max_batch_len=3))
        for t, ty in enumerate(types):
            prog.schedule(float(t), ("Increment", "Set")[ty])
        return prog

    base = build().build(backend="device").run(poc.initial_state())
    pal = build().build(
        backend="device", queue_kernels="pallas"
    ).run(poc.initial_state())
    assert int(pal.state) == int(base.state)
    assert pal.batches == base.batches
    assert pal.events == base.events
    assert np.float32(pal.final_time) == np.float32(base.final_time)
    assert int(base.state) == poc.reference_final_sum(types, 64)


def test_pallas_requires_tiered3():
    prog = poc.build_program(iters=4)
    prog.schedule(0.0, "Increment")
    with pytest.raises(ValueError, match="pallas"):
        prog.build(backend="device", queue_mode="flat",
                   queue_kernels="pallas")


@pytest.mark.slow
@pytest.mark.parametrize("seed", [10, 11])
def test_fill_extract_differential_full_capacity(seed):
    """Full-size front/stage tiers under overflow pressure — the
    eviction, preflush, and refill paths all fire.

    Runs in a fresh interpreter: the interpret-mode sweep is sensitive
    to state a long pytest session accumulates (observed as a rare
    segfault only when run after the full suite; standalone it passes
    reliably), and isolation also keeps a crash from taking the whole
    session down with it.
    """
    import os
    import subprocess
    import sys

    here = os.path.dirname(os.path.abspath(__file__))
    src = os.path.join(os.path.dirname(here), "src")
    script = (
        f"import sys; sys.path.insert(0, {here!r});"
        "from test_queue_kernels import _run_differential;"
        f"_run_differential(64, 32, 256, steps=60, R=24, k=8, "
        f"seed={seed}, t_cap=50.0)"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True)
    assert res.returncode == 0, \
        f"sweep subprocess exited {res.returncode}:\n{res.stderr[-3000:]}"


@pytest.mark.slow
def test_engine_pallas_parity_poc_long():
    rng = np.random.default_rng(12)
    types = list((rng.random(200) < 0.3).astype(int))

    def build(**kw):
        prog = poc.build_program(iters=16, config=Config(max_batch_len=4))
        for t, ty in enumerate(types):
            prog.schedule(float(t), ("Increment", "Set")[ty])
        return prog.build(backend="device", capacity=512, **kw)

    base = build().run(poc.initial_state())
    pal = build(queue_kernels="pallas").run(poc.initial_state())
    assert int(pal.state) == int(base.state)
    assert pal.batches == base.batches
    assert int(base.state) == poc.reference_final_sum(types, 16)
