"""End-to-end tests of the DES core on the paper's PoC model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import poc
from repro.core import (
    DeviceEngine,
    EventRegistry,
    HostEventQueue,
    Simulator,
    emits_events,
    extract_window,
    run_unbatched,
)

ITERS = 64  # small loop for tests; still > 32 so the closed form saturates


def make_sim(**kw):
    reg = poc.build_registry(iters=ITERS)
    return Simulator(reg, **kw)


def schedule_all(sim, types):
    for t, ty in enumerate(types):
        sim.queue.push(float(t), int(ty))


TYPES_MIXED = [poc.INCREMENT, poc.SET, poc.INCREMENT, poc.INCREMENT,
               poc.SET, poc.SET, poc.INCREMENT]


@pytest.mark.parametrize("mode", ["conservative", "speculative", "unbatched"])
@pytest.mark.parametrize("codec", ["dense", "paper"])
def test_host_modes_match_oracle(mode, codec):
    sim = make_sim(max_batch_len=3, codec=codec)
    schedule_all(sim, TYPES_MIXED)
    state, stats = sim.run(poc.initial_state(), mode=mode)
    assert int(state) == poc.reference_final_sum(TYPES_MIXED, ITERS)
    assert stats.events_executed == len(TYPES_MIXED)
    if mode != "unbatched":
        # infinite-lookahead PoC events -> all batches are maximal
        assert stats.batches_executed == -(-len(TYPES_MIXED) // 3)


def test_batched_equals_unbatched_random():
    rng = np.random.default_rng(0)
    types = [int(t) for t in (rng.random(40) < 0.4).astype(int)]
    sim_b = make_sim(max_batch_len=4)
    schedule_all(sim_b, types)
    sb, _ = sim_b.run(poc.initial_state(), mode="conservative")
    sim_u = make_sim(max_batch_len=4)
    schedule_all(sim_u, types)
    su, _ = sim_u.run(poc.initial_state(), mode="unbatched")
    assert int(sb) == int(su) == poc.reference_final_sum(types, ITERS)


def test_lookahead_window_limits_batch():
    """Events outside the dynamic lookahead window must not be batched."""
    reg = EventRegistry()
    log = []

    def h(state, t, arg):
        return state + 1

    reg.register("A", h, lookahead=1.5)  # t_max = t_first + 1.5
    reg.freeze()
    q = HostEventQueue()
    for t in [0.0, 1.0, 2.0, 3.0]:
        q.push(t, 0)
    batch = extract_window(q, reg, max_len=4)
    # e@0 -> t_max = 0+1.5 = 1.5; e@1 <= 1.5 extracted (t_max stays 1.5);
    # e@2 > 1.5 closes the window.
    assert [ev.time for ev in batch] == [0.0, 1.0]
    del log


def test_emitted_events_are_scheduled():
    """Self-scheduling handler: each A at t emits another A at t+2."""
    reg = EventRegistry()

    @emits_events
    def a(state, t, arg):
        return state + 1, [(2.0, 0, None)]

    reg.register("A", a, lookahead=2.0)
    sim = Simulator(reg, max_batch_len=2)
    sim.queue.push(0.0, 0)
    state, stats = sim.run(jnp.int32(0), max_events=5)
    assert int(state) == 5
    assert stats.final_time == 8.0  # 0,2,4,6,8


def test_causality_check_fires():
    from repro.core.scheduler import ConservativeScheduler

    reg = EventRegistry()

    @emits_events
    def bad(state, t, arg):
        return state, [(-5.0, 0, None)]  # violates its declared lookahead

    reg.register("Bad", bad, lookahead=10.0)
    sim = Simulator(reg, max_batch_len=2)
    sched = ConservativeScheduler(sim.registry, sim.composer, check_causality=True)
    q = HostEventQueue()
    q.push(0.0, 0)
    q.push(1.0, 0)
    with pytest.raises(RuntimeError, match="causality"):
        sched.run(jnp.int32(0), q)


def test_speculative_rollback_matches_sequential():
    """A model where speculation must roll back: event B emits an event
    that lands between already-extracted events."""
    reg = EventRegistry()

    @emits_events
    def emitter(state, t, arg):
        # emits at +0.5: inside the next integer slot
        return state * 2 + 1, [(0.5, 1, None)]

    def absorber(state, t, arg):
        return state * 3

    reg.register("E", emitter, lookahead=0.5)
    reg.register("Ab", absorber, lookahead=10.0)

    def build_queue():
        q = HostEventQueue()
        q.push(0.0, 0)
        q.push(1.0, 1)
        q.push(2.0, 1)
        return q

    sim = Simulator(reg, max_batch_len=3)
    from repro.core.scheduler import SpeculativeScheduler, run_unbatched

    spec = SpeculativeScheduler(sim.registry, sim.composer)
    s_spec, st_spec = spec.run(jnp.int32(0), build_queue(), max_events=16)
    s_seq, _ = run_unbatched(sim.registry, jnp.int32(0), build_queue(),
                             max_events=16)
    assert int(s_spec) == int(s_seq)


def test_speculative_violation_predicate_regression():
    """Regression for the or/and-precedence bug in the violation check:
    an emission landing strictly inside the executed window (anchored at
    the EMITTING event, not the batch end) must trigger a rollback, and
    the result must match sequential execution even when handlers do not
    commute."""
    from repro.core.scheduler import SpeculativeScheduler, run_unbatched

    reg = EventRegistry()

    @emits_events
    def emitter(state, t, arg):
        # lands at t+0.5, i.e. before the later events in the batch
        return state * 2 + 1, [(0.5, 1, None)]

    def absorber(state, t, arg):
        return state * 3 + 1  # deliberately does NOT commute with emitter

    reg.register("E", emitter, lookahead=0.5)
    reg.register("Ab", absorber, lookahead=10.0)

    def build_queue():
        q = HostEventQueue()
        q.push(0.0, 0)
        q.push(1.0, 1)
        q.push(2.0, 1)
        return q

    sim = Simulator(reg, max_batch_len=3)
    spec = SpeculativeScheduler(sim.registry, sim.composer)
    s_spec, stats = spec.run(jnp.int32(0), build_queue(), max_events=16)
    s_seq, _ = run_unbatched(sim.registry, jnp.int32(0), build_queue(),
                             max_events=16)
    assert int(s_spec) == int(s_seq)
    # the old predicate (batch_end + delay < batch_end) could never fire
    assert stats.rollbacks == 1


def test_conservative_emissions_anchor_at_emitting_event():
    """Batched and unbatched execution must schedule emissions at the
    same absolute time (emitter's timestamp + delay), regardless of how
    events were grouped into batches."""
    from repro.core.scheduler import run_unbatched

    reg = EventRegistry()

    @emits_events
    def emitter(state, t, arg):
        return state * 2 + 1, [(3.0, 1, None)]

    def absorber(state, t, arg):
        return state * 3 + 1

    reg.register("E", emitter, lookahead=3.0)
    reg.register("Ab", absorber, lookahead=10.0)

    def fill(q):
        q.push(0.0, 0)
        q.push(2.0, 1)
        return q

    sim = Simulator(reg, max_batch_len=2)
    fill(sim.queue)
    s_cons, stats = sim.run(jnp.int32(0), mode="conservative", max_events=8)
    s_seq, _ = run_unbatched(reg, jnp.int32(0), fill(HostEventQueue()),
                             max_events=8)
    # batch [E@0, Ab@2] emits at 0+3=3 (not batch_end 2+3=5); the
    # emitted Ab@3 runs after Ab@2 either way, but only event-anchored
    # times make final_time match sequential execution.
    assert int(s_cons) == int(s_seq)
    assert stats.final_time == 3.0


def test_eager_composer_precompiles_all():
    reg = poc.build_registry(iters=ITERS)
    sim = Simulator(
        reg,
        max_batch_len=2,
        codec="dense",
        composer="eager",
        state_spec=jax.ShapeDtypeStruct((), jnp.uint32),
        arg_spec=None,
    )
    assert sim.composer.num_composed == 2 + 4  # Σ^1 + Σ^2
    schedule_all(sim, TYPES_MIXED)
    state, _ = sim.run(poc.initial_state(), mode="conservative")
    assert int(state) == poc.reference_final_sum(TYPES_MIXED, ITERS)


# ---------------------------------------------------------------------------
# On-device engine
# ---------------------------------------------------------------------------

def test_device_engine_poc_matches_oracle():
    reg = poc.build_registry(iters=ITERS)
    eng = DeviceEngine(reg, max_batch_len=3, capacity=64)
    types = TYPES_MIXED
    queue = eng.initial_queue([(float(t), ty, None) for t, ty in enumerate(types)])
    state, queue, stats = eng.run(poc.initial_state(), queue)
    assert int(state) == poc.reference_final_sum(types, ITERS)
    assert int(stats["events"]) == len(types)
    assert int(stats["batches"]) == -(-len(types) // 3)
    assert int(queue.size) == 0


def test_device_engine_emitting_handlers():
    """On-device self-scheduling: A at t emits A at t+2, runs to budget."""
    from repro.core.events import ARG_WIDTH

    reg = EventRegistry()

    @emits_events
    def a(state, t, arg):
        emit = jnp.zeros((1, 2 + ARG_WIDTH), jnp.float32)
        emit = emit.at[0, 0].set(t + 2.0).at[0, 1].set(0.0)
        return state + 1, emit

    reg.register("A", a, lookahead=2.0)
    eng = DeviceEngine(reg, max_batch_len=2, capacity=32, max_emit=1)
    queue = eng.initial_queue([(0.0, 0, None)])
    state, queue, stats = eng.run(jnp.int32(0), queue, max_batches=5)
    assert int(state) == 5
    assert float(stats["time"]) == 8.0


def test_device_engine_respects_lookahead():
    """Two-type model where the window closes after 2 events."""
    reg = EventRegistry()
    reg.register("Short", lambda s, t, a: s + 1, lookahead=1.0)
    reg.register("Long", lambda s, t, a: s + 100, lookahead=100.0)
    eng = DeviceEngine(reg, max_batch_len=4, capacity=32)
    # events at t=0 (Short, la=1) -> window closes at 1.0; t=2 not batched
    queue = eng.initial_queue([(0.0, 0, None), (0.5, 1, None), (2.0, 1, None)])
    state, queue, stats = eng.run(jnp.int32(0), queue)
    assert int(state) == 201
    assert int(stats["batches"]) == 2  # [Short,Long] then [Long]
