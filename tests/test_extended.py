"""Extended coverage: MoE equivalences, device-engine properties,
grad-compression collective, serving splice correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro import poc
from repro.core import DeviceEngine, EventRegistry, Simulator, emits_events


# ---------------------------------------------------------------------------
# MoE: grouped-capacity vs dense-combine equivalence when nothing drops
# ---------------------------------------------------------------------------

def test_moe_grouped_matches_dense_when_dropless():
    from repro.models.moe import moe_apply, moe_apply_dense, moe_init

    E, K, D, F = 4, 2, 32, 16
    key = jax.random.PRNGKey(0)
    params = moe_init(key, d_model=D, d_ff_expert=F, num_experts=E)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, D),
                          jnp.float32).astype(jnp.bfloat16)
    # capacity_factor = E/K => capacity == tokens => no drops
    y_cap, aux = moe_apply(params, x, num_experts=E, top_k=K,
                           capacity_factor=float(E) / K, group_size=16)
    y_dense = moe_apply_dense(params, x, num_experts=E, top_k=K)
    np.testing.assert_allclose(
        np.asarray(y_cap, np.float32), np.asarray(y_dense, np.float32),
        rtol=0.06, atol=0.06)
    assert jnp.isfinite(aux)


def test_moe_group_size_invariance_when_dropless():
    from repro.models.moe import moe_apply, moe_init

    E, K, D, F = 4, 2, 16, 8
    params = moe_init(jax.random.PRNGKey(0), d_model=D, d_ff_expert=F,
                      num_experts=E)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, D)).astype(
        jnp.bfloat16)
    outs = [
        moe_apply(params, x, num_experts=E, top_k=K,
                  capacity_factor=float(E) / K, group_size=g)[0]
        for g in (8, 16, 32, 64)
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0], np.float32),
                                   np.asarray(o, np.float32),
                                   rtol=0.05, atol=0.05)


def test_moe_capacity_drops_are_bounded():
    """With tight capacity, output norm shrinks but stays finite."""
    from repro.models.moe import moe_apply, moe_init

    E, K, D, F = 4, 2, 16, 8
    params = moe_init(jax.random.PRNGKey(0), d_model=D, d_ff_expert=F,
                      num_experts=E)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, D)).astype(
        jnp.bfloat16)
    y, aux = moe_apply(params, x, num_experts=E, top_k=K,
                       capacity_factor=0.5, group_size=64)
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
    assert float(aux) > 0


# ---------------------------------------------------------------------------
# Device engine properties
# ---------------------------------------------------------------------------

@given(
    p_set=st.floats(0.0, 1.0),
    n=st.integers(1, 4),
    num_events=st.integers(1, 24),
)
@settings(max_examples=10, deadline=None)
def test_device_engine_matches_host_property(p_set, n, num_events):
    rng = np.random.default_rng(int(p_set * 100) + n)
    types = [int(x) for x in (rng.random(num_events) < p_set)]
    reg = poc.build_registry(iters=40)
    sim = Simulator(reg, max_batch_len=n)
    for t, ty in enumerate(types):
        sim.queue.push(float(t), ty)
    s_host, _ = sim.run(poc.initial_state(), mode="conservative")

    reg2 = poc.build_registry(iters=40)
    eng = DeviceEngine(reg2, max_batch_len=n, capacity=num_events + 4)
    q = eng.initial_queue([(float(t), ty, None)
                           for t, ty in enumerate(types)])
    s_dev, _, stats = eng.run(poc.initial_state(), q)
    assert int(s_host) == int(s_dev)
    assert int(stats["events"]) == num_events


def test_device_engine_t_end():
    reg = EventRegistry()
    reg.register("A", lambda s, t, a: s + 1, lookahead=0.5)
    eng = DeviceEngine(reg, max_batch_len=2, capacity=16, t_end=3.5)
    q = eng.initial_queue([(float(t), 0, None) for t in range(10)])
    s, _, stats = eng.run(jnp.int32(0), q)
    # events at t=0..3 processed; window closes after t_end
    assert int(s) >= 4


def test_device_queue_fifo_ties():
    """Events with identical timestamps run in insertion order."""
    from repro.core.queue import (device_queue_init, device_queue_pop,
                                  device_queue_push)

    q = device_queue_init(8)
    for i in range(4):
        q = device_queue_push(q, 1.0, i, jnp.zeros((4,)))
    order = []
    for _ in range(4):
        q, t, ty, _ = device_queue_pop(q)
        order.append(int(ty))
    assert order == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# Gradient compression inside shard_map (the real collective path)
# ---------------------------------------------------------------------------

def test_compressed_psum_under_shard_map():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.training.compression import compressed_psum_gradients

    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jnp.arange(8, dtype=jnp.float32) / 7.0}

    def f(g):
        return compressed_psum_gradients(g, mesh, ("data",))

    out = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P())(grads)
    err = jnp.abs(out["w"] - grads["w"])
    assert float(err.max()) < 1e-2  # int8 quantization error bound


# ---------------------------------------------------------------------------
# Serving cache splice
# ---------------------------------------------------------------------------

def test_serving_prefill_splice_isolates_slots():
    """Prefilling slot 1 must not perturb slot 0's cache."""
    from repro.configs import get_config
    from repro.models import LM
    from repro.serving.engine import ServingEngine

    cfg = get_config("stablelm-12b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_slots=2, max_len=64,
                        max_batch_len=2)
    eng.submit(0, [1, 2, 3], 4, at=0.0)
    eng.waiting.append(eng.requests[0])
    eng._h_prefill(None, 0.0, None)
    snap = jax.tree.map(lambda x: np.asarray(x).copy(),
                        eng.cache["stages"])
    eng.submit(1, [4, 5], 4, at=0.0)
    eng.waiting.append(eng.requests[1])
    eng._h_prefill(None, 0.0, None)

    def check(before, after):
        if before.ndim >= 2:  # [L, B, ...]: slot 0 rows must be equal
            np.testing.assert_array_equal(before[:, 0],
                                          np.asarray(after)[:, 0])

    jax.tree.map(check, snap, eng.cache["stages"])


# ---------------------------------------------------------------------------
# vocab padding
# ---------------------------------------------------------------------------

def test_padded_vocab_logits_masked():
    from repro.configs import get_config
    from repro.models import LM
    import dataclasses

    cfg = dataclasses.replace(
        get_config("granite-moe-1b-a400m").reduced(), vocab_size=250)
    assert cfg.padded_vocab == 256
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.zeros((1, 8), jnp.int32)
    logits, _ = model.forward(params, tokens=tokens)
    assert logits.shape[-1] == 256
    # padded ids can never win an argmax
    assert bool(jnp.all(logits[..., 250:] < -1e29))
