"""Codec unit + property tests (paper §III-A, §IV.C)."""

import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.codec import (
    DenseCodec,
    PaperCodec,
    dense_batch_count,
    paper_batch_count,
    redundant_batch_count,
)


def test_paper_batch_count_matches_paper_example():
    # §IV.A: 2 event types, max length 2 -> (1-3^3)/(1-3) - 1 = 12 batches.
    assert paper_batch_count(2, 2) == 12


def test_redundant_count_matches_paper_example():
    # §IV.C quotes "9331 batches (i.e., 58%) are redundant" for |Σ|=5,
    # n=5 — but the paper's own formula
    #   ((1-(|Σ|+1)^{n+1})/(1-(|Σ|+1)) - 1) - ((1-|Σ|^{n+1})/(1-|Σ|) - 1)
    # evaluates to 5425, and 5425/9330 = 58.1% matches the quoted
    # percentage.  9331 is the total word count *including ε*; we
    # reproduce the formula (and the 58%), noting the paper's 9331 as a
    # typo (see EXPERIMENTS.md).
    total = paper_batch_count(5, 5)
    assert total == 9330
    assert redundant_batch_count(5, 5) == 5425
    assert round(redundant_batch_count(5, 5) / total * 100) == 58


@pytest.mark.parametrize("codec_cls", [PaperCodec, DenseCodec])
@pytest.mark.parametrize("num_types,max_len", [(2, 2), (3, 4), (5, 3), (1, 5)])
def test_encode_decode_roundtrip_exhaustive(codec_cls, num_types, max_len):
    codec = codec_cls(num_types, max_len)
    seen = {}
    import itertools

    for k in range(1, max_len + 1):
        for word in itertools.product(range(num_types), repeat=k):
            code = codec.encode(word)
            assert codec.decode(code) == list(word)
            assert code not in seen, f"collision {word} vs {seen[code]}"
            seen[code] = word


def test_dense_ids_contiguous_and_complete():
    codec = DenseCodec(3, 3)
    assert codec.num_batches == 3 + 9 + 27
    words = dict(codec.enumerate_words())
    assert sorted(words) == list(range(codec.num_batches))
    # Every decoded word re-encodes to its id (bijection).
    for code, word in words.items():
        assert codec.encode(word) == code


def test_paper_codec_redundancy_is_real():
    """ν-containing codes decode to the same word as some ν-free code."""
    codec = PaperCodec(1, 2)  # Σ={a}: words ν, a, νν, νa, aν, aa -> B=6
    assert codec.num_batches == 6
    decoded = [codec.decode(c) for c in codec.enumerate_codes()]
    # 'a' appears under more than one code (the paper's aν/νa example).
    assert sum(1 for w in decoded if w == [0]) > 1


def test_horner_execution_order():
    """First event of the batch must be the first handler applied
    (paper Alg. 1 appends handlers from the least significant digit)."""
    for codec in (PaperCodec(3, 4), DenseCodec(3, 4)):
        word = [2, 0, 1, 1]
        assert codec.decode(codec.encode(word)) == word


@given(
    num_types=st.integers(1, 6),
    max_len=st.integers(1, 5),
    data=st.data(),
)
@settings(max_examples=200, deadline=None)
def test_property_roundtrip(num_types, max_len, data):
    k = data.draw(st.integers(1, max_len))
    word = data.draw(
        st.lists(st.integers(0, num_types - 1), min_size=k, max_size=k)
    )
    for codec in (PaperCodec(num_types, max_len), DenseCodec(num_types, max_len)):
        code = codec.encode(word)
        assert codec.decode(code) == word
        if isinstance(codec, DenseCodec):
            assert 0 <= code < codec.num_batches
        else:
            assert 1 <= code <= codec.num_batches


@given(
    num_types=st.integers(1, 5),
    max_len=st.integers(1, 4),
    data=st.data(),
)
@settings(max_examples=100, deadline=None)
def test_property_jnp_encode_matches_python(num_types, max_len, data):
    k = data.draw(st.integers(1, max_len))
    word = data.draw(
        st.lists(st.integers(0, num_types - 1), min_size=k, max_size=k)
    )
    padded = jnp.zeros((max_len,), jnp.int32).at[: len(word)].set(
        jnp.asarray(word, jnp.int32)
    )
    for codec in (PaperCodec(num_types, max_len), DenseCodec(num_types, max_len)):
        jcode = int(codec.encode_jnp(padded, jnp.int32(len(word))))
        assert jcode == codec.encode(word)


def test_geometric_sum_base_one():
    assert dense_batch_count(1, 7) == 7


@given(num_types=st.integers(1, 8), data=st.data())
@settings(max_examples=150, deadline=None)
def test_property_max_arity_words(num_types, data):
    """Edge words at the full batch arity (len == max_len) — the last
    Horner 'digit block'.  Their codes must fill exactly the top
    num_types^max_len slots of the dense space (the fused-dispatch slot
    table indexes straight into this layout)."""
    max_len = data.draw(st.integers(1, 5))
    word = data.draw(
        st.lists(st.integers(0, num_types - 1),
                 min_size=max_len, max_size=max_len)
    )
    codec = DenseCodec(num_types, max_len)
    code = codec.encode(word)
    assert codec.decode(code) == word
    shorter = dense_batch_count(num_types, max_len - 1) if max_len > 1 else 0
    assert shorter <= code < codec.num_batches
    assert codec.num_batches - shorter == num_types ** max_len
    # Padding beyond `length` must not perturb the jnp encode.
    padded = jnp.full((max_len,), num_types - 1, jnp.int32)
    padded = padded.at[:max_len].set(jnp.asarray(word, jnp.int32))
    assert int(codec.encode_jnp(padded, jnp.int32(max_len))) == code


@given(
    num_types=st.integers(1, 8),
    max_len=st.integers(1, 6),
    data=st.data(),
)
@settings(max_examples=150, deadline=None)
def test_property_single_type_words(num_types, max_len, data):
    """Edge words built from one repeated type ([t]*k) — the words the
    poc/phold hot sets are made of.  Round-trip through both codecs and
    pin that distinct (t, k) pairs never collide in the dense space."""
    t = data.draw(st.integers(0, num_types - 1))
    k = data.draw(st.integers(1, max_len))
    word = [t] * k
    dense = DenseCodec(num_types, max_len)
    paper = PaperCodec(num_types, max_len)
    dcode = dense.encode(word)
    assert dense.decode(dcode) == word
    assert paper.decode(paper.encode(word)) == word
    # Injectivity over the whole single-type family.
    codes = {
        dense.encode([ty] * n)
        for ty in range(num_types)
        for n in range(1, max_len + 1)
    }
    assert len(codes) == num_types * max_len
