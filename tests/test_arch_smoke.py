"""Per-architecture smoke tests (brief requirement f).

Each assigned architecture is instantiated at its REDUCED config (same
family: same mixer kinds, MoE/MLA/SSM structure, pattern) and runs
1) a forward pass, 2) one train step (loss + grad), 3) a prefill +
decode step when the arch supports decode — all on CPU, asserting
output shapes and absence of NaNs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.models import LM

ARCHS = [
    "stablelm-12b", "llama3-405b", "minicpm-2b", "phi4-mini-3.8b",
    "jamba-1.5-large-398b", "granite-moe-1b-a400m", "deepseek-v2-lite-16b",
    "rwkv6-1.6b", "hubert-xlarge", "qwen2-vl-72b",
]

B, T = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(
            ks[0], (B, T, cfg.d_model), jnp.float32) * 0.02
    else:
        batch["tokens"] = jax.random.randint(
            ks[0], (B, T), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(ks[1], (B, T), 0, cfg.vocab_size)
    if cfg.m_rope:
        pos = jnp.arange(T, dtype=jnp.int32)[None, :].repeat(B, 0)
        batch["positions"] = jnp.broadcast_to(pos[None], (3, B, T))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = LM(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux = jax.jit(model.forward)(
        params, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        positions=batch.get("positions"))
    assert logits.shape == (B, T, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), (
        f"{arch}: non-finite grads")


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if get_config(a).supports_decode])
def test_prefill_then_decode(arch):
    cfg = get_config(arch).reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T),
                                0, cfg.vocab_size)
    if cfg.input_mode == "embeds":
        embeds = jax.random.normal(jax.random.PRNGKey(2),
                                   (B, T, cfg.d_model)) * 0.02
        logits_last, cache = jax.jit(
            lambda p, e: model.prefill(p, embeds=e, max_len=T + 4)
        )(params, embeds)
    else:
        logits_last, cache = jax.jit(
            lambda p, t: model.prefill(p, tokens=t, max_len=T + 4)
        )(params, tokens)
    assert logits_last.shape == (B, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits_last)))
    assert int(cache["lengths"][0]) == T

    nxt = jnp.argmax(logits_last, axis=-1).astype(jnp.int32)[:, None]
    logits, cache = jax.jit(model.decode_step)(params, cache, nxt)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert int(cache["lengths"][0]) == T + 1


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if get_config(a).supports_decode])
def test_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce full-forward logits.

    This is the strongest cross-check of cache correctness: run T tokens
    through decode_step one at a time and compare the final-position
    logits against forward() on the full sequence.
    """
    cfg = get_config(arch).reduced()
    model = LM(cfg, attn_impl="reference")
    params = model.init(jax.random.PRNGKey(0))
    Td = 8
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, Td),
                                0, cfg.vocab_size)
    full_logits, _ = model.forward(params, tokens=tokens)

    cache = model.init_cache(B, Td + 1)
    step = jax.jit(model.decode_step)
    for t in range(Td):
        logits, cache = step(params, cache, tokens[:, t:t + 1])
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full_logits[:, -1]),
        rtol=0.08, atol=0.08,
    )


def test_param_count_sane():
    """Analytic param counts are within a few % of the advertised size
    for the dense archs (used by the 6ND roofline)."""
    expect = {
        "llama3-405b": 405e9,
        "qwen2-vl-72b": 72e9,
        "stablelm-12b": 12e9,
        "phi4-mini-3.8b": 3.8e9,
        "minicpm-2b": 2.4e9,
        "rwkv6-1.6b": 1.6e9,
        "hubert-xlarge": 1.0e9,
        "deepseek-v2-lite-16b": 16e9,
        "granite-moe-1b-a400m": 1.3e9,
        "jamba-1.5-large-398b": 398e9,
    }
    for name, n in expect.items():
        got = get_config(name).param_count()
        assert 0.5 * n < got < 1.6 * n, f"{name}: {got/1e9:.1f}B vs {n/1e9}B"


def test_all_configs_registered():
    assert set(ARCHS) <= set(list_configs())
