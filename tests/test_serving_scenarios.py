"""The serving admission scenario (DESIGN.md §8.2) is a real model of
the serving control plane AND a cross-backend executable contract: one
pure SimProgram definition must produce bit-identical admission
counters on the host schedulers and the device engine — in particular
under ``queue_mode="tiered3"`` and the sharded engine built on it,
the modes the ROADMAP's 64k+ serving scenarios depend on.

``max_batch_len`` stays small here: the dense-codec switch dispatcher
composes one branch per batch word (|types|^k), so compile time — not
the queue — bounds the batch length for multi-type device models.
"""

import numpy as np
import pytest

from _parity import ALL_BACKENDS, assert_parity, run_all
from repro.core.program import Config
from repro.serving.scenarios import build_admission_program, initial_state

CFG = Config(max_batch_len=3, capacity=256, max_emit=2)


def _build():
    return build_admission_program(
        num_slots=4, num_requests=24, max_decode=5, config=CFG
    )


def test_admission_parity_all_backends():
    """Same counters, event count, and final time on every backend —
    host schedulers, all four device queue modes, and the sharded
    engine at 2 and 4 shards (emissions route by request id)."""
    results = run_all(_build, initial_state(4))
    assert_parity(results)
    state = {k: np.asarray(v).tolist()
             for k, v in results["device/tiered3"].state.items()}
    # The run really finished and really contended for slots.
    assert state["arrivals"] == state["admitted"] == state["served"] == 24
    assert state["waiting"] == 0 and state["slots"] == [0, 0, 0, 0]
    assert state["retries"] > 0


def test_admission_large_capacity_tiered3():
    """Deep-capacity smoke: the tiered3 queue serves a 16k-capacity
    admission run to completion (the near-full path never strands or
    duplicates work)."""
    prog = build_admission_program(
        num_slots=32, num_requests=300, max_decode=6,
        config=Config(max_batch_len=3, capacity=16384, max_emit=2),
    )
    r = prog.build(backend="device", queue_mode="tiered3").run(
        initial_state(32))
    state = r.state
    assert int(state["served"]) == 300
    assert int(state["waiting"]) == 0
    assert int(np.asarray(state["slots"]).sum()) == 0
    assert r.dropped == 0
    # every admitted request decoded its full budget
    assert int(state["decoded"]) >= 300


@pytest.mark.slow
def test_admission_64k_capacity_4_shards_bit_identical():
    """The acceptance run: the admission scenario at 64k capacity on
    the sharded engine (4 shards) is bit-identical — state, events,
    batches, dropped, final_time — to the single-shard tiered3 run."""
    cfg = Config(max_batch_len=3, capacity=65536, max_emit=2)

    def build():
        return build_admission_program(
            num_slots=48, num_requests=400, max_decode=6, config=cfg
        )

    single = build().build(
        backend="device", queue_mode="tiered3").run(initial_state(48))
    sharded = build().build(
        backend="device", shards=4).run(initial_state(48))
    for k, v in single.state.items():
        np.testing.assert_array_equal(
            np.asarray(v), np.asarray(sharded.state[k]), err_msg=k)
    assert (single.events, single.batches, single.dropped) \
        == (sharded.events, sharded.batches, sharded.dropped)
    assert np.float32(single.final_time) == np.float32(sharded.final_time)
    assert int(single.state["served"]) == 400
    assert sharded.dropped == 0


def test_admission_lookahead_contract_validated():
    with pytest.raises(ValueError, match="arrival_lookahead"):
        build_admission_program(arrival_lookahead=0.5)
    with pytest.raises(ValueError, match="max_emit"):
        build_admission_program(config=Config(max_emit=1))


def test_sharded_backends_registered_in_harness():
    """The sharded engine is part of the shared parity matrix (the
    satellite contract: new backends register once, every suite
    inherits them)."""
    assert ALL_BACKENDS["device/tiered3-2shard"]["shards"] == 2
    assert ALL_BACKENDS["device/tiered3-4shard"]["shards"] == 4
