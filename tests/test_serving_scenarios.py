"""The serving admission scenario (DESIGN.md §7.2) is a real model of
the serving control plane AND a cross-backend executable contract: one
pure SimProgram definition must produce bit-identical admission
counters on the host schedulers and the device engine — in particular
under ``queue_mode="tiered3"``, the mode the ROADMAP's 64k+ serving
scenarios depend on.

``max_batch_len`` stays small here: the dense-codec switch dispatcher
composes one branch per batch word (|types|^k), so compile time — not
the queue — bounds the batch length for multi-type device models.
"""

import numpy as np
import pytest

from repro.core.program import Config
from repro.serving.scenarios import build_admission_program, initial_state

CFG = Config(max_batch_len=3, capacity=256, max_emit=2)


def _run(**build_kw):
    prog = build_admission_program(
        num_slots=4, num_requests=24, max_decode=5, config=CFG
    )
    r = prog.build(**build_kw).run(initial_state(4))
    return (
        {k: np.asarray(v).tolist() for k, v in r.state.items()},
        r.events, r.final_time, r.dropped,
    )


def test_admission_parity_device_tiered3_vs_host():
    """Same counters, event count, and final time on device tiered3,
    host conservative, and the sequential baseline."""
    base = _run(backend="device", queue_mode="tiered3")
    assert base == _run(backend="host")
    assert base == _run(backend="host", scheduler="unbatched")
    state = base[0]
    # The run really finished and really contended for slots.
    assert state["arrivals"] == state["admitted"] == state["served"] == 24
    assert state["waiting"] == 0 and state["slots"] == [0, 0, 0, 0]
    assert state["retries"] > 0
    assert base[3] == 0  # no overflow drops


def test_admission_large_capacity_tiered3():
    """Deep-capacity smoke: the tiered3 queue serves a 16k-capacity
    admission run to completion (the near-full path never strands or
    duplicates work)."""
    prog = build_admission_program(
        num_slots=32, num_requests=300, max_decode=6,
        config=Config(max_batch_len=3, capacity=16384, max_emit=2),
    )
    r = prog.build(backend="device", queue_mode="tiered3").run(
        initial_state(32))
    state = r.state
    assert int(state["served"]) == 300
    assert int(state["waiting"]) == 0
    assert int(np.asarray(state["slots"]).sum()) == 0
    assert r.dropped == 0
    # every admitted request decoded its full budget
    assert int(state["decoded"]) >= 300


def test_admission_lookahead_contract_validated():
    with pytest.raises(ValueError, match="arrival_lookahead"):
        build_admission_program(arrival_lookahead=0.5)
    with pytest.raises(ValueError, match="max_emit"):
        build_admission_program(config=Config(max_emit=1))
