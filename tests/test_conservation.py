"""Event conservation law across the device parity matrix.

Every device run must satisfy, exactly::

    seeded + emitted == executed + pending + dropped + spilled

``seeded`` is the initial schedule, ``emitted`` counts every valid
handler emit (whether it was queued, dropped, or spilled), ``executed``
is ``RunResult.events``, ``pending`` the residual queue occupancy.
This holds at ANY stopping point (drained, ``max_batches``, horizon)
and under every overflow policy — it's the accounting identity the
on-device conservation fault bit enforces per super-step.

Host backends don't surface emitted/pending (their RunResult fields
default to 0), so the matrix here is the device half of ALL_BACKENDS.
"""

import jax.numpy as jnp
import pytest

from _parity import ALL_BACKENDS
from repro.api import Config, SimProgram
from repro.testing.faults import tiny_phold

DEVICE_LABELS = sorted(
    label for label, kw in ALL_BACKENDS.items() if kw["backend"] == "device"
)

_SEEDED = 8  # tiny_phold default seeds


def _check(res, *, seeded):
    lhs = seeded + res.emitted
    rhs = res.events + res.pending + res.dropped + res.spilled
    assert lhs == rhs, (
        f"conservation violated: {seeded} seeded + {res.emitted} emitted "
        f"!= {res.events} executed + {res.pending} pending "
        f"+ {res.dropped} dropped + {res.spilled} spilled"
    )


@pytest.mark.parametrize("label", DEVICE_LABELS)
def test_conservation_across_matrix(label, tmp_path):
    sim = tiny_phold().build(**ALL_BACKENDS[label], validate="cheap")
    # stop mid-flight: pending > 0 makes the law non-trivial
    res = sim.run(jnp.int32(0), max_batches=15)
    assert res.pending > 0
    assert res.emitted > 0
    assert res.fault_word == 0
    _check(res, seeded=_SEEDED)


def _storm(cap):
    p = SimProgram("storm", config=Config(
        max_batch_len=2, capacity=cap, max_emit=2))

    @p.handler("GEN", lookahead=0.1, emits=True)
    def gen(state, t, arg):
        alive = t < 2.0
        e = jnp.full((2, 6), -1.0, jnp.float32).at[:, 0].set(0.0)
        e = e.at[0, 0].set(jnp.where(alive, 0.3, -1.0))
        e = e.at[0, 1].set(jnp.where(alive, 0.0, -1.0))
        e = e.at[1, 0].set(jnp.where(alive, 0.45, -1.0))
        e = e.at[1, 1].set(jnp.where(alive, 0.0, -1.0))
        return state + 1, e

    for i in range(6):
        p.schedule(0.05 * i, "GEN")
    return p


def test_conservation_with_drops():
    """overflow='drop': the dropped term balances the law exactly."""
    res = _storm(16).build(backend="device", validate="cheap").run(
        jnp.int32(0))
    assert res.dropped > 0
    _check(res, seeded=6)


def test_conservation_with_spill():
    """overflow='spill': nothing dropped; any residual spill pool is
    the spilled term (here the run completes, so it drains to zero)."""
    res = _storm(64).build(backend="device", overflow="spill",
                           validate="cheap").run(jnp.int32(0))
    assert res.dropped == 0
    _check(res, seeded=6)


def test_conservation_survives_resume(tmp_path):
    """The law holds for a segmented, interrupted-then-resumed run —
    the emitted/executed counters ride the checkpoint carry."""
    from _parity import run_interrupted_then_resumed

    sim = tiny_phold().build(backend="device", validate="cheap")
    res = run_interrupted_then_resumed(
        sim, jnp.int32(0), tmpdir=str(tmp_path),
        max_batches=24, checkpoint_every=4, crash_at_segment=3,
    )
    assert res.pending > 0
    _check(res, seeded=_SEEDED)
