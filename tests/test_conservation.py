"""Event conservation law across the device parity matrix.

Every device run must satisfy, exactly::

    seeded + ingested + emitted ==
        executed + pending + dropped + spilled + shed

``seeded`` is the initial schedule, ``ingested`` counts external
arrivals accepted from a stream (zero for closed runs — pinned below),
``emitted`` counts every valid handler emit (whether it was queued,
dropped, or spilled), ``executed`` is ``RunResult.events``, ``pending``
the residual queue occupancy, ``shed`` the arrivals refused under
``backpressure="shed"`` (zero for closed runs).  This holds at ANY
stopping point (drained, ``max_batches``, horizon) and under every
overflow policy — it's the accounting identity the on-device
conservation fault bit enforces per super-step, extended host-side to
the open-system boundary (DESIGN.md §10).

Host backends don't surface emitted/pending (their RunResult fields
default to 0), so the matrix here is the device half of ALL_BACKENDS.
"""

import jax.numpy as jnp
import pytest

from _parity import ALL_BACKENDS
from repro.api import Config, PoissonSource, SimProgram
from repro.testing.faults import tiny_phold

DEVICE_LABELS = sorted(
    label for label, kw in ALL_BACKENDS.items() if kw["backend"] == "device"
)

_SEEDED = 8  # tiny_phold default seeds


def _check(res, *, seeded):
    lhs = seeded + res.ingested + res.emitted
    rhs = res.events + res.pending + res.dropped + res.spilled + res.shed
    assert lhs == rhs, (
        f"conservation violated: {seeded} seeded + {res.ingested} ingested "
        f"+ {res.emitted} emitted != {res.events} executed "
        f"+ {res.pending} pending + {res.dropped} dropped "
        f"+ {res.spilled} spilled + {res.shed} shed"
    )


@pytest.mark.parametrize("label", DEVICE_LABELS)
def test_conservation_across_matrix(label, tmp_path):
    sim = tiny_phold().build(**ALL_BACKENDS[label], validate="cheap")
    # stop mid-flight: pending > 0 makes the law non-trivial
    res = sim.run(jnp.int32(0), max_batches=15)
    assert res.pending > 0
    assert res.emitted > 0
    assert res.fault_word == 0
    # closed runs: the open-system terms are identically zero
    assert res.ingested == 0
    assert res.shed == 0
    _check(res, seeded=_SEEDED)


def _storm(cap):
    p = SimProgram("storm", config=Config(
        max_batch_len=2, capacity=cap, max_emit=2))

    @p.handler("GEN", lookahead=0.1, emits=True)
    def gen(state, t, arg):
        alive = t < 2.0
        e = jnp.full((2, 6), -1.0, jnp.float32).at[:, 0].set(0.0)
        e = e.at[0, 0].set(jnp.where(alive, 0.3, -1.0))
        e = e.at[0, 1].set(jnp.where(alive, 0.0, -1.0))
        e = e.at[1, 0].set(jnp.where(alive, 0.45, -1.0))
        e = e.at[1, 1].set(jnp.where(alive, 0.0, -1.0))
        return state + 1, e

    for i in range(6):
        p.schedule(0.05 * i, "GEN")
    return p


def test_conservation_with_drops():
    """overflow='drop': the dropped term balances the law exactly."""
    res = _storm(16).build(backend="device", validate="cheap").run(
        jnp.int32(0))
    assert res.dropped > 0
    _check(res, seeded=6)


def test_conservation_with_spill():
    """overflow='spill': nothing dropped; any residual spill pool is
    the spilled term (here the run completes, so it drains to zero)."""
    res = _storm(64).build(backend="device", overflow="spill",
                           validate="cheap").run(jnp.int32(0))
    assert res.dropped == 0
    _check(res, seeded=6)


def test_conservation_survives_resume(tmp_path):
    """The law holds for a segmented, interrupted-then-resumed run —
    the emitted/executed counters ride the checkpoint carry."""
    from _parity import run_interrupted_then_resumed

    sim = tiny_phold().build(backend="device", validate="cheap")
    res = run_interrupted_then_resumed(
        sim, jnp.int32(0), tmpdir=str(tmp_path),
        max_batches=24, checkpoint_every=4, crash_at_segment=3,
    )
    assert res.pending > 0
    _check(res, seeded=_SEEDED)


# -- open-system runs (DESIGN.md §10) ----------------------------------------

def _sink_prog(cap, *, seeds=2):
    """Events that emit nothing — occupancy only ever shrinks, so a
    spilled/shed backlog drains as the engine frees capacity."""
    p = SimProgram("sink", config=Config(
        max_batch_len=4, capacity=cap, max_emit=1))

    @p.handler("SINK", lookahead=0.25)
    def sink(state, t, arg):
        return state + 1

    for i in range(seeds):
        p.schedule(0.25 * i, "SINK")
    return p


def test_conservation_streamed_midflight():
    """ingested joins the left side of the law; stopping mid-flight
    with arrivals absorbed across several block boundaries keeps it
    exact (pending > 0 makes it non-trivial)."""
    sim = tiny_phold(capacity=64).build(backend="device", validate="cheap")
    src = PoissonSource(2.0, 24, grid=0.25, type_id=0, block_size=8)
    res = sim.run(jnp.int32(0), max_batches=40, arrivals=src)
    assert res.fault_word == 0
    # the batch target can stop the run with blocks still unconsumed —
    # arrivals left in the source are in NO term of the law
    assert 0 < res.ingested <= 24
    assert res.shed == 0
    assert res.pending > 0
    _check(res, seeded=_SEEDED)


def test_conservation_streamed_shed():
    """backpressure='shed': refused arrivals balance the law via the
    shed term, never silently vanish."""
    sim = tiny_phold(capacity=16).build(backend="device", validate="cheap")
    src = PoissonSource(4.0, 32, grid=0.25, type_id=0, block_size=32)
    res = sim.run(jnp.int32(0), max_batches=30, arrivals=src,
                  backpressure="shed")
    assert res.shed > 0
    # ingested counts CONSUMED arrivals (absorbed + shed), so a fully
    # drained source always shows ingested == trace length
    assert res.ingested == 32
    assert res.shed < 32
    _check(res, seeded=_SEEDED)


def test_conservation_streamed_spill_midflight():
    """overflow='spill' + streaming: arrivals beyond capacity land in
    the host pool (counted ingested), and a mid-flight stop leaves a
    non-empty pool balanced by the spilled term."""
    sim = _sink_prog(8).build(backend="device", overflow="spill",
                              validate="cheap")
    src = PoissonSource(4.0, 32, grid=0.25, type_id=0)
    res = sim.run(jnp.int32(0), max_batches=3, arrivals=src)
    assert res.ingested == 32
    assert res.spilled > 0
    _check(res, seeded=2)


def test_conservation_streamed_spill_drains():
    """The same topology run to completion: the pool drains to zero and
    every ingested arrival was executed."""
    sim = _sink_prog(8).build(backend="device", overflow="spill",
                              validate="cheap")
    src = PoissonSource(4.0, 32, grid=0.25, type_id=0)
    res = sim.run(jnp.int32(0), max_batches=200, arrivals=src)
    assert res.ingested == 32
    assert res.spilled == 0
    assert res.pending == 0
    assert res.events == 2 + 32
    _check(res, seeded=2)
