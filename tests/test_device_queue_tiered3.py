"""Differential tests: log-structured tiered3 (front/staging/runs/main)
device-queue ops vs the seed per-event reference ops.

The tiered3 ops must reproduce the reference ``(time, seq)`` pop order
BIT-EXACTLY — including timestamp ties, run-pool exhaustion (the merge
into main, both the slack-append fast path and the rotate+merge
compaction), bounded k-way refills that consume from several runs at
once, and overflow ghosts landing across all four tiers.  The
stationary >=90%-occupancy property test drives exactly the
near-head/far-future re-emit shape that made the two-tier flush merge
O(capacity) — the workload the third tier exists for.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import DeviceEngine, EventRegistry, emits_events
from repro.core.events import ARG_WIDTH
from repro.core.queue import (
    device_queue_extract_ref,
    device_queue_from_host,
    device_queue_init,
    device_queue_pop,
    device_queue_push,
    device_queue_push_rows_serial,
    tiered3_queue_extract,
    tiered3_queue_fill_rows,
    tiered3_queue_from_host,
    tiered3_queue_has_pending,
    tiered3_queue_init,
    tiered3_queue_occupancy,
    tiered3_queue_to_flat,
    tiered_queue_fill_rows,
    tiered_queue_init,
    tiered_queue_to_flat,
)

EMIT_W = 2 + ARG_WIDTH

_fill_t3 = jax.jit(tiered3_queue_fill_rows)
_fill_t2 = jax.jit(tiered_queue_fill_rows)
_fill_ref = jax.jit(device_queue_push_rows_serial)
_extract_t3 = jax.jit(tiered3_queue_extract, static_argnums=1)
_extract_ref = jax.jit(device_queue_extract_ref, static_argnums=1)


def canonical(q):
    """Layout-independent view: occupied slots sorted by (time, seq)."""
    times = np.asarray(q.times)
    types = np.asarray(q.types)
    args = np.asarray(q.args)
    seqs = np.asarray(q.seqs)
    occ = types >= 0
    order = np.lexsort((seqs[occ], times[occ]))
    return {
        "times": times[occ][order],
        "types": types[occ][order],
        "args": args[occ][order],
        "seqs": seqs[occ][order],
        "size": int(q.size),
        "next_seq": int(q.next_seq),
        "dropped": int(q.dropped),
    }


def assert_t3_equals_flat(qt, qf, msg=""):
    ca, cb = canonical(tiered3_queue_to_flat(qt)), canonical(qf)
    for field, va in ca.items():
        np.testing.assert_array_equal(
            va, cb[field], err_msg=f"{msg}: field {field!r} diverged",
        )


def random_rows(rng, n_rows, *, p_valid=0.7, num_types=3, t_lo=0, t_hi=5):
    rows = np.zeros((n_rows, EMIT_W), np.float32)
    rows[:, 1] = -1.0
    for i in range(n_rows):
        if rng.random() < p_valid:
            # small integer times force heavy timestamp ties
            rows[i, 0] = float(rng.integers(t_lo, t_hi))
            rows[i, 1] = float(rng.integers(0, num_types))
            rows[i, 2:] = rng.random(ARG_WIDTH).astype(np.float32)
    return jnp.asarray(rows)


def run_differential(seed, capacity, max_len, front_cap, stage_cap,
                     num_runs, steps=50, n_rows=4):
    rng = np.random.default_rng(seed)
    lookaheads = jnp.asarray(
        rng.choice([0.0, 0.5, 1.0, np.inf], size=3), jnp.float32
    )
    qa = tiered3_queue_init(capacity, front_cap=front_cap,
                            stage_cap=stage_cap, num_runs=num_runs)
    qb = device_queue_init(capacity)
    for step in range(steps):
        if rng.random() < 0.5:
            rows = random_rows(rng, n_rows)
            qa = _fill_t3(qa, rows)
            qb = _fill_ref(qb, rows)
        else:
            qa, tsa, tya, aa, la = _extract_t3(qa, max_len, lookaheads)
            qb, tsb, tyb, ab, lb = _extract_ref(qb, max_len, lookaheads)
            msg = f"seed {seed} step {step}"
            np.testing.assert_array_equal(
                np.asarray(tsa), np.asarray(tsb), err_msg=msg)
            np.testing.assert_array_equal(
                np.asarray(tya), np.asarray(tyb), err_msg=msg)
            np.testing.assert_array_equal(
                np.asarray(aa), np.asarray(ab), err_msg=msg)
            assert int(la) == int(lb), msg
        assert_t3_equals_flat(qa, qb, msg=f"seed {seed} step {step}")
        occ = int(tiered3_queue_occupancy(qa))
        assert occ <= capacity, "tier occupancy exceeded logical capacity"
        assert bool(tiered3_queue_has_pending(qa)) == (occ > 0)


# Tiny tiers + tiny run pools force every rare path: run-pool
# exhaustion (merge into main: slack append AND rotate compaction),
# multi-run k-way refills, front eviction through staging into runs.
# num_runs=1 degenerates to flush-per-pool-slot; front_cap == capacity
# is the everything-in-front config.
@pytest.mark.parametrize("front_cap,stage_cap,num_runs", [
    (6, 4, 1), (4, 5, 2), (5, 7, 3), (24, 24, 2), (8, 40, 1),
])
@pytest.mark.parametrize("seed", [0, 1])
def test_interleaved_stream_differential(seed, front_cap, stage_cap,
                                         num_runs):
    run_differential(seed, capacity=24, max_len=4, front_cap=front_cap,
                     stage_cap=stage_cap, num_runs=num_runs)


def test_pop_order_bit_exact_under_ties():
    """max_len=1 extraction must reproduce device_queue_pop's
    lexicographic (time, seq) order exactly, including ties."""
    rng = np.random.default_rng(7)
    lookaheads = jnp.asarray([0.0, 0.0], jnp.float32)
    events = [(float(rng.integers(0, 3)), int(rng.integers(0, 2)),
               np.full((ARG_WIDTH,), float(i), np.float32))
              for i in range(12)]
    qa = tiered3_queue_from_host(events, 16, front_cap=4, stage_cap=4,
                                 num_runs=2)
    qb = device_queue_init(16)
    for (t, ty, arg) in events:
        qb = device_queue_push(qb, t, ty, jnp.asarray(arg))
    for _ in range(12):
        qa, ts, tys, args, length = _extract_t3(qa, 1, lookaheads)
        qb, t, ty, arg = device_queue_pop(qb)
        assert int(length) == 1
        assert float(ts[0]) == float(t)
        assert int(tys[0]) == int(ty)
        np.testing.assert_array_equal(np.asarray(args[0]), np.asarray(arg))
    assert int(qa.size) == 0 and int(qb.size) == 0
    assert not bool(tiered3_queue_has_pending(qa))


def test_from_host_matches_flat_from_host():
    """Tiered3 and flat host-side seed builds agree, incl. overflow."""
    rng = np.random.default_rng(3)
    capacity = 6
    events = []
    for i in range(9):  # 3 past capacity
        arg = rng.random(ARG_WIDTH).astype(np.float32)
        events.append((float(rng.integers(0, 4)),
                       int(rng.integers(0, 3)), arg))
    qa = tiered3_queue_from_host(events, capacity, front_cap=2,
                                 stage_cap=4, num_runs=2)
    qb = device_queue_from_host(events, capacity)
    assert_t3_equals_flat(qa, qb, "from_host")
    assert int(qa.dropped) == 3
    assert int(tiered3_queue_occupancy(qa)) == capacity


def test_overflow_across_tiers_bit_exact():
    """Emits dropped when front+staging+runs+main are full must match
    the reference dropped/size/next_seq accounting bit-exactly,
    including continued ghost growth after saturation."""
    capacity = 8
    qa = tiered3_queue_init(capacity, front_cap=4, stage_cap=3, num_runs=2)
    qb = device_queue_init(capacity)
    for lo in (0, 3, 6):
        rows = np.zeros((3, EMIT_W), np.float32)
        rows[:, 0] = np.arange(lo, lo + 3)
        rows[:, 1] = 0.0
        if lo == 6:
            rows[2, 1] = -1.0  # hole: 8 real events total
        qa = _fill_t3(qa, jnp.asarray(rows))
        qb = _fill_ref(qb, jnp.asarray(rows))
    assert_t3_equals_flat(qa, qb, "exactly full")
    assert int(tiered3_queue_occupancy(qa)) == capacity
    assert int(qa.dropped) == 0

    over = np.zeros((3, EMIT_W), np.float32)
    over[:, 0] = [100.0, 0.5, 102.0]   # 0.5 would land in the FRONT
    over[:, 1] = [1.0, 1.0, -1.0]
    qa = _fill_t3(qa, jnp.asarray(over))
    qb = _fill_ref(qb, jnp.asarray(over))
    assert_t3_equals_flat(qa, qb, "overflow")
    assert int(qa.dropped) == 2
    assert int(qa.size) == capacity + 2
    assert int(qa.next_seq) == capacity + 2
    assert int(tiered3_queue_occupancy(qa)) == capacity

    lookaheads = jnp.asarray([np.inf, np.inf], jnp.float32)
    for _ in range(4):
        qa, _, _, _, la = _extract_t3(qa, 4, lookaheads)
        qb, _, _, _, lb = _extract_ref(qb, 4, lookaheads)
        assert int(la) == int(lb)
        assert_t3_equals_flat(qa, qb, "drain")
    assert not bool(tiered3_queue_has_pending(qa))
    assert int(qa.size) == 2  # the ghosts remain in size, as reference


def test_run_pool_exhaustion_merges_into_main():
    """Far-future emit pressure with a tiny run pool must force the
    merge-into-main path (append AND compaction legs) while staying
    bit-exact, and the runs must all be freed afterwards."""
    qa = tiered3_queue_init(32, front_cap=4, stage_cap=3, num_runs=2)
    qb = device_queue_init(32)
    la = jnp.asarray([1.0], jnp.float32)
    t = 0.0
    for step in range(24):
        # mostly far-future appends, occasional near-head (compaction leg)
        near = step % 5 == 4
        base = t + (0.5 if near else 50.0)
        rows = np.zeros((3, EMIT_W), np.float32)
        rows[:, 0] = [base, base + 0.5, base + 1.0]
        rows[:, 1] = 0.0
        qa = _fill_t3(qa, jnp.asarray(rows))
        qb = _fill_ref(qb, jnp.asarray(rows))
        qa, tsa, _, _, lna = _extract_t3(qa, 3, la)
        qb, tsb, _, _, lnb = _extract_ref(qb, 3, la)
        np.testing.assert_array_equal(np.asarray(tsa), np.asarray(tsb))
        assert int(lna) == int(lnb)
        if int(lna):
            t = float(np.asarray(tsa)[int(lna) - 1])
        assert_t3_equals_flat(qa, qb, f"pool step {step}")
    # the stream above overflows the 2-run pool many times over
    assert int(qa.size) >= 0


# ---------------------------------------------------------------------------
# Satellite regression: overflow DURING a staging flush (ghost rows
# landing in the same fill_rows call that triggers the pre-flush) must
# not double- or under-count dropped/size/next_seq — pinned for both
# the two-tier and tiered3 queues against the serial reference.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tiered_kind", ["tiered", "tiered3"])
@pytest.mark.parametrize("hole_in_block", [False, True])
def test_overflow_during_flush_accounting(tiered_kind, hole_in_block):
    capacity, F, S = 8, 3, 3
    if tiered_kind == "tiered":
        qa = tiered_queue_init(capacity, front_cap=F, stage_cap=S)
        fill, to_flat = _fill_t2, tiered_queue_to_flat
    else:
        qa = tiered3_queue_init(capacity, front_cap=F, stage_cap=S,
                                num_runs=2)
        fill, to_flat = _fill_t3, tiered3_queue_to_flat
    qb = device_queue_init(capacity)

    def both(spec):
        nonlocal qa, qb
        rows = np.zeros((len(spec), EMIT_W), np.float32)
        rows[:, 1] = -1.0
        for i, (t, ty) in enumerate(spec):
            rows[i, 0], rows[i, 1] = t, ty
        qa = fill(qa, jnp.asarray(rows))
        qb = _fill_ref(qb, jnp.asarray(rows))

    # fill to 7 of 8, spread across tiers
    both([(10.0, 0), (20.0, 0), (30.0, 0)])
    both([(1.0, 0), (2.0, 0), (40.0, 0)])
    # near-head: front merge evicts the tail into staging (stage_n > 0,
    # so the NEXT 3-row block must pre-flush: stage_n + 3 > stage_cap 3)
    both([(0.5, 0)])
    # trigger block: pre-flush fires, then the valid rows arrive with
    # only 1 logical slot left -> the rest are ghosts landing mid-flush
    spec = [(0.25, 0), (999.0, 0), (1.5, 0)]
    if hole_in_block:
        spec[1] = (888.0, -1)   # ν-row must not advance any counter
    both(spec)
    ghosts = 1 if hole_in_block else 2

    ca = canonical(to_flat(qa))
    cb = canonical(qb)
    for field, va in ca.items():
        np.testing.assert_array_equal(
            va, cb[field],
            err_msg=f"{tiered_kind}: field {field!r} diverged")
    assert ca["dropped"] == ghosts
    assert ca["size"] == capacity + ghosts
    assert ca["next_seq"] == capacity + ghosts


# ---------------------------------------------------------------------------
# Hypothesis properties
# ---------------------------------------------------------------------------

@given(
    seed=st.integers(0, 2**16),
    front_cap=st.integers(4, 12),
    stage_cap=st.integers(4, 12),
    num_runs=st.integers(1, 4),
    capacity=st.sampled_from([8, 16, 24]),
)
@settings(max_examples=20, deadline=None)
def test_property_random_streams(seed, front_cap, stage_cap, num_runs,
                                 capacity):
    """For ANY tier geometry (incl. degenerate single-run pools) and
    random event stream, tiered3 reproduces the reference pop order and
    counters bit-exactly."""
    run_differential(seed, capacity=capacity, max_len=4,
                     front_cap=front_cap, stage_cap=stage_cap,
                     num_runs=num_runs, steps=24)


def _run_near_full_churn(seed, num_runs, near_period):
    """The flush-merge trigger shape: the queue held at >=90%
    stationary occupancy (every extract matched by an equal-size
    re-emit block) with re-emits alternating between near-head landings
    (front merges + evictions) and far-future landings
    (staging/run/main pressure) must stay bit-exact against the
    reference spec at every step."""
    rng = np.random.default_rng(seed)
    capacity, max_len = 40, 4
    qa = tiered3_queue_init(capacity, front_cap=6, stage_cap=5,
                            num_runs=num_runs)
    qb = device_queue_init(capacity)
    la = jnp.asarray([2.0], jnp.float32)
    seed_n = int(capacity * 0.92)
    # seed in blocks (keeps every tier populated, unlike from_host)
    t = 0.0
    n = 0
    while n < seed_n:
        k = min(4, seed_n - n)
        rows = np.zeros((4, EMIT_W), np.float32)
        rows[:, 1] = -1.0
        rows[:k, 0] = t + np.arange(k, dtype=np.float32) * 0.5
        rows[:k, 1] = 0.0
        qa = _fill_t3(qa, jnp.asarray(rows))
        qb = _fill_ref(qb, jnp.asarray(rows))
        t += 2.0
        n += k
    occ0 = int(tiered3_queue_occupancy(qa))
    assert occ0 >= int(capacity * 0.9)
    clock = 0.0
    for step in range(30):
        qa, tsa, _, _, lna = _extract_t3(qa, max_len, la)
        qb, tsb, _, _, lnb = _extract_ref(qb, max_len, la)
        np.testing.assert_array_equal(np.asarray(tsa), np.asarray(tsb),
                                      err_msg=f"step {step}")
        assert int(lna) == int(lnb)
        if int(lna):
            clock = float(np.asarray(tsa)[int(lna) - 1])
        # stationary re-emit: one row per extracted event, alternating
        # near-head / far-future by stripe
        near = (step // near_period) % 2 == 0
        rows = np.zeros((max_len, EMIT_W), np.float32)
        rows[:, 1] = -1.0
        k = int(lna)
        for i in range(k):
            delta = (0.5 + 0.5 * float(rng.integers(0, 3)) if near
                     else 1e5 + float(rng.integers(0, 9)))
            rows[i, 0] = clock + delta
            rows[i, 1] = 0.0
        qa = _fill_t3(qa, jnp.asarray(rows))
        qb = _fill_ref(qb, jnp.asarray(rows))
        assert_t3_equals_flat(qa, qb, f"churn step {step}")
    # occupancy really was stationary (re-emits replaced extractions)
    assert int(tiered3_queue_occupancy(qa)) == occ0


@pytest.mark.parametrize("seed,num_runs,near_period", [
    (0, 1, 2), (1, 2, 3), (2, 3, 2),
])
def test_near_full_churn_fixed_cases(seed, num_runs, near_period):
    """Bare-env coverage of the near-full churn shape (the hypothesis
    property below widens the same driver when available)."""
    _run_near_full_churn(seed, num_runs, near_period)


@given(
    seed=st.integers(0, 2**16),
    num_runs=st.integers(1, 3),
    near_period=st.integers(2, 4),
)
@settings(max_examples=10, deadline=None)
def test_property_near_full_churn(seed, num_runs, near_period):
    _run_near_full_churn(seed, num_runs, near_period)


# ---------------------------------------------------------------------------
# Engine level
# ---------------------------------------------------------------------------

def _order_sensitive_registry():
    reg = EventRegistry()

    @emits_events
    def ping(state, t, arg):
        emit = jnp.full((1, EMIT_W), -1.0, jnp.float32)
        emit = jnp.where(
            t < 6.0,
            emit.at[0, 0].set(t + 1.0).at[0, 1].set(1.0),
            emit,
        )
        return state * 7 + (t.astype(jnp.int32) * 2 + 1), emit

    def pong(state, t, arg):
        return state * 7 + (t.astype(jnp.int32) * 2 + 2)

    reg.register("Ping", ping, lookahead=1.0)
    reg.register("Pong", pong, lookahead=1.0)
    return reg.freeze()


@pytest.mark.parametrize("seed", [0, 1])
def test_engine_four_queue_modes_agree(seed):
    """Full DeviceEngine runs under tiered3 / tiered / flat / reference
    queues give identical states, stats, and final queue contents."""
    rng = np.random.default_rng(seed)
    events = [(float(t), int(rng.integers(0, 2)), None)
              for t in range(int(rng.integers(4, 10)))]
    results = {}
    for mode in ("tiered3", "tiered", "flat", "reference"):
        kw = {}
        if mode == "tiered":
            kw = {"front_cap": 4, "stage_cap": 3}
        elif mode == "tiered3":
            kw = {"front_cap": 4, "stage_cap": 3, "num_runs": 2}
        reg = _order_sensitive_registry()
        eng = DeviceEngine(reg, max_batch_len=3, capacity=32, max_emit=1,
                           queue_mode=mode, **kw)
        q = eng.initial_queue(events)
        s, q, stats = eng.run(jnp.int32(1), q, max_batches=64)
        results[mode] = (s, q, stats)
    s_t, q_t, st_t = results["tiered3"]
    for mode in ("tiered", "flat", "reference"):
        s_o, q_o, st_o = results[mode]
        assert int(s_t) == int(s_o), mode
        ca = canonical(tiered3_queue_to_flat(q_t))
        qf = q_o if mode in ("flat", "reference") \
            else tiered_queue_to_flat(q_o)
        cb = canonical(qf)
        for field, va in ca.items():
            np.testing.assert_array_equal(
                va, cb[field], err_msg=f"vs {mode}: {field}")
        for k in ("batches", "events", "dropped"):
            assert int(st_t[k]) == int(st_o[k]), (mode, k)
        assert float(st_t["time"]) == float(st_o["time"]), mode


def test_engine_overflow_cascade_across_tiers():
    """A 2^k spawning cascade over a tiny tiered3 queue must overflow
    with the same dropped/size/next_seq as the flat and reference
    engines, and the run must terminate (size counts ghosts)."""
    def make_reg():
        reg = EventRegistry()

        @emits_events
        def spawner(state, t, arg):
            emit = jnp.zeros((2, EMIT_W), jnp.float32)
            emit = emit.at[:, 0].set(t + 1.0).at[:, 1].set(0.0)
            return state + 1, emit

        reg.register("S", spawner, lookahead=1.0)
        return reg.freeze()

    outcomes = {}
    for mode in ("tiered3", "flat", "reference"):
        kw = {"front_cap": 2, "stage_cap": 5, "num_runs": 2} \
            if mode == "tiered3" else {}
        eng = DeviceEngine(make_reg(), max_batch_len=2, capacity=4,
                           max_emit=2, queue_mode=mode, **kw)
        q = eng.initial_queue([(0.0, 0, None)])
        s, q, stats = eng.run(jnp.int32(0), q, max_batches=8)
        outcomes[mode] = (int(s), int(stats["dropped"]), int(q.size),
                          int(q.next_seq))
    assert outcomes["tiered3"] == outcomes["flat"] == outcomes["reference"]
    assert outcomes["tiered3"][1] > 0  # it really overflowed


def test_engine_refill_aware_loop_termination():
    """With a front tier far smaller than the pending set (and events
    spread across runs and main), the engine must keep refilling and
    execute every event."""
    reg = EventRegistry()
    reg.register("N", lambda s, t, a: s + 1, lookahead=np.inf)
    eng = DeviceEngine(reg, max_batch_len=4, capacity=64, front_cap=4,
                       stage_cap=4, num_runs=2, queue_mode="tiered3")
    events = [(float(t), 0, None) for t in range(50)]
    s, q, stats = eng.run(jnp.int32(0), eng.initial_queue(events))
    assert int(s) == 50
    assert int(stats["events"]) == 50
    assert int(q.size) == 0
