"""Differential tests: vectorized single-pass device-queue ops vs the
seed per-event reference ops.

The vectorized ops (`device_queue_extract`, `device_queue_fill_rows`,
`device_queue_from_host`) must reproduce the reference ops'
``(time, seq)`` pop order BIT-EXACTLY — including timestamp ties,
exactly-full queues, overflow, and all-empty emit blocks — over random
event streams.  Plain numpy randomness (no hypothesis) so these run on
a bare environment.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DeviceEngine, EventRegistry, emits_events
from repro.core.events import ARG_WIDTH
from repro.core.queue import (
    device_queue_extract,
    device_queue_extract_ref,
    device_queue_fill_rows,
    device_queue_from_host,
    device_queue_init,
    device_queue_pop,
    device_queue_push,
    device_queue_push_rows,
)

EMIT_W = 2 + ARG_WIDTH


def canonical(q):
    """Layout-independent view: occupied slots sorted by (time, seq).

    The vectorized ops keep the queue in canonical (sorted-prefix)
    layout while the reference ops scatter into arbitrary free slots;
    both must agree on the CONTENT of the pending set and on all logical
    counters.
    """
    times = np.asarray(q.times)
    types = np.asarray(q.types)
    args = np.asarray(q.args)
    seqs = np.asarray(q.seqs)
    occ = types >= 0
    order = np.lexsort((seqs[occ], times[occ]))
    return {
        "times": times[occ][order],
        "types": types[occ][order],
        "args": args[occ][order],
        "seqs": seqs[occ][order],
        "size": int(q.size),
        "next_seq": int(q.next_seq),
        "dropped": int(q.dropped),
    }


def assert_queue_equal(qa, qb, msg=""):
    ca, cb = canonical(qa), canonical(qb)
    for field, va in ca.items():
        np.testing.assert_array_equal(
            va, cb[field], err_msg=f"{msg}: field {field!r} diverged",
        )


def random_rows(rng, n_rows, *, p_valid=0.7, num_types=3, tie_times=True):
    """Random emit block; ``type < 0`` rows are holes."""
    rows = np.zeros((n_rows, EMIT_W), np.float32)
    rows[:, 1] = -1.0
    for i in range(n_rows):
        if rng.random() < p_valid:
            # small integer times force heavy timestamp ties
            rows[i, 0] = float(rng.integers(0, 5) if tie_times
                               else rng.random() * 10)
            rows[i, 1] = float(rng.integers(0, num_types))
            rows[i, 2:] = rng.random(ARG_WIDTH).astype(np.float32)
    return jnp.asarray(rows)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_interleaved_stream_differential(seed):
    """Random interleaving of bulk inserts and window extractions:
    vectorized and reference paths must agree on every intermediate
    queue state and every extracted window."""
    rng = np.random.default_rng(seed)
    capacity, max_len = 24, 4
    lookaheads = jnp.asarray(
        rng.choice([0.0, 0.5, 1.0, np.inf], size=3), jnp.float32
    )
    qa = qb = device_queue_init(capacity)
    for step in range(30):
        if rng.random() < 0.5:
            rows = random_rows(rng, int(rng.integers(1, 8)))
            qa = device_queue_fill_rows(qa, rows)
            qb = device_queue_push_rows(qb, rows)
        else:
            qa, tsa, tya, aa, la = device_queue_extract(qa, max_len, lookaheads)
            qb, tsb, tyb, ab, lb = device_queue_extract_ref(
                qb, max_len, lookaheads
            )
            np.testing.assert_array_equal(np.asarray(tsa), np.asarray(tsb))
            np.testing.assert_array_equal(np.asarray(tya), np.asarray(tyb))
            np.testing.assert_array_equal(np.asarray(aa), np.asarray(ab))
            assert int(la) == int(lb)
        assert_queue_equal(qa, qb, msg=f"seed {seed} step {step}")


def test_pop_order_bit_exact_under_ties():
    """max_len=1 extraction must reproduce device_queue_pop's
    lexicographic (time, seq) order exactly, including ties."""
    rng = np.random.default_rng(7)
    lookaheads = jnp.asarray([0.0, 0.0], jnp.float32)
    # only three distinct times -> ties resolved by insertion seq
    events = [(float(rng.integers(0, 3)), int(rng.integers(0, 2)),
               np.full((ARG_WIDTH,), float(i), np.float32))
              for i in range(12)]
    qa = device_queue_from_host(events, 16)  # canonical layout
    qb = device_queue_init(16)               # arbitrary (push) layout
    for (t, ty, arg) in events:
        qb = device_queue_push(qb, t, ty, jnp.asarray(arg))
    for _ in range(12):
        qa, ts, tys, args, length = device_queue_extract(qa, 1, lookaheads)
        qb, t, ty, arg = device_queue_pop(qb)
        assert int(length) == 1
        assert float(ts[0]) == float(t)
        assert int(tys[0]) == int(ty)
        np.testing.assert_array_equal(np.asarray(args[0]), np.asarray(arg))
    assert int(qa.size) == 0 and int(qb.size) == 0


def test_exactly_full_queue_and_overflow():
    """Filling to exactly capacity works; the overflowing row is dropped
    with identical size/next_seq/dropped bookkeeping on both paths."""
    capacity = 8
    qa = qb = device_queue_init(capacity)
    rows = np.zeros((capacity, EMIT_W), np.float32)
    rows[:, 0] = np.arange(capacity)
    rows[:, 1] = 0.0
    qa = device_queue_fill_rows(qa, jnp.asarray(rows))
    qb = device_queue_push_rows(qb, jnp.asarray(rows))
    assert_queue_equal(qa, qb, "exactly full")
    assert int(qa.size) == capacity and int(qa.dropped) == 0

    over = np.zeros((3, EMIT_W), np.float32)
    over[:, 0] = [100.0, 101.0, 102.0]
    over[:, 1] = [1.0, -1.0, 1.0]  # two real rows onto a full queue
    qa = device_queue_fill_rows(qa, jnp.asarray(over))
    qb = device_queue_push_rows(qb, jnp.asarray(over))
    assert_queue_equal(qa, qb, "overflow")
    assert int(qa.dropped) == 2
    assert int(qa.size) == capacity + 2       # logical pushes keep counting
    assert int(qa.next_seq) == capacity + 2


def test_all_empty_emit_block_is_noop():
    q0 = device_queue_from_host(
        [(1.0, 0, np.zeros(ARG_WIDTH, np.float32))], 8
    )
    rows = jnp.asarray(np.full((4, EMIT_W), -1.0, np.float32))
    qa = device_queue_fill_rows(q0, rows)
    qb = device_queue_push_rows(q0, rows)
    assert_queue_equal(qa, qb, "empty block")
    assert_queue_equal(qa, q0, "empty block must not change the queue")


def test_from_host_matches_serial_pushes():
    """Host-side seed-queue build == N serial pushes, incl. overflow."""
    rng = np.random.default_rng(3)
    capacity = 6
    events = []
    for i in range(9):  # 3 past capacity
        arg = rng.random(ARG_WIDTH).astype(np.float32)
        events.append((float(rng.integers(0, 4)), int(rng.integers(0, 3)), arg))
    qa = device_queue_from_host(events, capacity)
    qb = device_queue_init(capacity)
    for (t, ty, arg) in events:
        qb = device_queue_push(qb, t, ty, jnp.asarray(arg))
    assert_queue_equal(qa, qb, "from_host")
    assert int(qa.dropped) == 3


def test_extract_on_empty_queue():
    lookaheads = jnp.asarray([1.0], jnp.float32)
    q = device_queue_init(8)
    qa, ts, tys, args, length = device_queue_extract(q, 4, lookaheads)
    qb, tsb, tysb, argsb, lengthb = device_queue_extract_ref(q, 4, lookaheads)
    assert int(length) == int(lengthb) == 0
    np.testing.assert_array_equal(np.asarray(tys), np.asarray(tysb))
    assert_queue_equal(qa, qb, "empty extract")


# ---------------------------------------------------------------------------
# Shared extraction semantics: device rule == host rule
# ---------------------------------------------------------------------------

def test_window_rule_matches_host_extract_window():
    from repro.core import HostEventQueue, extract_window
    from repro.core import extract_window_presorted

    rng = np.random.default_rng(11)
    reg = EventRegistry()
    reg.register("A", lambda s, t, a: s, lookahead=1.0)
    reg.register("B", lambda s, t, a: s, lookahead=0.25)
    reg.register("C", lambda s, t, a: s, lookahead=np.inf)
    reg.freeze()
    for _ in range(20):
        n = int(rng.integers(1, 10))
        evs = [(float(rng.integers(0, 5)), int(rng.integers(0, 3)))
               for _ in range(n)]
        hq = HostEventQueue()
        for t, ty in evs:
            hq.push(t, ty)
        sorted_events = sorted(
            (hq.pop() for _ in range(n)), key=lambda e: e.key()
        )
        hq2 = HostEventQueue()
        for t, ty in evs:
            hq2.push(t, ty)
        batch = extract_window(hq2, reg, max_len=4)
        k = extract_window_presorted(sorted_events, reg, max_len=4)
        assert k == len(batch)
        assert [e.key() for e in sorted_events[:k]] == \
               [e.key() for e in batch]


# ---------------------------------------------------------------------------
# Engine level
# ---------------------------------------------------------------------------

def _order_sensitive_registry():
    """Handlers whose effect encodes execution order in the state, with
    conditional emissions that stress the insert path."""
    reg = EventRegistry()

    @emits_events
    def ping(state, t, arg):
        emit = jnp.full((1, EMIT_W), -1.0, jnp.float32)
        # emit a pong at t+1 only while t < 6 (bounded cascade)
        emit = jnp.where(
            t < 6.0,
            emit.at[0, 0].set(t + 1.0).at[0, 1].set(1.0),
            emit,
        )
        return state * 7 + (t.astype(jnp.int32) * 2 + 1), emit

    def pong(state, t, arg):
        return state * 7 + (t.astype(jnp.int32) * 2 + 2)

    reg.register("Ping", ping, lookahead=1.0)
    reg.register("Pong", pong, lookahead=1.0)
    return reg.freeze()


def test_use_vectorized_queue_removed():
    """The removed flag fails fast with a pointer at queue_mode."""
    reg = _order_sensitive_registry()
    with pytest.raises(TypeError, match="queue_mode"):
        DeviceEngine(reg, max_batch_len=3, capacity=32,
                     use_vectorized_queue=True)


@pytest.mark.parametrize("seed", [0, 1])
def test_engine_vectorized_matches_reference_path(seed):
    """Full DeviceEngine runs: vectorized queue vs seed reference queue
    give identical states, stats, and final queue contents."""
    rng = np.random.default_rng(seed)
    events = [(float(t), int(rng.integers(0, 2)), None)
              for t in range(int(rng.integers(4, 10)))]
    results = []
    for mode in ("flat", "reference"):
        reg = _order_sensitive_registry()
        eng = DeviceEngine(reg, max_batch_len=3, capacity=32, max_emit=1,
                           queue_mode=mode)
        q = eng.initial_queue(events)
        s, q, stats = eng.run(jnp.int32(1), q, max_batches=64)
        results.append((s, q, stats))
    (sa, qa, sta), (sb, qb, stb) = results
    assert int(sa) == int(sb)
    assert_queue_equal(qa, qb, "engine final queue")
    for k in ("batches", "events", "dropped"):
        assert int(sta[k]) == int(stb[k]), k
    assert float(sta["time"]) == float(stb["time"])


def test_engine_surfaces_dropped_in_stats():
    """Overflowing emissions are counted, not silently lost."""
    reg = EventRegistry()

    @emits_events
    def spawner(state, t, arg):
        emit = jnp.zeros((2, EMIT_W), jnp.float32)
        emit = emit.at[:, 0].set(t + 1.0).at[:, 1].set(0.0)
        return state + 1, emit

    reg.register("S", spawner, lookahead=1.0)
    # capacity 4: the 2^k spawning cascade must overflow quickly
    eng = DeviceEngine(reg, max_batch_len=2, capacity=4, max_emit=2)
    q = eng.initial_queue([(0.0, 0, None)])
    s, q, stats = eng.run(jnp.int32(0), q, max_batches=8)
    assert int(stats["dropped"]) > 0
    assert int(stats["dropped"]) == int(q.dropped)


def test_entity_run_path_matches_sequential_dispatch():
    """Single-type-run windows dispatched via vmap == switch dispatch."""
    reg = EventRegistry()

    def bump_seq(state, t, arg):
        i = arg[0].astype(jnp.int32)
        return state.at[i].add(t + 1.0)

    reg.register("Bump", bump_seq, lookahead=10.0)
    reg.register("Other", lambda s, t, a: s * 0.5 + 1.0, lookahead=10.0)
    reg.freeze()

    def bump_local(entity_state, t, arg):
        return entity_state + t + 1.0

    rng = np.random.default_rng(5)
    events = []
    perm = rng.permutation(6)
    for k in range(12):
        ty = int(rng.integers(0, 2))
        arg = np.zeros((ARG_WIDTH,), np.float32)
        arg[0] = float(perm[k % 6])  # distinct entities within any window
        events.append((float(k), ty, arg))

    state0 = jnp.zeros((6,), jnp.float32)
    eng_run = DeviceEngine(reg, max_batch_len=4, capacity=32,
                           entity_handlers={0: bump_local})
    eng_seq = DeviceEngine(reg, max_batch_len=4, capacity=32)
    s_run, _, st_run = eng_run.run(state0, eng_run.initial_queue(events))
    s_seq, _, st_seq = eng_seq.run(state0, eng_seq.initial_queue(events))
    np.testing.assert_allclose(np.asarray(s_run), np.asarray(s_seq),
                               rtol=1e-6)
    assert int(st_run["events"]) == int(st_seq["events"]) == len(events)


def test_entity_handler_rejects_emitting_types():
    reg = EventRegistry()

    @emits_events
    def e(state, t, arg):
        return state, jnp.full((1, EMIT_W), -1.0, jnp.float32)

    reg.register("E", e, lookahead=1.0)
    with pytest.raises(ValueError, match="must not emit"):
        DeviceEngine(reg, entity_handlers={0: lambda s, t, a: s})


# ---------------------------------------------------------------------------
# Satellite regression: device_queue_push_rows is ONE scatter pass that
# must stay bit-identical to the serial seed spec INCLUDING slot
# placement (serial pushes fill free slots in ascending order), over
# full-queue and tie-heavy row batches.
# ---------------------------------------------------------------------------

def assert_layout_identical(qa, qb, msg=""):
    """Stronger than canonical(): every field equal slot-for-slot."""
    for name in qa._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(qa, name)), np.asarray(getattr(qb, name)),
            err_msg=f"{msg}: field {name!r} diverged",
        )


def _tie_rows(times, types):
    rows = np.zeros((len(times), EMIT_W), np.float32)
    rows[:, 0] = times
    rows[:, 1] = types
    for i in range(len(times)):
        rows[i, 2:] = i + 1
    return jnp.asarray(rows)


def test_push_rows_bulk_matches_serial_full_queue_and_ties():
    from repro.core.queue import device_queue_push_rows_serial

    fill_b = jax.jit(device_queue_push_rows)
    fill_s = jax.jit(device_queue_push_rows_serial)
    ex = jax.jit(device_queue_extract_ref, static_argnums=1)
    la = jnp.asarray([1.0, 1.0, 1.0], jnp.float32)

    qa, qb = device_queue_init(8), device_queue_init(8)
    # tie-heavy: every row same timestamp (order must fall back to seq)
    blk = _tie_rows([3.0, 3.0, 3.0, 3.0], [0, 1, 2, 0])
    qa, qb = fill_b(qa, blk), fill_s(qb, blk)
    assert_layout_identical(qa, qb, "tie block")
    # fill EXACTLY to capacity with a hole in the middle
    blk = _tie_rows([1.0, 2.0, 1.0, 2.0], [1, -1, 0, 2])
    qa, qb = fill_b(qa, blk), fill_s(qb, blk)
    blk = _tie_rows([0.5, 0.5], [2, 2])
    qa, qb = fill_b(qa, blk), fill_s(qb, blk)
    # 9 logical pushes into capacity 8: one ghost, all slots occupied
    assert int(qa.size) == 9 and int(qa.dropped) == 1
    assert int(jnp.sum(qa.types >= 0)) == 8
    assert_layout_identical(qa, qb, "exactly full")
    # overflowing block on the full queue: all ghosts
    blk = _tie_rows([9.0, 9.0, 9.0], [0, 0, 0])
    qa, qb = fill_b(qa, blk), fill_s(qb, blk)
    assert_layout_identical(qa, qb, "ghost block")
    assert int(qa.dropped) == 4
    # pop a couple (leaves interior holes), then refill over the holes —
    # the bulk path must pick the same first-free slots as serial pushes
    qa, *outa = ex(qa, 3, la)
    qb, *outb = ex(qb, 3, la)
    for x, y in zip(outa, outb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    blk = _tie_rows([4.0, 4.0], [1, 1])
    qa, qb = fill_b(qa, blk), fill_s(qb, blk)
    assert_layout_identical(qa, qb, "refill over holes")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_push_rows_bulk_matches_serial_random_streams(seed):
    from repro.core.queue import device_queue_push_rows_serial

    fill_b = jax.jit(device_queue_push_rows)
    fill_s = jax.jit(device_queue_push_rows_serial)
    ex = jax.jit(device_queue_extract_ref, static_argnums=1)
    rng = np.random.default_rng(seed)
    la = jnp.asarray(rng.choice([0.0, 1.0, np.inf], size=3), jnp.float32)
    qa, qb = device_queue_init(12), device_queue_init(12)
    for step in range(40):
        if rng.random() < 0.6:
            rows = random_rows(rng, 4)
            qa, qb = fill_b(qa, rows), fill_s(qb, rows)
        else:
            qa, *outa = ex(qa, 3, la)
            qb, *outb = ex(qb, 3, la)
            for x, y in zip(outa, outb):
                np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y),
                    err_msg=f"seed {seed} step {step}")
        assert_layout_identical(qa, qb, f"seed {seed} step {step}")
