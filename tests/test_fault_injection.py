"""Fault-injection harness: every fault class is detected AND
recovered (restore-and-replay is bit-identical to a clean run).

Drives :mod:`repro.testing.faults` — the same scenarios the CI smoke
step runs standalone (``python -m repro.testing.faults``) — plus
direct checks of the typed error surface (fault word decoding, entry
audit, full-audit dispatch).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import validate as V
from repro.testing.faults import (
    CORRUPTIONS,
    run_corruption_scenario,
    run_crash_scenario,
    run_overflow_scenario,
    tiny_phold,
)

_EXPECT_BITS = {
    "nan_time": V.FAULT_TIME_NONFINITE,
    "nonmonotone_front": V.FAULT_FRONT_ORDER,
    "dup_seq": V.FAULT_FRONT_ORDER,
    "truncate_run_log": V.FAULT_CONSERVATION,
    "seq_rewind": V.FAULT_SEQ_RANGE,
}


@pytest.fixture(scope="module")
def phold_sim():
    # one compile shared by every scenario in this module
    return tiny_phold().build(backend="device", validate="full")


@pytest.mark.parametrize("kind", sorted(CORRUPTIONS))
def test_corruption_detected_and_recovered(kind, phold_sim, tmp_path):
    report = run_corruption_scenario(kind, tmpdir=str(tmp_path),
                                     sim=phold_sim)
    assert report["recovered"]
    want = V.fault_names(_EXPECT_BITS[kind])[0]
    assert want in report["detected"], report


def test_crash_resume_bit_identical(phold_sim, tmp_path):
    report = run_crash_scenario(tmpdir=str(tmp_path), sim=phold_sim)
    assert report["recovered"]


def test_overflow_error_and_spill_recovery():
    report = run_overflow_scenario()
    assert report["detected"] == ["overflow"]
    assert report["recovered"]


def test_entry_audit_fires_before_any_execution(phold_sim, tmp_path):
    """A queue corrupted between segments trips the ENTRY audit: the
    resumed segment raises without executing a single further batch."""
    from repro.core.validate import EngineFaultError

    def corrupt_then_count(seg, state, queue, stats):
        if seg == 2:
            return state, CORRUPTIONS["nonmonotone_front"](queue), stats
        return None

    with pytest.raises(EngineFaultError) as ei:
        phold_sim.run(jnp.int32(0), max_batches=40, checkpoint_every=5,
                      checkpoint_dir=str(tmp_path),
                      _segment_hook=corrupt_then_count)
    # detected AT the boundary batch count (2 segments * 5 batches),
    # i.e. before the poisoned front reached a handler
    assert ei.value.fault_step == 10
    assert "front_order" in V.fault_names(ei.value.fault_word)


def test_fault_names_decode():
    names = V.fault_names(V.FAULT_FRONT_ORDER | V.FAULT_CONSERVATION)
    assert names == ["front_order", "conservation"]
    assert V.fault_names(0) == []


def test_full_audit_clean_queue(phold_sim):
    res = phold_sim.run(jnp.int32(0), max_batches=20)
    assert res.fault_word == 0
    assert res.fault_step == -1
    findings = V.full_audit(res.raw["final_queue"])
    assert findings == []
