"""Roofline machinery tests: HLO cost model vs analytic ground truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloModule, analyze_hlo
from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    model_flops_for,
)


def _compile(fn, *specs, donate=()):
    return jax.jit(fn, donate_argnums=donate).lower(*specs).compile()


def test_dot_flops_exact():
    M, K, N = 64, 128, 32
    a = jax.ShapeDtypeStruct((M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((K, N), jnp.float32)
    c = _compile(lambda a, b: a @ b, a, b)
    cost = analyze_hlo(c.as_text())
    assert cost.flops == 2 * M * K * N


def test_scan_multiplies_body_flops():
    """THE critical property: XLA's cost_analysis counts a scan body
    once; our loop-aware walk multiplies by the trip count."""
    M = 64
    L = 12
    w = jax.ShapeDtypeStruct((L, M, M), jnp.float32)
    x = jax.ShapeDtypeStruct((M,), jnp.float32)

    def f(w, x):
        def body(x, wi):
            return wi @ x, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    c = _compile(f, w, x)
    cost = analyze_hlo(c.as_text())
    expect = L * 2 * M * M
    assert abs(cost.flops - expect) / expect < 0.01, (
        f"scan flops {cost.flops} != {expect}")
    # and XLA's own number is ~L times smaller (documents the bug we fix)
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert ca.get("flops", 0) < expect / (L / 2)


def test_nested_scan_multiplies_through():
    M, L1, L2 = 32, 5, 7
    w = jax.ShapeDtypeStruct((L1, L2, M, M), jnp.float32)
    x = jax.ShapeDtypeStruct((M,), jnp.float32)

    def f(w, x):
        def outer(x, wo):
            def inner(x, wi):
                return wi @ x, None
            x, _ = jax.lax.scan(inner, x, wo)
            return x, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    c = _compile(f, w, x)
    cost = analyze_hlo(c.as_text())
    expect = L1 * L2 * 2 * M * M
    assert abs(cost.flops - expect) / expect < 0.01


def test_scan_sliced_weight_reads_not_full_stack():
    """Memory model: a scan body reading one layer's weight slice from
    the stacked [L, M, M] tensor must count ~L·M·M bytes per sweep, not
    L·(L·M·M)."""
    M, L = 128, 16
    w = jax.ShapeDtypeStruct((L, M, M), jnp.float32)
    x = jax.ShapeDtypeStruct((M,), jnp.float32)

    def f(w, x):
        def body(x, wi):
            return jnp.tanh(wi @ x), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    c = _compile(f, w, x)
    cost = analyze_hlo(c.as_text())
    stack_bytes = L * M * M * 4
    # one full sweep of weights, small activations; anything > 3 sweeps
    # would indicate the full-stack-per-iteration overcount
    assert cost.mem_bytes < 3 * stack_bytes, (
        f"mem {cost.mem_bytes} vs stack {stack_bytes}")
    assert cost.mem_bytes > 0.8 * stack_bytes


def test_trip_count_parsing():
    x = jax.ShapeDtypeStruct((8,), jnp.float32)

    def f(x):
        return jax.lax.fori_loop(0, 23, lambda i, x: x * 1.5 + 1.0, x)

    c = _compile(f, x)
    mod = HloModule(c.as_text())
    trips = []
    for comp in mod.comps.values():
        for i in comp:
            if i.opcode == "while":
                trips.append(mod._trip_count(i))
    assert 23 in trips


def test_roofline_terms_and_dominance():
    r = Roofline(
        arch="a", shape="s", mesh="single", chips=256,
        flops_per_device=197e12,          # exactly 1 s of compute
        bytes_per_device=819e9 * 2,       # 2 s of memory
        collective_bytes_per_device=50e9 * 0.5,
        collective_detail={}, model_flops=197e12 * 256 * 0.5,
        memory_stats={})
    assert r.compute_seconds == pytest.approx(1.0)
    assert r.memory_seconds == pytest.approx(2.0)
    assert r.collective_seconds == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.mfu == pytest.approx(0.25)   # useful/(bound*peak*chips)
    assert r.useful_flops_fraction == pytest.approx(0.5)


def test_model_flops_moe_uses_active_params():
    from repro.configs import get_config
    dense = get_config("stablelm-12b")
    moe = get_config("granite-moe-1b-a400m")
    assert model_flops_for(dense, "train", 100, 4096) == pytest.approx(
        6 * dense.param_count() * 100)
    assert model_flops_for(moe, "train", 100, 4096) < \
        6 * moe.param_count() * 100  # active < total

