"""Substrate tests: optimizer, data, checkpointing, compression,
supervisor fault handling, serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_batch, shard_slice
from repro.models import LM
from repro.training.compression import (
    apply_error_feedback,
    compress_residual,
    dequantize_int8,
    error_feedback_init,
    quantize_int8,
)
from repro.training.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    schedule_lr,
)
from repro.training.train_step import init_train_state, make_train_step


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_descends_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0]), "scale": jnp.asarray([1.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, schedule="constant",
                      warmup_steps=0)
    opt = adamw_init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * p, params)  # d/dp p^2
        params, opt, m = adamw_update(cfg, params, grads, opt)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_weight_decay_mask():
    """Norm scales and biases must not be decayed."""
    params = {"w": jnp.ones((2,)), "mixer_norm": {"scale": jnp.ones((2,))}}
    cfg = AdamWConfig(lr=0.0, weight_decay=1.0, schedule="constant")
    # lr=0: updates are identically zero; this is a smoke check on paths
    opt = adamw_init(params)
    p2, _, _ = adamw_update(cfg, params,
                            jax.tree.map(jnp.zeros_like, params), opt)
    assert jnp.allclose(p2["mixer_norm"]["scale"], 1.0)


def test_wsd_schedule_shape():
    cfg = AdamWConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                      total_steps=100, stable_frac=0.8)
    lrs = [float(schedule_lr(cfg, jnp.int32(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6          # end of warmup
    assert abs(lrs[50] - 1.0) < 1e-6          # stable plateau
    assert lrs[100] < 0.25 * lrs[50]          # decay tail
    cfg2 = AdamWConfig(lr=1.0, schedule="cosine", warmup_steps=10,
                       total_steps=100)
    lrs2 = [float(schedule_lr(cfg2, jnp.int32(s))) for s in (10, 55, 100)]
    assert lrs2[0] > lrs2[1] > lrs2[2] >= 0.0


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restart_safe():
    cfg = DataConfig(vocab_size=1000, seq_len=16, global_batch=8, seed=3)
    b1 = make_batch(cfg, 7)
    b2 = make_batch(cfg, 7)
    assert jnp.array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(cfg, 8)
    assert not jnp.array_equal(b1["tokens"], b3["tokens"])
    assert int(b1["tokens"].max()) < 1000


def test_data_shard_slices_partition_global_batch():
    cfg = DataConfig(vocab_size=100, seq_len=4, global_batch=8)
    full = make_batch(cfg, 0)
    parts = [shard_slice(cfg, 0, s, 4)["tokens"] for s in range(4)]
    assert jnp.array_equal(jnp.concatenate(parts, 0), full["tokens"])


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

def _tree():
    return {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
              "step": jnp.int32(7)},
    }


def test_checkpoint_roundtrip_bf16(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree()
    mgr.save(3, tree)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, step = mgr.restore(template)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree())
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_integrity_check(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    path = mgr.save(1, _tree())
    # corrupt one leaf
    victim = os.path.join(path, "a.npy")
    arr = np.load(victim)
    arr[0, 0] += 1
    np.save(victim, arr)
    template = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), _tree())
    with pytest.raises(IOError, match="checksum"):
        mgr.restore(template)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

@given(st.integers(0, 5))
@settings(max_examples=6, deadline=None)
def test_quantize_roundtrip_error_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 10
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum of (compressed + residual) over steps equals the true sum —
    error feedback loses nothing asymptotically."""
    key = jax.random.PRNGKey(0)
    grads = [jax.random.normal(jax.random.fold_in(key, i), (64,)) * 0.01
             for i in range(20)]
    ef = jnp.zeros((64,))
    sent_total = jnp.zeros((64,))
    for g in grads:
        comp = g + ef
        sent, ef = compress_residual(comp)
        sent_total = sent_total + sent
    true_total = sum(grads)
    # all that is missing is the final residual
    np.testing.assert_allclose(np.asarray(sent_total + ef),
                               np.asarray(true_total), rtol=1e-4, atol=1e-5)


def test_train_step_with_compression_descends():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    model = LM(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0),
                             compression=True)
    assert "ef" in state
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3),
                                   num_microbatches=2, remat=False))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    losses = []
    for i in range(6):
        state, metrics = step(state, make_batch(dc, i))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()


# ---------------------------------------------------------------------------
# microbatching consistency
# ---------------------------------------------------------------------------

def test_microbatched_grads_match_full_batch():
    cfg = get_config("stablelm-12b").reduced()
    model = LM(cfg)
    state = init_train_state(model, jax.random.PRNGKey(1))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
    batch = make_batch(dc, 0)
    s1 = jax.jit(make_train_step(model, AdamWConfig(), num_microbatches=1,
                                 remat=False))
    s4 = jax.jit(make_train_step(model, AdamWConfig(), num_microbatches=4,
                                 remat=False))
    _, m1 = s1(state, batch)
    _, m4 = s4(state, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-2
    assert abs(float(m1["grad_norm"]) - float(m4["grad_norm"])) < 5e-2


# ---------------------------------------------------------------------------
# supervisor
# ---------------------------------------------------------------------------

def test_supervisor_crash_recovery(tmp_path):
    from repro.runtime.supervisor import (
        FailureEvent, FailureInjector, TrainSupervisor)

    cfg = get_config("stablelm-12b").reduced()
    model = LM(cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=8, global_batch=2)

    def make_step(n):
        return jax.jit(make_train_step(model, opt_cfg))

    state = init_train_state(model, jax.random.PRNGKey(0))
    sup = TrainSupervisor(
        make_step=make_step, make_batch=lambda s: make_batch(dc, s),
        init_state=state, ckpt=CheckpointManager(str(tmp_path)),
        ckpt_every=4,
        injector=FailureInjector([
            FailureEvent(step=6, kind="crash"),
            FailureEvent(step=9, kind="slow_node", node=0),
        ]))
    report = sup.run(12)
    assert report.restarts == 1
    assert report.straggler_mitigations == 1
    assert int(sup.state["opt"]["step"]) == 12
    # crash at 6 restores ckpt@4 and replays 4..6: extra steps run
    assert report.steps_run == 12 + 2
    assert np.isfinite(report.final_loss)


# ---------------------------------------------------------------------------
# serving engine (DES-driven continuous batching)
# ---------------------------------------------------------------------------

def test_serving_engine_fuses_decode_runs():
    cfg = get_config("stablelm-12b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.serving.engine import ServingEngine

    eng = ServingEngine(model, params, max_slots=2, max_len=64,
                        max_batch_len=4, arrival_lookahead=5.0)
    eng.submit(0, [5, 6, 7], max_new_tokens=6, at=0.0)
    eng.submit(1, [8, 9], max_new_tokens=6, at=6.0)
    eng.schedule_decode_grid(1.0, 40.0)
    stats = eng.run()
    assert all(r.done for r in eng.requests.values())
    assert stats.fused_batches > 0, "no decode runs were batch-fused"
    assert stats.mean_fused_length > 1.5
    for r in eng.requests.values():
        assert len(r.output) == 6


def test_serving_fused_matches_single_step_decode():
    """The composed k-step program must produce the same tokens as k
    single steps (cross-event fusion is an optimization, not a change
    in semantics)."""
    cfg = get_config("stablelm-12b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.serving.engine import ServingEngine

    def serve(max_batch_len):
        eng = ServingEngine(model, params, max_slots=1, max_len=64,
                            max_batch_len=max_batch_len,
                            arrival_lookahead=3.0)
        eng.submit(0, [11, 12, 13, 14], max_new_tokens=8, at=0.0)
        eng.schedule_decode_grid(1.0, 30.0)
        eng.run()
        return eng.requests[0].output

    assert serve(1) == serve(4)


def test_serving_slot_exhaustion_queues_requests():
    """More requests than slots: later arrivals wait for evictions and
    still complete (the PREFILL retry path)."""
    cfg = get_config("stablelm-12b").reduced()
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.serving.engine import ServingEngine

    eng = ServingEngine(model, params, max_slots=1, max_len=64,
                        max_batch_len=3, arrival_lookahead=2.0)
    for rid in range(3):
        eng.submit(rid, [5 + rid, 6], max_new_tokens=3, at=float(rid))
    eng.schedule_decode_grid(1.0, 60.0)
    eng.run()
    assert all(r.done for r in eng.requests.values())
    finish = [eng.requests[r].finish_time for r in range(3)]
    assert finish[0] < finish[1] < finish[2]  # served in order
