"""Import guard for ``hypothesis`` (see requirements-dev.txt).

On a bare environment (no dev extras installed) the property-based
tests must still *collect* — module-level ``from hypothesis import ...``
used to abort collection of four whole test modules, hiding every
plain test they contain.  Importing ``given``/``settings``/``st`` from
here instead yields the real hypothesis API when available, and a
minimal stand-in otherwise: ``@given(...)`` tests collect normally and
individually skip at run time, while all non-property tests in the same
module keep running.
"""

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _StubStrategy:
        """Placeholder accepted anywhere a strategy object is used."""

        def __call__(self, *args, **kwargs):
            return _StubStrategy()

        def __getattr__(self, name):
            return _StubStrategy()

    st = _StubStrategy()

    def given(*_args, **_kwargs):
        def decorate(fn):
            # Zero-argument wrapper: pytest must not mistake the
            # strategy parameters for fixtures.
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate
