"""Differential tests: tiered (front/staging/main) device-queue ops vs
the seed per-event reference ops and the PR-1 flat vectorized ops.

The tiered ops must reproduce the reference ``(time, seq)`` pop order
BIT-EXACTLY — including timestamp ties, exactly-full tiers, staging-ring
spill (front eviction), the append fast path, ring compaction, and
overflow across all three tiers — over random interleaved event
streams.  ``tiered_queue_to_flat`` provides the layout-independent view
used for queue-content comparison.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import DeviceEngine, EventRegistry, emits_events
from repro.core.events import ARG_WIDTH
from repro.core.queue import (
    device_queue_extract_ref,
    device_queue_from_host,
    device_queue_init,
    device_queue_pop,
    device_queue_push_rows,
    tiered_queue_extract,
    tiered_queue_fill_rows,
    tiered_queue_from_host,
    tiered_queue_has_pending,
    tiered_queue_init,
    tiered_queue_occupancy,
    tiered_queue_to_flat,
)

EMIT_W = 2 + ARG_WIDTH

# jit the per-step ops once per shape config: the differential loops
# below apply them hundreds of times.
_fill_tiered = jax.jit(tiered_queue_fill_rows)
_fill_ref = jax.jit(device_queue_push_rows)
_extract_tiered = jax.jit(tiered_queue_extract, static_argnums=1)
_extract_ref = jax.jit(device_queue_extract_ref, static_argnums=1)


def canonical(q):
    """Layout-independent view: occupied slots sorted by (time, seq)."""
    times = np.asarray(q.times)
    types = np.asarray(q.types)
    args = np.asarray(q.args)
    seqs = np.asarray(q.seqs)
    occ = types >= 0
    order = np.lexsort((seqs[occ], times[occ]))
    return {
        "times": times[occ][order],
        "types": types[occ][order],
        "args": args[occ][order],
        "seqs": seqs[occ][order],
        "size": int(q.size),
        "next_seq": int(q.next_seq),
        "dropped": int(q.dropped),
    }


def assert_tiered_equals_flat(qt, qf, msg=""):
    ca, cb = canonical(tiered_queue_to_flat(qt)), canonical(qf)
    for field, va in ca.items():
        np.testing.assert_array_equal(
            va, cb[field], err_msg=f"{msg}: field {field!r} diverged",
        )


def random_rows(rng, n_rows, *, p_valid=0.7, num_types=3, t_lo=0, t_hi=5):
    rows = np.zeros((n_rows, EMIT_W), np.float32)
    rows[:, 1] = -1.0
    for i in range(n_rows):
        if rng.random() < p_valid:
            # small integer times force heavy timestamp ties
            rows[i, 0] = float(rng.integers(t_lo, t_hi))
            rows[i, 1] = float(rng.integers(0, num_types))
            rows[i, 2:] = rng.random(ARG_WIDTH).astype(np.float32)
    return jnp.asarray(rows)


def run_differential(seed, capacity, max_len, front_cap, stage_cap,
                     steps=50, n_rows=4):
    """Random interleaving of bulk inserts and window extractions; the
    tiered and reference paths must agree on every intermediate queue
    state and every extracted window."""
    rng = np.random.default_rng(seed)
    lookaheads = jnp.asarray(
        rng.choice([0.0, 0.5, 1.0, np.inf], size=3), jnp.float32
    )
    qa = tiered_queue_init(capacity, front_cap=front_cap,
                           stage_cap=stage_cap)
    qb = device_queue_init(capacity)
    for step in range(steps):
        if rng.random() < 0.5:
            rows = random_rows(rng, n_rows)
            qa = _fill_tiered(qa, rows)
            qb = _fill_ref(qb, rows)
        else:
            qa, tsa, tya, aa, la = _extract_tiered(qa, max_len, lookaheads)
            qb, tsb, tyb, ab, lb = _extract_ref(qb, max_len, lookaheads)
            msg = f"seed {seed} step {step}"
            np.testing.assert_array_equal(
                np.asarray(tsa), np.asarray(tsb), err_msg=msg)
            np.testing.assert_array_equal(
                np.asarray(tya), np.asarray(tyb), err_msg=msg)
            np.testing.assert_array_equal(
                np.asarray(aa), np.asarray(ab), err_msg=msg)
            assert int(la) == int(lb), msg
        assert_tiered_equals_flat(qa, qb, msg=f"seed {seed} step {step}")
        occ = int(tiered_queue_occupancy(qa))
        assert occ <= capacity, "tier occupancy exceeded logical capacity"
        assert bool(tiered_queue_has_pending(qa)) == (occ > 0)


# Tiny tiers force every rare path: front eviction, staging spill,
# flush merge, refill.  front_cap == capacity exercises the degenerate
# everything-in-front config; stage_cap > capacity the static
# append-elision path.
@pytest.mark.parametrize("front_cap,stage_cap", [
    (6, 4), (4, 5), (5, 7), (24, 24), (8, 40),
])
@pytest.mark.parametrize("seed", [0, 1])
def test_interleaved_stream_differential(seed, front_cap, stage_cap):
    run_differential(seed, capacity=24, max_len=4,
                     front_cap=front_cap, stage_cap=stage_cap)


def test_pop_order_bit_exact_under_ties():
    """max_len=1 extraction must reproduce device_queue_pop's
    lexicographic (time, seq) order exactly, including ties."""
    rng = np.random.default_rng(7)
    lookaheads = jnp.asarray([0.0, 0.0], jnp.float32)
    events = [(float(rng.integers(0, 3)), int(rng.integers(0, 2)),
               np.full((ARG_WIDTH,), float(i), np.float32))
              for i in range(12)]
    qa = tiered_queue_from_host(events, 16, front_cap=4, stage_cap=4)
    qb = device_queue_init(16)
    from repro.core.queue import device_queue_push
    for (t, ty, arg) in events:
        qb = device_queue_push(qb, t, ty, jnp.asarray(arg))
    for _ in range(12):
        qa, ts, tys, args, length = _extract_tiered(qa, 1, lookaheads)
        qb, t, ty, arg = device_queue_pop(qb)
        assert int(length) == 1
        assert float(ts[0]) == float(t)
        assert int(tys[0]) == int(ty)
        np.testing.assert_array_equal(np.asarray(args[0]), np.asarray(arg))
    assert int(qa.size) == 0 and int(qb.size) == 0
    assert not bool(tiered_queue_has_pending(qa))


def test_from_host_matches_flat_from_host():
    """Tiered and flat host-side seed builds agree, incl. overflow."""
    rng = np.random.default_rng(3)
    capacity = 6
    events = []
    for i in range(9):  # 3 past capacity
        arg = rng.random(ARG_WIDTH).astype(np.float32)
        events.append((float(rng.integers(0, 4)),
                       int(rng.integers(0, 3)), arg))
    qa = tiered_queue_from_host(events, capacity, front_cap=2, stage_cap=4)
    qb = device_queue_from_host(events, capacity)
    assert_tiered_equals_flat(qa, qb, "from_host")
    assert int(qa.dropped) == 3
    assert int(tiered_queue_occupancy(qa)) == capacity


def test_overflow_across_tiers_bit_exact():
    """Emits dropped when front+staging+main are full must match the
    reference dropped/size/next_seq accounting bit-exactly, including
    continued ghost growth after saturation."""
    capacity = 8
    qa = tiered_queue_init(capacity, front_cap=4, stage_cap=3)
    qb = device_queue_init(capacity)
    # fill to exactly capacity across all three tiers
    for lo in (0, 3, 6):
        rows = np.zeros((3, EMIT_W), np.float32)
        rows[:, 0] = np.arange(lo, lo + 3)
        rows[:, 1] = 0.0
        if lo == 6:
            rows[2, 1] = -1.0  # hole: 8 real events total
        qa = _fill_tiered(qa, jnp.asarray(rows))
        qb = _fill_ref(qb, jnp.asarray(rows))
    assert_tiered_equals_flat(qa, qb, "exactly full")
    assert int(tiered_queue_occupancy(qa)) == capacity
    assert int(qa.dropped) == 0

    # overflowing block: every real row past capacity is a ghost
    over = np.zeros((3, EMIT_W), np.float32)
    over[:, 0] = [100.0, 0.5, 102.0]   # 0.5 would land in the FRONT
    over[:, 1] = [1.0, 1.0, -1.0]
    qa = _fill_tiered(qa, jnp.asarray(over))
    qb = _fill_ref(qb, jnp.asarray(over))
    assert_tiered_equals_flat(qa, qb, "overflow")
    assert int(qa.dropped) == 2
    assert int(qa.size) == capacity + 2   # logical pushes keep counting
    assert int(qa.next_seq) == capacity + 2
    assert int(tiered_queue_occupancy(qa)) == capacity

    # ghosts must not spin has_pending after the queue drains
    lookaheads = jnp.asarray([np.inf, np.inf], jnp.float32)
    for _ in range(4):
        qa, _, _, _, la = _extract_tiered(qa, 4, lookaheads)
        qb, _, _, _, lb = _extract_ref(qb, 4, lookaheads)
        assert int(la) == int(lb)
        assert_tiered_equals_flat(qa, qb, "drain")
    assert not bool(tiered_queue_has_pending(qa))
    assert int(qa.size) == 2  # the ghosts remain in size, as reference


def test_staging_spill_and_append_fast_path():
    """Far-future emits take the staging append path; emits landing
    before the front boundary force evictions; both must stay
    bit-exact against the reference over a long alternating run."""
    rng = np.random.default_rng(42)
    qa = tiered_queue_init(64, front_cap=8, stage_cap=6)
    qb = device_queue_init(64)
    lookaheads = jnp.asarray([1.0, 1.0, 1.0], jnp.float32)
    t_clock = 0.0
    for step in range(40):
        rows = np.zeros((3, EMIT_W), np.float32)
        rows[:, 1] = -1.0
        for i in range(3):
            r = rng.random()
            if r < 0.6:   # far future: append fast path
                rows[i, 0] = t_clock + 10 + float(rng.integers(0, 5))
                rows[i, 1] = float(rng.integers(0, 3))
            elif r < 0.8:  # near future: front merge / eviction
                rows[i, 0] = t_clock + float(rng.integers(0, 3))
                rows[i, 1] = float(rng.integers(0, 3))
        rows = jnp.asarray(rows)
        qa = _fill_tiered(qa, rows)
        qb = _fill_ref(qb, rows)
        qa, tsa, _, _, la = _extract_tiered(qa, 4, lookaheads)
        qb, tsb, _, _, lb = _extract_ref(qb, 4, lookaheads)
        np.testing.assert_array_equal(np.asarray(tsa), np.asarray(tsb))
        assert int(la) == int(lb)
        if int(la):
            t_clock = float(np.asarray(tsa)[int(la) - 1])
        assert_tiered_equals_flat(qa, qb, f"spill step {step}")


@given(
    seed=st.integers(0, 2**16),
    front_cap=st.integers(4, 12),
    stage_cap=st.integers(4, 12),
    capacity=st.sampled_from([8, 16, 24]),
)
@settings(max_examples=20, deadline=None)
def test_property_random_streams(seed, front_cap, stage_cap, capacity):
    """Hypothesis property: for ANY tier geometry and random event
    stream, the tiered queue reproduces the reference pop order and
    counters bit-exactly."""
    run_differential(seed, capacity=capacity, max_len=4,
                     front_cap=front_cap, stage_cap=stage_cap, steps=24)


# ---------------------------------------------------------------------------
# Engine level
# ---------------------------------------------------------------------------

def _order_sensitive_registry():
    reg = EventRegistry()

    @emits_events
    def ping(state, t, arg):
        emit = jnp.full((1, EMIT_W), -1.0, jnp.float32)
        emit = jnp.where(
            t < 6.0,
            emit.at[0, 0].set(t + 1.0).at[0, 1].set(1.0),
            emit,
        )
        return state * 7 + (t.astype(jnp.int32) * 2 + 1), emit

    def pong(state, t, arg):
        return state * 7 + (t.astype(jnp.int32) * 2 + 2)

    reg.register("Ping", ping, lookahead=1.0)
    reg.register("Pong", pong, lookahead=1.0)
    return reg.freeze()


@pytest.mark.parametrize("seed", [0, 1])
def test_engine_three_queue_modes_agree(seed):
    """Full DeviceEngine runs under tiered / flat / reference queues
    give identical states, stats, and final queue contents."""
    rng = np.random.default_rng(seed)
    events = [(float(t), int(rng.integers(0, 2)), None)
              for t in range(int(rng.integers(4, 10)))]
    results = {}
    for mode in ("tiered", "flat", "reference"):
        kw = {"front_cap": 4, "stage_cap": 3} if mode == "tiered" else {}
        reg = _order_sensitive_registry()
        eng = DeviceEngine(reg, max_batch_len=3, capacity=32, max_emit=1,
                           queue_mode=mode, **kw)
        q = eng.initial_queue(events)
        s, q, stats = eng.run(jnp.int32(1), q, max_batches=64)
        results[mode] = (s, q, stats)
    s_t, q_t, st_t = results["tiered"]
    for mode in ("flat", "reference"):
        s_o, q_o, st_o = results[mode]
        assert int(s_t) == int(s_o), mode
        assert_tiered_equals_flat(q_t, q_o, f"final queue vs {mode}")
        for k in ("batches", "events", "dropped"):
            assert int(st_t[k]) == int(st_o[k]), (mode, k)
        assert float(st_t["time"]) == float(st_o["time"]), mode


def test_engine_overflow_cascade_across_tiers():
    """A 2^k spawning cascade over a tiny tiered queue must overflow
    with the same dropped/size/next_seq as the flat and reference
    engines, and the run must terminate (size counts ghosts)."""
    def make_reg():
        reg = EventRegistry()

        @emits_events
        def spawner(state, t, arg):
            emit = jnp.zeros((2, EMIT_W), jnp.float32)
            emit = emit.at[:, 0].set(t + 1.0).at[:, 1].set(0.0)
            return state + 1, emit

        reg.register("S", spawner, lookahead=1.0)
        return reg.freeze()

    outcomes = {}
    for mode in ("tiered", "flat", "reference"):
        kw = {"front_cap": 2, "stage_cap": 5} if mode == "tiered" else {}
        eng = DeviceEngine(make_reg(), max_batch_len=2, capacity=4,
                           max_emit=2, queue_mode=mode, **kw)
        q = eng.initial_queue([(0.0, 0, None)])
        s, q, stats = eng.run(jnp.int32(0), q, max_batches=8)
        outcomes[mode] = (int(s), int(stats["dropped"]), int(q.size),
                          int(q.next_seq))
    assert outcomes["tiered"] == outcomes["flat"] == outcomes["reference"]
    assert outcomes["tiered"][1] > 0  # it really overflowed


def test_engine_refill_aware_loop_termination():
    """With a front tier far smaller than the pending set, the engine
    must keep refilling (not stop when the front drains) and execute
    every event."""
    reg = EventRegistry()
    reg.register("N", lambda s, t, a: s + 1, lookahead=np.inf)
    eng = DeviceEngine(reg, max_batch_len=4, capacity=64, front_cap=4,
                       stage_cap=4, queue_mode="tiered")
    events = [(float(t), 0, None) for t in range(50)]
    s, q, stats = eng.run(jnp.int32(0), eng.initial_queue(events))
    assert int(s) == 50
    assert int(stats["events"]) == 50
    assert int(q.size) == 0


def test_run_consumes_queue_buffers():
    """DeviceEngine.run donates the queue: its capacity-sized buffers
    are reused for the output, so passing the same queue value twice
    must fail rather than silently recompute from stale data."""
    reg = EventRegistry()
    reg.register("N", lambda s, t, a: s + 1, lookahead=np.inf)
    eng = DeviceEngine(reg, max_batch_len=2, capacity=16)
    events = [(float(t), 0, None) for t in range(4)]
    q = eng.initial_queue(events)
    s, q_out, _ = eng.run(jnp.int32(0), q)
    assert int(s) == 4
    with pytest.raises((RuntimeError, ValueError)):
        eng.run(jnp.int32(0), q)
    # the returned queue is fresh and usable
    s2, _, stats2 = eng.run(jnp.int32(0), q_out)
    assert int(stats2["events"]) == 0  # q_out was drained
