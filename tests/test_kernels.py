"""Per-kernel validation: shape/dtype sweeps + hypothesis properties,
asserting allclose against the ref.py pure-jnp oracles (brief req. c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.ref import (
    decode_attention_ref,
    flash_attention_ref,
    mamba_scan_ref,
    rwkv6_scan_ref,
)
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "B,H,KV,T,S,D,bq,bk",
    [
        (1, 2, 2, 64, 64, 32, 32, 32),     # MHA square
        (2, 4, 2, 128, 256, 64, 64, 64),   # GQA, S > T
        (1, 8, 1, 64, 192, 128, 64, 64),   # MQA, S not multiple of block
        (1, 2, 2, 128, 96, 64, 64, 64),    # padded KV tail
    ],
)
def test_flash_attention_sweep(dtype, causal, B, H, KV, T, S, D, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, T, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, S, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, S, D)).astype(dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype))


def test_flash_attention_block_invariance():
    """Output must not depend on the block decomposition."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    outs = [
        flash_attention_pallas(q, k, v, causal=True, block_q=bq, block_k=bk)
        for bq, bk in [(32, 32), (64, 32), (128, 64), (128, 128)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.slow
@given(
    t_blocks=st.integers(1, 4),
    d=st.sampled_from([32, 64]),
    heads=st.sampled_from([(2, 1), (2, 2), (4, 2)]),
    causal=st.booleans(),
)
@settings(max_examples=12, deadline=None)
def test_flash_attention_property(t_blocks, d, heads, causal):
    H, KV = heads
    T = 32 * t_blocks
    ks = jax.random.split(jax.random.PRNGKey(t_blocks), 3)
    q = jax.random.normal(ks[0], (1, H, T, d))
    k = jax.random.normal(ks[1], (1, KV, T, d))
    v = jax.random.normal(ks[2], (1, KV, T, d))
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=32,
                                 block_k=32)
    ref = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,S,D,bk",
    [
        (2, 4, 4, 256, 64, 128),
        (3, 8, 2, 640, 64, 128),    # GQA + ragged lengths
        (1, 4, 1, 100, 32, 64),     # padded tail
    ],
)
def test_decode_attention_sweep(dtype, B, H, KV, S, D, bk):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, S, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, S, D)).astype(dtype)
    lengths = (jax.random.randint(ks[0], (B,), 1, S + 1)).astype(jnp.int32)
    out = decode_attention_pallas(q, k, v, lengths, block_k=bk)
    ref = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype))


def test_decode_attention_matches_flash_last_row():
    """Decoding token T-1 must equal row T-1 of causal flash attention."""
    B, H, T, D = 2, 4, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, T, D))
    k = jax.random.normal(ks[1], (B, H, T, D))
    v = jax.random.normal(ks[2], (B, H, T, D))
    full = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                  block_k=64)
    lengths = jnp.full((B,), T, jnp.int32)
    last = decode_attention_pallas(q[:, :, -1], k, v, lengths, block_k=64)
    np.testing.assert_allclose(np.asarray(full[:, :, -1]), np.asarray(last),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# rwkv6 scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.slow
@pytest.mark.parametrize(
    "B,H,T,K,chunk",
    [
        (2, 3, 100, 16, 32),    # padded tail
        (1, 2, 128, 64, 64),    # production head size
        (2, 1, 64, 32, 16),
    ],
)
def test_rwkv6_scan_sweep(dtype, B, H, T, K, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    r = jax.random.normal(ks[0], (B, H, T, K)).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, T, K)).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, T, K)).astype(dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, T, K)) * 0.5 - 1.0)
    u = (jax.random.normal(ks[4], (H, K)) * 0.1)
    out = rwkv6_scan_pallas(r, k, v, logw.astype(dtype), u.astype(dtype),
                            chunk=chunk)
    ref = rwkv6_scan_ref(r, k, v, logw.astype(dtype), u.astype(dtype))
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol)


def test_rwkv6_chunk_invariance():
    B, H, T, K = 1, 2, 96, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    r = jax.random.normal(ks[0], (B, H, T, K))
    k = jax.random.normal(ks[1], (B, H, T, K))
    v = jax.random.normal(ks[2], (B, H, T, K))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, T, K)) * 0.3 - 1.0)
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    outs = [rwkv6_scan_pallas(r, k, v, logw, u, chunk=c)
            for c in (16, 32, 48, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# jit'd ops wrappers (model layout)
# ---------------------------------------------------------------------------

def test_ops_flash_matches_model_reference():
    from repro.models.attention import reference_attention
    B, T, H, KV, D = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (B, T, H, D))
    k = jax.random.normal(ks[1], (B, T, KV, D))
    v = jax.random.normal(ks[2], (B, T, KV, D))
    out = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    ref = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_ops_decode_matches_model_reference():
    from repro.models.attention import decode_attention as model_decode
    B, S, H, KV, D = 2, 160, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    lengths = jnp.array([160, 77], jnp.int32)
    out = ops.decode_attention(q, k, v, lengths, block_k=64)
    ref = model_decode(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,I,N,chunk,bi",
    [
        (2, 50, 64, 8, 16, 32),    # padded tail
        (1, 64, 128, 16, 32, 128), # production-ish dims
        (3, 33, 32, 4, 8, 32),
    ],
)
def test_mamba_scan_sweep(dtype, B, T, I, N, chunk, bi):
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    xdt = jax.random.normal(ks[0], (B, T, I)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, I))).astype(dtype)
    bc = jax.random.normal(ks[2], (B, T, N)).astype(dtype)
    cc = jax.random.normal(ks[3], (B, T, N)).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[4], (I, N)) * 0.3)
    out = mamba_scan_pallas(xdt, dt, bc, cc, a, chunk=chunk, block_i=bi)
    ref = mamba_scan_ref(xdt, dt, bc, cc, a)
    tol = dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **tol)


def test_mamba_scan_chunk_invariance():
    B, T, I, N = 1, 48, 32, 8
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    xdt = jax.random.normal(ks[0], (B, T, I))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, I)))
    bc = jax.random.normal(ks[2], (B, T, N))
    cc = jax.random.normal(ks[3], (B, T, N))
    a = -jnp.exp(jax.random.normal(ks[4], (I, N)) * 0.3)
    outs = [mamba_scan_pallas(xdt, dt, bc, cc, a, chunk=c, block_i=16)
            for c in (8, 12, 16, 48)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-5, atol=2e-5)


def test_mamba_scan_matches_model_chunked_path():
    """The kernel must agree with models/ssm.py's associative-scan path
    (which the dry-run lowers)."""
    from repro.models.ssm import mamba_apply, mamba_init
    # indirect check: both equal the sequential oracle on shared math —
    # covered by test_mamba_scan_sweep + tests/test_arch_smoke decode
    # equivalences; here we assert the kernel handles the jamba dims.
    B, T, I, N = 1, 64, 256, 16
    ks = jax.random.split(jax.random.PRNGKey(10), 5)
    xdt = jax.random.normal(ks[0], (B, T, I))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, I)))
    bc = jax.random.normal(ks[2], (B, T, N))
    cc = jax.random.normal(ks[3], (B, T, N))
    a = -jnp.exp(jax.random.normal(ks[4], (I, N)) * 0.3)
    out = mamba_scan_pallas(xdt, dt, bc, cc, a, chunk=32, block_i=256)
    ref = mamba_scan_ref(xdt, dt, bc, cc, a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
