"""The sharded device engine's executable contract: bit-identical to
the single-shard tiered3 engine (mirrors the differential structure of
``test_device_queue_tiered3.py``, one level up).

Every super-step of :class:`~repro.core.sharded.ShardedDeviceEngine`
must reconstruct the exact single-queue §III-B window from the merged
shard heads, keep one global seq/overflow discipline across shards,
and route cross-shard emissions without perturbing order — so final
state (including an order-sensitive checksum), executed-event counts,
batch counts, ``dropped``, final time, AND the residual queue contents
(times/types/args/seqs) must all match the single queue exactly.  The
92%-occupancy churn drives the near-head / far-future / cross-shard
re-emit mix that stresses every exchange and refill path at once.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from _parity import assert_parity, run_all
from repro.core import DeviceEngine, EventRegistry, emits_events
from repro.core.events import ARG_WIDTH
from repro.core.queue import tiered3_queue_to_flat
from repro.core.sharded import (
    ShardedDeviceEngine,
    ShardedQueue,
    sharded_queue_to_flat,
)

EMIT_W = 2 + ARG_WIDTH


def _mix(t, src):
    """Counter hash of (time, entity) on the 0.5 grid (cf. phold)."""
    t2 = (t * 2.0).astype(jnp.uint32)
    h = (t2 * jnp.uint32(2654435761)
         + src.astype(jnp.uint32) * jnp.uint32(40503) + jnp.uint32(12345))
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0x5BD1E995)
    return h ^ (h >> 15)


def _churn_registry(num_entities: int, t_stop: float):
    """Order-sensitive near-full churn: each event folds its hash into
    a checksum (any order divergence corrupts it) and re-emits ONE row
    whose delay alternates near-head (0.5 grid) / far-future by the
    hash, routed to a hash-chosen entity — so re-emits continuously
    cross shard boundaries while occupancy stays stationary."""
    reg = EventRegistry()

    @emits_events
    def churn(state, t, arg):
        src = arg[0].astype(jnp.int32)
        h = _mix(t, src)
        near = (h % jnp.uint32(3)) != 0
        delay = jnp.where(
            near,
            0.5 + 0.5 * ((h >> 3) % jnp.uint32(4)).astype(jnp.float32),
            1e5 + ((h >> 3) % jnp.uint32(8)).astype(jnp.float32),
        )
        dst = ((h >> 7) % jnp.uint32(num_entities)).astype(jnp.int32)
        emit = jnp.zeros((1, EMIT_W), jnp.float32)
        emit = (emit.at[0, 0].set(t + delay)
                    .at[0, 1].set(jnp.where(t < t_stop, 0.0, -1.0))
                    .at[0, 2].set(dst.astype(jnp.float32)))
        return {
            "count": state["count"] + 1,
            "checksum": state["checksum"] * jnp.uint32(31) + h,
        }, emit

    reg.register("CHURN", churn, lookahead=0.5)
    return reg.freeze()


def _state0():
    return {"count": jnp.int32(0), "checksum": jnp.uint32(1)}


# One engine per static configuration: hypothesis re-feeds the SAME
# compiled engines new seed values, so the soak costs one compile per
# geometry, not per example.
_ENGINES = {}


def _engine(shards, *, capacity=48, max_len=4, num_entities=12,
            t_stop=64.0, front_cap=6, stage_cap=5, num_runs=2):
    key = (shards, capacity, max_len, num_entities, t_stop, front_cap,
           stage_cap, num_runs)
    if key not in _ENGINES:
        reg = _churn_registry(num_entities, t_stop)
        kw = dict(max_batch_len=max_len, capacity=capacity, max_emit=1,
                  front_cap=front_cap, stage_cap=stage_cap,
                  num_runs=num_runs)
        if shards == 0:
            _ENGINES[key] = DeviceEngine(reg, queue_mode="tiered3", **kw)
        else:
            _ENGINES[key] = ShardedDeviceEngine(reg, shards=shards, **kw)
    return _ENGINES[key]


def _seed_events(seed, capacity, num_entities, occupancy=0.92):
    """~92% of capacity seed events on the 0.5 grid, entities assigned
    pseudo-randomly so every shard starts loaded."""
    rng = np.random.default_rng(seed)
    n = int(capacity * occupancy)
    events = []
    for i in range(n):
        t = 0.5 * int(rng.integers(0, 2 * n))
        e = int(rng.integers(0, num_entities))
        events.append((t, 0, np.asarray([e, 0, 0, 0], np.float32)))
    return events


def _run_churn_differential(seed, shards, max_batches=48):
    single = _engine(0)
    sharded = _engine(shards)
    events = _seed_events(seed, single.capacity, 12)

    s0, q0, st0 = single.run(_state0(), single.initial_queue(events),
                             max_batches=max_batches)
    s1, q1, st1 = sharded.run(_state0(), sharded.initial_queue(events),
                              max_batches=max_batches)

    msg = f"seed {seed} shards {shards}"
    assert int(s0["count"]) == int(s1["count"]), msg
    assert int(s0["checksum"]) == int(s1["checksum"]), msg
    for k in ("batches", "events", "dropped"):
        assert int(st0[k]) == int(st1[k]), (msg, k)
    assert float(st0["time"]) == float(st1["time"]), msg
    # Residual pending sets must match bit-exactly, global counters
    # included — the mid-run exchange state is part of the contract.
    fa = tiered3_queue_to_flat(q0)
    fb = sharded_queue_to_flat(q1)
    for field in ("times", "types", "args", "seqs"):
        np.testing.assert_array_equal(
            np.asarray(getattr(fa, field)), np.asarray(getattr(fb, field)),
            err_msg=f"{msg}: {field}")
    for field in ("size", "next_seq", "dropped"):
        assert int(getattr(fa, field)) == int(getattr(fb, field)), \
            (msg, field)
    assert int(st0["batches"]) > 0 and int(st0["events"]) > 0


@pytest.mark.parametrize("seed,shards", [
    (0, 2), (1, 3), (2, 4), (3, 2),
])
def test_near_full_churn_fixed_cases(seed, shards):
    """Bare-env coverage of the 92%-occupancy cross-shard churn (the
    hypothesis property below widens the same driver)."""
    _run_churn_differential(seed, shards)


@given(seed=st.integers(0, 2**16), shards=st.sampled_from([2, 3, 4]))
@settings(max_examples=8, deadline=None)
def test_property_near_full_churn(seed, shards):
    """For ANY seed stream and shard count, the sharded engine stays
    bit-identical to the single tiered3 queue under sustained
    near-head/far-future/cross-shard re-emit pressure."""
    _run_churn_differential(seed, shards)


def test_seed_overflow_global_rule():
    """Seeding past capacity must apply the single-queue overflow rule
    BEFORE partitioning: same survivors, same global counters."""
    single = _engine(0, capacity=16, t_stop=1e9)
    sharded = _engine(3, capacity=16, t_stop=1e9)
    events = _seed_events(7, 16, 12, occupancy=1.5)  # 24 events, 8 ghost
    q0 = single.initial_queue(events)
    q1 = sharded.initial_queue(events)
    assert int(q1.dropped) == int(q0.dropped) == len(events) - 16
    assert int(q1.size) == int(q0.size) == len(events)
    assert int(q1.next_seq) == int(q0.next_seq) == len(events)
    fa, fb = tiered3_queue_to_flat(q0), sharded_queue_to_flat(q1)
    for field in ("times", "types", "args", "seqs"):
        np.testing.assert_array_equal(
            np.asarray(getattr(fa, field)), np.asarray(getattr(fb, field)),
            err_msg=field)


def test_emit_overflow_ghosts_match_single_queue():
    """A spawning cascade overflowing a tiny sharded queue must drop
    the SAME events as the single queue (global ghost rule at the
    exchange boundary), and the run must terminate."""
    def make_reg():
        reg = EventRegistry()

        @emits_events
        def spawner(state, t, arg):
            emit = jnp.zeros((2, EMIT_W), jnp.float32)
            emit = emit.at[:, 0].set(t + 1.0).at[:, 1].set(0.0)
            emit = emit.at[0, 2].set(arg[0] + 1.0)
            emit = emit.at[1, 2].set(arg[0] + 2.0)
            return state + 1, emit

        reg.register("S", spawner, lookahead=1.0)
        return reg.freeze()

    outcomes = {}
    for label, build in {
        "single": lambda: DeviceEngine(
            make_reg(), max_batch_len=2, capacity=5, max_emit=2,
            queue_mode="tiered3", front_cap=2, stage_cap=5, num_runs=2),
        "sh2": lambda: ShardedDeviceEngine(
            make_reg(), max_batch_len=2, capacity=5, max_emit=2,
            front_cap=2, stage_cap=5, num_runs=2, shards=2),
        "sh3": lambda: ShardedDeviceEngine(
            make_reg(), max_batch_len=2, capacity=5, max_emit=2,
            front_cap=2, stage_cap=5, num_runs=2, shards=3),
    }.items():
        eng = build()
        q = eng.initial_queue([(0.0, 0, [0.0, 0, 0, 0]),
                               (0.0, 0, [1.0, 0, 0, 0])])
        s, q, stats = eng.run(jnp.int32(0), q, max_batches=7)
        flat = (sharded_queue_to_flat(q) if isinstance(q, ShardedQueue)
                else tiered3_queue_to_flat(q))
        outcomes[label] = (
            int(s), int(stats["dropped"]), int(q.size), int(q.next_seq),
            int(stats["batches"]), np.asarray(flat.times).tolist(),
            np.asarray(flat.seqs).tolist(),
        )
    assert outcomes["single"] == outcomes["sh2"] == outcomes["sh3"]
    assert outcomes["single"][1] > 0  # it really overflowed


def test_front_smaller_than_pending_set_terminates():
    """Shard fronts far smaller than the pending set: every event still
    executes exactly once across refills and exchanges."""
    reg = EventRegistry()
    reg.register("N", lambda s, t, a: s + 1, lookahead=np.inf)
    eng = ShardedDeviceEngine(reg, max_batch_len=4, capacity=64,
                              front_cap=4, stage_cap=4, num_runs=2,
                              shards=3)
    events = [(float(t), 0, np.asarray([t % 7, 0, 0, 0], np.float32))
              for t in range(50)]
    s, q, stats = eng.run(jnp.int32(0), eng.initial_queue(events))
    assert int(s) == 50
    assert int(stats["events"]) == 50
    assert int(q.size) == 0


def test_custom_shard_fn_and_validation():
    """A custom routing function changes the partition but NOT the
    results (parity is partition-independent); invalid configs raise."""
    reg = _churn_registry(8, 32.0)
    events = _seed_events(5, 32, 8, occupancy=0.5)
    base = ShardedDeviceEngine(
        reg, max_batch_len=4, capacity=32, max_emit=1, shards=2)
    skewed = ShardedDeviceEngine(
        reg, max_batch_len=4, capacity=32, max_emit=1, shards=2,
        shard_fn=lambda tys, args: jnp.full(
            tys.shape, 7, jnp.int32))  # out-of-range: reduced mod shards
    s0, _, st0 = base.run(_state0(), base.initial_queue(events),
                          max_batches=24)
    s1, _, st1 = skewed.run(_state0(), skewed.initial_queue(events),
                            max_batches=24)
    assert int(s0["checksum"]) == int(s1["checksum"])
    assert int(st0["batches"]) == int(st1["batches"])

    with pytest.raises(ValueError, match="tiered3"):
        ShardedDeviceEngine(_churn_registry(4, 8.0), queue_mode="flat")
    with pytest.raises(ValueError, match="shards"):
        ShardedDeviceEngine(_churn_registry(4, 8.0), shards=0)


def test_build_knob_validation():
    """`shards` is a device knob, gated exactly like the others."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "examples"))
    import phold

    prog = phold.build_program(num_lps=3, t_stop=4.0)
    with pytest.raises(ValueError, match="shards"):
        prog.build(backend="host", shards=2)
    prog2 = phold.build_program(num_lps=3, t_stop=4.0)
    with pytest.raises(ValueError, match="tiered3"):
        prog2.build(backend="device", shards=2, queue_mode="flat")
    prog3 = phold.build_program(num_lps=3, t_stop=4.0)
    with pytest.raises(ValueError, match="shard_fn"):
        prog3.build(backend="device", shard_fn=lambda tys, args: tys)


def test_phold_parity_through_harness():
    """The shared parity harness exercises the sharded entries on the
    device-only matrix (full-matrix runs live in
    test_simprogram_parity.py; this pins the harness wiring itself)."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "examples"))
    import phold

    backends = {
        "host/unbatched": dict(backend="host", scheduler="unbatched"),
        "device/tiered3": dict(backend="device"),
        "device/tiered3-2shard": dict(backend="device", shards=2),
    }
    results = run_all(
        lambda: phold.build_program(num_lps=4, t_stop=10.0),
        phold.initial_state(4), backends=backends,
    )
    assert_parity(results)
