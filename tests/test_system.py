"""System-level behaviour: the paper's claims, checked end to end.

1. §IV.A — cross-event optimization really happens: the optimized HLO of
   the batch [Increment, Set] contains NO while loop, while the batch
   [Set, Increment] (and the lone Increment handler) contains one.
2. Fig 3 regime — batched execution is measurably faster than unbatched
   on the PoC model (coarse check here; the full sweep lives in
   benchmarks/poc_speedup.py).
3. §IV.C — composed-batch counts match the closed forms.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import poc
from repro.core import DenseCodec, Simulator, compose_word_fn
from repro.core.codec import paper_batch_count, redundant_batch_count


def _optimized_hlo(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def _count_while(hlo: str) -> int:
    return sum(
        1
        for line in hlo.splitlines()
        if " while(" in line or line.strip().startswith("while ")
        or "= while " in line
    )


STATE = jax.ShapeDtypeStruct((), jnp.uint32)
T = jax.ShapeDtypeStruct((), jnp.float32)


def test_xla_removes_dead_increment_loop():
    """The paper's §IV.A assembly inspection, against XLA."""
    reg = poc.build_registry(iters=1000)

    inc_set = compose_word_fn(reg, [poc.INCREMENT, poc.SET])
    set_inc = compose_word_fn(reg, [poc.SET, poc.INCREMENT])

    hlo_dead = _optimized_hlo(inc_set, STATE, [T, T], [None, None])
    hlo_live = _optimized_hlo(set_inc, STATE, [T, T], [None, None])

    assert _count_while(hlo_dead) == 0, (
        "XLA failed to DCE the Increment loop in batch [Increment, Set]"
    )
    assert _count_while(hlo_live) >= 1, (
        "sanity: batch [Set, Increment] must retain the loop"
    )


def test_batching_speedup_measurable():
    """Coarse Fig-3 check: p_s=0.5, n=4 => s_max = 4*0.5/(1-0.5^4) ≈ 2.13.

    We only assert >1.2x here to stay robust on a noisy single-core CI
    box; the benchmark harness measures the full curve.
    """
    iters = 200_000
    n_events = 64
    types = [int(t) for t in (np.random.default_rng(1).random(n_events) < 0.5)]

    def run(mode, max_len):
        reg = poc.build_registry(iters=iters)
        sim = Simulator(reg, max_batch_len=max_len)
        for t, ty in enumerate(types):
            sim.queue.push(float(t), ty)
        # warm up compilation outside the timed region
        state, _ = sim.run(poc.initial_state(), mode=mode)
        jax.block_until_ready(state)
        sim2 = Simulator(reg, max_batch_len=max_len)
        sim2.composer = sim.composer  # reuse compiled programs
        for t, ty in enumerate(types):
            sim2.queue.push(float(t), ty)
        t0 = time.perf_counter()
        state, _ = sim2.run(poc.initial_state(), mode=mode)
        jax.block_until_ready(state)
        return time.perf_counter() - t0, int(state)

    t_unbatched, s_u = run("unbatched", 4)
    t_batched, s_b = run("conservative", 4)
    assert s_u == s_b == poc.reference_final_sum(types, iters)
    assert t_unbatched / t_batched > 1.2, (
        f"batched {t_batched:.4f}s not faster than unbatched {t_unbatched:.4f}s"
    )


def test_batch_count_closed_forms():
    assert paper_batch_count(2, 2) == 12            # §IV.A
    # §IV.C formula value (paper text misquotes 9331; see test_codec.py)
    assert redundant_batch_count(5, 5) == 5425
    assert DenseCodec(5, 5).num_batches == paper_batch_count(5, 5) - 5425
