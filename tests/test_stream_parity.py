"""Closed-vs-open equivalence (DESIGN.md §10): streaming a trace is
bit-identical to pre-seeding it.

The seq-reservation discipline (arrival ``j`` carries seq
``len(seeds) + j``; mid-run emits draw past the reservation) plus the
lex admission fence mean a streamed run executes the EXACT event
sequence of a closed run whose initial schedule is
``seeds + source_events(trace)`` — state, executed events, dropped,
final_time, all bit-equal, on every streaming-capable backend
(``STREAM_BACKENDS``), through checkpoint interrupt/resume, and with a
streamed small-capacity + spill run against a closed large-capacity
reference (the bounded-device-memory serving shape).

Arrival times live on the 0.25 f32 grid — the scenario's cross-backend
parity convention (host f64 vs device f32 time arithmetic agree only on
grid-exact values).
"""

import numpy as np
import pytest

from _parity import (
    STREAM_BACKENDS,
    assert_parity,
    assert_resume_parity,
    run_all,
    run_interrupted_then_resumed,
)
from repro.core.program import Config
from repro.serving.scenarios import (
    build_open_admission_program,
    initial_state,
)
from repro.stream import PoissonSource, source_events

CFG = Config(max_batch_len=3, capacity=256, max_emit=2)
N_REQ = 40


def _source():
    # type 0 = ARRIVE; default arg0 = request index (the routing slot)
    return PoissonSource(1.5, N_REQ, seed=42, grid=0.25, t0=0.0,
                         type_id=0, block_size=16)


def _build(num_requests=N_REQ, config=CFG):
    return build_open_admission_program(
        num_slots=4, num_requests=num_requests, max_decode=5,
        config=config)


def _closed_events():
    """The pre-seeded reference schedule: program seeds FIRST (matching
    the device run's seq0 = len(seeds) reservation), then the trace."""
    return [(1.0, "TICK")] + [
        (t, ty, list(arg)) for (t, ty, arg) in source_events(_source())
    ]


def test_streamed_equals_preseeded_across_backends():
    closed = _build().build(backend="host", scheduler="unbatched").run(
        initial_state(4), events=_closed_events())
    assert closed.events > N_REQ  # arrivals + admits + ticks all ran
    results = run_all(_build, initial_state(4),
                      backends=STREAM_BACKENDS,
                      run_kw={"arrivals": _source()})
    results["closed/host-unbatched"] = closed
    # batched=[]: streamed absorption happens at segment boundaries, so
    # batch grouping is NOT part of the equivalence contract
    assert_parity(results, base="closed/host-unbatched", batched=[])
    for label, res in results.items():
        if label.endswith("+stream"):
            assert res.ingested == N_REQ, label
            assert res.shed == 0, label
    st = {k: int(np.asarray(v).sum())
          for k, v in results["device/tiered3+stream"].state.items()}
    assert st["arrivals"] == st["admitted"] == st["served"] == N_REQ
    assert st["waiting"] == 0 and st["slots"] == 0


@pytest.mark.parametrize("label", [
    "device/tiered3+stream",
    pytest.param("device/masked+stream", marks=pytest.mark.slow),
    pytest.param("device/fused-2shard+stream", marks=pytest.mark.slow),
])
def test_streamed_resume_bit_identical(label, tmp_path):
    """Interrupt a streamed run mid-flight and resume from the latest
    checkpoint (which carries the arrival cursor): bit-identical to the
    straight segmented run — state, counters, batch grouping, residual
    queue.  The straight run uses the SAME checkpoint cadence: streamed
    batch grouping depends on where segment boundaries fall (each
    boundary absorbs a block and moves the fence), so it is
    resume-invariant but not segmentation-invariant — which is exactly
    why the stream labels stay out of the BATCHED group."""
    kw = STREAM_BACKENDS[label]
    straight = _build().build(**kw).run(
        initial_state(4), arrivals=_source(), checkpoint_every=8,
        checkpoint_dir=str(tmp_path / "straight"))
    sim = _build().build(**kw)
    resumed = run_interrupted_then_resumed(
        sim, initial_state(4), tmpdir=str(tmp_path / "crashed"),
        max_batches=1 << 30, checkpoint_every=8, crash_at_segment=3,
        run_kw={"arrivals": _source()},
    )
    assert_resume_parity(straight, resumed, label=label)
    assert resumed.ingested == N_REQ


def test_streamed_resume_requires_arrivals(tmp_path):
    """A checkpoint written by a streamed run refuses a closed resume —
    silently dropping the rest of the trace would be data loss."""
    from repro.testing.faults import SimulatedCrash

    sim = _build().build(**STREAM_BACKENDS["device/tiered3+stream"])

    def hook(seg, state, queue, stats):
        if seg == 3:
            raise SimulatedCrash("stop")

    with pytest.raises(SimulatedCrash):
        sim.run(initial_state(4), arrivals=_source(), checkpoint_every=8,
                checkpoint_dir=str(tmp_path), _segment_hook=hook)
    sim2 = _build().build(**STREAM_BACKENDS["device/tiered3+stream"])
    with pytest.raises(ValueError, match="arrival cursor"):
        sim2.run(initial_state(4), checkpoint_every=8,
                 checkpoint_dir=str(tmp_path), resume_from="latest")


def test_streamed_small_capacity_spill_equals_closed_large():
    """The bounded-memory serving shape: stream through a device queue
    far smaller than the trace backlog (overflow='spill' parks the
    excess host-side) and match the closed large-capacity reference
    bit-for-bit."""
    small = Config(max_batch_len=3, capacity=24, max_emit=2)
    streamed = _build(config=small).build(
        backend="device", overflow="spill").run(
        initial_state(4), arrivals=_source())
    closed = _build().build(backend="device").run(
        initial_state(4), events=_closed_events())
    for k, v in closed.state.items():
        np.testing.assert_array_equal(
            np.asarray(streamed.state[k]), np.asarray(v), err_msg=k)
    assert streamed.events == closed.events
    assert streamed.dropped == closed.dropped == 0
    assert np.float32(streamed.final_time) == np.float32(closed.final_time)
    assert streamed.ingested == N_REQ
    assert streamed.spilled == 0  # drained by the end


def test_streamed_horizon_leaves_tail_unconsumed():
    """Arrivals past ``until`` are never consumed — they stay in the
    source, exactly like queued events past the horizon stay queued."""
    src = _source()
    rows_t = [t for (t, _, _) in source_events(src)]
    horizon = rows_t[len(rows_t) // 2]
    res = _build().build(backend="device").run(
        initial_state(4), arrivals=src, until=horizon)
    expect = sum(1 for t in rows_t if t <= horizon)
    assert res.ingested == expect
    assert res.shed == 0


def test_streamed_requires_tiered3():
    sim = _build().build(backend="device", queue_mode="flat")
    with pytest.raises(ValueError, match="tiered3"):
        sim.run(initial_state(4), arrivals=_source())


def test_backpressure_validation():
    sim = _build().build(backend="device")
    with pytest.raises(ValueError, match="backpressure"):
        sim.run(initial_state(4), arrivals=_source(),
                backpressure="reject")
    with pytest.raises(ValueError, match="arrivals"):
        sim.run(initial_state(4), backpressure="shed")
    host = _build().build(backend="host", scheduler="unbatched")
    with pytest.raises(ValueError, match="host"):
        host.run(initial_state(4), arrivals=_source(),
                 backpressure="shed")
