"""API-level tests for `repro.api` (SimProgram / CompiledSim) and the
emits_events wrap-not-mutate regression."""

import functools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ARG_WIDTH, Config, RunResult, SimProgram, emits_events
from repro.core.events import EventRegistry


# ---------------------------------------------------------------------------
# emits_events: wrap, don't mutate (regression)
# ---------------------------------------------------------------------------

def _plain(state, t, arg):
    return state, [(1.0, 0, None)]


def test_emits_events_does_not_mutate_original():
    marked = emits_events(_plain)
    assert marked.returns_events
    assert marked is not _plain
    assert not hasattr(_plain, "returns_events")
    assert marked.__wrapped__ is _plain
    assert marked("s", 0.0, None) == _plain("s", 0.0, None)


def test_emits_events_on_partial_bound_method_and_builtin():
    # functools.partial
    p = functools.partial(_plain)
    mp = emits_events(p)
    assert mp.returns_events and mp("s", 0.0, None) == _plain("s", 0.0, None)

    # bound method (setattr on these raises AttributeError)
    class M:
        def h(self, state, t, arg):
            return state, [(2.0, 0, None)]

    bound = M().h
    mb = emits_events(bound)
    assert mb.returns_events
    assert mb("s", 0.0, None) == ("s", [(2.0, 0, None)])

    # builtin (cannot take attributes either)
    mbuiltin = emits_events(len)
    assert mbuiltin.returns_events and mbuiltin([1, 2, 3]) == 3


def test_registry_detects_wrapped_handler():
    reg = EventRegistry()

    class M:
        def h(self, state, t, arg):
            return state + 1, [(1.0, 0, None)]

    et = reg.register("A", emits_events(M().h), lookahead=1.0)
    assert et.returns_events


# ---------------------------------------------------------------------------
# SimProgram registration / validation
# ---------------------------------------------------------------------------

def test_duplicate_and_post_freeze_registration_rejected():
    prog = SimProgram()
    prog.register("A", lambda s, t, a: s)
    with pytest.raises(ValueError, match="already registered"):
        prog.register("A", lambda s, t, a: s)
    prog.freeze()
    with pytest.raises(RuntimeError, match="frozen"):
        prog.register("B", lambda s, t, a: s)


def test_entity_handlers_must_not_emit():
    prog = SimProgram()
    with pytest.raises(ValueError, match="must not emit"):
        prog.register("E", lambda es, t, a: es, entity=True, emits=True)


def test_schedule_unknown_type():
    prog = SimProgram()
    prog.register("A", lambda s, t, a: s)
    with pytest.raises(KeyError, match="unknown event type"):
        prog.schedule(0.0, "Nope")


def test_config_validation():
    with pytest.raises(ValueError):
        Config(max_batch_len=0)
    with pytest.raises(ValueError):
        Config(max_emit=0)
    with pytest.raises(ValueError):
        Config(codec="huffman")


def test_build_rejects_unknown_targets():
    prog = SimProgram()
    prog.register("A", lambda s, t, a: s)
    with pytest.raises(ValueError, match="backend"):
        prog.build(backend="fpga")
    with pytest.raises(ValueError, match="scheduler"):
        prog.build(backend="host", scheduler="optimistic2")
    with pytest.raises(ValueError, match="queue_mode"):
        prog.build(backend="device", queue_mode="heap")


def test_build_rejects_misdirected_backend_knobs():
    """A knob the selected backend would not read must fail loudly,
    not silently run a different runtime."""
    prog = SimProgram()
    prog.register("A", lambda s, t, a: s)
    with pytest.raises(ValueError, match="host-backend"):
        prog.build(backend="device", scheduler="speculative")
    with pytest.raises(ValueError, match="host-backend"):
        prog.build(backend="device", window_slack=2.0)
    with pytest.raises(ValueError, match="device-backend"):
        prog.build(backend="host", queue_mode="flat")
    with pytest.raises(ValueError, match="device-backend"):
        prog.build(backend="host", capacity=64)


def test_emit_shape_validated():
    prog = SimProgram(config=Config(max_emit=2))

    @prog.handler("A", lookahead=1.0, emits=True)
    def a(state, t, arg):
        return state, jnp.zeros((1, 2 + ARG_WIDTH), jnp.float32)  # wrong

    prog.schedule(0.0, "A")
    with pytest.raises(ValueError, match="max_emit"):
        prog.build(backend="device").run(jnp.int32(0))


def test_arg_normalization_and_width_check():
    from repro.api import normalize_arg

    np.testing.assert_array_equal(normalize_arg(None),
                                  np.zeros((ARG_WIDTH,), np.float32))
    np.testing.assert_array_equal(normalize_arg(3.0)[:2],
                                  np.asarray([3.0, 0.0], np.float32))
    with pytest.raises(ValueError, match="ARG_WIDTH"):
        normalize_arg(np.arange(ARG_WIDTH + 1))


# ---------------------------------------------------------------------------
# CompiledSim run contract
# ---------------------------------------------------------------------------

def _counter_prog(**cfg):
    prog = SimProgram(config=Config(**cfg) if cfg else None)

    @prog.handler("TICK", lookahead=1.0)
    def tick(state, t, arg):
        return state + 1

    for t in range(6):
        prog.schedule(float(t), "TICK")
    return prog


def test_run_result_fields_and_mean_batch_length():
    res = _counter_prog(max_batch_len=2).build(backend="host").run(
        jnp.int32(0))
    assert isinstance(res, RunResult)
    assert int(res.state) == 6
    # lookahead 1.0 on the integer grid -> pairs: [0,1], [2,3], [4,5]
    assert res.events == 6 and res.batches == 3
    assert res.dropped == 0 and res.rollbacks == 0
    assert res.final_time == 5.0
    assert res.mean_batch_length == 2.0
    assert res.stats()["mean_batch_length"] == 2.0


def test_max_batches_uniform_across_backends():
    counts = set()
    for kw in (dict(backend="host", scheduler="conservative"),
               dict(backend="host", scheduler="unbatched"),
               dict(backend="device", queue_mode="tiered")):
        res = _counter_prog(max_batch_len=1).build(**kw).run(
            jnp.int32(0), max_batches=3)
        counts.add((int(res.state), res.batches))
    assert counts == {(3, 3)}


def test_device_rejects_max_events():
    sim = _counter_prog().build(backend="device")
    with pytest.raises(ValueError, match="max_events"):
        sim.run(jnp.int32(0), max_events=3)


def test_run_events_override():
    prog = _counter_prog()
    sim = prog.build(backend="host")
    res = sim.run(jnp.int32(0), events=[(0.0, "TICK"), (1.0, "TICK")])
    assert int(res.state) == 2 and res.events == 2
    # the program's own schedule is untouched
    res2 = sim.run(jnp.int32(0))
    assert res2.events == 6


def test_from_program_constructors():
    """The backend layer is constructible from a frozen program."""
    from repro.core.composer import LazyComposer
    from repro.core.engine import DeviceEngine, Simulator
    from repro.core.scheduler import ConservativeScheduler

    prog = _counter_prog(max_batch_len=3, capacity=32)
    eng = DeviceEngine.from_program(prog, queue_mode="flat")
    assert eng.capacity == 32 and eng.max_batch_len == 3
    s, _q, stats = eng.run(jnp.int32(0),
                           eng.initial_queue(prog.scheduled_events()))
    assert int(s) == 6

    sim = Simulator.from_program(prog)
    state, rs = sim.run(jnp.int32(0), mode="conservative")
    assert int(state) == 6

    sched = ConservativeScheduler.from_program(prog)
    assert isinstance(sched.composer, LazyComposer)
    assert sched.max_len == 3


def test_entity_sequential_derivation_matches_manual():
    """Mixed windows use the derived sequential handler; it must match
    applying the local handler by hand."""
    prog = SimProgram(config=Config(max_batch_len=2, capacity=16))

    @prog.entity_handler("BUMP", lookahead=1.0)
    def bump(es, t, arg):
        return es * 2 + 1

    prog.schedule(0.0, "BUMP", arg=[1.0])
    prog.schedule(0.0, "BUMP", arg=[3.0])
    state0 = jnp.arange(4, dtype=jnp.int32)
    expect = np.asarray(state0).copy()
    for eid in (1, 3):
        expect[eid] = expect[eid] * 2 + 1
    for kw in (dict(backend="host"), dict(backend="device")):
        res = prog.build(**kw).run(state0)
        np.testing.assert_array_equal(np.asarray(res.state), expect)
