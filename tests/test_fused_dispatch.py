"""Composition-specialized dispatch (DESIGN.md §7).

Pins the tentpole contracts:

* the three dispatch modes (switch / masked / fused) are bit-equivalent
  at the dispatcher level AND over whole runs, hot word or fallback;
* the hot-set plumbing — slot table, default hot set, name resolution
  through ``SimProgram.build``, profiling via ``word_counts`` /
  ``hot_words_from_counts``;
* the knob validation (mode typos, hot_words outside fused mode, host
  misdirection).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.codec import DenseCodec
from repro.core.composer import (
    build_fused_dispatcher,
    build_masked_dispatcher,
    build_switch_dispatcher,
    hot_words_from_counts,
)
from repro.core.engine import DeviceEngine
from repro.core.events import ARG_WIDTH, EventRegistry, emits_events

from repro import poc
from repro.core.program import Config


def _two_type_registry():
    """inc emits nothing; spawn emits one event (exercising the emit
    rows that dispatch must place identically across modes)."""
    reg = EventRegistry()

    def inc(state, t, arg):
        return state + jnp.float32(1.0) + arg[0]

    @emits_events
    def spawn(state, t, arg):
        emit = jnp.zeros((1, 2 + ARG_WIDTH), jnp.float32)
        emit = emit.at[0, 0].set(t + 1.5)
        emit = emit.at[0, 1].set(0.0)
        return state * jnp.float32(2.0), emit

    reg.register("inc", inc, lookahead=1.0)
    reg.register("spawn", spawn, lookahead=1.0)
    return reg.freeze()


def _rand_windows(rng, codec, n, arg_width):
    """Random (ts, types, args, length) windows spanning every word."""
    out = []
    for code in range(codec.num_batches):
        word = tuple(codec.decode(code))
        length = len(word)
        k = codec.max_len
        tys = np.zeros((k,), np.int32)
        tys[:length] = word
        ts = np.sort(rng.uniform(0, 5, k)).astype(np.float32)
        args = rng.uniform(0, 1, (k, arg_width)).astype(np.float32)
        out.append((
            jnp.asarray(ts), jnp.asarray(tys), jnp.asarray(args),
            jnp.int32(length),
        ))
    for _ in range(n):
        out.append(out[rng.integers(0, codec.num_batches)])
    return out


def test_three_modes_bit_equivalent_per_window():
    reg = _two_type_registry()
    codec = DenseCodec(num_types=2, max_len=3)
    sw = build_switch_dispatcher(reg, codec, max_emit=1)
    ma = build_masked_dispatcher(reg, codec, max_emit=1)
    fu = build_fused_dispatcher(
        reg, codec, [(0,), (0, 0), (1, 0)], max_emit=1
    )
    rng = np.random.default_rng(0)
    state0 = jnp.float32(3.0)
    for ts, tys, args, length in _rand_windows(rng, codec, 5, 4):
        code = codec.encode_jnp(tys, length)
        s_sw, e_sw = sw(code, state0, ts, tys, args)
        s_ma, e_ma = ma(state0, ts, tys, args, length)
        s_fu, e_fu = fu(code, state0, ts, tys, args, length)
        np.testing.assert_array_equal(np.asarray(s_sw), np.asarray(s_ma))
        np.testing.assert_array_equal(np.asarray(s_sw), np.asarray(s_fu))
        np.testing.assert_array_equal(np.asarray(e_sw), np.asarray(e_ma))
        np.testing.assert_array_equal(np.asarray(e_sw), np.asarray(e_fu))


def test_hot_slot_table():
    reg = _two_type_registry()
    codec = DenseCodec(num_types=2, max_len=2)
    hot = [(1,), (0, 1)]
    fu = build_fused_dispatcher(reg, codec, hot, max_emit=1)
    assert fu.hot_words == ((1,), (0, 1))
    assert fu.num_hot == 2
    table = np.asarray(fu.hot_slot_table)
    assert table.shape == (codec.num_batches,)
    for code in range(codec.num_batches):
        word = tuple(codec.decode(code))
        if word in hot:
            assert table[code] == hot.index(word)
        else:
            assert table[code] == len(hot)  # fallback slot


def test_fused_validates_hot_words():
    reg = _two_type_registry()
    codec = DenseCodec(num_types=2, max_len=2)
    with pytest.raises(ValueError):
        build_fused_dispatcher(reg, codec, [(0, 0, 0)])  # too long
    with pytest.raises(ValueError):
        build_fused_dispatcher(reg, codec, [(5,)])       # bad type id
    with pytest.raises(ValueError):
        build_fused_dispatcher(reg, codec, [()])         # empty word
    # Duplicates collapse rather than error.
    fu = build_fused_dispatcher(reg, codec, [(0,), (0,)], max_emit=1)
    assert fu.num_hot == 1


def test_default_hot_set_covers_small_alphabets():
    """num_batches <= 32: the default hot set is the whole code space,
    so the fallback leg is dead and fused degenerates to a (reordered)
    full switch."""
    prog = poc.build_program(iters=8, config=Config(max_batch_len=3))
    prog.schedule(0.0, "Increment")
    sim = prog.build(backend="device", dispatch_mode="fused")
    eng = sim.engine
    assert eng.dispatch_mode == "fused"
    assert len(eng.hot_words) == eng.codec.num_batches
    table = np.asarray(eng._dispatch_fused.hot_slot_table)
    assert (table < len(eng.hot_words)).all()


def test_word_counts_match_batches_and_composition():
    types = [0, 1, 0, 0, 1, 1, 0, 0, 1]

    def build(**kw):
        prog = poc.build_program(iters=8, config=Config(max_batch_len=3))
        for t, ty in enumerate(types):
            prog.schedule(float(t), ("Increment", "Set")[ty])
        return prog.build(backend="device", **kw)

    base = build().run(poc.initial_state())
    assert base.word_counts is not None
    assert int(base.word_counts.sum()) == base.batches
    # Identical composition histogram across dispatch modes.
    for mode in ("masked", "fused"):
        r = build(dispatch_mode=mode).run(poc.initial_state())
        np.testing.assert_array_equal(r.word_counts, base.word_counts)
    # The histogram counts real words: every nonzero code decodes to a
    # word no longer than max_batch_len.
    eng = build().engine
    for code in np.nonzero(base.word_counts)[0]:
        word = tuple(eng.codec.decode(int(code)))
        assert 1 <= len(word) <= 3


def test_hot_words_from_counts_ranking():
    codec = DenseCodec(num_types=2, max_len=2)
    counts = np.zeros((codec.num_batches,), np.int64)
    counts[codec.encode([0, 1])] = 5
    counts[codec.encode([1])] = 9
    counts[codec.encode([0])] = 5
    got = hot_words_from_counts(counts, codec, 2)
    assert got[0] == (1,)
    # tie between (0,) and (0,1) breaks toward the smaller code: (0,).
    assert got[1] == (0,)
    # dict input (host composer execute_counts) works too.
    got2 = hot_words_from_counts(
        {int(codec.encode([1])): 9, int(codec.encode([0])): 5}, codec, 8
    )
    assert got2 == [(1,), (0,)]


def test_hot_words_by_name_through_build():
    types = [0, 0, 1, 0]

    def build(**kw):
        prog = poc.build_program(iters=8, config=Config(max_batch_len=2))
        for t, ty in enumerate(types):
            prog.schedule(float(t), ("Increment", "Set")[ty])
        return prog.build(backend="device", **kw)

    base = build().run(poc.initial_state())
    hot = build(
        dispatch_mode="fused",
        hot_words=[("Increment", "Increment"), ("Set",)],
    )
    assert hot.engine.hot_words == ((0, 0), (1,))
    r = hot.run(poc.initial_state())
    assert int(r.state) == int(base.state)
    assert r.batches == base.batches


def test_knob_validation():
    reg = _two_type_registry()
    with pytest.raises(ValueError, match="dispatch_mode"):
        DeviceEngine(registry=reg, max_batch_len=2, capacity=32,
                     dispatch_mode="vectorized")
    with pytest.raises(ValueError, match="hot_words"):
        DeviceEngine(registry=reg, max_batch_len=2, capacity=32,
                     hot_words=[(0,)])  # only valid with fused
    with pytest.raises(ValueError, match="queue_kernels"):
        DeviceEngine(registry=reg, max_batch_len=2, capacity=32,
                     queue_kernels="cuda")
    prog = poc.build_program(iters=4)
    prog.schedule(0.0, "Increment")
    with pytest.raises(ValueError, match="dispatch_mode"):
        prog.build(backend="host", scheduler="conservative",
                   state_spec=jnp.zeros((), jnp.uint32),
                   dispatch_mode="fused")


def test_dispatch_attr_always_available():
    """benchmarks/device_engine.py probes eng.dispatch directly — it
    must exist (and work) in every dispatch mode."""
    prog = poc.build_program(iters=8, config=Config(max_batch_len=2))
    prog.schedule(0.0, "Increment")
    for mode in ("switch", "masked", "fused"):
        eng = prog.build(backend="device", dispatch_mode=mode).engine
        assert callable(eng.dispatch)
        assert eng.dispatch.num_batches == eng.codec.num_batches
