"""Cross-backend parity: the executable contract of `repro.api`.

One SimProgram definition must run unmodified on every runtime —
host (conservative / speculative / unbatched) and device (tiered3 /
tiered / flat / reference queue modes, plus the sharded engine at 2
and 4 shards) — with bit-identical final state and identical
normalized stats (events, dropped, final_time).  The backend matrix
and the assertion set live in the shared harness (``tests/_parity.py``);
the scenarios come from the in-repo examples, imported directly so the
shipped example models ARE the tested models.
"""

import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from _parity import ALL_BACKENDS, assert_parity, run_all
from repro import poc
from repro.api import Config

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "examples"))

import mmc_network  # noqa: E402
import phold  # noqa: E402


def test_phold_parity():
    results = run_all(
        lambda: phold.build_program(num_lps=5, t_stop=12.0),
        phold.initial_state(5),
    )
    assert_parity(results)
    # the scenario actually exercised emission scheduling
    assert results["host/unbatched"].events > 20


def test_mmc_network_parity():
    results = run_all(
        lambda: mmc_network.build_program(num_stations=3, t_open=12.0),
        mmc_network.initial_state(3),
    )
    assert_parity(results)
    st = results["device/tiered"].state
    # TALLY (entity-parallel) events really ran
    assert int(np.asarray(st["samples"]).sum()) > 0
    # conservation: arrived = served + queued + in-service, per station
    np.testing.assert_array_equal(
        np.asarray(st["arrived"]),
        np.asarray(st["served"]) + np.asarray(st["qlen"])
        + np.asarray(st["busy"]),
    )


def test_poc_parity_including_eager_composer():
    """The PoC model through every backend plus the eager AOT composer."""
    types = [0, 1, 0, 0, 1, 1, 0, 0, 1]

    def build():
        prog = poc.build_program(iters=64, config=Config(max_batch_len=3))
        for t, ty in enumerate(types):
            prog.schedule(float(t), ("Increment", "Set")[ty])
        return prog

    oracle = poc.reference_final_sum(types, 64)
    results = run_all(build, poc.initial_state())
    assert_parity(results)
    assert int(results["device/tiered3"].state) == oracle

    eager = build().build(
        backend="host", scheduler="conservative", composer="eager",
        state_spec=jnp.zeros((), jnp.uint32),
    )
    res = eager.run(poc.initial_state())
    assert int(res.state) == oracle
    assert res.batches == results["host/conservative"].batches


def test_until_horizon_identical_across_backends():
    """`until` caps the extraction window itself: exactly the events
    with timestamp <= until execute, on every backend — including the
    speculative scheduler, whose slack may not cross the horizon, and
    the sharded engine, whose merged super-step window carries the same
    cap."""
    results = run_all(
        lambda: phold.build_program(num_lps=4, t_stop=20.0),
        phold.initial_state(4),
        run_kw=dict(until=7.5),
    )
    states = [int(res.state["checksum"]) for res in results.values()]
    events = [res.events for res in results.values()]
    assert all(res.final_time <= 7.5 for res in results.values())
    assert len(set(states)) == 1
    assert len(set(events)) == 1


@pytest.mark.parametrize("label", sorted(ALL_BACKENDS))
def test_rerunnable_handle(label):
    """CompiledSim.run twice -> identical results (the device queue is
    donated internally and rebuilt per run; callers never see it)."""
    prog = phold.build_program(num_lps=4, t_stop=6.0)
    sim = prog.build(**ALL_BACKENDS[label])
    r1 = sim.run(phold.initial_state(4))
    r2 = sim.run(phold.initial_state(4))
    assert int(r1.state["checksum"]) == int(r2.state["checksum"])
    assert (r1.events, r1.batches, r1.dropped) \
        == (r2.events, r2.batches, r2.dropped)


def test_device_default_queue_mode_is_tiered3():
    """The ROADMAP promotion, pinned: a bare device build runs the
    tiered3 queue (both through the API and the engine default), and
    `tiered` stays selectable."""
    from repro.core.engine import DeviceEngine

    prog = phold.build_program(num_lps=3, t_stop=4.0)
    sim = prog.build(backend="device")
    assert sim.variant == "tiered3"
    assert sim.engine.queue_mode == "tiered3"
    assert DeviceEngine.__dataclass_fields__["queue_mode"].default \
        == "tiered3"
    prog2 = phold.build_program(num_lps=3, t_stop=4.0)
    assert prog2.build(backend="device", queue_mode="tiered").variant \
        == "tiered"
