"""Cross-backend parity: the executable contract of `repro.api`.

One SimProgram definition must run unmodified on every runtime —
host (conservative / speculative / unbatched) and device (tiered /
flat / reference queue modes) — with bit-identical final state and
identical normalized stats (events, dropped, final_time).  The
scenarios come from the in-repo examples, imported directly so the
shipped example models ARE the tested models.
"""

import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro import poc
from repro.api import Config, SimProgram

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "examples"))

import mmc_network  # noqa: E402
import phold  # noqa: E402

ALL_BACKENDS = {
    "host/conservative": dict(backend="host", scheduler="conservative"),
    "host/speculative": dict(backend="host", scheduler="speculative"),
    "host/unbatched": dict(backend="host", scheduler="unbatched"),
    "device/tiered": dict(backend="device", queue_mode="tiered"),
    "device/flat": dict(backend="device", queue_mode="flat"),
    "device/reference": dict(backend="device", queue_mode="reference"),
}

# Batched runtimes share the §III-B extraction rule, so they must agree
# on the batch count too (unbatched/speculative group differently).
BATCHED = ("host/conservative", "device/tiered", "device/flat",
           "device/reference")


def _run_everywhere(build_program, state0):
    results = {}
    for label, kw in ALL_BACKENDS.items():
        results[label] = build_program().build(**kw).run(state0)
    return results


def _assert_parity(results):
    import jax

    base = results["host/unbatched"]
    for label, res in results.items():
        for leaf_base, leaf in zip(
            jax.tree_util.tree_leaves(base.state),
            jax.tree_util.tree_leaves(res.state),
        ):
            np.testing.assert_array_equal(
                np.asarray(leaf), np.asarray(leaf_base), err_msg=label
            )
        assert res.events == base.events, label
        assert res.dropped == base.dropped == 0, label
        assert np.float32(res.final_time) == np.float32(base.final_time), \
            label
    batch_counts = {results[k].batches for k in BATCHED}
    assert len(batch_counts) == 1, batch_counts


def test_phold_parity():
    results = _run_everywhere(
        lambda: phold.build_program(num_lps=5, t_stop=12.0),
        phold.initial_state(5),
    )
    _assert_parity(results)
    # the scenario actually exercised emission scheduling
    assert results["host/unbatched"].events > 20


def test_mmc_network_parity():
    results = _run_everywhere(
        lambda: mmc_network.build_program(num_stations=3, t_open=12.0),
        mmc_network.initial_state(3),
    )
    _assert_parity(results)
    st = results["device/tiered"].state
    # TALLY (entity-parallel) events really ran
    assert int(np.asarray(st["samples"]).sum()) > 0
    # conservation: arrived = served + queued + in-service, per station
    np.testing.assert_array_equal(
        np.asarray(st["arrived"]),
        np.asarray(st["served"]) + np.asarray(st["qlen"])
        + np.asarray(st["busy"]),
    )


def test_poc_parity_including_eager_composer():
    """The PoC model through every backend plus the eager AOT composer."""
    types = [0, 1, 0, 0, 1, 1, 0, 0, 1]

    def build():
        prog = poc.build_program(iters=64, config=Config(max_batch_len=3))
        for t, ty in enumerate(types):
            prog.schedule(float(t), ("Increment", "Set")[ty])
        return prog

    oracle = poc.reference_final_sum(types, 64)
    results = _run_everywhere(build, poc.initial_state())
    _assert_parity(results)
    assert int(results["device/tiered"].state) == oracle

    eager = build().build(
        backend="host", scheduler="conservative", composer="eager",
        state_spec=jnp.zeros((), jnp.uint32),
    )
    res = eager.run(poc.initial_state())
    assert int(res.state) == oracle
    assert res.batches == results["host/conservative"].batches


def test_until_horizon_identical_across_backends():
    """`until` caps the extraction window itself: exactly the events
    with timestamp <= until execute, on every backend — including the
    speculative scheduler, whose slack may not cross the horizon."""
    states, events = [], []
    for label, kw in ALL_BACKENDS.items():
        prog = phold.build_program(num_lps=4, t_stop=20.0)
        res = prog.build(**kw).run(phold.initial_state(4), until=7.5)
        states.append(int(res.state["checksum"]))
        events.append(res.events)
        assert res.final_time <= 7.5, label
    assert len(set(states)) == 1
    assert len(set(events)) == 1


@pytest.mark.parametrize("label", sorted(ALL_BACKENDS))
def test_rerunnable_handle(label):
    """CompiledSim.run twice -> identical results (the device queue is
    donated internally and rebuilt per run; callers never see it)."""
    prog = phold.build_program(num_lps=4, t_stop=6.0)
    sim = prog.build(**ALL_BACKENDS[label])
    r1 = sim.run(phold.initial_state(4))
    r2 = sim.run(phold.initial_state(4))
    assert int(r1.state["checksum"]) == int(r2.state["checksum"])
    assert (r1.events, r1.batches, r1.dropped) \
        == (r2.events, r2.batches, r2.dropped)
