"""CheckpointManager integrity: full-content checksums, single-leaf
restore, atomicity and retention invariants the fault-tolerant run
driver depends on.

The regression of record: ``_checksum`` used to hash only the first
1 MiB of a leaf, so a bit flip past that offset restored silently — a
silent-corruption hole exactly where it matters most (capacity-sized
queue buffers are the largest leaves).  The checksum now covers every
byte; the tests here flip a byte in the LAST MiB of a multi-MiB leaf
and require the restore to fail loudly.
"""

import json
import os

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, _checksum


def _flip_byte(path, offset_from_end=-1):
    with open(path, "r+b") as f:
        f.seek(offset_from_end, os.SEEK_END)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0xFF]))


def test_bit_flip_past_first_mib_detected(tmp_path):
    """Corrupt the tail of a >1 MiB leaf: restore must raise, not
    silently hand back a poisoned queue buffer."""
    mgr = CheckpointManager(str(tmp_path))
    big = np.arange(3 * (1 << 20), dtype=np.int8)  # 3 MiB
    mgr.save(1, {"big": big})

    _flip_byte(str(tmp_path / "step_0000000001" / "big.npy"))

    with pytest.raises(IOError, match="checksum mismatch"):
        mgr.restore({"big": np.zeros_like(big)}, 1)
    with pytest.raises(IOError, match="checksum mismatch"):
        mgr.restore_leaf("big", 1)


def test_checksum_covers_every_byte():
    a = np.zeros(2 * (1 << 20), dtype=np.uint8)
    b = a.copy()
    b[-1] = 1  # differs only in the final byte, well past 1 MiB
    assert _checksum(a) != _checksum(b)
    # and shape participates (same bytes, different logical layout)
    assert _checksum(a) != _checksum(a.reshape(2, 1 << 20))


def test_restore_leaf_round_trip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {
        "state": np.float32(3.5),
        "pool_rows": np.arange(12, dtype=np.float32).reshape(2, 6),
        "nested": {"seqs": np.array([4, 7, 9], np.int32)},
    }
    mgr.save(5, tree)
    np.testing.assert_array_equal(
        mgr.restore_leaf("pool_rows", 5), tree["pool_rows"])
    np.testing.assert_array_equal(
        mgr.restore_leaf("nested.seqs"), tree["nested"]["seqs"])
    with pytest.raises(KeyError, match="available"):
        mgr.restore_leaf("no_such_leaf", 5)


def test_restore_leaf_variable_length(tmp_path):
    """The spill pool changes length between checkpoints; restore_leaf
    takes the shape from the file, not from a template."""
    mgr = CheckpointManager(str(tmp_path), keep_last=10)
    mgr.save(1, {"pool": np.zeros((0, 6), np.float32)})
    mgr.save(2, {"pool": np.ones((7, 6), np.float32)})
    assert mgr.restore_leaf("pool", 1).shape == (0, 6)
    assert mgr.restore_leaf("pool", 2).shape == (7, 6)
    assert mgr.restore_leaf("pool").shape == (7, 6)  # latest


def test_manifest_checksums_recorded(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    arr = np.arange(100, dtype=np.float64)
    mgr.save(3, {"x": arr})
    with open(tmp_path / "step_0000000003" / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["leaves"]["x"]["checksum"] == _checksum(arr)


def test_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": np.int32(s)})
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4
    restored, step = mgr.restore({"x": np.int32(0)})
    assert step == 4 and int(restored["x"]) == 4
