"""Checkpoint/resume bit-parity: the resume axis of the parity matrix.

An interrupted-then-resumed device run must be BIT-IDENTICAL to a
straight run — state, executed/batch/drop counters, final time, AND
the residual pending set — for every RESUME_BACKENDS member (queue
mode × dispatch mode × shard count).  Segmented execution threads the
whole loop carry (cumulative ``stats``) through the checkpoint and
``max_batches`` caps the TOTAL batch count, so a segmented run equals
an unsegmented one by construction; these tests prove the construction
end to end through the on-disk CheckpointManager round-trip.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from _parity import (
    ALL_BACKENDS,
    RESUME_BACKENDS,
    assert_resume_parity,
    queue_flat_view,
    run_interrupted_then_resumed,
)
from repro.testing.faults import tiny_phold

_MAX_BATCHES = 30
_CKPT_EVERY = 4
_CRASH_AT = 3


@pytest.fixture(scope="module")
def sims():
    cache = {}

    def get(label):
        if label not in cache:
            cache[label] = tiny_phold().build(**ALL_BACKENDS[label])
        return cache[label]

    return get


@pytest.mark.parametrize("label", RESUME_BACKENDS)
def test_interrupt_resume_bit_parity(label, sims, tmp_path):
    sim = sims(label)
    straight = sim.run(jnp.int32(0), max_batches=_MAX_BATCHES)
    resumed = run_interrupted_then_resumed(
        sim, jnp.int32(0), tmpdir=str(tmp_path),
        max_batches=_MAX_BATCHES, checkpoint_every=_CKPT_EVERY,
        crash_at_segment=_CRASH_AT,
    )
    assert_resume_parity(straight, resumed, label=label)
    # the scenario left real residual work (a trivial empty queue would
    # make the residual comparison vacuous)
    assert resumed.pending > 0, label


def test_segmented_equals_unsegmented(sims, tmp_path):
    """Uninterrupted segmented run (checkpoint_every=1: a segment per
    batch) is bit-identical to the single-launch run."""
    sim = sims("device/tiered3")
    straight = sim.run(jnp.int32(0), max_batches=12)
    segmented = sim.run(jnp.int32(0), max_batches=12,
                        checkpoint_every=1, checkpoint_dir=str(tmp_path))
    assert_resume_parity(straight, segmented, label="segmented")


def test_resume_from_explicit_step(sims, tmp_path):
    """``resume_from=<step>`` replays from that checkpoint, not just
    the latest, and still lands bit-identically."""
    sim = sims("device/tiered3")
    straight = sim.run(jnp.int32(0), max_batches=_MAX_BATCHES)
    sim.run(jnp.int32(0), max_batches=_MAX_BATCHES,
            checkpoint_every=_CKPT_EVERY, checkpoint_dir=str(tmp_path))
    # the manager retains the newest few checkpoints (24, 28, 30 here);
    # rewind to a non-latest one and replay forward
    resumed = sim.run(jnp.int32(0), max_batches=_MAX_BATCHES,
                      checkpoint_every=_CKPT_EVERY,
                      checkpoint_dir=str(tmp_path), resume_from=24)
    assert_resume_parity(straight, resumed, label="resume_from=24")


def test_checkpoint_knobs_validated(sims, tmp_path):
    sim = sims("device/tiered3")
    with pytest.raises(ValueError, match="checkpoint_dir"):
        sim.run(jnp.int32(0), max_batches=8, checkpoint_every=4)
    with pytest.raises(ValueError, match="checkpoint_every"):
        sim.run(jnp.int32(0), max_batches=8, checkpoint_every=0,
                checkpoint_dir=str(tmp_path))


def test_host_backend_rejects_checkpoint_knobs(tmp_path):
    sim = tiny_phold().build(backend="host", scheduler="conservative")
    with pytest.raises((ValueError, NotImplementedError)):
        sim.run(jnp.int32(0), max_batches=8, checkpoint_every=4,
                checkpoint_dir=str(tmp_path))


def test_queue_flat_view_is_canonical(sims):
    """Single-queue and sharded residuals normalize to the same flat
    (time, seq)-sorted layout for the same model."""
    r1 = sims("device/tiered3").run(jnp.int32(0), max_batches=10)
    r2 = sims("device/tiered3-2shard").run(jnp.int32(0), max_batches=10)
    for a, b in zip(queue_flat_view(r1), queue_flat_view(r2)):
        np.testing.assert_array_equal(a, b)
