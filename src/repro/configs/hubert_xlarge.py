"""hubert-xlarge [audio] — 48L d_model=1280 16H (MHA) d_ff=5120
vocab=504.  Encoder-only transformer backbone (w2v2 arch); the conv
feature extractor is a STUB per the brief — input_specs() supplies
precomputed frame embeddings [B, T, 1280].  [arXiv:2106.07447]"""

from repro.configs.base import ArchConfig, LayerSpec, register_config

CONFIG = register_config(ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,        # k-means cluster targets
    causal=False,          # encoder-only, bidirectional
    activation="gelu",
    norm="layernorm",
    rope_theta=10000.0,
    block_pattern=(LayerSpec("gqa", "mlp"),),
    supports_decode=False,  # no decode shapes for encoder-only
    subquadratic=False,
    input_mode="embeds",
    notes="encoder-only: decode_32k and long_500k SKIPPED per brief;"
          " train = masked-frame cluster prediction.",
))
