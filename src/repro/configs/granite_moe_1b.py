"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]"""

from repro.configs.base import ArchConfig, LayerSpec, MoESpec, register_config

CONFIG = register_config(ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    activation="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    moe=MoESpec(num_experts=32, top_k=8, d_ff_expert=512),
    block_pattern=(LayerSpec("gqa", "moe"),),
    supports_decode=True,
    subquadratic=False,
    notes="every layer MoE, 32 experts top-8; long_500k skipped"
          " (full attention).",
))
