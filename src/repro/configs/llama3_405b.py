"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256.  GQA, 128k vocab.  [arXiv:2407.21783]"""

from repro.configs.base import ArchConfig, LayerSpec, register_config

CONFIG = register_config(ArchConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=500000.0,
    block_pattern=(LayerSpec("gqa", "mlp"),),
    supports_decode=True,
    subquadratic=False,
    notes="largest dense cell; long_500k skipped (full attention).",
))
