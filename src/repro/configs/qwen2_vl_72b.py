"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064.  M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

The vision tower is a STUB per the brief: input_specs() provides
precomputed patch embeddings [B, T, 8192] plus the (3, B, T) M-RoPE
position grid (temporal/height/width).  Decode operates on text tokens.
"""

from repro.configs.base import ArchConfig, LayerSpec, register_config

CONFIG = register_config(ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    m_rope=True,
    m_rope_sections=(16, 24, 24),   # pairs per t/h/w section of 128-dim head
    block_pattern=(LayerSpec("gqa", "mlp"),),
    supports_decode=True,
    subquadratic=False,
    input_mode="embeds",
    notes="M-RoPE positions are a (3,B,T) grid; prefill takes patch"
          " embeddings, decode takes text tokens; long_500k skipped.",
))
