from repro.configs.base import (
    SHAPES,
    ArchConfig,
    LayerSpec,
    MLASpec,
    MambaSpec,
    MoESpec,
    get_config,
    list_configs,
    register_config,
    shape_applicable,
)

__all__ = [
    "SHAPES",
    "ArchConfig",
    "LayerSpec",
    "MLASpec",
    "MambaSpec",
    "MoESpec",
    "get_config",
    "list_configs",
    "register_config",
    "shape_applicable",
]
