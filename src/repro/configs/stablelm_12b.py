"""stablelm-12b [dense] — 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352.  [hf:stabilityai/stablelm-2-1_6b family; hf]"""

from repro.configs.base import ArchConfig, LayerSpec, register_config

CONFIG = register_config(ArchConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    block_pattern=(LayerSpec("gqa", "mlp"),),
    supports_decode=True,
    subquadratic=False,
    notes="dense GQA decoder; long_500k skipped (full attention).",
))
