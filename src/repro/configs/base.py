"""Architecture configuration system.

One :class:`ArchConfig` per assigned architecture (see configs/<id>.py),
resolvable by name via :func:`get_config`.  Configs are *exact* public
configurations; ``reduced()`` derives the small same-family variant used
by the CPU smoke tests (few layers, narrow width, tiny vocab, few
experts), as required by the brief.

The layer stack is described by ``block_pattern`` — a tuple of
``(mixer, ffn)`` layer specs that is tiled ``num_layers / len(pattern)``
times.  Homogeneous runs of the pattern become ONE ``lax.scan`` over
stacked params (compile-time O(1) in depth).  Examples:

    dense:    ((gqa, mlp),)                         × L
    granite:  ((gqa, moe),)                         × 24
    deepseek: ((mla, mlp),) first layer, ((mla, moe),) × 26
    jamba:    ((gqa, mlp), (mamba, moe), (mamba, mlp), ... 8 layers) × 9
    rwkv6:    ((rwkv, rwkv_cm),)                    × 24
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

# ---------------------------------------------------------------------------
# Sub-specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLASpec:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str   # "gqa" | "mla" | "mamba" | "rwkv"
    ffn: str     # "mlp" | "moe" | "rwkv_cm"


# ---------------------------------------------------------------------------
# ArchConfig
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    causal: bool = True
    norm: str = "rmsnorm"
    activation: str = "swiglu"
    rope_theta: float = 10000.0
    m_rope: bool = False
    m_rope_sections: tuple = (16, 24, 24)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # MoE / MLA / SSM specs
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None
    mamba: Optional[MambaSpec] = None
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 64
    # layer layout
    block_pattern: tuple = (LayerSpec("gqa", "mlp"),)
    first_layer_pattern: Optional[tuple] = None  # e.g. deepseek dense layer 0
    # shape applicability
    supports_decode: bool = True
    subquadratic: bool = False   # can run long_500k
    input_mode: str = "tokens"   # tokens | embeds (audio/vlm frontend stub)
    # attention impl knobs
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    notes: str = ""

    # -- derived ------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table vocab padded to a 256 multiple so the vocab
        dim shards evenly over the 16-way model axis (the standard
        production treatment of odd vocabs like granite's 49155 or
        minicpm's 122753).  Logits beyond ``vocab_size`` are masked to
        -inf by the model."""
        return -(-self.vocab_size // 256) * 256

    def stages(self):
        """List of (pattern: tuple[LayerSpec], repeat: int)."""
        out = []
        n = self.num_layers
        if self.first_layer_pattern is not None:
            k = len(self.first_layer_pattern)
            out.append((self.first_layer_pattern, 1))
            n -= k
        p = len(self.block_pattern)
        if n % p:
            raise ValueError(
                f"{self.name}: {n} layers not divisible by pattern {p}"
            )
        out.append((self.block_pattern, n // p))
        return out

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline numbers)."""
        d, hd = self.d_model, self.resolved_head_dim
        total = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for pattern, repeat in self.stages():
            per = 0
            for spec in pattern:
                if spec.mixer == "gqa":
                    per += d * self.num_heads * hd       # q
                    per += 2 * d * self.num_kv_heads * hd
                    per += self.num_heads * hd * d       # o
                elif spec.mixer == "mla":
                    m = self.mla
                    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
                    per += d * self.num_heads * qd
                    per += d * m.kv_lora_rank + d * m.qk_rope_head_dim
                    per += m.kv_lora_rank * self.num_heads * (
                        m.qk_nope_head_dim + m.v_head_dim)
                    per += self.num_heads * m.v_head_dim * d
                elif spec.mixer == "mamba":
                    mm = self.mamba
                    di = mm.d_inner(d)
                    dtr = max(1, math.ceil(d / 16))
                    per += d * 2 * di + mm.d_conv * di
                    per += di * (dtr + 2 * mm.d_state) + dtr * di
                    per += di * mm.d_state + di  # A, D
                    per += di * d
                elif spec.mixer == "rwkv":
                    per += 5 * d * d + 2 * d * 64  # r,k,v,g,o + decay lora
                if spec.ffn == "mlp":
                    mult = 3 if self.activation in ("swiglu", "geglu") else 2
                    per += mult * d * self.d_ff
                elif spec.ffn == "moe":
                    mo = self.moe
                    mult = 3 if self.activation in ("swiglu", "geglu") else 2
                    per += mo.num_experts * mult * d * mo.d_ff_expert
                    per += d * mo.num_experts  # router
                    if mo.num_shared:
                        per += mult * d * mo.d_ff_expert * mo.num_shared
                elif spec.ffn == "rwkv_cm":
                    per += 2 * d * self.d_ff + d * d
            total += per * repeat
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k only) for 6·N_active·D."""
        if self.moe is None:
            return self.param_count()
        dense_version = dataclasses.replace(
            self,
            moe=dataclasses.replace(
                self.moe,
                num_experts=self.moe.top_k,
            ),
        )
        # count with only top_k routed experts "active"
        return dense_version.param_count()

    # -- reduced smoke-test variant ------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Small same-family config: runs a forward/train step on CPU."""
        d_small = 64
        heads = max(2, min(4, self.num_heads))
        kv = heads if self.num_kv_heads == self.num_heads else 2
        pattern_len = len(self.block_pattern)
        extra = len(self.first_layer_pattern or ())
        layers = pattern_len * (2 if pattern_len <= 4 else 1) + extra
        kw = dict(
            name=self.name + "-reduced",
            num_layers=layers,
            d_model=d_small,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=d_small // heads,
            d_ff=128,
            vocab_size=256,
            attn_q_block=16,
            attn_kv_block=16,
            rwkv_head_dim=16,
            rwkv_chunk=8,
        )
        if self.moe is not None:
            # capacity_factor = E/K makes capacity == N: provably no
            # drops, so batched and incremental MoE agree exactly in the
            # decode-vs-forward cross-check.
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(2, self.moe.top_k),
                d_ff_expert=32,
                num_shared=min(1, self.moe.num_shared),
                capacity_factor=2.0,
            )
        if self.mla is not None:
            kw["mla"] = MLASpec(kv_lora_rank=32, qk_nope_head_dim=16,
                                qk_rope_head_dim=8, v_head_dim=16)
        if self.mamba is not None:
            kw["mamba"] = dataclasses.replace(self.mamba, d_state=4, chunk=8)
        if self.m_rope:
            hd = kw["head_dim"]
            kw["m_rope_sections"] = (hd // 2 - 2 * (hd // 8), hd // 8,
                                     hd // 8)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ArchConfig] = {}


def register_config(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


def _load_all():
    import importlib

    for mod in [
        "stablelm_12b", "llama3_405b", "minicpm_2b", "phi4_mini_3_8b",
        "jamba_1_5_large", "granite_moe_1b", "deepseek_v2_lite",
        "rwkv6_1_6b", "hubert_xlarge", "qwen2_vl_72b",
    ]:
        importlib.import_module(f"repro.configs.{mod}")


# Shape suites assigned to the LM family (the brief's 4 shapes).
SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def shape_applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the brief's skip rules."""
    spec = SHAPES[shape_name]
    if spec["kind"] == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch; 500k needs sub-quadratic"
    return True, ""
