"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H d_ff=1408
vocab=102400, MoE 64e top-6, MLA kv_lora=512, 2 shared experts.
[arXiv:2405.04434; hf]

Note: the assignment line lists both "MoE 64e top-6" and "2 shared+160
routed"; 160 routed is the *full* DeepSeek-V2.  The Lite model (which
the 16B size and kv_lora=512 identify) has 64 routed + 2 shared, top-6,
expert d_ff 1408, first layer dense (d_ff 10944) — we implement Lite.
MLA: qk_nope 128, qk_rope 64, v 128, no q-LoRA.
"""

from repro.configs.base import (
    ArchConfig, LayerSpec, MLASpec, MoESpec, register_config,
)

CONFIG = register_config(ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=10944,   # the single dense first layer
    vocab_size=102400,
    activation="swiglu",
    norm="rmsnorm",
    moe=MoESpec(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2),
    mla=MLASpec(kv_lora_rank=512, qk_nope_head_dim=128,
                qk_rope_head_dim=64, v_head_dim=128),
    first_layer_pattern=(LayerSpec("mla", "mlp"),),
    block_pattern=(LayerSpec("mla", "moe"),),
    supports_decode=True,
    subquadratic=False,
    notes="MLA: decode cache stores (512 latent + 64 rope) per token —"
          " weight-absorbed decode in models/attention.py;"
          " long_500k skipped (full attention).",
))
