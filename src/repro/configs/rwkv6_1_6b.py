"""rwkv6-1.6b [ssm] — 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536.  Finch: data-dependent decay.  [arXiv:2404.05892]"""

from repro.configs.base import ArchConfig, LayerSpec, register_config

CONFIG = register_config(ArchConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,          # 2048 / 64 time-mix heads
    num_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    activation="sqrelu",   # channel-mix uses squared relu
    norm="layernorm",
    rwkv_head_dim=64,
    rwkv_chunk=32,   # pairwise-exact intra-chunk decay: [L,L,K] per chunk
    block_pattern=(LayerSpec("rwkv", "rwkv_cm"),),
    supports_decode=True,
    subquadratic=True,     # linear attention: long_500k RUNS
    notes="attention-free; decode state is (H,64,64) per layer —"
          " long_500k decode is O(1) per token.",
))
