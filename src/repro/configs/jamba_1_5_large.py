"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2.  Mamba+attention 1:7 interleave,
MoE every other layer.  [arXiv:2403.19887; hf]

Layer layout: blocks of 8 = [attn] + 7×[mamba], MoE on every other
layer (4 MoE per block); 9 blocks -> 72 layers.  One lax.scan over the
9 stacked super-blocks.
"""

from repro.configs.base import (
    ArchConfig, LayerSpec, MambaSpec, MoESpec, register_config,
)

_BLOCK = (
    LayerSpec("gqa", "mlp"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "mlp"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "mlp"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "mlp"),
    LayerSpec("mamba", "moe"),
)

CONFIG = register_config(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    activation="swiglu",
    norm="rmsnorm",
    moe=MoESpec(num_experts=16, top_k=2, d_ff_expert=24576),
    mamba=MambaSpec(d_state=16, d_conv=4, expand=2, chunk=128),
    block_pattern=_BLOCK,
    supports_decode=True,
    subquadratic=True,   # attention only every 8th layer; 500k runs
    notes="hybrid: KV cache only for the 9 attention layers; mamba state"
          " is O(1) in seq len, so long_500k RUNS for this arch.",
))
