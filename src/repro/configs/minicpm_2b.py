"""minicpm-2b [dense] — 40L d_model=2304 36H (GQA kv=36, i.e. MHA)
d_ff=5760 vocab=122753.  WSD schedule (arch llama-like).
[arXiv:2404.06395; hf]"""

from repro.configs.base import ArchConfig, LayerSpec, register_config

CONFIG = register_config(ArchConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    block_pattern=(LayerSpec("gqa", "mlp"),),
    supports_decode=True,
    subquadratic=False,
    notes="trained with the WSD schedule (training/optim.py wsd_schedule);"
          " long_500k skipped (full attention).",
))
