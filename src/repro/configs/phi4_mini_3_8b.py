"""phi4-mini-3.8b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064.  RoPE SwiGLU GQA.  [arXiv:2412.08905; hf]"""

from repro.configs.base import ArchConfig, LayerSpec, register_config

CONFIG = register_config(ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    block_pattern=(LayerSpec("gqa", "mlp"),),
    supports_decode=True,
    subquadratic=False,
    notes="200k vocab stresses the vocab-sharded embed/unembed path;"
          " long_500k skipped (full attention).",
))
