"""Checkpointing: atomic, async, integrity-checked, mesh-elastic.

Format: one ``.npy`` per pytree leaf (path-addressed) + a JSON manifest
with shapes/dtypes/step and a per-file checksum.  Writes go to a temp
directory that is atomically renamed — a crash mid-save can never
corrupt the latest checkpoint (fault tolerance requirement).

* **Async**: ``save_async`` snapshots leaves to host memory and writes
  on a background thread; training continues immediately.  ``wait()``
  joins before the next save (single outstanding write, bounded memory).
* **Elastic resharding**: the manifest stores GLOBAL shapes only; a
  restore under ANY mesh re-shards each leaf with ``jax.device_put``
  against the target sharding — scaling from 256 to 512 chips (or down
  to 1 CPU) is a restore, not a migration tool.
* **Retention**: ``keep_last`` newest checkpoints are retained.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        fname = (
            name.replace("']['", ".").replace("['", "").replace("']", "")
            .replace("[", ".").replace("]", "").replace("/", "_")
        )
        out.append((fname, leaf))
    return out


def _checksum(arr: np.ndarray) -> str:
    # Full-content digest, chunked so large leaves never materialize a
    # second copy.  (An earlier version hashed only the first 1 MiB,
    # which let a bit flip past that offset restore silently — the
    # integrity check must cover every byte of a capacity-sized queue
    # buffer.)
    h = hashlib.sha256()
    view = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
    chunk = 1 << 24
    for start in range(0, view.size, chunk):
        h.update(view[start:start + chunk].tobytes())
    h.update(str(arr.shape).encode())
    return h.hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree) -> str:
        host_tree = jax.tree.map(np.asarray, tree)
        return self._write(step, host_tree)

    def save_async(self, step: int, tree) -> None:
        self.wait()
        # device->host copy happens NOW (consistent snapshot); disk I/O
        # happens on the thread.
        host_tree = jax.tree.map(np.asarray, tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree) -> str:
        final = os.path.join(self.directory, f"step_{step:010d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for fname, leaf in _leaf_paths(host_tree):
            arr = np.asarray(leaf)
            logical_dtype = str(arr.dtype)
            if logical_dtype == "bfloat16":
                # .npy cannot round-trip ml_dtypes; store the raw bits.
                arr = arr.view(np.uint16)
            np.save(os.path.join(tmp, fname + ".npy"), arr)
            manifest["leaves"][fname] = {
                "shape": list(arr.shape),
                "dtype": logical_dtype,
                "checksum": _checksum(arr),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    _MISSING = object()

    def restore_leaf(self, name: str, step: Optional[int] = None, *,
                     verify: bool = True, default=_MISSING) -> np.ndarray:
        """Load ONE leaf by manifest name, shape taken from the file.

        Escape hatch for variable-length sidecar leaves (e.g. the
        engine's host spill pool, the streaming arrival cursor) that
        cannot appear in a fixed-shape restore template.  ``default``
        (when given) is returned for a leaf absent from the manifest —
        back-compat for sidecars newer than the checkpoint.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        if name not in manifest["leaves"]:
            if default is not CheckpointManager._MISSING:
                return default
            raise KeyError(
                f"leaf {name!r} not in checkpoint step {step}; "
                f"available: {sorted(manifest['leaves'])}")
        arr = np.load(os.path.join(path, name + ".npy"))
        meta = manifest["leaves"][name]
        if verify and _checksum(arr) != meta["checksum"]:
            raise IOError(f"checksum mismatch for {name} @ step {step}")
        if meta["dtype"] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        return arr

    def restore(self, template, step: Optional[int] = None,
                shardings=None, *, verify: bool = True):
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree (or single Sharding) — each leaf
        is ``jax.device_put`` against it, which is what makes restores
        mesh-elastic: the checkpoint stores global arrays; the new mesh
        just re-shards them.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)

        names = [fname for fname, _ in _leaf_paths(template)]
        flat_template, treedef = jax.tree_util.tree_flatten(template)
        if shardings is not None and not isinstance(shardings, (list,)):
            flat_shard = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "addressable_devices")
            )
            if len(flat_shard) == 1:
                flat_shard = flat_shard * len(flat_template)
        else:
            flat_shard = [None] * len(flat_template)

        leaves = []
        for name, tmpl, shard in zip(names, flat_template, flat_shard):
            if not hasattr(tmpl, "shape"):
                # accept python/numpy scalars as template leaves
                # (shape ()); arrays and ShapeDtypeStructs pass through
                tmpl = np.asarray(tmpl)
            arr = np.load(os.path.join(path, name + ".npy"))
            meta = manifest["leaves"][name]
            if verify and _checksum(arr) != meta["checksum"]:
                raise IOError(f"checksum mismatch for {name} @ step {step}")
            if meta["dtype"] == "bfloat16":
                arr = arr.view(ml_dtypes.bfloat16)
            if list(arr.shape) != list(tmpl.shape):
                raise ValueError(
                    f"{name}: checkpoint shape {arr.shape} != "
                    f"template {tmpl.shape}")
            arr = arr.astype(tmpl.dtype)
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), step
