"""Device-compilable serving scenarios (DESIGN.md §8.2).

:class:`repro.serving.engine.ServingEngine` is the REAL control plane —
its handlers mutate Python state and drive device work, so it runs on
the host scheduler only.  This module is its simulation twin: the same
admission/decode/evict event alphabet expressed as a pure
:class:`~repro.core.program.SimProgram`, so capacity planning ("what do
64k queued requests do to this admission policy?") compiles to ANY
backend — in particular the device engine with
``queue_mode="tiered3"``, whose bounded near-full scheduling cost is
what makes the large-pending-set regime affordable (the ROADMAP's 64k+
serving scenarios).

Event alphabet (ids are registration order):

* ``ARRIVE`` (0) — a request joins the waiting pool and chains the next
  arrival (counter-hashed inter-arrival gap on the exact f32 grid, so
  every backend computes bit-identical timestamps); also emits an
  ``ADMIT`` attempt one ``arrival_lookahead`` later.  Every declared
  lookahead is a TRUE lower bound on the type's emission delays — the
  contract the conservative window trusts; a delay below the lookahead
  would make the windowed backends diverge from sequential execution.
  Every emission carries its REQUEST INDEX in ``arg[0]`` — the routing
  slot the sharded engine partitions on (``shards=N`` spreads the
  admission traffic across per-shard queues; the handlers ignore the
  arg, so the sharded run stays bit-identical to every other backend).
* ``ADMIT`` (1) — admit the longest-waiting request into the first free
  slot (counter-hashed decode budget); with no free slot it re-emits
  itself one decode tick later — the retry loop of
  ``ServingEngine._h_prefill``.
* ``TICK``  (2) — one decode step for every active slot on the integer
  time grid (the pre-scheduled decode cadence); slots reaching zero
  finish and free themselves (eviction folded into the tick, as the
  real engine does at the next decode boundary).  Re-emits itself while
  any work remains or can still arrive.

Everything is branchless jnp, so one definition runs bit-identically on
host conservative/speculative/unbatched and device
tiered3/tiered/flat/reference — asserted by
``tests/test_serving_scenarios.py``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.program import EMIT_WIDTH, Config, SimProgram

__all__ = [
    "build_admission_program",
    "build_open_admission_program",
    "initial_state",
]

_ARRIVE, _ADMIT, _TICK = 0.0, 1.0, 2.0


def _hash_mod(k, salt: int, mod: int):
    """Deterministic counter hash -> [0, mod), pure i32 (same wraparound
    on every backend)."""
    h = (k + jnp.int32(salt)) * jnp.int32(1103515245)
    return jnp.abs(h) % jnp.int32(mod)


def initial_state(num_slots: int):
    """All-idle serving state: per-slot remaining decode budget plus the
    admission counters."""
    return {
        "slots": jnp.zeros((num_slots,), jnp.int32),
        "waiting": jnp.int32(0),
        "arrivals": jnp.int32(0),
        "admitted": jnp.int32(0),
        "served": jnp.int32(0),
        "decoded": jnp.int32(0),
        "retries": jnp.int32(0),
    }


def build_admission_program(*, num_slots: int = 8, num_requests: int = 64,
                            max_decode: int = 6,
                            arrival_lookahead: float = 0.25,
                            config: Config | None = None) -> SimProgram:
    """Serving admission/decode/evict control plane as a SimProgram.

    ``num_requests`` bounds the arrival chain (so runs terminate);
    inter-arrival gaps are ``0.25 * (1 + hash % 8)`` — multiples of the
    exact f32 grid, the repo's cross-backend parity convention — which
    pins ``arrival_lookahead`` to exactly 0.25 (validated).  Decode
    budgets are ``1 + hash % max_decode`` ticks.  Build with
    ``prog.build(backend="device", queue_mode="tiered3",
    capacity=...)`` for the large-pending-set regime — add
    ``shards=4`` for the multi-queue engine (emissions carry the
    request index in ``arg[0]``, so the default routing spreads the
    admission traffic across shards) — or any other backend for
    bit-identical validation.
    """
    cfg = config or Config(max_batch_len=8, capacity=1024, max_emit=2)
    if cfg.max_emit < 2:
        raise ValueError("admission program needs Config(max_emit >= 2)")
    if arrival_lookahead != 0.25:
        raise ValueError(
            "arrival_lookahead must be exactly 0.25: it is ARRIVE's "
            "minimum emission delay AND its declared lookahead, it may "
            "not exceed the 0.25 minimum inter-arrival gap, and "
            "off-grid values (not a multiple of 0.25) silently break "
            "the cross-backend f32 timestamp parity this scenario "
            "asserts"
        )
    prog = SimProgram("serving-admission", config=cfg)

    def _blank():
        return jnp.full((cfg.max_emit, EMIT_WIDTH), -1.0, jnp.float32)

    @prog.handler("ARRIVE", lookahead=arrival_lookahead, emits=True)
    def arrive(state, t, arg):
        k = state["arrivals"]
        state = dict(state, arrivals=k + 1, waiting=state["waiting"] + 1)
        gap = 0.25 * (1.0 + _hash_mod(k, 101, 8).astype(jnp.float32))
        more = (k + 1) < num_requests
        emits = _blank()
        emits = emits.at[0, 0].set(gap).at[0, 1].set(
            jnp.where(more, _ARRIVE, -1.0))
        emits = emits.at[1, 0].set(arrival_lookahead).at[1, 1].set(_ADMIT)
        # arg[0] = request index: the shard-routing slot (ignored here).
        emits = emits.at[0, 2].set((k + 1).astype(jnp.float32))
        emits = emits.at[1, 2].set(k.astype(jnp.float32))
        return state, emits

    @prog.handler("ADMIT", lookahead=1.0, emits=True)
    def admit(state, t, arg):
        slots = state["slots"]
        free = slots <= 0
        any_free = jnp.any(free)
        have_wait = state["waiting"] > 0
        do = have_wait & any_free
        took = do.astype(jnp.int32)
        slot = jnp.argmax(free)
        budget = 1 + _hash_mod(state["admitted"], 977, max_decode)
        slots = jnp.where(do, slots.at[slot].set(budget), slots)
        retry = have_wait & ~any_free
        state = dict(
            state, slots=slots,
            waiting=state["waiting"] - took,
            admitted=state["admitted"] + took,
            retries=state["retries"] + retry.astype(jnp.int32),
        )
        emits = _blank()
        emits = emits.at[0, 0].set(1.0).at[0, 1].set(
            jnp.where(retry, _ADMIT, -1.0))
        emits = emits.at[0, 2].set(arg[0])   # retry keeps its request id
        return state, emits

    @prog.handler("TICK", lookahead=1.0, emits=True)
    def tick(state, t, arg):
        slots = state["slots"]
        active = slots > 0
        slots = jnp.where(active, slots - 1, slots)
        finished = active & (slots == 0)
        state = dict(
            state, slots=slots,
            served=state["served"] + jnp.sum(finished).astype(jnp.int32),
            decoded=state["decoded"] + jnp.sum(active).astype(jnp.int32),
        )
        # Keep the cadence alive while anything is active, waiting, or
        # still to arrive.  A pending ADMIT retry implies waiting > 0,
        # so this predicate never strands work.
        more = ((state["arrivals"] < num_requests)
                | (state["waiting"] > 0) | jnp.any(slots > 0))
        emits = _blank()
        emits = emits.at[0, 0].set(1.0).at[0, 1].set(
            jnp.where(more, _TICK, -1.0))
        return state, emits

    prog.schedule(0.0, "ARRIVE")
    prog.schedule(1.0, "TICK")
    return prog.freeze()


def build_open_admission_program(*, num_slots: int = 8,
                                 num_requests: int = 64,
                                 max_decode: int = 6,
                                 config: Config | None = None
                                 ) -> SimProgram:
    """The admission scenario as an OPEN system (DESIGN.md §10).

    Same event alphabet and handlers as
    :func:`build_admission_program`, except ``ARRIVE`` does NOT chain
    the next arrival — requests come from an external stream
    (``sim.run(state0, arrivals=source)``) or, for the closed-system
    reference, from pre-seeded ``ARRIVE`` events at the same
    timestamps.  ``num_requests`` must equal the trace length: the
    ``TICK`` cadence keeps itself alive until that many arrivals have
    executed, so the run terminates exactly when the stream drains.

    Arrival timestamps must live on the 0.25 f32 grid (build sources
    with ``grid=0.25``, e.g. ``PoissonSource(rate, n, grid=0.25,
    type_id=0)``) — the scenario's cross-backend parity convention.
    Streams should put the request index in ``arg[0]`` (the synthetic
    sources' default), which is both the shard-routing slot and what
    keeps sharded streamed runs bit-identical to the single queue.
    """
    cfg = config or Config(max_batch_len=8, capacity=1024, max_emit=2)
    if cfg.max_emit < 2:
        raise ValueError("admission program needs Config(max_emit >= 2)")
    prog = SimProgram("serving-admission-open", config=cfg)

    def _blank():
        return jnp.full((cfg.max_emit, EMIT_WIDTH), -1.0, jnp.float32)

    @prog.handler("ARRIVE", lookahead=0.25, emits=True)
    def arrive(state, t, arg):
        k = state["arrivals"]
        state = dict(state, arrivals=k + 1, waiting=state["waiting"] + 1)
        emits = _blank()
        emits = emits.at[0, 0].set(0.25).at[0, 1].set(_ADMIT)
        emits = emits.at[0, 2].set(k.astype(jnp.float32))
        return state, emits

    @prog.handler("ADMIT", lookahead=1.0, emits=True)
    def admit(state, t, arg):
        slots = state["slots"]
        free = slots <= 0
        any_free = jnp.any(free)
        have_wait = state["waiting"] > 0
        do = have_wait & any_free
        took = do.astype(jnp.int32)
        slot = jnp.argmax(free)
        budget = 1 + _hash_mod(state["admitted"], 977, max_decode)
        slots = jnp.where(do, slots.at[slot].set(budget), slots)
        retry = have_wait & ~any_free
        state = dict(
            state, slots=slots,
            waiting=state["waiting"] - took,
            admitted=state["admitted"] + took,
            retries=state["retries"] + retry.astype(jnp.int32),
        )
        emits = _blank()
        emits = emits.at[0, 0].set(1.0).at[0, 1].set(
            jnp.where(retry, _ADMIT, -1.0))
        emits = emits.at[0, 2].set(arg[0])
        return state, emits

    @prog.handler("TICK", lookahead=1.0, emits=True)
    def tick(state, t, arg):
        slots = state["slots"]
        active = slots > 0
        slots = jnp.where(active, slots - 1, slots)
        finished = active & (slots == 0)
        state = dict(
            state, slots=slots,
            served=state["served"] + jnp.sum(finished).astype(jnp.int32),
            decoded=state["decoded"] + jnp.sum(active).astype(jnp.int32),
        )
        more = ((state["arrivals"] < num_requests)
                | (state["waiting"] > 0) | jnp.any(slots > 0))
        emits = _blank()
        emits = emits.at[0, 0].set(1.0).at[0, 1].set(
            jnp.where(more, _TICK, -1.0))
        return state, emits

    prog.schedule(1.0, "TICK")
    return prog.freeze()
