"""Event-driven serving engine: continuous batching as a DES.

The serving control plane IS a discrete-event simulation (DESIGN.md
§8.2):

* ``ARRIVE``  — a request joins; lookahead = the trace's minimum
  inter-arrival gap (known from the ingress SLA).
* ``PREFILL`` — prompt processed into a cache slot.
* ``DECODE``  — one generation step for every active slot, pre-scheduled
  on the integer time grid (decode cadence is deterministic while any
  slot is active); lookahead 1.
* ``EVICT``   — slot freed when a sequence finishes.

The paper's compile-time event batching applies directly: *runs* of
DECODE events inside the dynamic lookahead window are dispatched to
pre-composed **fused k-step decode programs** — one ``jax.jit`` tracing
``lax.scan`` over k decode steps + greedy sampling, so XLA optimizes
across the k events (single dispatch, cross-step fusion, no host sync
per token).  This is the serving-side analogue of the paper's
Increment/Set batch: the batch is composed at compile time (first use,
LazyComposer-style) and selected at runtime by the lookahead window.

Mixed windows (a DECODE run interrupted by an ARRIVE) fall back to
per-event execution, exactly like a batch whose window closes early.

This engine drives REAL device work from host handlers, so it runs on
the host scheduler.  Its simulation twin —
:mod:`repro.serving.scenarios` — expresses the same admission/decode/
evict alphabet as a pure ``SimProgram``, which compiles to every
backend; build it with ``queue_mode="tiered3"`` for capacity-planning
runs with 64k+ pending events (bounded near-full scheduling cost,
DESIGN.md §4.4).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.program import SimProgram
from repro.core.queue import HostEventQueue
from repro.core.scheduler import extract_window
from repro.models import LM

ARRIVE, PREFILL, DECODE, EVICT = 0, 1, 2, 3


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list
    max_new_tokens: int
    arrival: float
    slot: int = -1
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    finish_time: float = -1.0


@dataclasses.dataclass
class ServeStats:
    decode_events: int = 0
    fused_batches: int = 0
    fused_events: int = 0
    singles: int = 0
    prefills: int = 0
    compiled_programs: dict = dataclasses.field(default_factory=dict)
    wall_seconds: float = 0.0

    @property
    def mean_fused_length(self) -> float:
        return self.fused_events / self.fused_batches if self.fused_batches \
            else 0.0


class ServingEngine:
    def __init__(self, model: LM, params, *, max_slots: int = 8,
                 max_len: int = 256, max_batch_len: int = 4,
                 arrival_lookahead: float = 4.0,
                 prompt_buckets=(32, 64, 128)):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.max_batch_len = max_batch_len
        self.arrival_lookahead = arrival_lookahead
        self.prompt_buckets = tuple(sorted(prompt_buckets))

        self.cache = model.init_cache(max_slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * max_slots
        self.waiting: list[Request] = []
        self.requests: dict[int, Request] = {}
        self.stats = ServeStats()

        # --- compile-time batch composition (lazy, per run-length k) ---
        self._decode_k_programs: dict[int, Any] = {}
        self._prefill_programs: dict[int, Any] = {}

        # --- the event alphabet (paper §III-A: constant handler array),
        # declared on a SimProgram like every other model in the repo.
        # The serving control plane keeps its own run loop (the fused
        # k-step decode fast path below), so it consumes the program's
        # host registry directly rather than a CompiledSim; bound
        # methods register fine — the handlers mutate `self`, which is
        # the control-plane state.
        prog = SimProgram("serving-control-plane")
        prog.register("ARRIVE", self._h_arrive, lookahead=arrival_lookahead)
        prog.register("PREFILL", self._h_prefill, lookahead=0.0)
        # DECODE lookahead = arrival lookahead: the only events a decode
        # emits are EVICTs, and evictions cannot affect other DECODEs in
        # the window (slot reuse requires a PREFILL, which is gated by
        # the ARRIVE lookahead) — so decode runs may batch up to the
        # next possible arrival, the paper's dynamic window at work.
        prog.register("DECODE", self._h_decode_single,
                      lookahead=arrival_lookahead)
        prog.register("EVICT", self._h_evict, lookahead=0.0)
        self.program = prog.freeze()
        self.registry = prog.host_registry()
        self.queue = HostEventQueue()

    # ------------------------------------------------------------------
    # Composed programs (the compile-time batching)
    # ------------------------------------------------------------------
    def _decode_k(self, k: int):
        """Fused k-step decode program: ONE jit containing a lax.scan of
        k (decode_step -> greedy sample) iterations.  XLA sees the k
        events as a contiguous procedure — the paper's batch."""
        if k not in self._decode_k_programs:
            model = self.model

            def fused(params, cache, tokens, active):
                def step(carry, _):
                    cache, tokens = carry
                    logits, cache = model.decode_step(params, cache, tokens)
                    nxt = jnp.argmax(logits[:, -1], axis=-1)
                    nxt = jnp.where(active, nxt, tokens[:, 0]).astype(
                        jnp.int32)[:, None]
                    return (cache, nxt), nxt

                (cache, _), toks = jax.lax.scan(
                    step, (cache, tokens), None, length=k)
                return cache, jnp.swapaxes(toks[..., 0], 0, 1)  # [B, k]

            t0 = time.perf_counter()
            prog = jax.jit(fused)
            self._decode_k_programs[k] = prog
            self.stats.compiled_programs[f"decode_{k}"] = (
                time.perf_counter() - t0)
        return self._decode_k_programs[k]

    def _prefill_bucket(self, length: int) -> int:
        # Recurrent mixers (mamba/rwkv) carry state across EVERY token,
        # so right-padding a prompt would corrupt the state: use exact
        # lengths (one compile per distinct length). Attention-only
        # archs use buckets (lengths mask the padded cache tail).
        if any(spec.mixer in ("mamba", "rwkv")
               for pattern, _ in self.model.cfg.stages()
               for spec in pattern):
            return length
        for b in self.prompt_buckets:
            if length <= b:
                return b
        return self.prompt_buckets[-1]

    def _prefill_prog(self, bucket: int):
        if bucket not in self._prefill_programs:
            model = self.model

            def prefill_one(params, tokens, length):
                # tokens [1, bucket]; returns (next_token, cache slice)
                logits, cache = model.prefill(params, tokens=tokens,
                                              max_len=self.max_len)
                del logits
                pos = length - 1
                # recompute last VALID logit (bucket padding may exceed
                # length): cheap decode-free gather via forward logits
                full_logits, _ = model.forward(params, tokens=tokens)
                last = jnp.take_along_axis(
                    full_logits, pos[None, None, None].astype(jnp.int32),
                    axis=1)[:, 0]
                nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
                return nxt, cache

            self._prefill_programs[bucket] = jax.jit(prefill_one)
        return self._prefill_programs[bucket]

    # ------------------------------------------------------------------
    # Event handlers (host side; device work inside)
    # ------------------------------------------------------------------
    def _h_arrive(self, state, t, req: Request):
        self.waiting.append(req)
        self.queue.push(float(t), PREFILL, None)
        return state

    def _free_slot(self) -> int:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return -1

    def _h_prefill(self, state, t, arg):
        if not self.waiting:
            return state
        slot = self._free_slot()
        if slot < 0:   # no capacity: retry after the next decode tick
            self.queue.push(float(t) + 1.0, PREFILL, None)
            return state
        req = self.waiting.pop(0)
        req.slot = slot
        self.slot_req[slot] = req
        bucket = self._prefill_bucket(len(req.prompt))
        toks = jnp.zeros((1, bucket), jnp.int32)
        toks = toks.at[0, :len(req.prompt)].set(
            jnp.asarray(req.prompt, jnp.int32))
        nxt, cache1 = self._prefill_prog(bucket)(
            self.params, toks, jnp.int32(len(req.prompt)))
        # splice the single-slot cache into the global slot cache
        self.cache = _splice_slot(self.cache, cache1, slot)
        self.cache["lengths"] = self.cache["lengths"].at[slot].set(
            len(req.prompt))
        req.output.append(int(nxt[0]))
        self.stats.prefills += 1
        return state

    def _pending_tokens_default(self):
        toks = []
        for r in self.slot_req:
            toks.append(r.output[-1] if r is not None and r.output else 0)
        return jnp.asarray(toks, jnp.int32)[:, None]

    def _active_mask(self):
        return jnp.asarray(
            [r is not None and not r.done for r in self.slot_req],
            dtype=bool)

    def _h_decode_single(self, state, t, arg):
        """Fallback: one DECODE event executed alone."""
        self._decode_run(1, float(t))
        self.stats.singles += 1
        return state

    def _h_evict(self, state, t, arg):
        for i, r in enumerate(self.slot_req):
            if r is not None and r.done:
                self.slot_req[i] = None
                self.cache["lengths"] = self.cache["lengths"].at[i].set(0)
        return state

    # ------------------------------------------------------------------
    # Decode execution (single or fused run)
    # ------------------------------------------------------------------
    def _decode_run(self, k: int, t_end: float):
        active = self._active_mask()
        if not bool(active.any()):
            return
        tokens = self._pending_tokens_default()
        prog = self._decode_k(k)
        self.cache, toks = prog(self.params, self.cache, tokens, active)
        toks = jax.device_get(toks)              # [slots, k]
        self.stats.decode_events += k
        for i, r in enumerate(self.slot_req):
            if r is None or r.done:
                continue
            for j in range(k):
                r.output.append(int(toks[i, j]))
                if len(r.output) >= r.max_new_tokens:
                    r.done = True
                    r.finish_time = t_end
                    self.queue.push(t_end, EVICT, None)
                    break

    # ------------------------------------------------------------------
    # Main loop: lookahead-window batch extraction (paper §III-B)
    # ------------------------------------------------------------------
    def submit(self, rid: int, prompt, max_new_tokens: int, at: float):
        req = Request(rid=rid, prompt=list(prompt),
                      max_new_tokens=max_new_tokens, arrival=at)
        self.requests[rid] = req
        self.queue.push(at, ARRIVE, req)
        return req

    def schedule_decode_grid(self, t0: float, t1: float):
        """Pre-schedule the decode cadence (one event per integer t)."""
        t = float(t0)
        while t <= t1:
            self.queue.push(t, DECODE, None)
            t += 1.0

    def run(self, *, max_events: int | None = None):
        t_start = time.perf_counter()
        processed = 0
        budget = float("inf") if max_events is None else max_events
        while self.queue and processed < budget:
            batch = extract_window(self.queue, self.registry,
                                   self.max_batch_len)
            types = [ev.type_id for ev in batch]
            if all(ty == DECODE for ty in types) and len(batch) > 1:
                # the composed-batch fast path
                self._decode_run(len(batch), batch[-1].time)
                self.stats.fused_batches += 1
                self.stats.fused_events += len(batch)
            else:
                for ev in batch:
                    et = self.registry[ev.type_id]
                    et.handler(None, ev.time, ev.arg)
            processed += len(batch)
            # stop once every submitted request finished (only the
            # pre-scheduled decode grid remains in the queue)
            if self.requests and all(r.done
                                     for r in self.requests.values()):
                break
        self.stats.wall_seconds = time.perf_counter() - t_start
        return self.stats


def _splice_slot(cache, cache1, slot: int):
    """Write the single-sequence cache1 (batch size 1) into ``slot`` of
    the multi-slot cache (same structure, batch dim 1 vs max_slots)."""
    def splice(big, small):
        if big.ndim < 2:
            return big
        # batch dim is axis 1 for stage leaves [L, B, ...]
        return big.at[:, slot].set(small[:, 0].astype(big.dtype))

    new_stages = jax.tree.map(splice, cache["stages"], cache1["stages"])
    return {"stages": new_stages, "lengths": cache["lengths"]}
