"""Per-(arch × shape) lowering specs for the dry-run and launchers.

``build_cell(cfg, shape_name, mesh)`` returns a :class:`CellSpec` with
the step function, ShapeDtypeStruct argument avatars (no allocation),
and in/out shardings — everything ``jax.jit(...).lower()`` needs.

Shape kinds (configs/base.SHAPES):
* train_*   -> train_step   (microbatched, remat, AdamW)
* prefill_* -> prefill_step (full sequence -> last logits + cache)
* decode_*  -> serve_step   (ONE new token against a seq_len cache)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, ArchConfig
from repro.launch.mesh import dp_axes, dp_size
from repro.launch.sharding import (
    batch_shardings,
    cache_shardings,
    param_shardings,
    set_batch_axes,
    state_shardings,
)
from repro.models import LM
from repro.training.optim import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: str
    kind: str
    fn: Callable
    arg_specs: tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple
    static_info: dict


def _batch_specs(cfg: ArchConfig, B: int, T: int):
    specs = {}
    if cfg.input_mode == "embeds":
        specs["embeds"] = jax.ShapeDtypeStruct((B, T, cfg.d_model),
                                               jnp.bfloat16)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
    specs["labels"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
    if cfg.m_rope:
        specs["positions"] = jax.ShapeDtypeStruct((3, B, T), jnp.int32)
    return specs


def pick_microbatches(global_batch: int, dp: int, *,
                      target_per_device: int = 1, cap: int = 16) -> int:
    per_dev = max(1, global_batch // dp)
    return max(1, min(cap, per_dev // target_per_device))


def build_cell(cfg: ArchConfig, shape_name: str, mesh, *,
               num_microbatches: int | None = None,
               attn_impl: str = "blockwise",
               fsdp: bool = True,
               model_kwargs: dict | None = None) -> CellSpec:
    shape = SHAPES[shape_name]
    kind = shape["kind"]
    B, T = shape["global_batch"], shape["seq_len"]
    model = LM(cfg, attn_impl=attn_impl, **(model_kwargs or {}))
    dp = dp_size(mesh)
    set_batch_axes(dp_axes(mesh))  # anchor activation batch sharding

    params_spec = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    ps = param_shardings(mesh, params_spec, fsdp=fsdp)

    if kind == "train":
        nm = num_microbatches or pick_microbatches(B, dp)
        opt = AdamWConfig()
        step = make_train_step(model, opt, num_microbatches=nm, remat=True)
        state_spec = jax.eval_shape(
            lambda: init_train_state(model, jax.random.PRNGKey(0)))
        ss = state_shardings(mesh, state_spec, fsdp=fsdp)
        bspec = _batch_specs(cfg, B, T)
        bs = batch_shardings(mesh, bspec)
        return CellSpec(
            arch=cfg.name, shape=shape_name, kind=kind, fn=step,
            arg_specs=(state_spec, bspec), in_shardings=(ss, bs),
            out_shardings=(ss, None), donate_argnums=(0,),
            static_info={"num_microbatches": nm, "tokens": B * T},
        )

    if kind == "prefill":
        def prefill_step(params, batch):
            return model.prefill(
                params, tokens=batch.get("tokens"),
                embeds=batch.get("embeds"),
                positions=batch.get("positions"), max_len=T)

        bspec = _batch_specs(cfg, B, T)
        bspec.pop("labels")
        bs = batch_shardings(mesh, bspec)
        with mesh:  # shard_batch_dim constraints need the mesh context
            out_spec = jax.eval_shape(prefill_step, params_spec, bspec)
        logits_sh = NamedSharding(mesh, P(dp_axes(mesh), "model"))
        cs = cache_shardings(mesh, out_spec[1], batch=B)
        return CellSpec(
            arch=cfg.name, shape=shape_name, kind=kind, fn=prefill_step,
            arg_specs=(params_spec, bspec), in_shardings=(ps, bs),
            out_shardings=(logits_sh, cs), donate_argnums=(),
            static_info={"tokens": B * T},
        )

    # decode: one new token against a seq_len cache
    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    cache_spec = jax.eval_shape(lambda: model.init_cache(B, T))
    # pretend the cache is nearly full (ShapeDtypeStruct: lengths only
    # matter dynamically; the lowering covers any fill level)
    cs = cache_shardings(mesh, cache_spec, batch=B)
    tok_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_sh = NamedSharding(mesh, P(dp_axes(mesh) if B >= dp else None, None))
    logits_sh = NamedSharding(
        mesh, P(dp_axes(mesh) if B >= dp else None, None, "model"))
    return CellSpec(
        arch=cfg.name, shape=shape_name, kind=kind, fn=serve_step,
        arg_specs=(params_spec, cache_spec, tok_spec),
        in_shardings=(ps, cs, tok_sh),
        out_shardings=(logits_sh, cs), donate_argnums=(1,),
        static_info={"tokens": B},
    )
