"""Mesh construction for the production topology.

Single pod: (16, 16) = 256 chips, axes ("data", "model") — TP within
the "model" axis (ICI-adjacent), DP/FSDP over "data".

Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") —
the "pod" axis carries ONLY data parallelism (gradient all-reduce over
DCN); parameters, FSDP shards and TP stay within a pod, which is the
standard DCN-aware layout (params never cross the slow inter-pod links
outside the once-per-step gradient reduction).

Everything here is a FUNCTION — importing this module never touches JAX
device state (the dry-run must set XLA_FLAGS before any device query).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """A mesh over whatever devices exist (CPU tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The axes carrying the batch dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def tp_size(mesh) -> int:
    return mesh.shape["model"] if "model" in mesh.axis_names else 1
