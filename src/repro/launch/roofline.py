"""Roofline analysis from the compiled dry-run artifact (deliverable g).

Terms (TPU v5e constants, per the brief):

    compute    = HLO_FLOPs_total  / (chips * 197e12 FLOP/s)
    memory     = HLO_bytes_total  / (chips * 819e9  B/s)
    collective = collective_bytes / (chips * 50e9   B/s per link)

``compiled.cost_analysis()`` reports the PER-DEVICE partitioned module
(verified in tests/test_roofline.py), so totals are per-device × chips.
Collective bytes are parsed from the optimized HLO text: the sum of
operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, per device, × chips.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

# --- hardware constants (TPU v5e) ---
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_DEF_RE = re.compile(r"^\s*(%?[\w.-]+)\s*=\s*(.*?)([\w-]+)\(")
_OPERAND_RE = re.compile(r"%[\w.-]+")


def parse_collectives(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in a (per-device) HLO module.

    Post-optimization HLO references operands by NAME only, so this is
    two passes: (1) map every instruction name to its result byte size
    (tuples sum their components); (2) for each collective op, look up
    and sum its operand sizes.  Async ``-start``/``-done`` pairs are
    counted once (at the start op).

    Returns {'total_bytes': int, 'by_op': {op: {'bytes': int, 'count': n}}}.
    """
    defs: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, typestr, _opcode = m.groups()
        nbytes = sum(_shape_bytes(dt, dims)
                     for dt, dims in _SHAPE_RE.findall(typestr))
        defs[name if name.startswith("%") else "%" + name] = nbytes

    by_op: dict[str, dict] = {op: {"bytes": 0, "count": 0}
                              for op in _COLLECTIVES}
    coll_re = re.compile(
        r"=\s*(?:\([^)]*\)|\S+)\s+(all-gather|all-reduce|reduce-scatter|"
        r"all-to-all|collective-permute)(-start|-done)?\(")
    for line in lines:
        m = coll_re.search(line)
        if not m:
            continue
        op, variant = m.group(1), m.group(2)
        if variant == "-done":
            continue  # operands were counted at the -start op
        args = line[m.end():]
        # cut at attribute section (channel_id / replica_groups / metadata)
        for cut in (", channel_id", ", replica_groups", ", metadata",
                    ", dimensions", ", source_target_pairs"):
            idx = args.find(cut)
            if idx >= 0:
                args = args[:idx]
        nbytes = 0
        for ref in _OPERAND_RE.findall(args):
            nbytes += defs.get(ref, 0)
        by_op[op]["bytes"] += nbytes
        by_op[op]["count"] += 1
    total = sum(v["bytes"] for v in by_op.values())
    return {"total_bytes": total, "by_op": by_op}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_detail: dict
    model_flops: float           # 6·N(active)·D analytic
    memory_stats: dict

    @property
    def compute_seconds(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_seconds(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_seconds(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_seconds,
            "memory": self.memory_seconds,
            "collective": self.collective_seconds,
        }
        return max(terms, key=terms.get)

    @property
    def bound_seconds(self) -> float:
        return max(self.compute_seconds, self.memory_seconds,
                   self.collective_seconds)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs_total — remat/redundancy waste."""
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline bound: the score the
        perf loop pushes up (useful flops / chip-seconds at the bound)."""
        denom = self.bound_seconds * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_detail": self.collective_detail,
            "model_flops": self.model_flops,
            "compute_seconds": self.compute_seconds,
            "memory_seconds": self.memory_seconds,
            "collective_seconds": self.collective_seconds,
            "dominant": self.dominant,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu_at_bound": self.mfu,
            "memory_stats": self.memory_stats,
        }


def model_flops_for(cfg, kind: str, tokens: int, seq_len: int) -> float:
    """Analytic MODEL_FLOPS: 6·N·D for train, 2·N·D for inference
    (forward only), N = active params for MoE."""
    n = cfg.active_param_count()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens


def attention_score_hbm_bytes(cfg, kind: str, batch: int,
                              seq_len: int) -> float:
    """Analytic HBM traffic of materialized attention score blocks.

    The pure-JAX blockwise attention (what the dry-run lowers) writes
    each [q_block, kv_block] fp32 score/prob block to HBM between the
    QK and PV dots — XLA cannot fuse dot->dot.  The Pallas flash kernel
    (kernels/flash_attention.py) keeps them in VMEM, so the roofline
    table reports memory terms both as-lowered and pallas-adjusted
    (memory_seconds - this/HBM_BW/chips).

    Model: s write + s read + p write + p read = 4 touches x fp32 per
    (B, H, T, S) element; causal halves; train ≈ 3 passes (fwd + remat
    fwd + bwd), prefill 1 pass.  Attention layers only (mamba/rwkv
    layers contribute none).
    """
    n_attn = 0
    for pattern, repeat in cfg.stages():
        for spec in pattern:
            if spec.mixer in ("gqa", "mla"):
                n_attn += repeat
    if kind == "decode" or n_attn == 0:
        return 0.0
    passes = 3.0 if kind == "train" else 1.0
    causal = 0.5 if cfg.causal else 1.0
    elems = float(batch) * cfg.num_heads * seq_len * seq_len
    return n_attn * passes * causal * elems * 4.0 * 4.0  # 4 touches, fp32


def analyze(compiled, *, arch: str, shape: str, mesh_name: str,
            chips: int, model_flops: float) -> Roofline:
    # Loop-aware cost terms (launch/hlo_cost.py): XLA's cost_analysis()
    # counts scan bodies ONCE and under-counts deep models by orders of
    # magnitude; its numbers are kept alongside for reference.
    from repro.launch.hlo_cost import analyze_hlo

    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    text = compiled.as_text()
    cost = analyze_hlo(text)
    cost_bf16 = analyze_hlo(text, assume_native_bf16=True)
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
        "output_bytes": getattr(ma, "output_size_in_bytes", 0),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
        "xla_cost_analysis_flops": float(ca.get("flops", 0.0)),
        "xla_cost_analysis_bytes": float(ca.get("bytes accessed", 0.0)),
        # memory bytes assuming TPU-native bf16 (no CPU dot legalization
        # convert-wrapping of in-place cache/residual updates):
        "mem_bytes_native_bf16": cost_bf16.mem_bytes,
        "memory_seconds_native_bf16": cost_bf16.mem_bytes / HBM_BW,
    }
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=cost.flops, bytes_per_device=cost.mem_bytes,
        collective_bytes_per_device=cost.coll_bytes,
        collective_detail=cost.coll_by_op, model_flops=model_flops,
        memory_stats=mem,
    )
