"""Loop-aware cost model over optimized (per-device, partitioned) HLO.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 126 layers contributes a single body's FLOPs, which
under-counts deep models by orders of magnitude (verified in
tests/test_roofline.py).  This module re-walks the HLO text with loop
multipliers:

* ``while`` ops carry ``backend_config={"known_trip_count":{"n":K}}``
  after XLA optimization — body and condition costs are multiplied by K
  (nested loops multiply through).  Fallback: largest integer constant
  in the condition closure.
* **FLOPs** — 2·result_elems·contracted_size for every ``dot`` (operand
  shapes resolved from the instruction's computation; batch dims are in
  the result).  Elementwise FLOPs are excluded by convention (matches
  the MODEL_FLOPS=6ND accounting).
* **Memory traffic** — Σ(operand bytes + result bytes) of every
  *materializing* top-level instruction (fusions count at their call
  boundary; fusion-internal values never touch HBM; parameter /
  constant / tuple plumbing excluded).  An estimate of post-fusion HBM
  traffic.
* **Collective bytes** — Σ operand bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute, × loop multipliers.

All numbers are PER DEVICE (the module is the partitioned per-device
program).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.-]+)\s*=\s*(.*?)\s*([\w-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.-]+)\s*\(.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[="{\s]*\{?["\s]*n["\':\s]+"?(\d+)')
_REF_RE = re.compile(r"%[\w.-]+")

_PLUMBING = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id",
             "iota"}


def _dims(dimstr: str) -> list[int]:
    return [int(d) for d in dimstr.split(",") if d] if dimstr else []


def _type_bytes(typestr: str) -> int:
    return sum(
        (lambda n: n * _DTYPE_BYTES.get(dt, 0))(
            __import__("math").prod(_dims(dims)) if dims else 1)
        for dt, dims in _SHAPE_RE.findall(typestr)
    )


@dataclasses.dataclass
class Instr:
    name: str
    typestr: str
    opcode: str
    rest: str          # everything after the opening paren of the call
    result_bytes: int
    shapes: list       # [(dtype, [dims])] of the result type


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)

    def __add__(self, o):
        by = dict(self.coll_by_op)
        for k, v in o.coll_by_op.items():
            d = by.setdefault(k, {"bytes": 0.0, "count": 0.0})
            d["bytes"] += v["bytes"]
            d["count"] += v["count"]
        return Cost(self.flops + o.flops, self.mem_bytes + o.mem_bytes,
                    self.coll_bytes + o.coll_bytes, by)

    def scaled(self, k: float) -> "Cost":
        by = {op: {"bytes": v["bytes"] * k, "count": v["count"] * k}
              for op, v in self.coll_by_op.items()}
        return Cost(self.flops * k, self.mem_bytes * k,
                    self.coll_bytes * k, by)


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, list[Instr]] = {}
        self._parse(text)
        self._memo: dict[tuple, Cost] = {}

    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for line in text.splitlines():
            mc = _COMP_RE.match(line)
            if mc and "->" in line:
                cur = mc.group(1)
                self.comps[cur] = []
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            mi = _INSTR_RE.match(line)
            if not mi:
                continue
            name, typestr, opcode, rest = mi.groups()
            self.comps[cur].append(Instr(
                name=name, typestr=typestr, opcode=opcode, rest=rest,
                result_bytes=_type_bytes(typestr),
                shapes=[( dt, _dims(d)) for dt, d in
                        _SHAPE_RE.findall(typestr)],
            ))

    # -- helpers -----------------------------------------------------------
    def _defs(self, comp: str) -> dict[str, Instr]:
        return {i.name: i for i in self.comps.get(comp, [])}

    def _operand_refs(self, instr: Instr) -> list[str]:
        args = instr.rest
        for cut in ("), ", ") ,", "),\t"):
            idx = args.find(cut)
            if idx >= 0:
                args = args[:idx]
                break
        else:
            idx = args.rfind(")")
            if idx >= 0:
                args = args[:idx]
        return _REF_RE.findall(args)

    def _attr(self, instr: Instr, key: str) -> Optional[str]:
        m = re.search(key + r"=(%[\w.-]+)", instr.rest)
        return m.group(1) if m else None

    def _trip_count(self, instr: Instr) -> int:
        m = _TRIP_RE.search(instr.rest)
        if m:
            return int(m.group(1))
        cond = self._attr(instr, "condition")
        if cond and cond in self.comps:
            consts = []
            for i in self.comps[cond]:
                if i.opcode == "constant":
                    mm = re.match(r"(\d+)", i.rest)
                    if mm:
                        consts.append(int(mm.group(1)))
            if consts:
                return max(consts)
        return 1

    def _fusion_operand_bytes(self, instr: Instr, called: str, pos: int,
                              defs: dict) -> int:
        """HBM bytes read for a fusion's ``pos``-th operand.

        When the operand's matching parameter inside the fused
        computation is consumed ONLY by dynamic-slice / gather ops, the
        fusion reads just the slice(s), not the whole buffer — e.g. the
        per-layer weight slice from a [L, ...] stacked tensor inside a
        scan body.  Counting full operands there inflated llama3-405b
        train memory ~20x (EXPERIMENTS §Roofline methodology).
        """
        refs = self._operand_refs(instr)
        full = defs[refs[pos]].result_bytes if refs[pos] in defs else 0
        comp = self.comps.get(called)
        if not comp:
            return full
        pname = None
        for i in comp:
            if i.opcode == "parameter" and i.rest.startswith(f"{pos})"):
                pname = i.name
                break
        if pname is None:
            return full
        sliced = 0
        for i in comp:
            if i.opcode == "parameter":
                continue
            if pname in self._operand_refs(i):
                if i.opcode in ("dynamic-slice", "gather", "slice"):
                    sliced += i.result_bytes
                else:
                    return full  # some consumer reads it wholesale
        return min(full, sliced) if sliced else full

    def _dot_flops(self, instr: Instr, defs: dict) -> float:
        result_elems = 1
        for _dt, dims in instr.shapes:
            for d in dims:
                result_elems *= d
        refs = self._operand_refs(instr)
        contracted = 1
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
        if m and refs:
            lhs = defs.get(refs[0])
            if lhs is not None and lhs.shapes:
                dims = lhs.shapes[0][1]
                for ci in _dims(m.group(1)):
                    if ci < len(dims):
                        contracted *= dims[ci]
        return 2.0 * result_elems * contracted

    # -- the walk ------------------------------------------------------------
    def cost(self, comp: str, *, count_mem: bool = True) -> Cost:
        key = (comp, count_mem)
        if key in self._memo:
            return self._memo[key]
        total = Cost()
        defs = self._defs(comp)
        for instr in self.comps.get(comp, []):
            op = instr.opcode
            if op == "while":
                body = self._attr(instr, "body")
                cond = self._attr(instr, "condition")
                trip = self._trip_count(instr)
                sub = Cost()
                if body in self.comps:
                    sub = sub + self.cost(body, count_mem=count_mem)
                if cond in self.comps:
                    sub = sub + self.cost(cond, count_mem=count_mem)
                total = total + sub.scaled(trip)
                continue
            if op == "conditional":
                branches = re.findall(r"%[\w.-]+", instr.rest)
                comps = [b for b in branches if b in self.comps]
                if comps:
                    subs = [self.cost(b, count_mem=count_mem)
                            for b in comps]
                    best = max(subs, key=lambda c: c.flops + c.mem_bytes)
                    total = total + best
                continue
            if op == "fusion":
                called = self._attr(instr, "calls")
                if called in self.comps:
                    # fusion internals never touch HBM
                    total = total + self.cost(called, count_mem=False)
                if count_mem:
                    ops_b = [self._fusion_operand_bytes(instr, called, pos,
                                                        defs)
                             for pos, _ in enumerate(
                                 self._operand_refs(instr))]
                    root = (self.comps[called][-1]
                            if called in self.comps and self.comps[called]
                            else None)
                    # CPU-backend artifact: bf16 dots are legalized to
                    # f32, and XLA hoists the converts through cache
                    # updates, wrapping the in-place DUS in full-buffer
                    # converts (convert(DUS(convert(stack)))).  On TPU
                    # (native bf16 MXU) the DUS roots cleanly and
                    # aliases.  With assume_native_bf16 we look through
                    # a convert root to the DUS beneath.
                    if root is not None and root.opcode == "convert" and \
                            getattr(self, "assume_native_bf16", False):
                        for cand in reversed(self.comps.get(called, [])):
                            if cand.opcode in ("dynamic-update-slice",
                                               "scatter"):
                                root = cand
                                break
                    if root is not None and \
                            root.opcode in ("dynamic-update-slice",
                                            "scatter"):
                        # In-place scan-slice / cache-scatter update:
                        # XLA aliases the destination buffer; real
                        # traffic is the update (read + region write),
                        # not the whole stacked tensor.
                        upd_refs = self._operand_refs(root)
                        cdefs = self._defs(called)
                        upd_ref = (upd_refs[1]
                                   if root.opcode == "dynamic-update-slice"
                                   else (upd_refs[-1] if upd_refs else None))
                        upd = (cdefs[upd_ref].result_bytes
                               if upd_ref in cdefs else 0)
                        big = max(ops_b) if ops_b else 0
                        total.mem_bytes += sum(ops_b) - big + 2 * upd
                    else:
                        total.mem_bytes += sum(ops_b) + instr.result_bytes
                continue
            if op == "dynamic-update-slice":
                if count_mem:
                    refs = self._operand_refs(instr)
                    upd = (defs[refs[1]].result_bytes
                           if len(refs) > 1 and refs[1] in defs else 0)
                    total.mem_bytes += 2 * upd
                continue
            if op == "scatter":
                # KV-cache token updates: donated buffers alias, so the
                # real traffic is the updates (operand 2), not a full
                # cache rewrite (that overcounted decode cells ~700x).
                if count_mem:
                    refs = self._operand_refs(instr)
                    upd = (defs[refs[-1]].result_bytes
                           if refs and refs[-1] in defs else 0)
                    total.mem_bytes += 2 * upd
                continue
            if op == "dynamic-slice":
                if count_mem:
                    total.mem_bytes += 2 * instr.result_bytes
                continue
            if op in ("call",):
                called = self._attr(instr, "to_apply")
                if called in self.comps:
                    total = total + self.cost(called, count_mem=count_mem)
                continue
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                opbytes = sum(defs[r].result_bytes
                              for r in self._operand_refs(instr)
                              if r in defs)
                total.coll_bytes += opbytes
                d = total.coll_by_op.setdefault(
                    base, {"bytes": 0.0, "count": 0.0})
                d["bytes"] += opbytes
                d["count"] += 1
                if count_mem:
                    total.mem_bytes += opbytes + instr.result_bytes
                continue
            if op == "dot":
                total.flops += self._dot_flops(instr, defs)
            if count_mem and op not in _PLUMBING:
                opbytes = sum(defs[r].result_bytes
                              for r in self._operand_refs(instr)
                              if r in defs)
                total.mem_bytes += opbytes + instr.result_bytes
        self._memo[key] = total
        return total

    def entry_cost(self) -> Cost:
        # the ENTRY computation is conventionally named %main.*
        entry = None
        for name in self.comps:
            if name.startswith("%main"):
                entry = name
                break
        if entry is None:
            entry = next(iter(self.comps))
        return self.cost(entry)


def analyze_hlo(text: str, *, assume_native_bf16: bool = False) -> Cost:
    mod = HloModule(text)
    mod.assume_native_bf16 = assume_native_bf16
    return mod.entry_cost()
