import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Proves the distribution config is coherent without hardware: for every
(architecture × applicable input shape × mesh), ``jax.jit(step,
in_shardings, out_shardings).lower(**ShapeDtypeStructs).compile()`` must
succeed on the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh.
Memory/cost/collective stats are recorded to a JSON the roofline tables
in EXPERIMENTS.md are generated from.

The XLA_FLAGS line above MUST run before any jax import (device count
locks at first init) — this module is the only place it is set.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
        --shape train_4k --mesh multi                            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --out results.json
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import SHAPES, get_config, list_configs, shape_applicable
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell

DEFAULT_OUT = "dryrun_results.json"


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             attn_impl: str = "blockwise", fsdp: bool = True,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.size
    cell = build_cell(cfg, shape_name, mesh, attn_impl=attn_impl, fsdp=fsdp)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        ).lower(*cell.arg_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    shape = SHAPES[shape_name]
    mf = rl.model_flops_for(cfg, cell.kind, cell.static_info["tokens"],
                            shape["seq_len"])
    roof = rl.analyze(compiled, arch=arch, shape=shape_name,
                      mesh_name=mesh_name, chips=chips, model_flops=mf)
    score_bytes = rl.attention_score_hbm_bytes(
        cfg, cell.kind, shape["global_batch"], shape["seq_len"])
    mem_adj = max(0.0, roof.memory_seconds -
                  score_bytes / chips / rl.HBM_BW)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "kind": cell.kind,
        "lower_seconds": round(t_lower, 2),
        "compile_seconds": round(t_compile, 2),
        "static_info": cell.static_info,
        "roofline": roof.to_dict(),
        "memory_seconds_pallas_adj": mem_adj,
        "attention_score_hbm_bytes_total": score_bytes,
    }
    if verbose:
        ms = roof.memory_stats
        print(f"[{arch} × {shape_name} × {mesh_name}] OK "
              f"compile={t_compile:.1f}s "
              f"args={ms['argument_bytes']/1e9:.2f}GB/dev "
              f"temp={ms['temp_bytes']/1e9:.2f}GB/dev "
              f"compute={roof.compute_seconds*1e3:.2f}ms "
              f"memory={roof.memory_seconds*1e3:.2f}ms "
              f"collective={roof.collective_seconds*1e3:.2f}ms "
              f"dominant={roof.dominant} mfu@bound={roof.mfu:.3f}",
              flush=True)
        # the brief asks for these two printed verbatim:
        print("  memory_analysis:", compiled.memory_analysis(), flush=True)
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        print("  cost_analysis: flops=%.3e bytes=%.3e" %
              (ca.get("flops", 0.0), ca.get("bytes accessed", 0.0)),
              flush=True)
    return result


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, help="one arch id (default all)")
    p.add_argument("--shape", default=None, choices=list(SHAPES),
                   help="one shape (default all)")
    p.add_argument("--mesh", default=None, choices=["single", "multi"],
                   help="one mesh (default both)")
    p.add_argument("--attn-impl", default="blockwise")
    p.add_argument("--no-fsdp", action="store_true")
    p.add_argument("--out", default=DEFAULT_OUT)
    p.add_argument("--append", action="store_true",
                   help="merge into an existing results file")
    args = p.parse_args()

    archs = [args.arch] if args.arch else list_configs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    results = []
    if args.append and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    # re-attempt FAILED cells on resume; keep ok/skipped
    results = [r for r in results if r["status"] != "FAILED"]
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_name in meshes:
                key = (arch, shape_name, mesh_name)
                if key in done:
                    continue
                try:
                    r = run_cell(arch, shape_name, mesh_name,
                                 attn_impl=args.attn_impl,
                                 fsdp=not args.no_fsdp)
                except Exception as e:  # a failure here is a system bug
                    traceback.print_exc()
                    r = {"arch": arch, "shape": shape_name,
                         "mesh": mesh_name, "status": "FAILED",
                         "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                if r["status"] == "skipped":
                    print(f"[{arch} × {shape_name} × {mesh_name}] "
                          f"skipped: {r['reason']}", flush=True)
                results.append(r)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    print(f"\ndry-run complete: {ok} ok, {sk} skipped, {failures} FAILED "
          f"-> {args.out}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
