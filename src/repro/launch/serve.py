"""Serving launcher: the DES-driven continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-12b \
        --reduced --requests 6 --max-new 12
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import LM
from repro.serving.engine import ServingEngine


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--requests", type=int, default=6)
    p.add_argument("--max-new", type=int, default=12)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--max-batch-len", type=int, default=4)
    p.add_argument("--arrival-gap", type=float, default=6.0)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.supports_decode:
        raise SystemExit(f"{cfg.name} is encoder-only; no serving path")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServingEngine(
        model, params, max_slots=args.slots, max_len=256,
        max_batch_len=args.max_batch_len,
        arrival_lookahead=args.arrival_gap)

    rng = np.random.default_rng(args.seed)
    t = 0.0
    horizon = args.requests * args.arrival_gap + args.max_new * 4 + 64
    for rid in range(args.requests):
        plen = int(rng.integers(4, 17))
        prompt = rng.integers(0, cfg.vocab_size, plen).tolist()
        engine.submit(rid, prompt, args.max_new, at=t)
        t += args.arrival_gap + float(rng.random())
    engine.schedule_decode_grid(1.0, horizon)

    stats = engine.run()
    done = sum(1 for r in engine.requests.values() if r.done)
    print(f"served {done}/{args.requests} requests in "
          f"{stats.wall_seconds:.2f}s wall")
    print(f"decode events: {stats.decode_events}  "
          f"fused batches: {stats.fused_batches} "
          f"(mean len {stats.mean_fused_length:.2f})  "
          f"singles: {stats.singles}  prefills: {stats.prefills}")
    print(f"composed decode programs: "
          f"{sorted(k for k in stats.compiled_programs)}")
    for rid, r in sorted(engine.requests.items()):
        print(f"  req {rid}: arrived {r.arrival:.1f} "
              f"finished {r.finish_time:.1f} tokens={len(r.output)}")
    return 0 if done == args.requests else 1


if __name__ == "__main__":
    raise SystemExit(main())
