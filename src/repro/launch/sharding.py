"""Partitioning rules: param / batch / cache shardings for every arch.

Parallelism layout (DESIGN.md §8.1):

* **TP** over ``model``: attention heads (wq/wk/wv out-dim), wo in-dim,
  MLP hidden, MoE experts (EP), mamba d_inner, rwkv projections, vocab.
* **FSDP** over ``data``: the *other* matrix dim of every 2-D param —
  ZeRO-3-style; under GSPMD the per-layer all-gathers materialize inside
  the layer scan.  Optimizer moments inherit leaf-for-leaf.
* **DP** over ``(pod, data)``: the batch dim of activations.  The pod
  axis appears ONLY here — params/FSDP/TP never cross DCN.

Rules are (regex over the leaf path, spec for the TRAILING dims);
leading dims (the layer-stack axis) are padded with None.  First match
wins — order matters (e.g. ``ffn.*wv`` before the attention ``wv``).
"""

from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes

# ---------------------------------------------------------------------------
# Activation batch-axis anchoring
# ---------------------------------------------------------------------------
# The embedding gather's output sharding is ambiguous to GSPMD (vocab-
# sharded table x batch-sharded ids); left alone it picks feature-
# sharded/batch-REPLICATED activations and every layer downstream runs
# the full batch on every data shard (16x executed FLOPs — caught by the
# loop-aware HLO cost model, EXPERIMENTS §Perf).  The launcher registers
# the DP axes here; the model anchors its post-embed activations.

_BATCH_AXES = None


def set_batch_axes(axes) -> None:
    """Called by the launcher (dry-run/train/serve) before tracing."""
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes) if axes else None


def shard_batch_dim(x, dim: int = 0):
    """with_sharding_constraint pinning the batch dim to the DP axes
    (no-op when no launcher registered axes — e.g. CPU unit tests)."""
    if _BATCH_AXES is None:
        return x
    spec = [None] * x.ndim
    spec[dim] = _BATCH_AXES
    return jax.lax.with_sharding_constraint(x, P(*spec))


def gather_head_for_unembed(head):
    """Constrain the unembedding table to P('model', None) right before
    the logits einsum: the D (FSDP) dim is all-gathered ONCE per use
    (~weights/TP bytes) instead of GSPMD's default strategy of
    contracting the sharded D into partial logits and all-reducing the
    [B,T,V/TP] fp32 logits over the data axis — which cost phi4-mini
    (200k vocab, tied embeddings) 500+ GB/dev/step (EXPERIMENTS §Perf
    cell B)."""
    if _BATCH_AXES is None:
        return head
    if head.shape[0] % 16 == 0:
        return jax.lax.with_sharding_constraint(head, P("model", None))
    return head


def shard_seq_dim(x, batch_dim: int = 0, seq_dim: int = 1):
    """Sequence-parallel residual constraint: batch over DP axes AND the
    sequence dim over 'model' (Megatron-SP style).  GSPMD then lowers
    the TP projection all-reduces as reduce-scatter + all-gather pairs
    and runs norms/elementwise on T/tp tokens per chip."""
    if _BATCH_AXES is None:
        return x
    if x.shape[seq_dim] % 16:
        return shard_batch_dim(x, batch_dim)
    spec = [None] * x.ndim
    spec[batch_dim] = _BATCH_AXES
    spec[seq_dim] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))


# (path regex, trailing-dims spec). "fsdp" -> data, "tp" -> model.
_RULES: list[tuple[str, tuple]] = [
    # --- embeddings / head: [V, D] ---
    (r"embed|head", ("tp", "fsdp")),
    # --- rwkv channel-mix (must precede attention wk/wv rules) ---
    (r"ffn.*\bwk\b", ("fsdp", "tp")),
    (r"ffn.*\bwv\b", ("tp", "fsdp")),
    (r"ffn.*\bwr\b", ("fsdp", "tp")),
    # --- MoE ---
    (r"router", ("fsdp", None)),
    (r"experts.*(gate|up)", ("tp", "fsdp", None)),
    (r"experts.*down", ("tp", None, "fsdp")),
    (r"shared.*(gate|up)", ("fsdp", "tp")),
    (r"shared.*down", ("tp", "fsdp")),
    # --- attention (GQA + MLA) ---
    (r"\bwq\b|\bwk\b|\bwv\b", ("fsdp", "tp")),
    (r"\bwo\b", ("tp", "fsdp")),
    (r"wdkv", ("fsdp", "tp")),
    (r"wkr", ("fsdp", None)),
    (r"wuk|wuv", ("fsdp", "tp")),
    # --- dense MLP ---
    (r"gate|up", ("fsdp", "tp")),
    (r"down", ("tp", "fsdp")),
    # --- mamba ---
    (r"in_proj", ("fsdp", "tp")),
    (r"out_proj", ("tp", "fsdp")),
    (r"conv_w", (None, "tp")),
    (r"conv_b", ("tp",)),
    (r"x_proj", ("tp", None)),
    (r"dt_proj", (None, "tp")),
    (r"dt_bias", ("tp",)),
    (r"A_log", ("tp", None)),
    (r"\bD\b", ("tp",)),
    # --- rwkv time-mix ---
    (r"\bwg\b|\bwr\b", ("fsdp", "tp")),
    (r"decay_A", ("fsdp", None)),
    (r"decay_B", (None, "tp")),
    # everything else (norm scales, mixes, bonus_u, ...) replicated
]


def _spec_for(path: str, shape: tuple, mesh, *, fsdp: bool = True) -> P:
    ndim = len(shape)
    for pat, core in _RULES:
        if re.search(pat, path):
            core = tuple(
                ("model" if a == "tp" else
                 ("data" if (a == "fsdp" and fsdp) else None))
                for a in core
            )
            if len(core) > ndim:   # e.g. scalar-ish leaves
                core = core[-ndim:]
            spec = (None,) * (ndim - len(core)) + core
            # divisibility guard: drop axes that don't divide the dim
            # (e.g. 36-head minicpm attention on a 16-way model axis).
            spec = tuple(
                a if a is not None and dim % mesh.shape[a] == 0 and
                dim >= mesh.shape[a] else None
                for dim, a in zip(shape, spec)
            )
            return P(*spec)
    return P(*((None,) * ndim))


def param_shardings(mesh, params, *, fsdp: bool = True):
    """NamedSharding pytree matching ``params`` leaf-for-leaf."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        spec = _spec_for(jax.tree_util.keystr(path), tuple(leaf.shape),
                         mesh, fsdp=fsdp)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def state_shardings(mesh, state, *, fsdp: bool = True):
    """TrainState sharding: m/v/ef mirror params; step replicated."""
    def shard_like_params(subtree):
        return param_shardings(mesh, subtree, fsdp=fsdp)

    out = {"params": shard_like_params(state["params"]),
           "opt": {
               "m": shard_like_params(state["opt"]["m"]),
               "v": shard_like_params(state["opt"]["v"]),
               "step": NamedSharding(mesh, P()),
           }}
    if "ef" in state:
        out["ef"] = shard_like_params(state["ef"])
    return out


def batch_shardings(mesh, batch):
    """Batch-dim DP sharding for input pytrees (tokens/labels/embeds).

    m_rope 'positions' have shape (3, B, T): batch is dim 1.
    """
    dp = dp_axes(mesh)

    def spec(path, leaf):
        name = jax.tree_util.keystr(path)
        if "positions" in name and leaf.ndim == 3:
            return NamedSharding(mesh, P(None, dp, *(None,) * (leaf.ndim - 2)))
        return NamedSharding(mesh, P(dp, *(None,) * (leaf.ndim - 1)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def cache_shardings(mesh, cache, *, batch: int):
    """Decode-cache sharding.

    Cache leaves are [L, B, S, ...] (attention) or [L, B, ...] (states).
    If the batch covers the DP axes, shard batch over DP and the seq dim
    over model; for tiny batches (long_500k: B=1) shard the SEQ dim over
    all axes instead — attention over the sharded length then lowers to
    partial-softmax + all-reduce instead of a cache all-gather.
    """
    dp = dp_axes(mesh)
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    batch_covers = batch % dp_n == 0 and batch >= dp_n

    def axes_size(ax) -> int:
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= mesh.shape[a]
            return n
        return mesh.shape[ax]

    def guard(leaf, proposal):
        """Drop axes that don't divide the dim (divisibility guard)."""
        out = []
        for dim, ax in zip(leaf.shape, proposal):
            out.append(ax if dim % axes_size(ax) == 0 and
                       dim >= axes_size(ax) else None)
        return NamedSharding(mesh, P(*out))

    def spec(path, leaf):
        name = jax.tree_util.keystr(path)
        nd = leaf.ndim
        if "lengths" in name:
            return NamedSharding(mesh, P())
        bdim = dp if batch_covers else None
        if re.search(r"\['k'\]$|\['v'\]$|ckv|kr", name):
            # attention caches [L, B, S, ...]
            sdim = "model" if batch_covers else dp + ("model",)
            return guard(leaf, (None, bdim, sdim) + (None,) * (nd - 3))
        if "conv" in name:     # [L, B, K-1, I]
            return guard(leaf, (None, bdim, None, "model"))
        if re.search(r"x_att|x_ffn", name):   # [L, B, 1, D]
            return guard(leaf, (None, bdim, None, "model"))
        if name.endswith("['h']"):            # mamba [L, B, I, N]
            return guard(leaf, (None, bdim, "model", None))
        if name.endswith("['S']"):            # rwkv [L, B, H, K, V]
            return guard(leaf, (None, bdim, "model", None, None))
        return NamedSharding(mesh, P(*((None,) * nd)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])
