"""Training launcher.

Runs real steps on whatever devices exist (CPU: use ``--reduced``).
Demonstrates the full production loop: deterministic data pipeline,
microbatched+remat train step, async atomic checkpoints, crash
recovery, elastic rescale and straggler mitigation via the supervisor.

    PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
        --reduced --steps 50 --batch 8 --seq-len 64 \
        --ckpt-dir /tmp/ckpt --inject-crash 23
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_batch
from repro.models import LM
from repro.runtime.supervisor import (
    FailureEvent,
    FailureInjector,
    TrainSupervisor,
)
from repro.training.optim import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=20)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq-len", type=int, default=64)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--schedule", default="cosine",
                   choices=["constant", "cosine", "wsd"])
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--remat", action="store_true")
    p.add_argument("--inject-crash", type=int, default=None,
                   help="simulate a crash at this step (recovery demo)")
    p.add_argument("--inject-straggler", type=int, default=None)
    p.add_argument("--log-every", type=int, default=5)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # minicpm trains with the WSD schedule by default (its paper's setup)
    schedule = "wsd" if cfg.name.startswith("minicpm") else args.schedule
    model = LM(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, schedule=schedule,
                          total_steps=args.steps)
    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, input_mode=cfg.input_mode,
        d_model=cfg.d_model)

    def make_step(num_nodes):
        del num_nodes  # single-device container; mesh rebuild is a no-op
        return jax.jit(make_train_step(
            model, opt_cfg, num_microbatches=args.microbatches,
            remat=args.remat))

    state = init_train_state(model, jax.random.PRNGKey(0))
    ckpt = CheckpointManager(args.ckpt_dir)
    if args.resume and ckpt.latest_step() is not None:
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        state, at = ckpt.restore(template)
        print(f"resumed from checkpoint @ step {at}")

    events = []
    if args.inject_crash is not None:
        events.append(FailureEvent(step=args.inject_crash, kind="crash"))
    if args.inject_straggler is not None:
        events.append(FailureEvent(step=args.inject_straggler,
                                   kind="slow_node", node=0))

    losses = []

    def make_batch_logged(step):
        b = make_batch(data_cfg, step)
        return b

    sup = TrainSupervisor(
        make_step=make_step, make_batch=make_batch_logged,
        init_state=state, ckpt=ckpt, ckpt_every=args.ckpt_every,
        injector=FailureInjector(events))

    # wrap step fn to log
    inner = sup._step_fn

    def logged(state, batch):
        state, metrics = inner(state, batch)
        step = int(state["opt"]["step"])
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        return state, metrics

    sup._step_fn = logged
    report = sup.run(args.steps)
    print(f"\ndone: {report.steps_run} steps, "
          f"{report.checkpoints_saved} checkpoints, "
          f"{report.restarts} restarts, "
          f"{report.straggler_mitigations} straggler mitigations; "
          f"final loss {report.final_loss:.4f}")
    for e in report.events:
        print("  event:", e)
    if len(losses) > 10:
        first = sum(losses[:5]) / 5
        last = sum(losses[-5:]) / 5
        print(f"loss first5={first:.4f} last5={last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
