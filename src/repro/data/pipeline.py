"""Deterministic synthetic data pipeline (shardable, restart-safe).

Every batch is a pure function of (seed, step) via ``jax.random.fold_in``
— so a restarted job resumes mid-epoch with byte-identical batches (the
checkpoint only needs to store the step), and every DP shard can
generate ITS OWN slice locally from (step, shard_index) with zero host
I/O or cross-host traffic: the pipeline never becomes the straggler.

Token streams are drawn from a skewed (Zipf-ish) distribution so MoE
routers and the loss see realistic token frequencies rather than a flat
histogram.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    input_mode: str = "tokens"   # tokens | embeds
    d_model: int = 0             # for embeds mode
    zipf_alpha: float = 1.1


def _zipf_tokens(key, shape, vocab, alpha):
    """Inverse-CDF sampling of a truncated Zipf over [0, vocab)."""
    u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0)
    # rank ~ u^{-1/(alpha-1)} heavy tail, clipped to vocab
    ranks = jnp.clip(u ** (-1.0 / (alpha - 1.0)), 1.0, float(vocab))
    return (ranks - 1.0).astype(jnp.int32)


def make_batch(cfg: DataConfig, step: int):
    """Global batch for ``step`` (host-agnostic, deterministic)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k_tok, k_emb, k_lab = jax.random.split(key, 3)
    batch = {}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(
            k_emb, (cfg.global_batch, cfg.seq_len, cfg.d_model),
            jnp.float32) * 0.02
        batch["labels"] = _zipf_tokens(
            k_lab, (cfg.global_batch, cfg.seq_len), cfg.vocab_size,
            cfg.zipf_alpha)
    else:
        tokens = _zipf_tokens(
            k_tok, (cfg.global_batch, cfg.seq_len), cfg.vocab_size,
            cfg.zipf_alpha)
        batch["tokens"] = tokens
        batch["labels"] = tokens   # causal LM: model shifts internally
    return batch


def shard_slice(cfg: DataConfig, step: int, shard: int, num_shards: int):
    """The per-DP-shard slice of the global batch, generated locally."""
    if cfg.global_batch % num_shards:
        raise ValueError("global_batch must divide by DP shards")
    per = cfg.global_batch // num_shards
    full = make_batch(cfg, step)
    return jax.tree.map(lambda x: x[shard * per:(shard + 1) * per], full)
