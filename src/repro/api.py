"""`repro.api` — the supported way to define and run simulations.

Define a model once on a :class:`SimProgram`, then compile it to any
runtime with :meth:`SimProgram.build`:

    from repro.api import ARG_WIDTH, Config, SimProgram

    prog = SimProgram("demo", config=Config(max_batch_len=4))

    @prog.handler("TICK", lookahead=1.0)
    def tick(state, t, arg):
        return state + 1

    prog.schedule(0.0, "TICK")

    result = prog.build(backend="device").run(jnp.int32(0))
    result = prog.build(backend="device", shards=4).run(jnp.int32(0))
    result = prog.build(backend="host", scheduler="speculative").run(...)

Every backend — host (conservative / speculative / unbatched × lazy /
eager composition) and device (tiered3 / tiered / flat / reference
queues, single or ``shards=N`` multi-queue) — runs
the same definition with bit-identical final state and normalized
:class:`RunResult` stats.  The classes in :mod:`repro.core` remain the
backend layer underneath; reach for them only when benchmarking a
specific runtime mechanism.

Open-system runs stream arrivals from a host-side source instead of
pre-seeding them: ``sim.run(state0, arrivals=PoissonSource(...))`` —
see :mod:`repro.stream` and DESIGN.md §10 for the determinism contract
(a streamed run is bit-identical to pre-seeding the same trace).
"""

from repro.core.events import ARG_WIDTH, emits_events
from repro.core.program import (
    EMIT_WIDTH,
    CompiledSim,
    Config,
    RunResult,
    SimProgram,
    normalize_arg,
)
from repro.core.validate import FAULT_NAMES, EngineFaultError, fault_names
from repro.stream import (
    ArrivalSource,
    BurstySource,
    DiurnalSource,
    PoissonSource,
    StreamFeeder,
    TraceReader,
    TraceWriter,
    source_events,
)

__all__ = [
    "ARG_WIDTH",
    "EMIT_WIDTH",
    "ArrivalSource",
    "BurstySource",
    "CompiledSim",
    "Config",
    "DiurnalSource",
    "EngineFaultError",
    "FAULT_NAMES",
    "PoissonSource",
    "RunResult",
    "SimProgram",
    "StreamFeeder",
    "TraceReader",
    "TraceWriter",
    "emits_events",
    "fault_names",
    "normalize_arg",
    "source_events",
]
