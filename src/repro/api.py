"""`repro.api` — the supported way to define and run simulations.

Define a model once on a :class:`SimProgram`, then compile it to any
runtime with :meth:`SimProgram.build`:

    from repro.api import ARG_WIDTH, Config, SimProgram

    prog = SimProgram("demo", config=Config(max_batch_len=4))

    @prog.handler("TICK", lookahead=1.0)
    def tick(state, t, arg):
        return state + 1

    prog.schedule(0.0, "TICK")

    result = prog.build(backend="device").run(jnp.int32(0))
    result = prog.build(backend="device", shards=4).run(jnp.int32(0))
    result = prog.build(backend="host", scheduler="speculative").run(...)

Every backend — host (conservative / speculative / unbatched × lazy /
eager composition) and device (tiered3 / tiered / flat / reference
queues, single or ``shards=N`` multi-queue) — runs
the same definition with bit-identical final state and normalized
:class:`RunResult` stats.  The classes in :mod:`repro.core` remain the
backend layer underneath; reach for them only when benchmarking a
specific runtime mechanism.
"""

from repro.core.events import ARG_WIDTH, emits_events
from repro.core.program import (
    EMIT_WIDTH,
    CompiledSim,
    Config,
    RunResult,
    SimProgram,
    normalize_arg,
)
from repro.core.validate import FAULT_NAMES, EngineFaultError, fault_names

__all__ = [
    "ARG_WIDTH",
    "EMIT_WIDTH",
    "CompiledSim",
    "Config",
    "EngineFaultError",
    "FAULT_NAMES",
    "RunResult",
    "SimProgram",
    "emits_events",
    "fault_names",
    "normalize_arg",
]
