"""KV / recurrent-state caches for decode.

Caches are pytrees with leaves stacked over the layer (or block) axis so
the decode step can ``lax.scan`` over layers.  Three layouts:

* GQA:   k/v  [L, B, S, KV, D]
* MLA:   ckv  [L, B, S, R],  kr [L, B, S, dr]   (compressed latents)
* SSM:   mamba {h: [L,B,I,N], conv: [L,B,K-1,I]}, rwkv {x_prev_att,
         x_prev_ffn: [L,B,1,D], S: [L,B,H,K,V]}

``lengths: i32[B]`` counts valid tokens per sequence (shared across
layers).  All caches are bf16 except recurrent/conv states (fp32) —
decode numerics are dominated by the state recurrences.
"""

from __future__ import annotations

import jax.numpy as jnp


def gqa_cache_init(num_layers, batch, max_len, num_kv_heads, head_dim,
                   dtype=jnp.bfloat16):
    shape = (num_layers, batch, max_len, num_kv_heads, head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def mla_cache_init(num_layers, batch, max_len, kv_lora_rank, rope_dim,
                   dtype=jnp.bfloat16):
    return {
        "ckv": jnp.zeros((num_layers, batch, max_len, kv_lora_rank), dtype),
        "kr": jnp.zeros((num_layers, batch, max_len, rope_dim), dtype),
    }


def mamba_cache_init(num_layers, batch, d_inner, d_state, d_conv,
                     conv_dtype=jnp.bfloat16):
    return {
        "h": jnp.zeros((num_layers, batch, d_inner, d_state), jnp.float32),
        "conv": jnp.zeros((num_layers, batch, d_conv - 1, d_inner),
                          conv_dtype),
    }


def rwkv_cache_init(num_layers, batch, d_model, num_heads, head_dim,
                    dtype=jnp.bfloat16):
    return {
        "x_att": jnp.zeros((num_layers, batch, 1, d_model), dtype),
        "x_ffn": jnp.zeros((num_layers, batch, 1, d_model), dtype),
        "S": jnp.zeros((num_layers, batch, num_heads, head_dim, head_dim),
                       jnp.float32),
    }
