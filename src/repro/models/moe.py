"""Mixture-of-Experts: top-k router + capacity-based one-hot dispatch.

TPU-native (GShard/Mesh-TF style): token→expert assignment is realized
with static-shape one-hot einsums and a per-expert capacity
``C = ceil(T·k/E · capacity_factor)`` — no dynamic shapes, no sorts on
the critical path.  The expert dimension is sharded over the ``model``
mesh axis (expert parallelism); the dispatch/combine einsums then lower
to all-to-all-style collectives under GSPMD.

Auxiliary load-balancing loss (Switch-style) is returned alongside the
output and accumulated by the model's scan.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import DEFAULT_DTYPE, dense_init, mlp_apply, mlp_init


def moe_init(key, *, d_model: int, d_ff_expert: int, num_experts: int,
             num_shared: int = 0, activation: str = "swiglu",
             dtype=DEFAULT_DTYPE):
    ks = jax.random.split(key, 3)
    # Experts as stacked MLPs: leaves [E, d_model, d_ff] / [E, d_ff, d_model].
    ekeys = jax.random.split(ks[0], num_experts)
    experts = jax.vmap(
        lambda k: mlp_init(k, d_model, d_ff_expert, activation=activation,
                           dtype=dtype)
    )(ekeys)
    params = {
        "router": dense_init(ks[1], d_model, num_experts,
                             dtype=jnp.float32),   # router in fp32
        "experts": experts,
    }
    if num_shared:
        params["shared"] = mlp_init(
            ks[2], d_model, d_ff_expert * num_shared, activation=activation,
            dtype=dtype,
        )
    return params


def _top_k_mask(logits, k):
    """[T,E] fp32 -> (weights [T,E] renormalized over top-k, mask [T,E])."""
    vals, idx = jax.lax.top_k(logits, k)                  # [T,k]
    mask = jax.nn.one_hot(idx, logits.shape[-1],
                          dtype=jnp.float32).sum(axis=-2)  # [T,E]
    probs = jax.nn.softmax(vals, axis=-1)                  # renorm over top-k
    weights = jnp.zeros_like(logits)
    weights = jnp.einsum("tk,tke->te", probs,
                         jax.nn.one_hot(idx, logits.shape[-1],
                                        dtype=jnp.float32))
    return weights, mask


def moe_apply(params, x, *, num_experts: int, top_k: int,
              capacity_factor: float = 1.25, activation: str = "swiglu",
              group_size: int = 1024):
    """x: [B,T,D] -> (y, aux_loss).

    GROUPED GShard dispatch: tokens are split into groups of
    ``group_size`` and capacity applies PER GROUP
    (``C = ceil(group_size·k/E · cf)``).  The one-hot dispatch/combine
    tensor is [G, n, E, C] — total bytes N·E·C_group ∝ N·k·cf·group_size
    /... i.e. LINEAR in N (a global capacity makes it quadratic: at 1M
    prefill tokens that materialized a 2.7 TB all-gathered tensor, see
    EXPERIMENTS.md §Perf).  Groups align with the data axis; experts are
    sharded over the model axis, so dispatch/expert/combine einsums are
    all local to a (data, model) shard pair.
    """
    B, T, D = x.shape
    E, K = num_experts, top_k
    N = B * T
    n = min(group_size, N)
    if N % n:  # fall back to one group per sequence
        n = T if N % T == 0 else N
    G = N // n
    xg = x.reshape(G, n, D)
    capacity = max(1, int(math.ceil(n * K / E * capacity_factor)))

    logits = jnp.einsum("gnd,de->gne", xg.astype(jnp.float32),
                        params["router"])                  # fp32 router
    vals, idx = jax.lax.top_k(logits, K)                   # [G,n,K]
    probs = jax.nn.softmax(vals, axis=-1)
    oh = jax.nn.one_hot(idx, E, dtype=jnp.float32)         # [G,n,K,E]
    weights = jnp.einsum("gnk,gnke->gne", probs, oh)       # [G,n,E]
    mask = oh.sum(axis=-2)                                 # [G,n,E]

    # Load-balancing aux loss (Switch): E * sum_e f_e * p_e.
    probs_full = jax.nn.softmax(logits, axis=-1)
    f = jnp.mean(mask, axis=(0, 1))
    p = jnp.mean(probs_full, axis=(0, 1))
    aux = E * jnp.sum(f * p)

    # Position of each token within its expert's per-group buffer.
    pos_in_expert = jnp.cumsum(mask, axis=1) * mask - 1.0  # [G,n,E]
    in_cap = (pos_in_expert < capacity) & (pos_in_expert >= 0)
    pos_clipped = jnp.clip(pos_in_expert, 0, capacity - 1).astype(jnp.int32)
    pos_oh = jax.nn.one_hot(pos_clipped, capacity, dtype=jnp.float32)
    dispatch = pos_oh * in_cap[..., None]                  # [G,n,E,C]
    combine = dispatch * weights[..., None]

    xe = jnp.einsum("gnd,gnec->gecd", xg.astype(jnp.float32),
                    dispatch).astype(x.dtype)              # [G,E,C,D]
    # Expert FFN with the expert dim in place (weights [E,D,F]/[E,F,D]):
    ex = params["experts"]
    # NB: no preferred_element_type here — the CPU dot thunk rejects
    # bf16xbf16->f32 on these 4D einsums; TPU MXU accumulates fp32
    # internally either way.
    if activation in ("swiglu", "geglu"):
        gph = jnp.einsum("gecd,edf->gecf", xe, ex["gate"]).astype(
            jnp.float32)
        uph = jnp.einsum("gecd,edf->gecf", xe, ex["up"]).astype(
            jnp.float32)
        act = jax.nn.silu(gph) if activation == "swiglu" else \
            jax.nn.gelu(gph)
        he = (act * uph).astype(x.dtype)
    else:
        uph = jnp.einsum("gecd,edf->gecf", xe, ex["up"]).astype(
            jnp.float32)
        he = jax.nn.gelu(uph).astype(x.dtype) if activation == "gelu" \
            else jnp.square(jax.nn.relu(uph)).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", he, ex["down"]).astype(jnp.float32)
    yg = jnp.einsum("gecd,gnec->gnd", ye, combine).astype(x.dtype)

    y = yg.reshape(B, T, D)
    if "shared" in params:
        y = y + mlp_apply(params["shared"], x.reshape(B * T, D),
                          activation=activation).reshape(B, T, D)
    return y, aux


def moe_apply_dense(params, x, *, num_experts: int, top_k: int,
                    activation: str = "swiglu"):
    """Dropless decode-path MoE: every expert runs on every token, the
    top-k weights combine.  EXACT (no capacity drops) and, for the
    memory-bound decode regime, roofline-equivalent to sparse dispatch:
    the HBM traffic is the expert weights either way (every expert is
    active at decode batch sizes), while the extra FLOPs are far below
    the memory roofline.  Keeps decode shapes fully static.
    """
    B, T, D = x.shape
    xt = x.reshape(B * T, D)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        params["router"])
    weights, _ = _top_k_mask(logits, top_k)               # [N,E]
    ye = jax.vmap(
        lambda p_: mlp_apply(p_, xt, activation=activation)
    )(params["experts"])                                   # [E,N,D]
    y = jnp.einsum("end,ne->nd", ye.astype(jnp.float32), weights)
    y = y.astype(x.dtype)
    if "shared" in params:
        y = y + mlp_apply(params["shared"], xt, activation=activation)
    return y.reshape(B, T, D)
