"""Shared neural-net layers (pure functional JAX, no framework deps).

All params are plain dict pytrees; every layer is an ``init(key, ...)``
returning params plus an ``apply(params, x, ...)``.  Weights are stored
bf16 by default with fp32 norm scales and router weights (standard mixed
precision discipline); matmuls accumulate in fp32 via
``preferred_element_type``.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

DEFAULT_DTYPE = jnp.bfloat16

# §Perf experiment knob: when False, projection einsums emit bf16
# outputs directly (partial sums + TP all-reduces run in bf16 — half the
# collective bytes; TPU MXU accumulates fp32 internally either way).
PREFER_F32_PROJ = True


def set_matmul_precision(prefer_f32: bool) -> None:
    global PREFER_F32_PROJ
    PREFER_F32_PROJ = prefer_f32


def proj_einsum(spec, x, w, out_dtype=None):
    """Projection einsum honoring the PREFER_F32_PROJ knob."""
    if PREFER_F32_PROJ:
        y = jnp.einsum(spec, x, w, preferred_element_type=jnp.float32)
    else:
        y = jnp.einsum(spec, x, w)
    return y.astype(out_dtype) if out_dtype is not None else y


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, *, dtype=DEFAULT_DTYPE,
               scale: float | None = None):
    """Truncated-normal fan-in init (the MaxText/T5 default)."""
    std = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    w = jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim)) * std
    return w.astype(dtype)


def embed_init(key, vocab: int, dim: int, *, dtype=DEFAULT_DTYPE):
    w = jax.random.normal(key, (vocab, dim)) * (1.0 / math.sqrt(dim))
    return w.astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim: int):
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rmsnorm(params, x, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return y.astype(x.dtype)


def layernorm_init(dim: int):
    return {
        "scale": jnp.ones((dim,), jnp.float32),
        "bias": jnp.zeros((dim,), jnp.float32),
    }


def layernorm(params, x, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(x.dtype)


def make_norm(kind: str):
    if kind == "rmsnorm":
        return rmsnorm_init, rmsnorm
    if kind == "layernorm":
        return layernorm_init, layernorm
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE and Qwen2-VL's M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies f32[head_dim // 2]."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x, positions, *, theta: float = 10000.0):
    """Rotate pairs (x[..., :d/2], x[..., d/2:]) by position angles.

    x: [..., T, H, D]; positions: broadcastable to [..., T] (i32/f32).
    Uses the "split halves" convention (llama-style).
    """
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                       # [d/2]
    angles = positions.astype(jnp.float32)[..., None] * inv  # [..., T, d/2]
    # broadcast over the head axis: x is [..., T, H, D]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_m_rope(x, positions_thw, *, theta: float = 10000.0,
                 sections=(16, 24, 24)):
    """Qwen2-VL multimodal RoPE: the head dim is split into
    (temporal, height, width) sections, each rotated by its own position
    stream.  ``positions_thw``: i32[3, ..., T].  ``sections`` are in
    *pairs* (halves of each section), summing to head_dim//2.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = rope_freqs(d, theta)                       # [d/2]
    # Build per-pair position by section.
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d // 2
    )                                                 # [d/2] in {0,1,2}
    # positions_thw[sec_id] per pair: gather -> [..., T, d/2]
    pos = jnp.moveaxis(positions_thw, 0, -1).astype(jnp.float32)  # [..., T, 3]
    pos_per_pair = jnp.take(pos, sec_id, axis=-1)     # [..., T, d/2]
    angles = pos_per_pair * inv
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, *, activation: str = "swiglu",
             dtype=DEFAULT_DTYPE):
    ks = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {
            "gate": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "up": dense_init(ks[1], d_model, d_ff, dtype=dtype),
            "down": dense_init(ks[2], d_ff, d_model, dtype=dtype),
        }
    return {
        "up": dense_init(ks[0], d_model, d_ff, dtype=dtype),
        "down": dense_init(ks[1], d_ff, d_model, dtype=dtype),
    }


def mlp_apply(params, x, *, activation: str = "swiglu"):
    dtype = x.dtype
    if activation in ("swiglu", "geglu"):
        g = proj_einsum("...d,df->...f", x, params["gate"])
        u = proj_einsum("...d,df->...f", x, params["up"])
        act = jax.nn.silu(g) if activation == "swiglu" else jax.nn.gelu(g)
        h = (act * u).astype(dtype)
    else:
        u = proj_einsum("...d,df->...f", x, params["up"])
        if activation == "gelu":
            h = jax.nn.gelu(u).astype(dtype)
        elif activation == "sqrelu":  # RWKV channel-mix
            h = jnp.square(jax.nn.relu(u)).astype(dtype)
        else:
            raise ValueError(activation)
    return proj_einsum("...f,fd->...d", h, params["down"],
                       out_dtype=dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_apply(embedding, tokens):
    return jnp.take(embedding, tokens, axis=0)


def unembed_apply(embedding_or_head, x):
    """Logits in fp32 (loss-numerics discipline).

    The head is constrained to P('model', None) first so the logits
    einsum contracts a REPLICATED d — see gather_head_for_unembed."""
    from repro.launch.sharding import gather_head_for_unembed
    head = gather_head_for_unembed(embedding_or_head)
    return jnp.einsum("...d,vd->...v", x, head,
                      preferred_element_type=jnp.float32)


def cross_entropy_loss(logits, labels, *, ignore_id: int = -1):
    """Mean token NLL in fp32; ``labels == ignore_id`` masked out."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
