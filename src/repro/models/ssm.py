"""State-space / linear-recurrence blocks: Mamba (Jamba) and RWKV6.

Both are implemented in the *chunked* form that is the TPU-native
adaptation of their CUDA kernels (DESIGN.md §2): sequence chunks are
processed with dense matmuls/cumsums (MXU-friendly), while a short
``lax.scan`` carries the recurrent state across chunks.  Chunk size
bounds the live state-expansion memory to O(B·chunk·d_inner·d_state)
instead of O(B·T·d_inner·d_state) — this is what makes the 4k-train and
500k-decode shapes fit HBM.

Decode (single token) uses the exact recurrences.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import DEFAULT_DTYPE, dense_init


# ===========================================================================
# Mamba (v1 selective SSM, as interleaved in Jamba)
# ===========================================================================

def mamba_init(key, *, d_model: int, d_state: int = 16, d_conv: int = 4,
               expand: int = 2, dt_rank: int | None = None,
               dtype=DEFAULT_DTYPE):
    d_inner = expand * d_model
    dt_rank = dt_rank or max(1, math.ceil(d_model / 16))
    ks = jax.random.split(key, 7)
    # S4D-real initialization for A (negative, log-spaced).
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    params = {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner)) *
                   (1.0 / math.sqrt(d_conv))).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": dense_init(ks[2], d_inner, dt_rank + 2 * d_state,
                             dtype=dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_inner, dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (d_inner,)) * 0.099 + 0.001,
                     1e-4, None))).astype(jnp.float32),
        "A_log": jnp.log(a),                      # fp32 [d_inner, d_state]
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[5], d_inner, d_model, dtype=dtype),
    }
    params["meta"] = {}  # reserved
    return params


def _mamba_project(params, x, *, d_state: int, dt_rank: int):
    """x: [B,L,D] -> (xz gate split, dt, Bc, Cc) all [B,L,...]."""
    xz = jnp.einsum("bld,de->ble", x, params["in_proj"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    xs, z = jnp.split(xz, 2, axis=-1)                     # [B,L,d_inner]
    return xs, z


def _mamba_ssm_inputs(params, xs, *, d_state: int, dt_rank: int):
    proj = jnp.einsum("bli,ie->ble", xs, params["x_proj"],
                      preferred_element_type=jnp.float32)  # fp32
    dt_in = proj[..., :dt_rank]
    Bc = proj[..., dt_rank:dt_rank + d_state]              # [B,L,N]
    Cc = proj[..., dt_rank + d_state:]                     # [B,L,N]
    dt = jnp.einsum("blr,ri->bli", dt_in,
                    params["dt_proj"].astype(jnp.float32))
    dt = jax.nn.softplus(dt + params["dt_bias"])           # [B,L,d_inner]
    return dt, Bc, Cc


def _conv1d_causal(params, xs, conv_state=None):
    """Depthwise causal conv over time.  xs: [B,L,C]; conv_state:
    [B,d_conv-1,C] tail of the previous segment (decode) or None."""
    w = params["conv_w"].astype(jnp.float32)               # [K,C]
    K = w.shape[0]
    pad = xs if conv_state is None else jnp.concatenate(
        [conv_state.astype(xs.dtype), xs], axis=1)
    if conv_state is None:
        pad = jnp.pad(pad, ((0, 0), (K - 1, 0), (0, 0)))
    acc = jnp.zeros(xs.shape, jnp.float32)
    L = xs.shape[1]
    for i in range(K):
        acc = acc + pad[:, i:i + L].astype(jnp.float32) * w[i]
    acc = acc + params["conv_b"].astype(jnp.float32)
    return jax.nn.silu(acc).astype(xs.dtype)


def mamba_apply(params, x, *, d_state: int = 16, d_conv: int = 4,
                dt_rank: int | None = None, chunk: int = 256,
                h0=None, conv0=None, return_state: bool = False):
    """Full-sequence selective scan, chunked.

    x: [B,T,D] -> y [B,T,D].  When ``return_state`` also returns the
    final (h [B,d_inner,N] fp32, conv tail [B,d_conv-1,d_inner]).
    """
    B, T, D = x.shape
    dt_rank = dt_rank or max(1, math.ceil(D / 16))
    xs, z = _mamba_project(params, x, d_state=d_state, dt_rank=dt_rank)
    d_inner = xs.shape[-1]
    conv_tail = xs[:, -(d_conv - 1):, :] if return_state else None
    xs = _conv1d_causal(params, xs, conv0)
    dt, Bc, Cc = _mamba_ssm_inputs(params, xs, d_state=d_state,
                                   dt_rank=dt_rank)
    A = -jnp.exp(params["A_log"])                          # [d_inner,N] <0

    chunk = min(chunk, T)
    nch = -(-T // chunk)
    Tp = nch * chunk
    if Tp != T:
        padspec = ((0, 0), (0, Tp - T), (0, 0))
        xs = jnp.pad(xs, padspec)
        dt = jnp.pad(dt, padspec)
        Bc = jnp.pad(Bc, padspec)
        Cc = jnp.pad(Cc, padspec)

    def reshape_c(t):
        return t.reshape(B, nch, chunk, t.shape[-1]).swapaxes(0, 1)

    xs_c, dt_c, B_c, C_c = map(reshape_c, (xs, dt, Bc, Cc))

    h_init = (jnp.zeros((B, d_inner, d_state), jnp.float32)
              if h0 is None else h0)

    def chunk_step(h, inputs):
        xc, dtc, bc, cc = inputs                # [B,chunk,...]
        # a_t = exp(dt*A): [B,chunk,d_inner,N]; u_t = dt*B_t*x_t
        dA = dtc[..., None] * A                 # fp32 [B,L,I,N]
        u = (dtc * xc.astype(jnp.float32))[..., None] * bc[:, :, None, :]
        # In-chunk associative scan over time for h_t = a h_{t-1} + u.
        a = jnp.exp(dA)

        def comb(p, q):
            a1, b1 = p
            a2, b2 = q
            return a1 * a2, a2 * b1 + b2

        a_sc, u_sc = jax.lax.associative_scan(comb, (a, u), axis=1)
        # include the carried-in state: h_t = a_sc_t * h_init + u_sc_t
        h_t = a_sc * h[:, None] + u_sc          # [B,L,I,N]
        y = jnp.einsum("blin,bln->bli", h_t, cc)
        y = y + params["D"] * xc.astype(jnp.float32)
        return h_t[:, -1], y

    h_fin, ys = jax.lax.scan(chunk_step, h_init, (xs_c, dt_c, B_c, C_c))
    y = ys.swapaxes(0, 1).reshape(B, Tp, d_inner)[:, :T]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bli,id->bld", y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if return_state:
        return out, (h_fin, conv_tail)
    return out


def mamba_state_init(batch: int, *, d_model: int, d_state: int = 16,
                     d_conv: int = 4, expand: int = 2):
    d_inner = expand * d_model
    return {
        "h": jnp.zeros((batch, d_inner, d_state), jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), DEFAULT_DTYPE),
    }


def mamba_decode_step(params, x, state, *, d_state: int = 16,
                      d_conv: int = 4, dt_rank: int | None = None):
    """One-token recurrence.  x: [B,1,D]; state: {'h','conv'}."""
    B, _, D = x.shape
    dt_rank = dt_rank or max(1, math.ceil(D / 16))
    xs, z = _mamba_project(params, x, d_state=d_state, dt_rank=dt_rank)
    new_conv = jnp.concatenate([state["conv"][:, 1:], xs.astype(
        state["conv"].dtype)], axis=1) if d_conv > 1 else state["conv"]
    xs = _conv1d_causal(params, xs, state["conv"])
    dt, Bc, Cc = _mamba_ssm_inputs(params, xs, d_state=d_state,
                                   dt_rank=dt_rank)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A)                      # [B,I,N]
    u = (dt[:, 0] * xs[:, 0].astype(jnp.float32))[..., None] * \
        Bc[:, 0, None, :]
    h = dA * state["h"] + u
    y = jnp.einsum("bin,bn->bi", h, Cc[:, 0])
    y = y + params["D"] * xs[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bi,id->bd", y, params["out_proj"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out[:, None], {"h": h, "conv": new_conv}


# ===========================================================================
# RWKV6 ("Finch": data-dependent decay)
# ===========================================================================

def rwkv6_init(key, *, d_model: int, head_dim: int = 64,
               decay_lora: int = 64, dtype=DEFAULT_DTYPE):
    H = d_model // head_dim
    ks = jax.random.split(key, 12)
    def lin(k, o=d_model):
        return dense_init(k, d_model, o, dtype=dtype)
    # Decay per-channel base + data-dependent LoRA (the Finch signature).
    decay_base = jnp.linspace(-6.0, -0.5, d_model).astype(jnp.float32)
    params = {
        "mix": {  # token-shift lerp coefficients per stream
            "r": jnp.full((d_model,), 0.5, jnp.float32),
            "k": jnp.full((d_model,), 0.5, jnp.float32),
            "v": jnp.full((d_model,), 0.5, jnp.float32),
            "w": jnp.full((d_model,), 0.5, jnp.float32),
            "g": jnp.full((d_model,), 0.5, jnp.float32),
        },
        "wr": lin(ks[0]), "wk": lin(ks[1]), "wv": lin(ks[2]),
        "wg": lin(ks[3]), "wo": lin(ks[4]),
        "decay_base": decay_base,
        "decay_A": dense_init(ks[5], d_model, decay_lora, dtype=dtype),
        "decay_B": dense_init(ks[6], decay_lora, d_model, dtype=dtype),
        "bonus_u": (jax.random.normal(ks[7], (d_model,)) * 0.1).astype(
            jnp.float32),
        "ln_x": {"scale": jnp.ones((d_model,), jnp.float32),
                 "bias": jnp.zeros((d_model,), jnp.float32)},
    }
    return params


def _token_shift(x, x_prev, mu):
    """lerp(x_t, x_{t-1}, mu): RWKV token shift.  x: [B,T,D]; x_prev is
    the last token of the previous segment [B,1,D] (zeros at start)."""
    prev = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    return x + (prev - x) * mu


def _rwkv_streams(params, x, x_prev):
    mix = params["mix"]
    xr = _token_shift(x, x_prev, mix["r"].astype(x.dtype))
    xk = _token_shift(x, x_prev, mix["k"].astype(x.dtype))
    xv = _token_shift(x, x_prev, mix["v"].astype(x.dtype))
    xw = _token_shift(x, x_prev, mix["w"].astype(x.dtype))
    xg = _token_shift(x, x_prev, mix["g"].astype(x.dtype))
    r = jnp.einsum("btd,de->bte", xr, params["wr"],
                   preferred_element_type=jnp.float32)
    k = jnp.einsum("btd,de->bte", xk, params["wk"],
                   preferred_element_type=jnp.float32)
    v = jnp.einsum("btd,de->bte", xv, params["wv"],
                   preferred_element_type=jnp.float32)
    g = jnp.einsum("btd,de->bte", xg, params["wg"],
                   preferred_element_type=jnp.float32)
    # data-dependent decay (Finch): w = exp(-exp(base + tanh(x A) B))
    dd = jnp.einsum("btd,dr->btr", xw, params["decay_A"],
                    preferred_element_type=jnp.float32)
    dd = jnp.einsum("btr,rd->btd", jnp.tanh(dd),
                    params["decay_B"].astype(jnp.float32))
    logw = -jnp.exp(jnp.clip(params["decay_base"] + dd, -20.0, 4.0))
    return r, k, v, g, logw                     # all fp32 [B,T,D]


def rwkv6_attn(params, x, *, head_dim: int = 64, chunk: int = 64,
               x_prev=None, s0=None, return_state: bool = False):
    """RWKV6 time-mix over a full sequence, chunked linear attention.

    Within a chunk the decay factorizes as exp(A_t - A_s) with
    A = cumsum(log w); pairs are computed with two matmuls on decayed
    r'/k' (clamped at -30 in log space for stability).  The recurrent
    state S [B,H,K,V] carries across chunks via lax.scan.
    """
    B, T, D = x.shape
    H = D // head_dim
    K = V = head_dim
    if x_prev is None:
        x_prev = jnp.zeros((B, 1, D), x.dtype)
    r, k, v, g, logw = _rwkv_streams(params, x, x_prev)

    chunk = min(chunk, T)
    nch = -(-T // chunk)
    Tp = nch * chunk
    if Tp != T:
        pads = ((0, 0), (0, Tp - T), (0, 0))
        r = jnp.pad(r, pads)
        k = jnp.pad(k, pads)
        v = jnp.pad(v, pads)
        logw = jnp.pad(logw, pads)  # log w = 0 -> w = 1 on padding

    def heads(t):  # [B,Tp,D] -> [nch,B,H,chunk,hd]
        t = t.reshape(B, nch, chunk, H, K).transpose(1, 0, 3, 2, 4)
        return t

    r_c, k_c, v_c, lw_c = map(heads, (r, k, v, logw))
    u = params["bonus_u"].reshape(H, 1, K)

    s_init = jnp.zeros((B, H, K, V), jnp.float32) if s0 is None else s0

    def chunk_step(S, inp):
        rc, kc, vc, lwc = inp                   # [B,H,L,hd]
        Acum = jnp.cumsum(lwc, axis=2)          # inclusive cumsum of log w
        # decay of state from chunk start to *before* token t:
        # prod_{j<t} w_j = exp(Acum_{t-1}) = exp(Acum_t - lwc_t)
        A_before = Acum - lwc
        L = rc.shape[2]
        # Intra-chunk pair decays EXACT (exponent <= 0 for t > s, no
        # clipping — the factorized form underflows under strong decay;
        # see kernels/rwkv6_scan.py for the same construction in VMEM).
        tri = (jnp.arange(L)[:, None] > jnp.arange(L)[None, :])[..., None]
        expo = A_before[:, :, :, None, :] - Acum[:, :, None, :, :]
        pair = jnp.where(tri, jnp.exp(jnp.where(tri, expo, 0.0)), 0.0)
        scores = jnp.einsum("bhlk,bhmk,bhlmk->bhlm", rc, kc, pair)
        y_intra = jnp.einsum("bhlm,bhmv->bhlv", scores, vc)
        # bonus: current-token diagonal, (r_t ⊙ u)·k_t scalar times v_t
        y_diag = jnp.einsum("bhl,bhlv->bhlv",
                            jnp.einsum("bhlk,bhlk->bhl", rc * u, kc), vc)
        # inter-chunk: y_t += (r_t * exp(A_before_t)) . S
        r_dec = rc * jnp.exp(A_before)
        y_inter = jnp.einsum("bhlk,bhkv->bhlv", r_dec, S)
        y = y_intra + y_diag + y_inter
        # state update: S' = exp(Acum_L) S + sum_s exp(Acum_L - Acum_s) k v^T
        # (exponents <= 0: exact, no clipping)
        wtot = jnp.exp(Acum[:, :, -1])          # [B,H,K]
        k_for_state = kc * jnp.exp(Acum[:, :, -1:, :] - Acum)
        S_new = wtot[..., None] * S + jnp.einsum(
            "bhlk,bhlv->bhkv", k_for_state, vc)
        return S_new, y

    S_fin, ys = jax.lax.scan(chunk_step, s_init, (r_c, k_c, v_c, lw_c))
    # ys: [nch,B,H,chunk,V] -> [B,Tp,D]
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, Tp, D)[:, :T]
    # group-norm per head (ln_x), then gate
    y = y.reshape(B, T, H, K)
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, T, D) * params["ln_x"]["scale"] + params["ln_x"]["bias"]
    y = y * jax.nn.silu(g)
    out = jnp.einsum("btd,de->bte", y.astype(x.dtype), params["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    if return_state:
        return out, (x[:, -1:, :], S_fin)
    return out


def rwkv6_attn_decode(params, x, x_prev, S, *, head_dim: int = 64):
    """Exact single-token recurrence.  x: [B,1,D]."""
    B, _, D = x.shape
    H = D // head_dim
    K = V = head_dim
    r, k, v, g, logw = _rwkv_streams(params, x, x_prev)
    rh = r.reshape(B, H, K)
    kh = k.reshape(B, H, K)
    vh = v.reshape(B, H, V)
    w = jnp.exp(logw.reshape(B, H, K))
    u = params["bonus_u"].reshape(H, K)
    kv = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    y = jnp.einsum("bhk,bhkv->bhv", rh, S + u[None, :, :, None] * kv)
    S_new = w[..., None] * S + kv
    y = y.reshape(B, 1, H, V)
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, 1, D) * params["ln_x"]["scale"] + params["ln_x"]["bias"]
    y = y * jax.nn.silu(g)
    out = jnp.einsum("btd,de->bte", y.astype(x.dtype), params["wo"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, (x, S_new)


def rwkv6_channel_mix_init(key, *, d_model: int, d_ff: int,
                           dtype=DEFAULT_DTYPE):
    ks = jax.random.split(key, 3)
    return {
        "mix_k": jnp.full((d_model,), 0.5, jnp.float32),
        "mix_r": jnp.full((d_model,), 0.5, jnp.float32),
        "wk": dense_init(ks[0], d_model, d_ff, dtype=dtype),
        "wv": dense_init(ks[1], d_ff, d_model, dtype=dtype),
        "wr": dense_init(ks[2], d_model, d_model, dtype=dtype),
    }


def rwkv6_channel_mix(params, x, x_prev=None, *, return_state: bool = False):
    B, T, D = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, 1, D), x.dtype)
    xk = _token_shift(x, x_prev, params["mix_k"].astype(x.dtype))
    xr = _token_shift(x, x_prev, params["mix_r"].astype(x.dtype))
    k = jnp.einsum("btd,df->btf", xk, params["wk"],
                   preferred_element_type=jnp.float32)
    k = jnp.square(jax.nn.relu(k)).astype(x.dtype)
    v = jnp.einsum("btf,fd->btd", k, params["wv"],
                   preferred_element_type=jnp.float32)
    r = jnp.einsum("btd,de->bte", xr, params["wr"],
                   preferred_element_type=jnp.float32)
    out = (jax.nn.sigmoid(r) * v).astype(x.dtype)
    if return_state:
        return out, x[:, -1:, :]
    return out
