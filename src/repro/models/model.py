"""LM: config-driven composable model (all 10 assigned architectures).

The layer stack is organized into *stages* (configs/base.py): each stage
is a pattern of layers whose params are stacked along a leading axis and
applied with ONE ``lax.scan`` — compile time and HLO size are O(1) in
depth (126-layer llama3-405b compiles as fast as a 2-layer model), and
the stacked leaves carry the FSDP/TP shardings on their trailing dims.

Three entry points per model, matching the brief's shape kinds:

* ``forward``/``loss``  — full-sequence training (train_4k)
* ``prefill``           — full sequence + returns the decode cache
* ``decode_step``       — one token against the cache (decode_32k,
                          long_500k)
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LayerSpec
from repro.models import kvcache
from repro.models.attention import (
    gqa_apply,
    gqa_decode_apply,
    gqa_init,
    mla_apply,
    mla_decode_apply,
    mla_init,
)
from repro.models.layers import (
    DEFAULT_DTYPE,
    cross_entropy_loss,
    dense_init,
    embed_apply,
    embed_init,
    make_norm,
    mlp_apply,
    mlp_init,
    unembed_apply,
)
from repro.models.moe import moe_apply, moe_apply_dense, moe_init
from repro.models.ssm import (
    mamba_apply,
    mamba_decode_step,
    mamba_init,
    rwkv6_attn,
    rwkv6_attn_decode,
    rwkv6_channel_mix,
    rwkv6_channel_mix_init,
    rwkv6_init,
)

MOE_AUX_WEIGHT = 0.01


class LM:
    def __init__(self, cfg: ArchConfig, *, attn_impl: str = "blockwise",
                 remat_prevent_cse: bool = False,
                 seq_parallel: bool = False):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.remat_prevent_cse = remat_prevent_cse
        self.seq_parallel = seq_parallel
        self.norm_init, self.norm_apply = make_norm(cfg.norm)
        self.stages = cfg.stages()

    # ------------------------------------------------------------------
    # Init
    # ------------------------------------------------------------------
    def _init_layer(self, key, spec: LayerSpec):
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p: dict[str, Any] = {
            "mixer_norm": self.norm_init(cfg.d_model),
            "ffn_norm": self.norm_init(cfg.d_model),
        }
        if spec.mixer == "gqa":
            p["mixer"] = gqa_init(
                k1, d_model=cfg.d_model, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim)
        elif spec.mixer == "mla":
            m = cfg.mla
            p["mixer"] = mla_init(
                k1, d_model=cfg.d_model, num_heads=cfg.num_heads,
                kv_lora_rank=m.kv_lora_rank,
                qk_nope_head_dim=m.qk_nope_head_dim,
                qk_rope_head_dim=m.qk_rope_head_dim,
                v_head_dim=m.v_head_dim)
        elif spec.mixer == "mamba":
            mm = cfg.mamba
            p["mixer"] = mamba_init(
                k1, d_model=cfg.d_model, d_state=mm.d_state,
                d_conv=mm.d_conv, expand=mm.expand)
        elif spec.mixer == "rwkv":
            p["mixer"] = rwkv6_init(
                k1, d_model=cfg.d_model, head_dim=cfg.rwkv_head_dim)
        else:
            raise ValueError(spec.mixer)
        if spec.ffn == "mlp":
            p["ffn"] = mlp_init(k2, cfg.d_model, cfg.d_ff,
                                activation=cfg.activation)
        elif spec.ffn == "moe":
            mo = cfg.moe
            p["ffn"] = moe_init(
                k2, d_model=cfg.d_model, d_ff_expert=mo.d_ff_expert,
                num_experts=mo.num_experts, num_shared=mo.num_shared,
                activation=cfg.activation)
        elif spec.ffn == "rwkv_cm":
            p["ffn"] = rwkv6_channel_mix_init(
                k2, d_model=cfg.d_model, d_ff=cfg.d_ff)
        else:
            raise ValueError(spec.ffn)
        return p

    def init(self, key):
        cfg = self.cfg
        keys = jax.random.split(key, 3 + len(self.stages))
        params: dict[str, Any] = {
            "embed": embed_init(keys[0], cfg.padded_vocab, cfg.d_model),
            "final_norm": self.norm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["head"] = embed_init(keys[1], cfg.padded_vocab,
                                        cfg.d_model)
        stage_params = []
        for si, (pattern, repeat) in enumerate(self.stages):
            skeys = jax.random.split(keys[3 + si], repeat)

            def init_unit(k, pattern=pattern):
                uks = jax.random.split(k, len(pattern))
                return {f"l{j}": self._init_layer(uks[j], spec)
                        for j, spec in enumerate(pattern)}

            stage_params.append(jax.vmap(init_unit)(skeys))
        params["stages"] = stage_params
        return params

    # ------------------------------------------------------------------
    # Full-sequence forward (train / prefill)
    # ------------------------------------------------------------------
    def _mixer_full(self, spec, lp, x, positions, collect_cache):
        cfg = self.cfg
        h = self.norm_apply(lp["mixer_norm"], x, eps=cfg.norm_eps)
        cache = None
        if spec.mixer == "gqa":
            y, (k, v) = gqa_apply(
                lp["mixer"], h, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, positions=positions,
                causal=cfg.causal, rope_theta=cfg.rope_theta,
                m_rope=cfg.m_rope, m_rope_sections=cfg.m_rope_sections,
                impl=self.attn_impl, q_block=cfg.attn_q_block,
                kv_block=cfg.attn_kv_block)
            if collect_cache:
                cache = {"k": k, "v": v}
        elif spec.mixer == "mla":
            m = cfg.mla
            y, (ckv, kr) = mla_apply(
                lp["mixer"], h, num_heads=cfg.num_heads,
                kv_lora_rank=m.kv_lora_rank,
                qk_nope_head_dim=m.qk_nope_head_dim,
                qk_rope_head_dim=m.qk_rope_head_dim,
                v_head_dim=m.v_head_dim, positions=positions,
                causal=cfg.causal, rope_theta=cfg.rope_theta,
                impl=self.attn_impl, q_block=cfg.attn_q_block,
                kv_block=cfg.attn_kv_block)
            if collect_cache:
                cache = {"ckv": ckv, "kr": kr}
        elif spec.mixer == "mamba":
            mm = cfg.mamba
            if collect_cache:
                y, (hst, conv) = mamba_apply(
                    lp["mixer"], h, d_state=mm.d_state, d_conv=mm.d_conv,
                    chunk=mm.chunk, return_state=True)
                cache = {"h": hst, "conv": conv}
            else:
                y = mamba_apply(lp["mixer"], h, d_state=mm.d_state,
                                d_conv=mm.d_conv, chunk=mm.chunk)
        elif spec.mixer == "rwkv":
            if collect_cache:
                y, (x_prev, S) = rwkv6_attn(
                    lp["mixer"], h, head_dim=cfg.rwkv_head_dim,
                    chunk=cfg.rwkv_chunk, return_state=True)
                cache = {"x_att": x_prev, "S": S}
            else:
                y = rwkv6_attn(lp["mixer"], h, head_dim=cfg.rwkv_head_dim,
                               chunk=cfg.rwkv_chunk)
        else:
            raise ValueError(spec.mixer)
        return x + y, cache

    def _ffn_full(self, spec, lp, x, collect_cache):
        cfg = self.cfg
        h = self.norm_apply(lp["ffn_norm"], x, eps=cfg.norm_eps)
        aux = jnp.float32(0.0)
        cache = None
        if spec.ffn == "mlp":
            y = mlp_apply(lp["ffn"], h, activation=cfg.activation)
        elif spec.ffn == "moe":
            mo = cfg.moe
            y, aux = moe_apply(lp["ffn"], h, num_experts=mo.num_experts,
                               top_k=mo.top_k,
                               capacity_factor=mo.capacity_factor,
                               activation=cfg.activation)
        elif spec.ffn == "rwkv_cm":
            if collect_cache:
                y, x_prev = rwkv6_channel_mix(lp["ffn"], h,
                                              return_state=True)
                cache = {"x_ffn": x_prev}
            else:
                y = rwkv6_channel_mix(lp["ffn"], h)
        else:
            raise ValueError(spec.ffn)
        return x + y, aux, cache

    def _run_stages(self, params, x, positions, *, collect_cache=False,
                    remat=False):
        aux_total = jnp.float32(0.0)
        caches = []
        for (pattern, repeat), sp in zip(self.stages, params["stages"]):

            def unit_body(carry, layer_params, pattern=pattern):
                x, aux = carry
                unit_cache = {}
                for j, spec in enumerate(pattern):
                    lp = layer_params[f"l{j}"]
                    x, mc = self._mixer_full(spec, lp, x, positions,
                                             collect_cache)
                    x, aux_l, fc = self._ffn_full(spec, lp, x, collect_cache)
                    aux = aux + aux_l
                    if self.seq_parallel:
                        from repro.launch.sharding import shard_seq_dim
                        x = shard_seq_dim(x)
                    if collect_cache:
                        c = dict(mc or {})
                        c.update(fc or {})
                        unit_cache[f"l{j}"] = c
                return (x, aux), (unit_cache if collect_cache else None)

            body = unit_body
            if remat:
                body = jax.checkpoint(
                    unit_body,
                    policy=jax.checkpoint_policies.nothing_saveable,
                    prevent_cse=self.remat_prevent_cse,
                )
            (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), sp)
            caches.append(ys)
        return x, aux_total, caches

    def _embed_in(self, params, tokens, embeds):
        from repro.launch.sharding import shard_batch_dim
        if embeds is not None:
            return shard_batch_dim(embeds.astype(DEFAULT_DTYPE))
        return shard_batch_dim(embed_apply(params["embed"], tokens))

    def _positions(self, x_shape, positions):
        B, T = x_shape[0], x_shape[1]
        if positions is not None:
            return positions
        pos = jnp.arange(T, dtype=jnp.int32)[None, :].repeat(B, axis=0)
        if self.cfg.m_rope:
            pos = jnp.broadcast_to(pos[None], (3, B, T))
        return pos

    def _mask_pad(self, logits):
        """-inf the vocab-padding tail (padded_vocab > vocab_size)."""
        cfg = self.cfg
        if cfg.padded_vocab == cfg.vocab_size:
            return logits
        import jax.numpy as _jnp
        ids = _jnp.arange(cfg.padded_vocab)
        return _jnp.where(ids < cfg.vocab_size, logits, -1e30)

    def forward(self, params, tokens=None, embeds=None, positions=None,
                *, remat=False):
        """-> (logits [B,T,V] fp32, moe_aux scalar)."""
        cfg = self.cfg
        x = self._embed_in(params, tokens, embeds)
        positions = self._positions(x.shape, positions)
        x, aux, _ = self._run_stages(params, x, positions, remat=remat)
        x = self.norm_apply(params["final_norm"], x, eps=cfg.norm_eps)
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        return self._mask_pad(unembed_apply(head, x)), aux

    def loss(self, params, batch, *, remat=False):
        """batch: {'tokens' | 'embeds', 'labels'} -> scalar fp32 loss.

        Causal LMs shift internally (labels may equal tokens); encoders
        predict labels frame-wise.
        """
        logits, aux = self.forward(
            params, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
            positions=batch.get("positions"), remat=remat)
        labels = batch["labels"]
        if self.cfg.causal:
            logits = logits[:, :-1]
            labels = labels[:, 1:]
        ce = cross_entropy_loss(logits, labels)
        return ce + MOE_AUX_WEIGHT * aux

    # ------------------------------------------------------------------
    # Decode cache
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        stage_caches = []
        for pattern, repeat in self.stages:
            unit = {}
            for j, spec in enumerate(pattern):
                c = {}
                if spec.mixer == "gqa":
                    c.update(kvcache.gqa_cache_init(
                        repeat, batch, max_len, cfg.num_kv_heads,
                        cfg.resolved_head_dim))
                elif spec.mixer == "mla":
                    m = cfg.mla
                    c.update(kvcache.mla_cache_init(
                        repeat, batch, max_len, m.kv_lora_rank,
                        m.qk_rope_head_dim))
                elif spec.mixer == "mamba":
                    mm = cfg.mamba
                    c.update({
                        "h": jnp.zeros((repeat, batch,
                                        mm.d_inner(cfg.d_model),
                                        mm.d_state), jnp.float32),
                        "conv": jnp.zeros((repeat, batch, mm.d_conv - 1,
                                           mm.d_inner(cfg.d_model)),
                                          DEFAULT_DTYPE),
                    })
                elif spec.mixer == "rwkv":
                    H = cfg.d_model // cfg.rwkv_head_dim
                    c.update({
                        "x_att": jnp.zeros((repeat, batch, 1, cfg.d_model),
                                           DEFAULT_DTYPE),
                        "S": jnp.zeros((repeat, batch, H, cfg.rwkv_head_dim,
                                        cfg.rwkv_head_dim), jnp.float32),
                    })
                if spec.ffn == "rwkv_cm":
                    c["x_ffn"] = jnp.zeros((repeat, batch, 1, cfg.d_model),
                                           DEFAULT_DTYPE)
                unit[f"l{j}"] = c
            stage_caches.append(unit)
        return {"stages": stage_caches,
                "lengths": jnp.zeros((batch,), jnp.int32)}

    # ------------------------------------------------------------------
    # Decode step
    # ------------------------------------------------------------------
    def _mixer_decode(self, spec, lp, x, cache, lengths, positions):
        cfg = self.cfg
        h = self.norm_apply(lp["mixer_norm"], x, eps=cfg.norm_eps)
        new_cache = dict(cache)
        if spec.mixer == "gqa":
            y, ck, cv = gqa_decode_apply(
                lp["mixer"], h, cache["k"], cache["v"], lengths,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.resolved_head_dim, positions=positions,
                rope_theta=cfg.rope_theta, m_rope=cfg.m_rope,
                m_rope_sections=cfg.m_rope_sections)
            new_cache["k"], new_cache["v"] = ck, cv
        elif spec.mixer == "mla":
            m = cfg.mla
            y, ckv, kr = mla_decode_apply(
                lp["mixer"], h, cache["ckv"], cache["kr"], lengths,
                num_heads=cfg.num_heads, kv_lora_rank=m.kv_lora_rank,
                qk_nope_head_dim=m.qk_nope_head_dim,
                qk_rope_head_dim=m.qk_rope_head_dim,
                v_head_dim=m.v_head_dim, positions=positions,
                rope_theta=cfg.rope_theta)
            new_cache["ckv"], new_cache["kr"] = ckv, kr
        elif spec.mixer == "mamba":
            mm = cfg.mamba
            y, st = mamba_decode_step(
                lp["mixer"], h, {"h": cache["h"], "conv": cache["conv"]},
                d_state=mm.d_state, d_conv=mm.d_conv)
            new_cache["h"], new_cache["conv"] = st["h"], st["conv"]
        elif spec.mixer == "rwkv":
            y, (x_prev, S) = rwkv6_attn_decode(
                lp["mixer"], h, cache["x_att"], cache["S"],
                head_dim=cfg.rwkv_head_dim)
            new_cache["x_att"], new_cache["S"] = x_prev, S
        else:
            raise ValueError(spec.mixer)
        return x + y, new_cache

    def _ffn_decode(self, spec, lp, x, cache):
        cfg = self.cfg
        h = self.norm_apply(lp["ffn_norm"], x, eps=cfg.norm_eps)
        new_cache = cache
        if spec.ffn == "mlp":
            y = mlp_apply(lp["ffn"], h, activation=cfg.activation)
        elif spec.ffn == "moe":
            mo = cfg.moe
            # Dropless dense-combine MoE at decode: exact and
            # memory-roofline-equivalent (see moe_apply_dense docstring).
            y = moe_apply_dense(lp["ffn"], h, num_experts=mo.num_experts,
                                top_k=mo.top_k, activation=cfg.activation)
        elif spec.ffn == "rwkv_cm":
            y, x_prev = rwkv6_channel_mix(lp["ffn"], h, cache["x_ffn"],
                                          return_state=True)
            new_cache = dict(cache)
            new_cache["x_ffn"] = x_prev
        else:
            raise ValueError(spec.ffn)
        return x + y, new_cache

    def decode_step(self, params, cache, tokens):
        """tokens: i32[B,1] -> (logits [B,1,V] fp32, new cache).

        ``cache['lengths']`` counts tokens BEFORE this step; the new
        token is written at position lengths (0-based) and lengths
        increments.
        """
        cfg = self.cfg
        lengths = cache["lengths"] + 1            # incl. the new token
        B = tokens.shape[0]
        pos = (lengths - 1).astype(jnp.int32)[:, None]   # [B,1]
        if cfg.m_rope:
            pos = jnp.broadcast_to(pos[None], (3, B, 1))
        x = embed_apply(params["embed"], tokens)
        new_stage_caches = []
        for (pattern, repeat), sp, sc in zip(
                self.stages, params["stages"], cache["stages"]):

            # The cache stack rides the scan CARRY and each iteration
            # dynamic-updates its own layer slice — XLA aliases the
            # donated buffer, so the update is in place.  Passing the
            # cache through scan xs/ys instead re-materializes the FULL
            # [L, B, S, ...] stack every layer (2x ~1 TB/token/dev for
            # llama3-405b decode_32k; EXPERIMENTS §Perf cell D).
            def body(carry, layer_params, pattern=pattern):
                x, cstack, li = carry
                take = lambda c: jax.lax.dynamic_index_in_dim(
                    c, li, 0, keepdims=False)
                put = lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), li, 0)
                for j, spec in enumerate(pattern):
                    lp = layer_params[f"l{j}"]
                    lc = jax.tree.map(take, cstack[f"l{j}"])
                    x, nc = self._mixer_decode(spec, lp, x, lc, lengths, pos)
                    x, nc2 = self._ffn_decode(spec, lp, x, nc)
                    cstack = dict(cstack)
                    cstack[f"l{j}"] = jax.tree.map(put, cstack[f"l{j}"], nc2)
                return (x, cstack, li + 1), None

            (x, new_sc, _), _ = jax.lax.scan(body, (x, sc, jnp.int32(0)), sp)
            new_stage_caches.append(new_sc)
        x = self.norm_apply(params["final_norm"], x, eps=cfg.norm_eps)
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = self._mask_pad(unembed_apply(head, x))
        return logits, {"stages": new_stage_caches, "lengths": lengths}

    # ------------------------------------------------------------------
    # Prefill
    # ------------------------------------------------------------------
    def prefill(self, params, tokens=None, embeds=None, positions=None,
                max_len: int | None = None):
        """Full-sequence pass that also builds the decode cache.

        Returns (last-token logits [B,V], cache padded to ``max_len``).
        """
        cfg = self.cfg
        x = self._embed_in(params, tokens, embeds)
        B, T = x.shape[0], x.shape[1]
        max_len = max_len or T
        positions = self._positions(x.shape, positions)
        x, _aux, caches = self._run_stages(params, x, positions,
                                           collect_cache=True)
        x = self.norm_apply(params["final_norm"], x, eps=cfg.norm_eps)
        head = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = self._mask_pad(unembed_apply(head, x[:, -1]))
        # Assemble the padded cache.
        full = self.init_cache(B, max_len)
        for si, ((pattern, repeat), got) in enumerate(zip(self.stages,
                                                          caches)):
            for j, spec in enumerate(pattern):
                tgt = full["stages"][si][f"l{j}"]
                src = got[f"l{j}"]
                for name, val in src.items():
                    if name in ("k", "v", "ckv", "kr"):
                        # [repeat,B,T,...] -> pad into [repeat,B,S,...]
                        tgt[name] = jax.lax.dynamic_update_slice(
                            tgt[name], val.astype(tgt[name].dtype),
                            (0,) * tgt[name].ndim)
                    else:
                        tgt[name] = val.astype(tgt[name].dtype) \
                            if tgt[name].dtype != val.dtype else val
        full["lengths"] = jnp.full((B,), T, jnp.int32)
        return logits, full
