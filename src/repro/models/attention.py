"""Attention: GQA/MHA with RoPE or M-RoPE, and DeepSeek MLA.

Three execution paths, selected by ``impl``:

* ``"blockwise"`` (default) — flash-style O(T·block) memory attention in
  pure JAX (lax.scan over KV blocks with running max/denominator).  This
  is the path the distributed dry-run lowers: it never materializes the
  (T, S) score matrix, so 32k-prefill fits HBM, and its HLO is plain
  dot-generals that cost_analysis reads faithfully.
* ``"reference"`` — naive full-matrix softmax attention; the oracle the
  kernels and the blockwise path are tested against.
* ``"pallas"`` — the TPU Pallas flash kernel (kernels/flash_attention.py),
  validated in interpret mode on CPU; selected on real TPU runs.

Decode uses a dense KV cache (models/kvcache.py) and a single-token
attention with full-length masking; MLA decode uses the weight-absorbed
form operating directly on the compressed latent cache.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import (
    DEFAULT_DTYPE,
    apply_m_rope,
    apply_rope,
    dense_init,
    proj_einsum,
    rmsnorm,
    rmsnorm_init,
)

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Reference attention (oracle)
# ---------------------------------------------------------------------------

def reference_attention(q, k, v, *, causal: bool, scale: float | None = None,
                        q_offset: int = 0):
    """q: [B,T,H,D], k/v: [B,S,KV,D] with H = KV*G.  fp32 softmax."""
    B, T, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, T, KV, G, D)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(T) + q_offset
        mask = qpos[:, None] >= jnp.arange(S)[None, :]
        logits = jnp.where(mask[None, None, None], logits, _NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", w, v.astype(jnp.float32))
    return out.reshape(B, T, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention in pure JAX
# ---------------------------------------------------------------------------

def blockwise_attention(q, k, v, *, causal: bool, q_block: int = 512,
                        kv_block: int = 1024, scale: float | None = None,
                        skip_masked_blocks: bool = True):
    """Numerically exact flash-style attention, O(T·kv_block) memory.

    Outer lax.scan over query blocks; inner lax.scan over KV blocks with
    running (m, l, acc) in fp32.  With ``skip_masked_blocks`` (causal
    only) fully-masked KV blocks are skipped with ``lax.cond``, halving
    the executed FLOPs for long causal sequences.

    SHARDING CONTRACT: requires k/v already expanded to H heads
    (``expand_kv``) — the head dim stays a single axis end-to-end, so a
    model-axis sharding on H propagates through every reshape here.  (A
    [B,T,KV,G,D] split breaks GSPMD propagation and silently replicates
    the whole attention on every model shard — a 16x executed-FLOP
    regression found via the loop-aware HLO cost model; see
    EXPERIMENTS.md §Perf.)
    """
    B, T, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    if KV != H:
        raise ValueError("blockwise_attention requires expanded KV heads "
                         f"(got H={H}, KV={KV}); use expand_kv()")
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    q_block = min(q_block, T)
    kv_block = min(kv_block, S)
    # Pad T and S to block multiples (padded keys are masked out).
    Tp = -(-T // q_block) * q_block
    Sp = -(-S // kv_block) * kv_block
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    nq, nk = Tp // q_block, Sp // kv_block

    qb = q.reshape(B, nq, q_block, H, D)
    kb = k.reshape(B, nk, kv_block, H, D)
    vb = v.reshape(B, nk, kv_block, H, D)

    kv_pos = jnp.arange(Sp).reshape(nk, kv_block)

    def q_step(_, qi):
        qblk, q_idx = qi          # [B, q_block, H, D], scalar
        q_pos = q_idx * q_block + jnp.arange(q_block)

        def kv_step(carry, ki):
            m, l, acc = carry
            kblk, vblk, k_idx = ki

            def compute(args):
                m, l, acc = args
                s = jnp.einsum(
                    "bqhd,bshd->bhqs",
                    qblk.astype(jnp.float32), kblk.astype(jnp.float32)
                ) * scale
                valid = kv_pos[k_idx] < S
                if causal:
                    cm = q_pos[:, None] >= kv_pos[k_idx][None, :]
                    valid = valid[None, :] & cm
                else:
                    valid = jnp.broadcast_to(valid[None, :],
                                             (q_block, kv_block))
                s = jnp.where(valid[None, None], s, _NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                pv = jnp.einsum("bhqs,bshd->bhqd", p,
                                vblk.astype(jnp.float32))
                acc_new = acc * corr[..., None] + pv
                return m_new, l_new, acc_new

            if causal and skip_masked_blocks:
                # Entire KV block is in the future -> skip it.
                needed = (k_idx * kv_block) <= (q_idx * q_block + q_block - 1)
                m, l, acc = jax.lax.cond(
                    needed, compute, lambda args: args, (m, l, acc)
                )
            else:
                m, l, acc = compute((m, l, acc))
            return (m, l, acc), None

        m0 = jnp.full((B, H, q_block), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_block), jnp.float32)
        a0 = jnp.zeros((B, H, q_block, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), jnp.arange(nk)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # [B,H,qb,D]
        out = jnp.moveaxis(out, 2, 1)                     # [B,qb,H,D]
        return None, out

    _, outs = jax.lax.scan(
        q_step, None, (jnp.moveaxis(qb, 1, 0), jnp.arange(nq))
    )
    # outs: [nq, B, q_block, H, D] -> [B, T, H, D]
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Tp, H, D)[:, :T]
    return out.astype(q.dtype)


def expand_kv(k, G: int):
    """[B,S,KV,D] -> [B,S,KV*G,D]: replicate each KV head for its G query
    heads.  The TP-friendly layout: head dim stays one axis, sharded over
    the model mesh axis; the replication is the standard per-TP-rank KV
    copy and never hits HBM un-sharded."""
    if G == 1:
        return k
    B, S, KV, D = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (B, S, KV, G, D)
    ).reshape(B, S, KV * G, D)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     scale: float | None = None):
    """Single-token attention against a dense KV cache.

    q: [B,H,D]; k_cache/v_cache: [B,S,KV,D]; cache_len: i32[B] valid
    lengths (the new token's position is cache_len-1 inclusive).

    The cache-touching dots run in the CACHE dtype (bf16): upcasting the
    cache forces XLA to materialize an f32 copy of the full [L,B,S,..]
    stack per layer (EXPERIMENTS §Perf cell D).  Only the small [B,H,S]
    score tensor is f32 (exact softmax); production decode uses the
    Pallas kernel, which accumulates f32 in VMEM.
    """
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, KV, G, D).astype(k_cache.dtype)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    s = s * scale
    valid = jnp.arange(S)[None, :] < cache_len[:, None]      # [B,S]
    s = jnp.where(valid[:, None, None], s, _NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(B, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def gqa_init(key, *, d_model: int, num_heads: int, num_kv_heads: int,
             head_dim: int, dtype=DEFAULT_DTYPE):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, num_heads * head_dim, dtype=dtype),
        "wk": dense_init(ks[1], d_model, num_kv_heads * head_dim, dtype=dtype),
        "wv": dense_init(ks[2], d_model, num_kv_heads * head_dim, dtype=dtype),
        "wo": dense_init(ks[3], num_heads * head_dim, d_model, dtype=dtype),
    }


def _project_qkv(params, x, *, num_heads, num_kv_heads, head_dim):
    B, T, _ = x.shape
    q = proj_einsum("btd,dh->bth", x, params["wq"], out_dtype=x.dtype)
    k = proj_einsum("btd,dh->bth", x, params["wk"], out_dtype=x.dtype)
    v = proj_einsum("btd,dh->bth", x, params["wv"], out_dtype=x.dtype)
    q = q.reshape(B, T, num_heads, head_dim)
    k = k.reshape(B, T, num_kv_heads, head_dim)
    v = v.reshape(B, T, num_kv_heads, head_dim)
    return q, k, v


def gqa_apply(params, x, *, num_heads: int, num_kv_heads: int,
              head_dim: int, positions, causal: bool = True,
              rope_theta: float = 10000.0, m_rope: bool = False,
              m_rope_sections=(16, 24, 24), impl: str = "blockwise",
              q_block: int = 512, kv_block: int = 1024):
    """Full-sequence (train/prefill) GQA.  Returns (y, (k, v)) so callers
    can build the KV cache during prefill."""
    B, T, _ = x.shape
    q, k, v = _project_qkv(params, x, num_heads=num_heads,
                           num_kv_heads=num_kv_heads, head_dim=head_dim)
    if m_rope:
        q = apply_m_rope(q, positions, theta=rope_theta,
                         sections=m_rope_sections)
        k = apply_m_rope(k, positions, theta=rope_theta,
                         sections=m_rope_sections)
    elif positions is not None:
        q = apply_rope(q, positions, theta=rope_theta)
        k = apply_rope(k, positions, theta=rope_theta)
    G = num_heads // num_kv_heads
    if impl == "reference":
        o = reference_attention(q, k, v, causal=causal)
    elif impl == "blockwise":
        o = blockwise_attention(q, expand_kv(k, G), expand_kv(v, G),
                                causal=causal, q_block=q_block,
                                kv_block=kv_block)
    elif impl == "pallas":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=causal)
    else:
        raise ValueError(impl)
    y = proj_einsum("bth,hd->btd", o.reshape(B, T, num_heads * head_dim),
                    params["wo"], out_dtype=x.dtype)
    return y, (k, v)


def gqa_decode_apply(params, x, cache_k, cache_v, cache_len, *,
                     num_heads: int, num_kv_heads: int, head_dim: int,
                     positions, rope_theta: float = 10000.0,
                     m_rope: bool = False, m_rope_sections=(16, 24, 24),
                     impl: str = "blockwise"):
    """One-token decode.  x: [B,1,d]; cache_*: [B,S,KV,D]; cache_len:
    i32[B] length INCLUDING the new token.  Returns (y, k_new, v_new)."""
    B = x.shape[0]
    q, k, v = _project_qkv(params, x, num_heads=num_heads,
                           num_kv_heads=num_kv_heads, head_dim=head_dim)
    if m_rope:
        q = apply_m_rope(q, positions, theta=rope_theta,
                         sections=m_rope_sections)
        k = apply_m_rope(k, positions, theta=rope_theta,
                         sections=m_rope_sections)
    elif positions is not None:
        q = apply_rope(q, positions, theta=rope_theta)
        k = apply_rope(k, positions, theta=rope_theta)
    # Write the new K/V at position cache_len-1, then attend.
    idx = cache_len - 1                                   # [B]
    cache_k = _scatter_token(cache_k, k[:, 0], idx)
    cache_v = _scatter_token(cache_v, v[:, 0], idx)
    if impl == "pallas":
        from repro.kernels import ops as kops
        o = kops.decode_attention(q[:, 0], cache_k, cache_v, cache_len)
    else:
        o = decode_attention(q[:, 0], cache_k, cache_v, cache_len)
    y = jnp.einsum("bh,hd->bd", o.reshape(B, num_heads * head_dim),
                   params["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y[:, None, :], cache_k, cache_v


def _scatter_token(cache, new, idx):
    """cache: [B,S,KV,D]; new: [B,KV,D]; idx: i32[B] -> cache updated."""
    B = cache.shape[0]
    return cache.at[jnp.arange(B), idx].set(new.astype(cache.dtype))


# ---------------------------------------------------------------------------
# DeepSeek MLA (multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, *, d_model: int, num_heads: int, kv_lora_rank: int,
             qk_nope_head_dim: int, qk_rope_head_dim: int,
             v_head_dim: int, dtype=DEFAULT_DTYPE):
    ks = jax.random.split(key, 6)
    qd = qk_nope_head_dim + qk_rope_head_dim
    return {
        "wq": dense_init(ks[0], d_model, num_heads * qd, dtype=dtype),
        "wdkv": dense_init(ks[1], d_model, kv_lora_rank, dtype=dtype),
        "wkr": dense_init(ks[2], d_model, qk_rope_head_dim, dtype=dtype),
        "kv_norm": rmsnorm_init(kv_lora_rank),
        "wuk": dense_init(ks[3], kv_lora_rank,
                          num_heads * qk_nope_head_dim, dtype=dtype),
        "wuv": dense_init(ks[4], kv_lora_rank,
                          num_heads * v_head_dim, dtype=dtype),
        "wo": dense_init(ks[5], num_heads * v_head_dim, d_model, dtype=dtype),
    }


def mla_apply(params, x, *, num_heads: int, kv_lora_rank: int,
              qk_nope_head_dim: int, qk_rope_head_dim: int,
              v_head_dim: int, positions, causal: bool = True,
              rope_theta: float = 10000.0, impl: str = "blockwise",
              q_block: int = 512, kv_block: int = 1024):
    """Full-sequence MLA (naive/un-absorbed form).  Returns
    (y, (c_kv, k_rope)) — the COMPRESSED cache entries."""
    B, T, _ = x.shape
    H, dn, dr, dv = num_heads, qk_nope_head_dim, qk_rope_head_dim, v_head_dim
    q = jnp.einsum("btd,dh->bth", x, params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = q.reshape(B, T, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    c_kv = jnp.einsum("btd,dr->btr", x, params["wdkv"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
    c_kv = rmsnorm(params["kv_norm"], c_kv)
    k_rope = jnp.einsum("btd,dr->btr", x, params["wkr"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        theta=rope_theta)                # [B,T,1,dr]
    q_rope = apply_rope(q_rope, positions, theta=rope_theta)
    k_nope = jnp.einsum("btr,rh->bth", c_kv, params["wuk"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    k_nope = k_nope.reshape(B, T, H, dn)
    v = jnp.einsum("btr,rh->bth", c_kv, params["wuv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = v.reshape(B, T, H, dv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, T, H, dr))], axis=-1
    )
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = 1.0 / math.sqrt(dn + dr)
    # v head dim (dv) may differ from qk dim; pad v to qk dim for the
    # shared blockwise path, then slice.
    if dv < dn + dr:
        v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
    else:
        v_p = v
    if impl == "reference":
        o = reference_attention(qf, k, v_p, causal=causal, scale=scale)
    else:
        o = blockwise_attention(qf, k, v_p, causal=causal, scale=scale,
                                q_block=q_block, kv_block=kv_block)
    o = o[..., :dv]
    y = jnp.einsum("bth,hd->btd", o.reshape(B, T, H * dv), params["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, (c_kv, k_rope[:, :, 0, :])


def mla_decode_apply(params, x, cache_ckv, cache_kr, cache_len, *,
                     num_heads: int, kv_lora_rank: int,
                     qk_nope_head_dim: int, qk_rope_head_dim: int,
                     v_head_dim: int, positions,
                     rope_theta: float = 10000.0):
    """Weight-absorbed MLA decode on the compressed cache.

    score_nope = (q_nope W_uk^T) · c_kv   — absorb W_uk into the query
    out        = (attn · c_kv) W_uv       — absorb W_uv into the output
    The per-token cache row is only (kv_lora_rank + rope_dim) wide — the
    whole point of MLA — and decode never expands K/V to H heads.
    """
    B = x.shape[0]
    H, dn, dr, dv = num_heads, qk_nope_head_dim, qk_rope_head_dim, v_head_dim
    R = kv_lora_rank
    q = jnp.einsum("btd,dh->bth", x, params["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = q.reshape(B, 1, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, theta=rope_theta)[:, 0]  # [B,H,dr]
    # absorb W_uk: q_lat[b,h,r] = sum_dn q_nope * wuk[r, h*dn+dn']
    wuk = params["wuk"].reshape(R, H, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       wuk.astype(jnp.float32))            # [B,H,R]
    # new cache rows
    c_new = jnp.einsum("btd,dr->btr", x, params["wdkv"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    c_new = rmsnorm(params["kv_norm"], c_new)[:, 0]         # [B,R]
    kr_new = jnp.einsum("btd,dr->btr", x, params["wkr"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    kr_new = apply_rope(kr_new[:, :, None, :], positions,
                        theta=rope_theta)[:, 0, 0]          # [B,dr]
    idx = cache_len - 1
    cache_ckv = cache_ckv.at[jnp.arange(B), idx].set(
        c_new.astype(cache_ckv.dtype))
    cache_kr = cache_kr.at[jnp.arange(B), idx].set(
        kr_new.astype(cache_kr.dtype))
    scale = 1.0 / math.sqrt(dn + dr)
    # latent-cache dots in cache dtype (see decode_attention docstring)
    s = (
        jnp.einsum("bhr,bsr->bhs", q_lat.astype(cache_ckv.dtype),
                   cache_ckv).astype(jnp.float32)
        + jnp.einsum("bhd,bsd->bhs", q_rope.astype(cache_kr.dtype),
                     cache_kr).astype(jnp.float32)
    ) * scale
    S = cache_ckv.shape[1]
    valid = jnp.arange(S)[None, :] < cache_len[:, None]
    s = jnp.where(valid[:, None], s, _NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", w.astype(cache_ckv.dtype),
                       cache_ckv).astype(jnp.float32)       # [B,H,R]
    wuv = params["wuv"].reshape(R, H, dv)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, wuv.astype(jnp.float32))
    y = jnp.einsum("bh,hd->bd", o.reshape(B, H * dv).astype(x.dtype),
                   params["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y[:, None, :], cache_ckv, cache_kr
