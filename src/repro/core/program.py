"""`SimProgram`: one declarative model definition, every runtime.

The paper's premise is that the modeler writes small event handlers once
and the *system* decides how to compose and execute them.  This module
is the API that delivers that split (DESIGN.md §1.1): a model is defined
exactly once on a :class:`SimProgram` —

    prog = SimProgram("mm1", config=Config(max_batch_len=4))

    @prog.handler("ARRIVE", lookahead=1.0, emits=True)
    def arrive(state, t, arg):
        ...
        return state, emits          # fixed-record delay rows, see below

    @prog.entity_handler("TALLY")    # vmap-able entity-parallel type
    def tally(entity_state, t, arg):
        ...
        return entity_state

    prog.schedule(0.0, "ARRIVE")

— and then compiled against any backend without touching the model:

    sim = prog.build(backend="device")               # tiered3 queue
    sim = prog.build(backend="device", shards=4)     # sharded, 4 queues
    sim = prog.build(backend="host", scheduler="speculative")
    result = sim.run(state0)         # -> RunResult, re-runnable

Portable emission convention
----------------------------
A handler registered with ``emits=True`` returns ``(state, emits)``
where ``emits`` is ``f32[config.max_emit, 2 + ARG_WIDTH]`` rows of
``(delay, type_id, arg...)``; rows with ``type_id < 0`` are ν-rows
(unused slots).  Delays are *relative to the handler's own timestamp*,
which is the one convention that can be compiled to both runtimes:

* device: a wrapper rewrites column 0 to the absolute time ``t + delay``
  (the on-device insert convention) inside the traced program;
* host: a wrapper returns the rows as ``(delay, type, arg)`` tuples and
  the host schedulers anchor them at the emitter's timestamp, skipping
  ν-rows after the batch returns concrete values.

Because both adapters wrap the SAME handler and both runtimes execute
events in the same ``(time, seq)`` order, a model built this way
produces bit-identical final states across every backend (the
executable contract lives in ``tests/test_simprogram_parity.py``).

Entity-parallel types (``entity_handler``) are written against an entity
slice of the state pytree (leading axis = entity, ``arg[0]`` = entity
index) and must not emit.  The sequential form every backend needs for
mixed windows is derived automatically; the device engine additionally
dispatches single-type runs of such events as one ``vmap`` over the
touched entities.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import ARG_WIDTH, EventRegistry
from repro.core.queue import HostEventQueue

EMIT_WIDTH = 2 + ARG_WIDTH

_HOST_SCHEDULERS = ("conservative", "speculative", "unbatched")
_QUEUE_MODES = ("tiered3", "tiered", "flat", "reference")
_DEFAULT_QUEUE_MODE = "tiered3"


@dataclasses.dataclass(frozen=True)
class Config:
    """Shared capacity/batch knobs — the part of the execution setup
    that must agree across backends for results to be comparable.

    ``capacity``/``max_emit`` only bound device-side buffers (the host
    heap is unbounded and host emission lists are sized by the same
    ``max_emit`` via the fixed-record convention).  ``codec`` selects
    the host batch-id codec; the device engine always uses the dense
    codec.
    """

    max_batch_len: int = 4
    capacity: int = 1024
    max_emit: int = 2
    codec: str = "dense"

    def __post_init__(self):
        if self.max_batch_len < 1:
            raise ValueError("max_batch_len must be >= 1")
        if self.capacity < 1:
            raise ValueError("capacity must be >= 1")
        if self.max_emit < 1:
            raise ValueError("max_emit must be >= 1")
        if self.codec not in ("dense", "paper"):
            raise ValueError(f"unknown codec {self.codec!r}")


@dataclasses.dataclass(frozen=True)
class _HandlerSpec:
    type_id: int
    name: str
    fn: Callable
    lookahead: float
    emits: bool
    entity: bool


def normalize_arg(arg, arg_width: int = ARG_WIDTH) -> np.ndarray:
    """Canonicalize an event argument to the fixed ``f32[ARG_WIDTH]``
    record every backend carries (None -> zeros; scalars/short vectors
    are zero-padded)."""
    if arg is None:
        return np.zeros((arg_width,), np.float32)
    a = np.asarray(arg, np.float32).reshape(-1)
    if a.size > arg_width:
        raise ValueError(
            f"event arg has {a.size} elements; ARG_WIDTH is {arg_width}"
        )
    out = np.zeros((arg_width,), np.float32)
    out[: a.size] = a
    return out


def _check_emits(emits, max_emit: int, name: str):
    emits = jnp.asarray(emits, jnp.float32)
    if emits.shape != (max_emit, EMIT_WIDTH):
        raise ValueError(
            f"handler {name!r} must return emits of shape "
            f"({max_emit}, {EMIT_WIDTH}) = (config.max_emit, 2+ARG_WIDTH) "
            f"rows of (delay, type, arg...); got {emits.shape}"
        )
    return emits


def _adapt_emits_host(fn: Callable, max_emit: int, name: str) -> Callable:
    """Portable delay rows -> host ``(delay, type, arg)`` tuples.

    The tuples keep traced values; the schedulers concretize them after
    the batch and skip ν-rows (type < 0)."""

    @functools.wraps(fn)
    def host_handler(state, t, arg):
        state, emits = fn(state, t, arg)
        emits = _check_emits(emits, max_emit, name)
        new = [(emits[i, 0], emits[i, 1], emits[i, 2:])
               for i in range(max_emit)]
        return state, new

    host_handler.returns_events = True
    return host_handler


def _adapt_emits_device(fn: Callable, max_emit: int, name: str) -> Callable:
    """Portable delay rows -> on-device absolute-time rows."""

    @functools.wraps(fn)
    def device_handler(state, t, arg):
        state, emits = fn(state, t, arg)
        emits = _check_emits(emits, max_emit, name)
        valid = emits[:, 1] >= 0
        times = jnp.where(valid, t + emits[:, 0], 0.0)
        return state, emits.at[:, 0].set(times)

    device_handler.returns_events = True
    return device_handler


def _sequential_from_entity(local: Callable, name: str) -> Callable:
    """Derive the whole-state sequential handler from an entity-local
    one: gather the entity row (``arg[0]``), apply, scatter back.

    This is the form mixed windows dispatch on every backend; the device
    engine's vmapped run path applies the same local handler per lane,
    so the two dispatch routes stay bit-identical.
    """

    @functools.wraps(local)
    def handler(state, t, arg):
        arg = jnp.asarray(arg, jnp.float32)
        eid = arg[0].astype(jnp.int32)
        sub = jax.tree.map(lambda leaf: leaf[eid], state)
        out = local(sub, t, arg)
        return jax.tree.map(
            lambda leaf, new: leaf.at[eid].set(new), state, out
        )

    handler.__name__ = f"entity_seq_{name}"
    return handler


@dataclasses.dataclass(frozen=True)
class RunResult:
    """Normalized result of one :meth:`CompiledSim.run`.

    ``events``/``batches``/``dropped``/``final_time`` mean the same
    thing on every backend (``dropped`` is always 0 on the host's
    unbounded heap; ``rollbacks`` is only nonzero under the speculative
    scheduler).  ``raw`` keeps the backend-native stats object.

    ``word_counts`` (device backends, when the code space is small
    enough to track) is the per-word batch histogram: entry ``c`` is
    the number of executed batches whose Horner composition code was
    ``c`` — the observable profiling source for
    ``build(..., dispatch_mode="fused", hot_words=...)`` hot-word
    selection (see :func:`repro.core.composer.hot_words_from_counts`);
    ``None`` on host backends.

    ``emitted``/``pending``/``spilled`` (device backends) complete the
    conservation law ``seeded + ingested + emitted == events + pending
    + dropped + spilled + shed``; ``fault_word``/``fault_step`` surface
    the on-device auditor's packed invariant bits (``0``/``-1`` when
    clean or when ``validate="off"``) — see :mod:`repro.core.validate`.

    ``ingested``/``shed`` account the open-system arrival stream of
    ``run(arrivals=...)`` (DESIGN.md §10): ``ingested`` counts every
    arrival CONSUMED from the source — absorbed into the queue, parked
    in the spill pool, or refused — mirroring how ``emitted`` counts
    dropped/spilled emits; ``shed`` is the refused subset (nonzero only
    under ``backpressure="shed"``), which balances the law's right side
    exactly like ``dropped`` does for emits.  Both are 0 for closed
    runs on every backend.
    """

    state: Any
    events: int
    batches: int
    dropped: int
    final_time: float
    rollbacks: int = 0
    raw: Any = None
    word_counts: Any = None
    emitted: int = 0
    pending: int = 0
    spilled: int = 0
    fault_word: int = 0
    fault_step: int = -1
    ingested: int = 0
    shed: int = 0

    @property
    def mean_batch_length(self) -> float:
        return self.events / self.batches if self.batches else 0.0

    def stats(self) -> dict:
        return {
            "events": self.events,
            "batches": self.batches,
            "dropped": self.dropped,
            "final_time": self.final_time,
            "rollbacks": self.rollbacks,
            "mean_batch_length": self.mean_batch_length,
            "emitted": self.emitted,
            "pending": self.pending,
            "spilled": self.spilled,
            "fault_word": self.fault_word,
            "fault_step": self.fault_step,
            "ingested": self.ingested,
            "shed": self.shed,
        }


class SimProgram:
    """Declarative model: event alphabet + lookaheads + initial events.

    Registration (``handler`` / ``entity_handler`` / ``register``) must
    happen before the program is frozen; :meth:`build` freezes it.
    Initial events may be scheduled at any time — they are snapshotted
    into each :class:`CompiledSim` run, never consumed.
    """

    def __init__(self, name: str = "sim", config: Config | None = None):
        self.name = name
        self.config = config or Config()
        self._specs: list[_HandlerSpec] = []
        self._by_name: dict[str, _HandlerSpec] = {}
        self._schedule: list[tuple[float, int, np.ndarray]] = []
        self._frozen = False
        self._registries: dict[str, EventRegistry] = {}

    # -- registration -----------------------------------------------------
    def register(self, name: str, fn: Callable, *,
                 lookahead: float = float("inf"), emits: bool = False,
                 entity: bool = False) -> _HandlerSpec:
        """Register one event type.  ``emits=True`` handlers follow the
        portable fixed-record delay convention (module docstring);
        ``entity=True`` handlers are entity-local and must not emit."""
        if self._frozen:
            raise RuntimeError(
                "SimProgram is frozen; register all event types before "
                "build() (paper §III-A: constant handler array)"
            )
        if name in self._by_name:
            raise ValueError(f"event type {name!r} already registered")
        if entity and emits:
            raise ValueError(
                f"entity-parallel type {name!r} must not emit events "
                "(vmapped run dispatch has no emission lanes)"
            )
        spec = _HandlerSpec(
            type_id=len(self._specs), name=name, fn=fn,
            lookahead=float(lookahead), emits=bool(emits),
            entity=bool(entity),
        )
        self._specs.append(spec)
        self._by_name[name] = spec
        return spec

    def handler(self, name: str | Callable | None = None, *,
                lookahead: float = float("inf"), emits: bool = False):
        """Decorator form: ``@prog.handler("ARRIVE", lookahead=1.0,
        emits=True)`` (or bare ``@prog.handler``)."""
        if callable(name):
            fn, name = name, None
            self.register(fn.__name__, fn)
            return fn

        def wrap(fn):
            self.register(name or fn.__name__, fn,
                          lookahead=lookahead, emits=emits)
            return fn

        return wrap

    def entity_handler(self, name: str | Callable | None = None, *,
                       lookahead: float = float("inf")):
        """Decorator registering an entity-parallel type.  The function
        maps an entity slice: ``(entity_state, t, arg) -> entity_state``
        with ``arg[0]`` the entity index and every state leaf carrying
        the entity dimension on axis 0."""
        if callable(name):
            fn, name = name, None
            self.register(fn.__name__, fn, entity=True)
            return fn

        def wrap(fn):
            self.register(name or fn.__name__, fn,
                          lookahead=lookahead, entity=True)
            return fn

        return wrap

    # -- initial events ---------------------------------------------------
    def schedule(self, time: float, name: str, arg: Any = None) -> None:
        """Add one initial event (by type name; ``arg`` is canonicalized
        to the fixed f32[ARG_WIDTH] record)."""
        if name not in self._by_name:
            raise KeyError(
                f"unknown event type {name!r}; registered: "
                f"{sorted(self._by_name)}"
            )
        self._schedule.append(
            (float(time), self._by_name[name].type_id, normalize_arg(arg))
        )

    def schedule_many(
        self, events: Iterable[tuple[float, str] | tuple[float, str, Any]]
    ) -> None:
        for ev in events:
            self.schedule(*ev)

    def scheduled_events(self) -> list[tuple[float, int, np.ndarray]]:
        """Snapshot of the initial events as (time, type_id, arg_vec)."""
        return list(self._schedule)

    # -- introspection ----------------------------------------------------
    def freeze(self) -> "SimProgram":
        self._frozen = True
        return self

    @property
    def frozen(self) -> bool:
        return self._frozen

    @property
    def names(self) -> list[str]:
        return [s.name for s in self._specs]

    def type_id(self, name: str) -> int:
        return self._by_name[name].type_id

    def __len__(self) -> int:
        return len(self._specs)

    # -- backend registries ------------------------------------------------
    def _registry(self, backend: str) -> EventRegistry:
        self.freeze()
        if backend not in self._registries:
            adapt = (_adapt_emits_device if backend == "device"
                     else _adapt_emits_host)
            reg = EventRegistry()
            for spec in self._specs:
                fn = spec.fn
                if spec.entity:
                    fn = _sequential_from_entity(fn, spec.name)
                if spec.emits:
                    fn = adapt(fn, self.config.max_emit, spec.name)
                reg.register(spec.name, fn, lookahead=spec.lookahead)
            self._registries[backend] = reg.freeze()
        return self._registries[backend]

    def host_registry(self) -> EventRegistry:
        """Registry with handlers adapted to the host schedulers'
        list-of-``(delay, type, arg)`` emission convention."""
        return self._registry("host")

    def device_registry(self) -> EventRegistry:
        """Registry with handlers adapted to the on-device absolute-time
        fixed-record emission convention."""
        return self._registry("device")

    def device_entity_handlers(self) -> dict[int, Callable]:
        """type_id -> entity-local handler, for the device engine's
        vmapped single-type-run dispatch."""
        return {s.type_id: s.fn for s in self._specs if s.entity}

    # -- compilation -------------------------------------------------------
    def build(self, *, backend: str = "device",
              scheduler: str = "conservative", composer: str = "lazy",
              queue_mode: str = _DEFAULT_QUEUE_MODE,
              shards: int | None = None, shard_fn=None,
              capacity: int | None = None,
              front_cap: int | None = None, stage_cap: int | None = None,
              num_runs: int | None = None,
              dispatch_mode: str = "switch",
              hot_words: Sequence | None = None,
              queue_kernels: str = "xla",
              validate: str = "off",
              overflow: str = "drop",
              state_spec=None, arg_spec=None,
              check_causality: bool = False,
              window_slack: float = float("inf"),
              jit_handlers: bool = True) -> "CompiledSim":
        """Compile this model against one runtime.

        ``backend="device"`` honors ``queue_mode`` (default
        ``"tiered3"`` — bounded per-batch cost at any capacity,
        DESIGN.md §4.4) plus the optional capacity/tier overrides, and
        ``shards=N`` (with optional ``shard_fn``): N per-shard tiered3
        queues run under the lookahead-synchronized
        :class:`~repro.core.sharded.ShardedDeviceEngine`,
        bit-identical to the single queue (DESIGN.md §5.1) —
        entity-parallel types route by their entity index
        (``arg[0]``) by default.  ``dispatch_mode`` selects the window
        dispatch path (``"switch"``: one switch over every composed
        word; ``"masked"``: the generic per-lane path; ``"fused"``:
        top-W hot-word super-procedures + masked fallback, DESIGN.md
        §7) — all three bit-identical; ``hot_words`` declares the
        fused hot set as sequences of type names or ids (default: the
        first 32 dense codes; profile a run's
        ``RunResult.word_counts`` for a real selection).
        ``queue_kernels="pallas"`` swaps the tiered3 front-tier hot
        loops for the Pallas kernels (interpret mode off-TPU).
        ``validate`` arms the on-device invariant auditor (DESIGN.md
        §9): ``"cheap"`` folds per-super-step fault bits into the
        loop carry (CI-gated at <=1.10x the ``"off"`` cost),
        ``"full"`` adds an exact audit at segment boundaries; a
        violation raises :class:`~repro.core.validate.EngineFaultError`
        naming the invariant and super-step.  ``overflow`` picks the
        full-queue policy: ``"drop"`` (count ghosts), ``"error"``
        (fail fast), or ``"spill"`` (divert to a host pool reabsorbed
        at segment boundaries — bit-parity with an oversized queue).
        ``backend="host"`` honors
        ``scheduler`` and ``composer`` (+ eager specs / causality /
        slack knobs).  Passing a knob that the selected backend does
        not read is an error, not a silent default — a mis-targeted
        ``scheduler=`` must not quietly run a different runtime.
        Everything model-level — handlers, lookaheads, Config, initial
        events — comes from the program; nothing about the model is
        repeated at the call site.  ``max_emit`` is Config-only: the
        portable emit-row shape is baked into the handler adapters.
        """
        self.freeze()
        if backend == "device":
            from repro.core.engine import DeviceEngine
            from repro.core.sharded import ShardedDeviceEngine

            misdirected = {
                "scheduler": scheduler != "conservative",
                "composer": composer != "lazy",
                "state_spec": state_spec is not None,
                "arg_spec": arg_spec is not None,
                "check_causality": check_causality,
                "window_slack": window_slack != float("inf"),
                "jit_handlers": not jit_handlers,
            }
            bad = [k for k, hit in misdirected.items() if hit]
            if bad:
                raise ValueError(
                    f"{bad} are host-backend knobs; the device backend "
                    "would silently ignore them — drop them or build "
                    "with backend='host'"
                )
            if queue_mode not in _QUEUE_MODES:
                raise ValueError(
                    f"unknown queue_mode {queue_mode!r}; "
                    f"expected one of {_QUEUE_MODES}"
                )
            if shard_fn is not None and shards is None:
                raise ValueError("shard_fn requires shards=N")
            if hot_words is not None:
                # Type names are the API-level spelling; the engines
                # take ids.
                hot_words = [
                    tuple(self.type_id(t) if isinstance(t, str) else int(t)
                          for t in word)
                    for word in hot_words
                ]
            if shards is not None:
                if queue_mode != "tiered3":
                    raise ValueError(
                        f"shards={shards} requires queue_mode='tiered3' "
                        f"(got {queue_mode!r}): the per-shard pending "
                        "sets are tiered3 queues"
                    )
                engine = ShardedDeviceEngine.from_program(
                    self, shards=shards, shard_fn=shard_fn,
                    capacity=capacity, front_cap=front_cap,
                    stage_cap=stage_cap, num_runs=num_runs,
                    dispatch_mode=dispatch_mode, hot_words=hot_words,
                    queue_kernels=queue_kernels,
                    validate=validate, overflow=overflow,
                )
                return CompiledSim(
                    self, backend="device", engine=engine,
                    variant=f"tiered3/shards={shards}",
                )
            engine = DeviceEngine.from_program(
                self, queue_mode=queue_mode, capacity=capacity,
                front_cap=front_cap, stage_cap=stage_cap,
                num_runs=num_runs,
                dispatch_mode=dispatch_mode, hot_words=hot_words,
                queue_kernels=queue_kernels,
                validate=validate, overflow=overflow,
            )
            return CompiledSim(self, backend="device", engine=engine,
                               variant=queue_mode)
        if backend == "host":
            misdirected = {
                "queue_mode": queue_mode != _DEFAULT_QUEUE_MODE,
                "shards": shards is not None,
                "shard_fn": shard_fn is not None,
                "capacity": capacity is not None,
                "front_cap": front_cap is not None,
                "stage_cap": stage_cap is not None,
                "num_runs": num_runs is not None,
                "dispatch_mode": dispatch_mode != "switch",
                "hot_words": hot_words is not None,
                "queue_kernels": queue_kernels != "xla",
                "validate": validate != "off",
                "overflow": overflow != "drop",
            }
            bad = [k for k, hit in misdirected.items() if hit]
            if bad:
                raise ValueError(
                    f"{bad} are device-backend knobs; the host backend "
                    "would silently ignore them — drop them or build "
                    "with backend='device'"
                )
            from repro.core.composer import EagerComposer, LazyComposer
            from repro.core.scheduler import (
                ConservativeScheduler,
                SpeculativeScheduler,
            )

            if scheduler not in _HOST_SCHEDULERS:
                raise ValueError(
                    f"unknown scheduler {scheduler!r}; "
                    f"expected one of {_HOST_SCHEDULERS}"
                )
            if scheduler == "unbatched":
                return CompiledSim(self, backend="host", variant="unbatched",
                                   jit_handlers=jit_handlers)
            if composer == "lazy":
                comp = LazyComposer.from_program(self)
            elif composer == "eager":
                if arg_spec is None:
                    arg_spec = jax.ShapeDtypeStruct(
                        (ARG_WIDTH,), jnp.float32
                    )
                comp = EagerComposer.from_program(
                    self, state_spec=state_spec, arg_spec=arg_spec
                )
            else:
                raise ValueError(f"unknown composer {composer!r}")
            if scheduler == "conservative":
                sched = ConservativeScheduler.from_program(
                    self, composer=comp, check_causality=check_causality
                )
            else:
                sched = SpeculativeScheduler.from_program(
                    self, composer=comp, window_slack=window_slack
                )
            return CompiledSim(self, backend="host", sched=sched,
                               variant=scheduler)
        raise ValueError(
            f"unknown backend {backend!r}; expected 'device' or 'host'"
        )


class CompiledSim:
    """One (model, runtime) pairing with a uniform ``run`` contract.

    ``run`` is re-runnable: every call rebuilds the initial pending set
    from the program's schedule.  On the device backend that hides the
    queue-donation footgun — the donated (consumed) queue value is an
    internal detail, callers never hold one.  Composed batch programs
    and the engine's jitted main loop are cached on this object, so
    repeat runs pay no recompilation.
    """

    def __init__(self, program: SimProgram, *, backend: str,
                 engine=None, sched=None, variant: str = "",
                 jit_handlers: bool = True):
        self.program = program
        self.backend = backend
        self.engine = engine
        self.sched = sched
        self.variant = variant
        self.jit_handlers = jit_handlers

    def __repr__(self):
        return (f"CompiledSim({self.program.name!r}, "
                f"backend={self.backend!r}, variant={self.variant!r})")

    @property
    def registry(self) -> EventRegistry:
        return (self.program.device_registry() if self.backend == "device"
                else self.program.host_registry())

    def _initial_events(self, events):
        if events is None:
            evs = self.program.scheduled_events()
        else:
            evs = []
            for (t, ty, *rest) in events:
                type_id = (self.program.type_id(ty) if isinstance(ty, str)
                           else int(ty))
                arg = rest[0] if rest else None
                evs.append((float(t), type_id, normalize_arg(arg)))
        return evs

    # -- segmented device driver -------------------------------------------
    def _rebalance_spill(self, queue, pool_rows, pool_seqs):
        """The pool outgrew the queue's slack: merge queue ∪ pool and
        keep the lex-smallest ``capacity`` events on device; the rest
        stays host-side.  Host O(capacity log capacity) at a segment
        boundary (off the hot path); the global counters are preserved
        exactly, so the logical pending set is untouched — only its
        device/host split moves.
        """
        from repro.core.queue import (
            tiered3_queue_from_host,
            tiered3_queue_to_flat,
        )

        eng = self.engine
        flat = tiered3_queue_to_flat(queue)
        occ = np.asarray(flat.types) >= 0
        times = np.concatenate(
            [np.asarray(flat.times)[occ], pool_rows[:, 0]]
        )
        types = np.concatenate(
            [np.asarray(flat.types)[occ],
             pool_rows[:, 1].astype(np.int32)]
        )
        args = np.concatenate(
            [np.asarray(flat.args)[occ], pool_rows[:, 2:]]
        )
        seqs = np.concatenate([np.asarray(flat.seqs)[occ], pool_seqs])
        order = np.lexsort((seqs, times))
        C = eng.capacity
        keep, rest = order[:C], order[C:]
        q = tiered3_queue_from_host(
            [(float(times[i]), int(types[i]), args[i]) for i in keep],
            C, front_cap=eng.front_cap, stage_cap=eng.stage_cap,
            num_runs=eng.num_runs, seqs=seqs[keep],
        )
        q = q._replace(next_seq=queue.next_seq, dropped=queue.dropped)
        new_rows = np.zeros((rest.size, EMIT_WIDTH), np.float32)
        new_rows[:, 0] = times[rest]
        new_rows[:, 1] = types[rest]
        new_rows[:, 2:] = args[rest]
        return q, new_rows, seqs[rest].astype(np.int32)

    def _absorb_spill(self, queue, pool_rows, pool_seqs, stats):
        """Reabsorb the host spill pool — wholesale when it fits,
        otherwise via the lex rebalance — and refresh the engine's
        execution fence to the lex-earliest key still outstanding.
        Returns ``(queue, pool_rows, pool_seqs, stats)``."""
        from repro.core.queue import tiered3_queue_absorb_rows

        eng = self.engine
        if pool_seqs.size:
            occ = int(np.asarray(eng.queue_occupancy(queue)))
            room = eng.capacity - occ
            if room >= int(pool_seqs.size):
                queue = tiered3_queue_absorb_rows(
                    queue, jnp.asarray(pool_rows),
                    jnp.asarray(pool_seqs),
                )
                pool_rows = np.zeros((0, EMIT_WIDTH), np.float32)
                pool_seqs = np.zeros((0,), np.int32)
            else:
                queue, pool_rows, pool_seqs = self._rebalance_spill(
                    queue, pool_rows, pool_seqs
                )
        stats = dict(eng.initial_run_stats() if stats is None else stats)
        if pool_seqs.size:
            order = np.lexsort((pool_seqs, pool_rows[:, 0]))
            stats["bound_t"] = jnp.float32(pool_rows[order[0], 0])
            stats["bound_seq"] = jnp.int32(pool_seqs[order[0]])
        else:
            stats["bound_t"] = jnp.float32(np.inf)
            stats["bound_seq"] = jnp.int32(2**31 - 1)
        return queue, pool_rows, pool_seqs, stats

    def _absorb_fn(self):
        """Jitted masked arrival absorb, cached per CompiledSim.

        The admitted count rides a traced ``[lo, hi)`` prefix mask and
        the queue is donated, so ONE compile serves every segment
        boundary of a streamed run — the per-boundary cost is a device
        call, not a trace."""
        fn = getattr(self, "_absorb_jit", None)
        if fn is None:
            eng = self.engine

            def absorb(queue, rows, seqs, lo, hi):
                idx = jnp.arange(rows.shape[0], dtype=jnp.int32)
                return eng.absorb_rows(
                    queue, rows, seqs, (idx >= lo) & (idx < hi)
                )

            fn = jax.jit(absorb, donate_argnums=(0,))
            self._absorb_jit = fn
        return fn

    def _queue_next_time(self, queue):
        """Earliest pending timestamp (host float), single or sharded."""
        from repro.core.queue import tiered3_queue_next_time

        if hasattr(queue, "shards"):
            return min(
                float(np.asarray(tiered3_queue_next_time(q)))
                for q in queue.shards
            )
        return float(np.asarray(tiered3_queue_next_time(queue)))

    @staticmethod
    def _save_checkpoint(manager, step, state, queue, stats,
                         pool_rows, pool_seqs, *, extra=None, strip=()):
        # "dropped" lives on the queue (re-derived after every segment),
        # not in the loop carry — keep the saved stats restorable
        # against the initial_run_stats template.  Fence-only streamed
        # runs additionally strip the host-injected bound keys (the
        # template never carries them; they are recomputed from the
        # restored cursor at the first resumed boundary).
        drop = {"dropped", *strip}
        payload = {
            "state": state,
            "queue": queue,
            "stats": {k: v for k, v in stats.items() if k not in drop},
            "pool_rows": np.asarray(pool_rows),
            "pool_seqs": np.asarray(pool_seqs),
        }
        if extra:
            payload.update(extra)
        manager.save_async(step, payload)

    def _run_device(self, state, evs, t_end, total_batches, *,
                    checkpoint_every, checkpoint_dir, resume_from,
                    segment_hook, arrivals=None, backpressure="block",
                    stream_prefetch=True):
        eng = self.engine
        spill = getattr(eng, "overflow", "drop") == "spill"
        streamed = arrivals is not None
        if streamed:
            if getattr(eng, "queue_mode", None) != "tiered3":
                raise ValueError(
                    "run(arrivals=...) on the device backend requires "
                    f"queue_mode='tiered3', got {eng.queue_mode!r}: the "
                    "admission fence is a tiered3 lex bound"
                )
            from repro.core.sharded import ShardedDeviceEngine
            if (eng.queue_kernels == "pallas"
                    and not isinstance(eng, ShardedDeviceEngine)):
                raise ValueError(
                    "run(arrivals=...) needs the bounded extract's lex "
                    "fence, which the pallas front tier does not "
                    "implement — build with queue_kernels='xla'"
                )
        if (checkpoint_every is not None or resume_from is not None) \
                and checkpoint_dir is None:
            raise ValueError(
                "checkpoint_every/resume_from require checkpoint_dir="
            )
        seg = None if checkpoint_every is None else int(checkpoint_every)
        if seg is not None and seg < 1:
            raise ValueError(f"checkpoint_every must be >= 1, got {seg}")
        manager = None
        if checkpoint_dir is not None:
            from repro.checkpoint.manager import CheckpointManager
            manager = CheckpointManager(checkpoint_dir)

        if spill:
            queue, pool_rows, pool_seqs = eng.initial_queue_spill(evs)
        else:
            queue = eng.initial_queue(evs)
            pool_rows = np.zeros((0, EMIT_WIDTH), np.float32)
            pool_seqs = np.zeros((0,), np.int32)
        stats = None
        cursor, ingested, shed = 0, 0, 0
        if streamed:
            # Reserve the arrival seq range upfront: arrival j carries
            # seq len(evs)+j, and mid-run emits draw seqs PAST the
            # reservation — so an absorbed arrival occupies exactly the
            # (time, seq) lex rank it would have had pre-seeded, even
            # under timestamp ties (DESIGN.md §10).
            queue = queue._replace(
                next_seq=queue.next_seq + jnp.int32(len(arrivals))
            )

        if resume_from is not None:
            step = None if resume_from == "latest" else int(resume_from)
            restored, at_step = manager.restore({
                "state": state,
                "queue": queue,
                "stats": eng.initial_run_stats(),
            }, step)
            state, queue = restored["state"], restored["queue"]
            stats = restored["stats"]
            pool_rows = np.asarray(
                manager.restore_leaf("pool_rows", at_step), np.float32
            )
            pool_seqs = np.asarray(
                manager.restore_leaf("pool_seqs", at_step), np.int32
            )
            saved_cursor = manager.restore_leaf(
                "ingest_cursor", at_step, default=None
            )
            if saved_cursor is not None and not streamed:
                raise ValueError(
                    "checkpoint was written by a streamed run "
                    f"(arrival cursor {int(saved_cursor)}): resume with "
                    "the same arrivals= source"
                )
            if streamed and saved_cursor is not None:
                cursor = int(np.asarray(saved_cursor))
                ingested = int(np.asarray(manager.restore_leaf(
                    "ingested", at_step, default=np.int64(0))))
                shed = int(np.asarray(manager.restore_leaf(
                    "shed", at_step, default=np.int64(0))))

        feeder = None
        if streamed:
            from repro.stream.ingest import StreamFeeder
            feeder = StreamFeeder(
                arrivals, len(evs), start=cursor,
                prefetch=stream_prefetch,
            )

        seg_index = 0
        idle_rounds = 0
        try:
            (state, queue, stats, pool_rows, pool_seqs,
             ingested, shed) = self._segment_loop(
                state, queue, stats, pool_rows, pool_seqs,
                t_end=t_end, total_batches=total_batches, seg=seg,
                spill=spill, manager=manager, segment_hook=segment_hook,
                seg_index=seg_index, idle_rounds=idle_rounds,
                feeder=feeder, backpressure=backpressure,
                ingested=ingested, shed=shed,
            )
        finally:
            if feeder is not None:
                feeder.close()
            if manager is not None:
                # Even on a fault path, drain the async writer so the
                # newest on-disk checkpoint is complete (atomic rename
                # means a partial write is never visible as "latest").
                manager.wait()

        word_counts = stats.get("word_counts")
        raw = dict(stats)
        raw["final_queue"] = queue
        return RunResult(
            state=state,
            events=int(stats["events"]),
            batches=int(stats["batches"]),
            dropped=int(stats["dropped"]),
            final_time=float(stats["time"]),
            raw=raw,
            word_counts=(None if word_counts is None
                         else np.asarray(word_counts)),
            emitted=int(np.asarray(stats.get("emitted", 0))),
            pending=int(np.asarray(eng.queue_occupancy(queue))),
            spilled=int(pool_seqs.size),
            fault_word=int(np.asarray(stats.get("fault_word", 0))),
            fault_step=int(np.asarray(stats.get("fault_step", -1))),
            ingested=int(ingested),
            shed=int(shed),
        )

    def _segment_loop(self, state, queue, stats, pool_rows, pool_seqs, *,
                      t_end, total_batches, seg, spill, manager,
                      segment_hook, seg_index, idle_rounds,
                      feeder=None, backpressure="block",
                      ingested=0, shed=0):
        from repro.core.validate import (
            FAULT_INGEST,
            FAULT_SPILL_STALL,
            EngineFaultError,
        )

        eng = self.engine
        streamed = feeder is not None
        while True:
            progressed = False
            if spill and pool_seqs.size:
                queue, pool_rows, pool_seqs, stats = \
                    self._absorb_spill(queue, pool_rows, pool_seqs, stats)
            # -- streamed admission: at most ONE arrival block per
            # boundary, so the admitted/spilled/shed split is a pure
            # function of the cursor, the horizon, and queue occupancy
            # — never of prefetch timing.
            if streamed and feeder.has_pending():
                # Arrivals past the horizon are never consumed: they
                # stay in the source, like queued events past t_end
                # stay in the queue.
                adm = feeder.admissible(t_end)
                if adm:
                    occ = int(np.asarray(eng.queue_occupancy(queue)))
                    k = min(adm, max(eng.capacity - occ, 0))
                    if k > 0:
                        rows_d, seqs_d, lo = feeder.device_block()
                        queue = self._absorb_fn()(
                            queue, rows_d, seqs_d,
                            jnp.int32(lo), jnp.int32(lo + k),
                        )
                        feeder.advance(k)
                        ingested += k
                        progressed = True
                    rest = adm - k
                    if rest > 0:
                        if spill:
                            r_rows, r_seqs = feeder.host_slice(rest)
                            pool_rows = np.concatenate(
                                [pool_rows, r_rows])
                            pool_seqs = np.concatenate(
                                [pool_seqs, r_seqs])
                            feeder.advance(rest)
                            ingested += rest
                            progressed = True
                        elif backpressure == "shed":
                            feeder.advance(rest)
                            ingested += rest
                            shed += rest
                            progressed = True
                        elif backpressure == "error":
                            raise EngineFaultError(
                                FAULT_INGEST,
                                0 if stats is None
                                else int(np.asarray(stats["batches"])),
                                detail=(
                                    f"{rest} arrival(s) found the "
                                    f"capacity-{eng.capacity} queue "
                                    "full (backpressure='error')"
                                ),
                            )
                        # backpressure='block': the rows wait in the
                        # feeder; the fence keeps order safe and the
                        # stall detector below converts a wedged
                        # topology into FAULT_INGEST.
            if streamed:
                # Refresh the admission fence: the lex-min outstanding
                # external key — next unconsumed arrival vs. spilled
                # pool head — with (inf, I32_MAX) meaning no fence.
                stats = dict(eng.initial_run_stats()
                             if stats is None else stats)
                f_t, f_s = feeder.next_key()
                if spill and pool_seqs.size:
                    order = np.lexsort((pool_seqs, pool_rows[:, 0]))
                    p_key = (float(pool_rows[order[0], 0]),
                             int(pool_seqs[order[0]]))
                    if p_key < (f_t, f_s):
                        f_t, f_s = p_key
                stats["bound_t"] = jnp.float32(f_t)
                stats["bound_seq"] = jnp.int32(f_s)
            done = 0 if stats is None else int(np.asarray(stats["batches"]))
            target = (total_batches if seg is None
                      else min(total_batches, done + seg))
            state, queue, stats = eng.run(
                state, queue, max_batches=target, t_end=t_end, stats=stats
            )
            new_done = int(stats["batches"])
            if new_done > done:
                progressed = True
            if spill and int(np.asarray(stats.get("spill_n", 0))) > 0:
                n = int(stats["spill_n"])
                pool_rows = np.concatenate(
                    [pool_rows, np.asarray(stats["spill_rows"])[:n]]
                )
                pool_seqs = np.concatenate(
                    [pool_seqs, np.asarray(stats["spill_seqs"])[:n]]
                )
                stats = dict(stats)
                stats["spill_n"] = jnp.int32(0)
            seg_index += 1
            # Save BEFORE the injection seam: the newest checkpoint is
            # always a clean pre-corruption snapshot, so fault recovery
            # is restore-latest-and-replay.
            if manager is not None and seg is not None:
                self._save_checkpoint(
                    manager, new_done, state, queue, stats,
                    pool_rows, pool_seqs,
                    extra=(dict(
                        ingest_cursor=np.int64(feeder.cursor),
                        ingested=np.int64(ingested),
                        shed=np.int64(shed),
                    ) if streamed else None),
                    strip=(("bound_t", "bound_seq")
                           if streamed and not spill else ()),
                )
            if segment_hook is not None:
                out = segment_hook(seg_index, state, queue, stats)
                if out is not None:
                    state, queue, stats = out
            if new_done >= total_batches:
                break
            pool_live = bool(spill and pool_seqs.size)
            feeder_live = streamed and feeder.has_pending()
            if pool_live or feeder_live:
                qt = self._queue_next_time(queue)
                pool_t = (float(pool_rows[:, 0].min()) if pool_live
                          else float("inf"))
                feed_t = (feeder.next_time() if feeder_live
                          else float("inf"))
                if qt > t_end and pool_t > t_end and feed_t > t_end:
                    # Everything outstanding is past the horizon — the
                    # external remainder stays pending, like the
                    # queue's own post-horizon events.
                    break
                if not progressed:
                    idle_rounds += 1
                    # One idle round is legal (the absorb/rebalance
                    # runs NEXT iteration); repeated idleness means
                    # the fence can never clear.
                    if idle_rounds >= 3:
                        word = (FAULT_INGEST if feeder_live
                                else FAULT_SPILL_STALL)
                        n_out = (int(pool_seqs.size) if pool_live
                                 else feeder.n - feeder.cursor)
                        raise EngineFaultError(
                            word, new_done,
                            detail=(f"{n_out} external event(s) "
                                    "outstanding but no segment can "
                                    "make progress"),
                        )
                else:
                    idle_rounds = 0
                continue
            if new_done < target:
                # Loop exited before its batch target: drained, horizon,
                # or admission fence with nothing outstanding — all
                # terminal.
                break
        return state, queue, stats, pool_rows, pool_seqs, ingested, shed

    def run(self, state, *, until: float | None = None,
            max_batches: int | None = None,
            max_events: int | None = None,
            events: Sequence | None = None,
            arrivals=None,
            backpressure: str = "block",
            checkpoint_every: int | None = None,
            checkpoint_dir: str | None = None,
            resume_from: int | str | None = None,
            _segment_hook: Callable | None = None,
            _stream_prefetch: bool = True) -> RunResult:
        """Execute until the pending set drains (or a bound trips).

        ``until`` stops before any event later than it runs (identical
        horizon rule on every backend); ``max_batches`` bounds executed
        batches; ``max_events`` bounds executed events (host backends
        only — the device loop counts batches).  ``events`` optionally
        replaces the program's initial schedule for this run, as
        ``(time, type_name_or_id[, arg])`` tuples.

        ``arrivals`` opens the system (DESIGN.md §10): an
        :class:`repro.stream.ArrivalSource` streamed into the run in
        fixed blocks.  The result is bit-identical to pre-seeding the
        same trace (state, executed events, dropped, final_time) as
        long as neither run overflows; arrivals with ``time > until``
        are never consumed.  On the device backend blocks are absorbed
        at segment boundaries under the lex admission fence with
        double-buffered host→device staging; ``backpressure`` picks
        what happens when an admissible arrival finds the queue full:
        ``"block"`` (wait for capacity; a wedged topology raises
        ``FAULT_INGEST``), ``"shed"`` (drop it, counted in
        ``RunResult.shed``) or ``"error"`` (raise immediately).  With
        ``overflow='spill'`` the non-fitting remainder joins the spill
        pool instead (never sheds).  Device streaming requires
        ``queue_mode='tiered3'`` (+ ``queue_kernels='xla'`` on the
        single queue); host backends push the stream into the unbounded
        heap (only ``backpressure='block'`` is meaningful there).

        Device backends additionally run SEGMENTED: ``checkpoint_every=N``
        snapshots the full engine pytree (state, every queue tier, the
        cumulative stats carry) to ``checkpoint_dir`` every N super-steps
        through :class:`repro.checkpoint.manager.CheckpointManager`
        (async + atomic, off the hot path), and ``resume_from=step`` (or
        ``"latest"``) restores one and continues — a resumed run is
        bit-identical to an uninterrupted one because the while-loop
        carry IS the checkpoint (streamed runs snapshot the arrival
        cursor and ingest counters alongside it).  ``_segment_hook(
        seg_index, state, queue, stats)`` is the fault-injection seam:
        called between segments, it may return a replacement ``(state,
        queue, stats)`` triple (tests only).
        """
        t_end = float("inf") if until is None else float(until)
        if backpressure not in ("block", "shed", "error"):
            raise ValueError(
                f"backpressure must be 'block', 'shed' or 'error', "
                f"got {backpressure!r}"
            )
        if arrivals is None and backpressure != "block":
            raise ValueError(
                "backpressure= configures streamed runs — pass "
                "arrivals= as well"
            )
        evs = self._initial_events(events)
        if self.backend == "device":
            if max_events is not None:
                raise ValueError(
                    "max_events is host-only; the device loop counts "
                    "batches — use max_batches"
                )
            return self._run_device(
                state, evs, t_end,
                (1 << 30) if max_batches is None else int(max_batches),
                checkpoint_every=checkpoint_every,
                checkpoint_dir=checkpoint_dir,
                resume_from=resume_from,
                segment_hook=_segment_hook,
                arrivals=arrivals,
                backpressure=backpressure,
                stream_prefetch=_stream_prefetch,
            )
        if (checkpoint_every is not None or checkpoint_dir is not None
                or resume_from is not None or _segment_hook is not None):
            raise ValueError(
                "checkpoint_every/checkpoint_dir/resume_from are "
                "device-backend knobs; the host backend would silently "
                "ignore them — drop them or build with backend='device'"
            )
        if arrivals is not None and backpressure != "block":
            raise ValueError(
                "host backends push the stream into an unbounded heap: "
                "backpressure='shed'/'error' can never trigger there — "
                "use the default 'block' or build backend='device'"
            )
        queue = HostEventQueue()
        for (t, type_id, arg) in evs:
            queue.push(t, type_id, arg)
        n_ingested = 0
        if arrivals is not None:
            # Host iterator path: seeds pushed first (seqs 0..n0-1),
            # then the stream in source order (seqs n0..) — exactly the
            # device reservation discipline, so the heap's (time, seq)
            # total order matches the closed pre-seeded run's.
            arrivals.seek(0)
            for block in arrivals.blocks():
                for row in np.asarray(block, np.float32):
                    if row[1] < 0:
                        continue
                    queue.push(float(row[0]), int(row[1]),
                               normalize_arg(row[2:]))
                    n_ingested += 1
        if self.variant == "unbatched":
            from repro.core.scheduler import run_unbatched

            state, rs = run_unbatched(
                self.program.host_registry(), state, queue,
                jit_handlers=self.jit_handlers,
                max_events=max_events, max_batches=max_batches,
                t_end=t_end,
            )
        else:
            state, rs = self.sched.run(
                state, queue, max_events=max_events,
                max_batches=max_batches, t_end=t_end,
            )
        return RunResult(
            state=state,
            events=rs.events_executed,
            batches=rs.batches_executed,
            dropped=0,
            final_time=float(rs.final_time),
            rollbacks=rs.rollbacks,
            raw=rs,
            ingested=n_ingested,
        )
