"""Core DES engine: compile-time event batching (the paper's contribution).

Model-definition API (preferred — one definition, every runtime):

    from repro.api import SimProgram, Config

Backend layer (schedulers, composers, queues, engines):

    from repro.core import (
        EventRegistry, emits_events, Simulator, DeviceEngine,
        PaperCodec, DenseCodec,
    )
"""

from repro.core.codec import (
    DenseCodec,
    PaperCodec,
    dense_batch_count,
    make_codec,
    paper_batch_count,
    redundant_batch_count,
)
from repro.core.composer import (
    EagerComposer,
    LazyComposer,
    build_switch_dispatcher,
    compose_word_fn,
)
from repro.core.engine import DeviceEngine, Simulator
from repro.core.events import ARG_WIDTH, Event, EventRegistry, EventType, emits_events
from repro.core.program import (
    EMIT_WIDTH,
    CompiledSim,
    Config,
    RunResult,
    SimProgram,
    normalize_arg,
)
from repro.core.queue import (
    DeviceQueue,
    HostEventQueue,
    TieredDeviceQueue,
    device_queue_extract,
    device_queue_extract_ref,
    device_queue_fill_rows,
    device_queue_from_host,
    device_queue_init,
    device_queue_next_time,
    device_queue_next_time_ref,
    device_queue_peek,
    device_queue_pop,
    device_queue_push,
    device_queue_push_rows,
    tiered_queue_extract,
    tiered_queue_fill_rows,
    tiered_queue_from_host,
    tiered_queue_has_pending,
    tiered_queue_init,
    tiered_queue_next_time,
    tiered_queue_occupancy,
    tiered_queue_to_flat,
    window_prefix_mask,
)
from repro.core.scheduler import (
    ConservativeScheduler,
    RunStats,
    SpeculativeScheduler,
    extract_window,
    extract_window_presorted,
    run_unbatched,
)
from repro.core.vectorize import (
    is_single_type_run,
    make_masked_run_handler,
    make_run_handler,
)

__all__ = [
    "ARG_WIDTH",
    "EMIT_WIDTH",
    "CompiledSim",
    "Config",
    "ConservativeScheduler",
    "DenseCodec",
    "DeviceEngine",
    "DeviceQueue",
    "EagerComposer",
    "Event",
    "EventRegistry",
    "EventType",
    "HostEventQueue",
    "LazyComposer",
    "PaperCodec",
    "RunResult",
    "RunStats",
    "SimProgram",
    "Simulator",
    "SpeculativeScheduler",
    "TieredDeviceQueue",
    "build_switch_dispatcher",
    "compose_word_fn",
    "dense_batch_count",
    "device_queue_extract",
    "device_queue_extract_ref",
    "device_queue_fill_rows",
    "device_queue_from_host",
    "device_queue_init",
    "device_queue_next_time",
    "device_queue_next_time_ref",
    "device_queue_peek",
    "device_queue_pop",
    "device_queue_push",
    "device_queue_push_rows",
    "emits_events",
    "extract_window",
    "extract_window_presorted",
    "tiered_queue_extract",
    "tiered_queue_fill_rows",
    "tiered_queue_from_host",
    "tiered_queue_has_pending",
    "tiered_queue_init",
    "tiered_queue_next_time",
    "tiered_queue_occupancy",
    "tiered_queue_to_flat",
    "is_single_type_run",
    "make_codec",
    "normalize_arg",
    "make_masked_run_handler",
    "make_run_handler",
    "window_prefix_mask",
    "paper_batch_count",
    "redundant_batch_count",
    "run_unbatched",
]
