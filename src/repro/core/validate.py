"""On-device invariant auditing for the device engines (DESIGN.md §9).

Two layers, selected by ``DeviceEngine(validate=...)``:

* **cheap** — O(per-batch-work) checks folded into every super-step of
  the ``lax.while_loop``: the functions here return an i32 *fault word*
  (a bit per invariant class) that the engine ORs into its stats carry.
  No host sync, no extra compiled programs — the checks ride the same
  XLA module as the simulation, and the loop's ``cond`` gains
  ``fault_word == 0`` so a corrupted pending set stops the run at the
  first poisoned super-step instead of silently propagating.
* **full** — an O(capacity) cross-tier audit (:func:`full_audit`) run
  host-side at segment boundaries only (the checkpoint cadence), where
  the queue is being snapshotted anyway.  It covers what the cheap
  layer structurally cannot: duplicated seqs across tiers, sortedness
  of every run-log remainder, the cross-tier boundary invariant, and
  occupancy recounted from the raw buffers.

The per-bit meaning is shared by both layers; ``FAULT_NAMES`` is the
wire format surfaced on :class:`repro.api.RunResult` and in
:class:`EngineFaultError`.

Check costs are matched to the queue family they guard: the tiered
fronts get O(front_cap) order/finiteness/seq scans (capacity-
independent, like every tiered per-batch path), while ``flat`` /
``reference`` — whose extraction is already O(capacity) per batch —
get whole-array checks that cannot change their complexity class.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "EngineFaultError",
    "FAULT_NAMES",
    "FAULT_FRONT_ORDER",
    "FAULT_TIME_NONFINITE",
    "FAULT_SEQ_RANGE",
    "FAULT_TIER_COUNTS",
    "FAULT_CONSERVATION",
    "FAULT_CLOCK",
    "FAULT_OVERFLOW",
    "FAULT_SPILL_STALL",
    "FAULT_AUDIT",
    "FAULT_INGEST",
    "fault_names",
    "full_audit",
]

# Packed fault-word layout (i32).  Bits are sticky: once set in the
# while-loop carry they survive to the host.  The faulting super-step
# is NOT carried on device — the loop guard freezes on a nonzero word,
# so the engine reconstructs it from the batch counter at exit.
FAULT_FRONT_ORDER = 1      # front/flat tier not (time, seq)-sorted
FAULT_TIME_NONFINITE = 2   # NaN/inf timestamp on an occupied slot
FAULT_SEQ_RANGE = 4        # occupied seq >= next_seq (counter bound)
FAULT_TIER_COUNTS = 8      # tier counter outside its structural range
FAULT_CONSERVATION = 16    # occupancy(+dropped) != size
FAULT_CLOCK = 32           # window head precedes the committed clock
FAULT_OVERFLOW = 64        # overflow='error' tripped (dropped > 0)
FAULT_SPILL_STALL = 128    # spill held host-side but no room to absorb
FAULT_AUDIT = 256          # full cross-tier audit finding (host-side)
FAULT_INGEST = 512         # arrival stream stalled (backpressure) or
                           # rejected (backpressure='error'), host-side

FAULT_NAMES = {
    FAULT_FRONT_ORDER: "front_order",
    FAULT_TIME_NONFINITE: "time_nonfinite",
    FAULT_SEQ_RANGE: "seq_range",
    FAULT_TIER_COUNTS: "tier_counts",
    FAULT_CONSERVATION: "conservation",
    FAULT_CLOCK: "clock_regression",
    FAULT_OVERFLOW: "overflow",
    FAULT_SPILL_STALL: "spill_stall",
    FAULT_AUDIT: "full_audit",
    FAULT_INGEST: "ingest_stall",
}


def fault_names(word: int) -> list[str]:
    """Decode a fault word into its invariant names (LSB first)."""
    return [name for bit, name in sorted(FAULT_NAMES.items())
            if int(word) & bit]


class EngineFaultError(RuntimeError):
    """A run tripped an engine invariant (or the overflow='error' /
    spill policies could not proceed).  ``fault_word`` is the packed
    bit set, ``fault_step`` the super-step that first set it (-1 when
    detected host-side between segments), ``faults`` the decoded
    names."""

    def __init__(self, fault_word: int, fault_step: int = -1,
                 detail: str = ""):
        self.fault_word = int(fault_word)
        self.fault_step = int(fault_step)
        self.faults = fault_names(fault_word)
        where = (f" at super-step {self.fault_step}"
                 if self.fault_step >= 0 else "")
        msg = (f"engine invariant violated{where}: "
               f"{', '.join(self.faults) or hex(self.fault_word)}")
        if detail:
            msg += f" ({detail})"
        super().__init__(msg)


# ---------------------------------------------------------------------------
# Cheap per-super-step checks (traced; return an i32 fault word)
# ---------------------------------------------------------------------------

def _bit(pred, bit):
    return jnp.where(pred, jnp.int32(bit), jnp.int32(0))


def _lex_sorted_bits(times, seqs, occ_n):
    """FRONT_ORDER bit for a canonical occupied-prefix layout: every
    adjacent occupied pair must ascend under (time, seq).  NaNs fail
    every comparison, so a poisoned slot also trips this bit."""
    i = jnp.arange(times.shape[0] - 1, dtype=jnp.int32)
    pair_occ = (i + 1) < occ_n
    t0, t1 = times[:-1], times[1:]
    s0, s1 = seqs[:-1], seqs[1:]
    ok = (t0 < t1) | ((t0 == t1) & (s0 < s1))
    return _bit(jnp.any(pair_occ & ~ok), FAULT_FRONT_ORDER)


def _occupied_slot_bits(times, seqs, occ_mask, next_seq):
    bits = _bit(jnp.any(occ_mask & ~jnp.isfinite(times)),
                FAULT_TIME_NONFINITE)
    bits |= _bit(jnp.any(occ_mask & (seqs >= next_seq)), FAULT_SEQ_RANGE)
    return bits


def tiered3_fault_bits(q, *, local: bool) -> jnp.ndarray:
    """Cheap fault word for one :class:`Tiered3DeviceQueue` —
    O(front_cap + num_runs), the same bound as every tiered3 per-batch
    path.  ``local=True`` applies the occupancy conservation discipline
    of shard-local / spill-mode queues (``size`` == real occupancy,
    ``dropped`` == 0); ``local=False`` the single-queue reference rule
    (``size`` counts ghosts: occupancy + dropped == size).

    This runs EVERY super-step inside the while-loop body, where each
    kernel launch on a small array costs more than its arithmetic, so
    the whole check compiles to TWO reductions: one fused max over
    per-slot fault words covering the front (order / finiteness / seq
    bounds — built from slices of the same arrays, which fuse into the
    reduce producer; no concatenation materializes), and one sum over
    the run pool whose per-run live counts are POISONED when a run's
    offsets are structurally invalid, so a bad run surfaces through the
    conservation equation.  Two coarsenings follow, both covered by the
    exact host-side :func:`full_audit`: (a) max is not bitwise-OR
    across slots — when different slots violate different invariants in
    one super-step only the larger word is named (any violation is
    still a nonzero word), and (b) a structurally-bad run reports
    ``conservation`` rather than ``tier_counts``."""
    F, S = q.front_cap, q.stage_cap
    t, s = q.f_times, q.f_seqs
    i = jnp.arange(F - 1, dtype=jnp.int32)
    occ_i = i < q.front_n          # slot i occupied
    pair_occ = (i + 1) < q.front_n  # slots i, i+1 both occupied
    t0, t1 = t[:-1], t[1:]
    s0, s1 = s[:-1], s[1:]
    pair_ok = (t0 < t1) | ((t0 == t1) & (s0 < s1))
    word = jnp.where(pair_occ & ~pair_ok,
                     jnp.int32(FAULT_FRONT_ORDER), jnp.int32(0))
    word |= jnp.where(occ_i & ~jnp.isfinite(t0),
                      jnp.int32(FAULT_TIME_NONFINITE), jnp.int32(0))
    word |= jnp.where(occ_i & (s0 >= q.next_seq),
                      jnp.int32(FAULT_SEQ_RANGE), jnp.int32(0))
    bits = jnp.max(word)
    # the F-1'th slot has no successor pair; its slot checks are scalar
    last_occ = q.front_n >= F
    bits |= _bit(last_occ & ~jnp.isfinite(t[F - 1]), FAULT_TIME_NONFINITE)
    bits |= _bit(last_occ & (s[F - 1] >= q.next_seq), FAULT_SEQ_RANGE)

    live = q.r_len - q.r_off
    run_bad = (q.r_off < 0) | (live < 0) | (q.r_len > S)
    # poison makes the occupancy sum exceed any reachable size, so a
    # corrupt run pool cannot cancel back to a conserved total
    occ = (q.front_n + q.stage_n + q.main_n
           + jnp.sum(jnp.where(run_bad, jnp.int32(1 << 24), live))
           .astype(jnp.int32))
    counts_ok = (
        (q.front_n >= 0) & (q.front_n <= F)
        & (q.stage_n >= 0) & (q.stage_n <= S)
        & (q.main_n >= 0) & (q.main_n <= q.main_phys)
    )
    bits |= _bit(~counts_ok, FAULT_TIER_COUNTS)
    conserved = (occ == q.size) if local else (occ + q.dropped == q.size)
    bits |= _bit(~conserved, FAULT_CONSERVATION)
    return bits


def tiered_fault_bits(q) -> jnp.ndarray:
    """Cheap fault word for a two-tier :class:`TieredDeviceQueue`."""
    F, S = q.front_cap, q.stage_cap
    occ_f = jnp.arange(F, dtype=jnp.int32) < q.front_n
    bits = _lex_sorted_bits(q.f_times, q.f_seqs, q.front_n)
    bits |= _occupied_slot_bits(q.f_times, q.f_seqs, occ_f, q.next_seq)
    counts_ok = (
        (q.front_n >= 0) & (q.front_n <= F)
        & (q.stage_n >= 0) & (q.stage_n <= S)
        & (q.main_n >= 0) & (q.main_n <= q.m_times.shape[0])
    )
    bits |= _bit(~counts_ok, FAULT_TIER_COUNTS)
    occ = q.front_n + q.stage_n + q.main_n
    bits |= _bit(occ + q.dropped != q.size, FAULT_CONSERVATION)
    return bits


def flat_fault_bits(q, *, sorted_layout: bool) -> jnp.ndarray:
    """Cheap fault word for a flat :class:`DeviceQueue`.  O(capacity),
    matching the flat/reference per-batch extraction cost.
    ``sorted_layout=False`` (the reference queue) skips the order
    check — its slot placement is legitimately unsorted."""
    occ = q.types >= 0
    n_occ = jnp.sum(occ).astype(jnp.int32)
    bits = jnp.int32(0)
    if sorted_layout:
        # Canonical layout: occupied prefix, sorted.
        bits |= _lex_sorted_bits(q.times, q.seqs, n_occ)
        prefix_ok = ~jnp.any(occ & (jnp.cumsum(~occ) > 0))
        bits |= _bit(~prefix_ok, FAULT_TIER_COUNTS)
    bits |= _occupied_slot_bits(q.times, q.seqs, occ, q.next_seq)
    bits |= _bit(n_occ + q.dropped != q.size, FAULT_CONSERVATION)
    return bits


def sharded_fault_bits(sq) -> jnp.ndarray:
    """Cheap fault word for a :class:`ShardedQueue`: each shard audited
    under the local discipline, plus the GLOBAL conservation law
    Σ occupancy_i + dropped == size."""
    from repro.core.queue import tiered3_queue_occupancy

    bits = jnp.int32(0)
    total_occ = jnp.int32(0)
    for q in sq.shards:
        bits |= tiered3_fault_bits(q, local=True)
        total_occ = total_occ + tiered3_queue_occupancy(q)
    bits |= _bit(total_occ + sq.dropped != sq.size, FAULT_CONSERVATION)
    return bits


# ---------------------------------------------------------------------------
# Full cross-tier audit (host-side, segment boundaries only)
# ---------------------------------------------------------------------------

def _audit_columns(findings, label, times, seqs, *, expect_sorted):
    if times.size == 0:
        return
    if not np.all(np.isfinite(times)):
        findings.append((FAULT_TIME_NONFINITE,
                         f"{label}: non-finite timestamp"))
    if expect_sorted and times.size > 1:
        t0, t1 = times[:-1], times[1:]
        s0, s1 = seqs[:-1], seqs[1:]
        if not np.all((t0 < t1) | ((t0 == t1) & (s0 < s1))):
            findings.append((FAULT_FRONT_ORDER,
                             f"{label}: not (time, seq)-sorted"))


def _tiered3_live_columns(q):
    """(label, times, seqs) per live tier region of a tiered3 queue."""
    off = np.asarray(q.r_off)
    rlen = np.asarray(q.r_len)
    head, main_n = int(q.m_head), int(q.main_n)
    fn = int(q.front_n)
    sn = int(q.stage_n)
    regions = [
        ("front", np.asarray(q.f_times)[:fn], np.asarray(q.f_seqs)[:fn],
         True),
        ("staging", np.asarray(q.s_times)[:sn], np.asarray(q.s_seqs)[:sn],
         False),
        ("main", np.asarray(q.m_times)[head:head + main_n],
         np.asarray(q.m_seqs)[head:head + main_n], True),
    ]
    for i in range(q.num_runs):
        regions.append((
            f"run[{i}]",
            np.asarray(q.r_times)[i, off[i]:rlen[i]],
            np.asarray(q.r_seqs)[i, off[i]:rlen[i]],
            True,
        ))
    return regions


def _audit_tiered3(q, findings, *, local: bool):
    F, S = q.front_cap, q.stage_cap
    fn, sn = int(q.front_n), int(q.stage_n)
    off, rlen = np.asarray(q.r_off), np.asarray(q.r_len)
    if not (0 <= fn <= F and 0 <= sn <= S and 0 <= int(q.main_n)
            and np.all((off >= 0) & (off <= rlen) & (rlen <= S))):
        findings.append((FAULT_TIER_COUNTS,
                         "tier counter outside structural range"))
        return  # slicing below would be ill-defined
    regions = _tiered3_live_columns(q)
    for label, times, seqs, expect_sorted in regions:
        _audit_columns(findings, label, times, seqs,
                       expect_sorted=expect_sorted)
    all_seqs = np.concatenate([r[2] for r in regions]) if regions else \
        np.zeros((0,), np.int32)
    if all_seqs.size and np.unique(all_seqs).size != all_seqs.size:
        findings.append((FAULT_SEQ_RANGE, "duplicated seq across tiers"))
    if all_seqs.size and int(all_seqs.max()) >= int(q.next_seq):
        findings.append((FAULT_SEQ_RANGE,
                         "queued seq >= next_seq counter"))
    # Cross-tier boundary invariant: max(front) <= min(everything else)
    # under the lexicographic key.
    front = regions[0]
    rest = [(t[i], s[i]) for _, t, s, _ in regions[1:]
            for i in range(t.size)]
    if fn and rest:
        fmax = (float(front[1][-1]), int(front[2][-1]))
        rmin = min(rest)
        if fmax > rmin:
            findings.append((FAULT_FRONT_ORDER,
                             f"tier boundary inverted: front max {fmax} "
                             f"> rest min {rmin}"))
    occ = sum(r[1].size for r in regions)
    expect = int(q.size) if local else int(q.size) - int(q.dropped)
    if occ != expect:
        findings.append((FAULT_CONSERVATION,
                         f"occupancy {occ} != expected {expect} "
                         f"(size {int(q.size)}, dropped "
                         f"{int(q.dropped)})"))


def full_audit(queue, *, local: bool = False) -> list[tuple[int, str]]:
    """O(capacity) cross-tier audit of a pending set; returns findings
    as ``(fault_bit, message)``.  Accepts a single tiered3 queue, a
    :class:`~repro.core.sharded.ShardedQueue`, or a flat/tiered queue
    (reduced checks).  Host-side — call at segment boundaries only."""
    findings: list[tuple[int, str]] = []
    if hasattr(queue, "shards") and not hasattr(queue, "f_times"):
        total_occ = 0
        for i, q in enumerate(queue.shards):
            shard_findings: list[tuple[int, str]] = []
            _audit_tiered3(q, shard_findings, local=True)
            findings.extend((bit, f"shard {i}: {msg}")
                            for bit, msg in shard_findings)
            total_occ += sum(
                r[1].size for r in _tiered3_live_columns(q))
        if total_occ + int(queue.dropped) != int(queue.size):
            findings.append((
                FAULT_CONSERVATION,
                f"global occupancy {total_occ} + dropped "
                f"{int(queue.dropped)} != size {int(queue.size)}"))
        return findings
    if hasattr(queue, "r_times"):
        _audit_tiered3(queue, findings, local=local)
        return findings
    if hasattr(queue, "f_times"):  # two-tier
        _audit_columns(findings, "front",
                       np.asarray(queue.f_times)[:int(queue.front_n)],
                       np.asarray(queue.f_seqs)[:int(queue.front_n)],
                       expect_sorted=True)
        occ = int(queue.front_n) + int(queue.stage_n) + int(queue.main_n)
        if occ + int(queue.dropped) != int(queue.size):
            findings.append((FAULT_CONSERVATION,
                             f"occupancy {occ} + dropped != size"))
        return findings
    # flat / reference
    occ_mask = np.asarray(queue.types) >= 0
    times = np.asarray(queue.times)[occ_mask]
    seqs = np.asarray(queue.seqs)[occ_mask]
    if times.size and not np.all(np.isfinite(times)):
        findings.append((FAULT_TIME_NONFINITE,
                         "flat: non-finite timestamp"))
    if seqs.size and np.unique(seqs).size != seqs.size:
        findings.append((FAULT_SEQ_RANGE, "flat: duplicated seq"))
    if int(occ_mask.sum()) + int(queue.dropped) != int(queue.size):
        findings.append((FAULT_CONSERVATION,
                         "flat: occupancy + dropped != size"))
    return findings


def raise_on_findings(findings, *, step: int = -1):
    """Collapse :func:`full_audit` findings into one typed error."""
    if not findings:
        return
    word = FAULT_AUDIT
    for bit, _ in findings:
        word |= bit
    detail = "; ".join(msg for _, msg in findings)
    raise EngineFaultError(word, step, detail)
