"""Pending-event set: host binary heap + device-resident array queue.

The paper's runtime mechanism reads the set of future events in
non-decreasing timestamp order (§III-B).  Two implementations:

* :class:`HostEventQueue` — a classic binary heap over
  :class:`repro.core.events.Event`, used by the paper-faithful host
  scheduler and by the serving engine's host control plane.

* :class:`DeviceEventQueue` — a fixed-capacity struct-of-arrays queue
  whose operations are pure jnp (usable inside ``lax.while_loop``), used
  by the fully on-device scheduler.  Pop is a masked argmin (O(capacity)
  on the VPU — for the queue sizes of interest this is cheaper on TPU
  than maintaining heap order with data-dependent scatters, and it has
  no host round-trips).  Ties on the timestamp are broken by insertion
  sequence number for deterministic, schedule-order execution.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.events import ARG_WIDTH, Event

_INF = jnp.float32(jnp.inf)
_I32_MAX = jnp.int32(2**31 - 1)


class HostEventQueue:
    """Binary heap of Events keyed by (time, seq)."""

    def __init__(self):
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = 0
        self.push_count = 0
        self.pop_count = 0

    def push(self, time: float, type_id: int, arg: Any = None) -> Event:
        ev = Event(time=float(time), type_id=int(type_id), arg=arg, seq=self._seq)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._seq += 1
        self.push_count += 1
        return ev

    def push_event(self, ev: Event) -> None:
        ev = dataclasses.replace(ev, seq=self._seq)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._seq += 1
        self.push_count += 1

    def pop(self) -> Event:
        self.pop_count += 1
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Event:
        return self._heap[0][2]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class DeviceQueue(NamedTuple):
    """Struct-of-arrays pending-event set (a JAX pytree).

    ``types == -1`` marks a free slot.  ``seq`` is the global insertion
    counter used for deterministic tie-breaking.
    """

    times: jnp.ndarray   # f32[capacity]
    types: jnp.ndarray   # i32[capacity], -1 = empty
    args: jnp.ndarray    # f32[capacity, ARG_WIDTH]
    seqs: jnp.ndarray    # i32[capacity]
    size: jnp.ndarray    # i32 scalar
    next_seq: jnp.ndarray  # i32 scalar

    @property
    def capacity(self) -> int:
        return self.times.shape[0]


def device_queue_init(capacity: int, arg_width: int = ARG_WIDTH) -> DeviceQueue:
    return DeviceQueue(
        times=jnp.full((capacity,), jnp.inf, jnp.float32),
        types=jnp.full((capacity,), -1, jnp.int32),
        args=jnp.zeros((capacity, arg_width), jnp.float32),
        seqs=jnp.full((capacity,), 2**31 - 1, jnp.int32),
        size=jnp.int32(0),
        next_seq=jnp.int32(0),
    )


def device_queue_push(q: DeviceQueue, time, type_id, arg) -> DeviceQueue:
    """Insert one event into the first free slot (pure jnp).

    If the queue is full the event is dropped and ``size`` still
    increments past capacity so callers can detect overflow; the engine
    asserts on it in debug runs.
    """
    occupied = q.types >= 0
    # argmin over the boolean mask finds the first False (free) slot.
    slot = jnp.argmin(occupied)
    have_room = q.size < q.capacity
    time = jnp.asarray(time, jnp.float32)
    type_id = jnp.asarray(type_id, jnp.int32)
    arg = jnp.asarray(arg, jnp.float32)

    def do_push(q):
        return DeviceQueue(
            times=q.times.at[slot].set(time),
            types=q.types.at[slot].set(type_id),
            args=q.args.at[slot].set(arg),
            seqs=q.seqs.at[slot].set(q.next_seq),
            size=q.size + 1,
            next_seq=q.next_seq + 1,
        )

    def overflow(q):
        return q._replace(size=q.size + 1, next_seq=q.next_seq + 1)

    return jax.lax.cond(have_room, do_push, overflow, q)


def device_queue_push_rows(q: DeviceQueue, rows) -> DeviceQueue:
    """Insert a fixed-size block of emit rows ``f32[R, 2+W]``.

    Row layout is ``(time, type, arg...)``; ``type < 0`` rows are
    skipped.  Used by the on-device engine to apply a batch's deferred
    emissions (paper §IV.D) in one pass.
    """
    def body(i, q):
        row = rows[i]
        t, ty = row[0], row[1].astype(jnp.int32)
        return jax.lax.cond(
            ty >= 0,
            lambda q: device_queue_push(q, t, ty, row[2:]),
            lambda q: q,
            q,
        )

    return jax.lax.fori_loop(0, rows.shape[0], body, q)


def _min_key_slot(q: DeviceQueue):
    """Index of the occupied slot with lexicographic-min (time, seq)."""
    occupied = q.types >= 0
    times = jnp.where(occupied, q.times, jnp.inf)
    tmin = jnp.min(times)
    at_min = occupied & (times == tmin)
    seqs = jnp.where(at_min, q.seqs, _I32_MAX)
    slot = jnp.argmin(seqs)
    return slot, tmin


def device_queue_peek(q: DeviceQueue):
    """(time, type, slot) of the earliest event; type=-1 when empty."""
    slot, tmin = _min_key_slot(q)
    empty = q.size <= 0
    t = jnp.where(empty, _INF, tmin)
    ty = jnp.where(empty, jnp.int32(-1), q.types[slot])
    return t, ty, slot


def device_queue_pop(q: DeviceQueue):
    """Remove and return the earliest event.

    Returns ``(q', time, type, arg)``; when empty, type is -1 and the
    queue is unchanged.
    """
    t, ty, slot = device_queue_peek(q)
    arg = q.args[slot]
    nonempty = ty >= 0

    def do_pop(q):
        return DeviceQueue(
            times=q.times.at[slot].set(jnp.inf),
            types=q.types.at[slot].set(-1),
            args=q.args,
            seqs=q.seqs.at[slot].set(2**31 - 1),
            size=q.size - 1,
            next_seq=q.next_seq,
        )

    q = jax.lax.cond(nonempty, do_pop, lambda q: q, q)
    return q, t, ty, arg
