"""Pending-event set: host binary heap + device-resident array queue.

The paper's runtime mechanism reads the set of future events in
non-decreasing timestamp order (§III-B).  Two implementations:

* :class:`HostEventQueue` — a classic binary heap over
  :class:`repro.core.events.Event`, used by the paper-faithful host
  scheduler and by the serving engine's host control plane.

* :class:`DeviceEventQueue` — a fixed-capacity struct-of-arrays queue
  whose operations are pure jnp (usable inside ``lax.while_loop``), used
  by the fully on-device scheduler.

Device queue layout
-------------------
``types == -1`` marks a free slot, and free slots always hold the
sentinel key ``(time=+inf, seq=i32_max)`` so they order after every real
event.  ``seq`` is the global insertion counter used for deterministic
``(time, seq)`` lexicographic pop order.  ``size`` counts *logical*
pushes (it keeps incrementing past ``capacity`` on overflow so callers
can detect it); ``dropped`` counts events lost to overflow.

Two families of operations are provided:

* **Reference ops** (seed semantics, layout-independent, O(capacity)
  work *per event* with a serial dependence chain):
  :func:`device_queue_peek`, :func:`device_queue_pop`,
  :func:`device_queue_push`, :func:`device_queue_push_rows`,
  :func:`device_queue_extract_ref`.  Pop is a masked argmin; push is a
  first-free-slot scatter.  Kept as the executable specification for
  differential tests.

* **Vectorized single-pass ops**, which require and preserve the
  *canonical layout*: occupied slots form a prefix of the arrays,
  ordered by ``(time, seq)`` (:func:`device_queue_from_host` builds it;
  an empty queue has it trivially).  With the pending set kept sorted,
  every per-batch interaction is a constant number of fused
  data-parallel passes — no sorts, no reductions, no serial chains:

  - :func:`device_queue_extract` reads the lookahead window directly
    from the first ``max_batch_len`` slots, evaluates the §III-B
    dynamic-lookahead take rule as a shifted ``cummin`` + prefix mask
    (:func:`window_prefix_mask` — the rule is monotone on time-sorted
    candidates, so no serial scan is needed), and pops all taken slots
    by shifting each column left with one ``dynamic_slice``.

  - :func:`device_queue_fill_rows` merges a whole emit block at once:
    merge positions come from all-pairs key comparisons
    (rows × capacity fused bools, a counting merge), and each column is
    rebuilt with a single gather/select pass.

  Both reproduce the reference ops' ``(time, seq)`` pop order and
  overflow behaviour bit-exactly; the two families must not be
  interleaved on one queue (the reference pushes do not maintain the
  canonical layout).

* **Tiered ops** (DESIGN.md §4) over :class:`TieredDeviceQueue`, which
  splits the pending set into a small sorted *front* tier (the globally
  earliest events), an unsorted *staging* ring, and the capacity-sized
  sorted *main* array, with the invariant ``max(front) <= min(staging
  ∪ main)`` under the ``(time, seq)`` key.  Per-batch work touches only
  the front and staging tiers — O(front_cap) regardless of capacity:

  - :func:`tiered_queue_extract` reads the window from the front tier
    (same shifted-cummin take rule); when the front has drained below
    ``max_len`` it first refills from the main array (a rare
    ``lax.cond`` path, amortized to ~zero per batch).

  - :func:`tiered_queue_fill_rows` counting-merges emit rows whose
    timestamp precedes the tier boundary into the front (evicting the
    front tail to staging when full) and appends the rest to staging;
    staging is bulk-merged into the main array only when it could
    overflow on the next batch or the front drains.

  The tiered ops reproduce the flat/reference ``(time, seq)`` pop order
  and the ``size``/``next_seq``/``dropped`` accounting bit-exactly;
  the logical capacity of the whole tiered queue equals the main
  array's capacity (front and staging are structure, not extra room).

* **Log-structured tiered ops** (DESIGN.md §4.4) over
  :class:`Tiered3DeviceQueue`: the two-tier design's one remaining
  O(capacity) path — the staging flush's lex merge + ring compaction,
  which near-full workloads with near-head re-emits hit every few
  batches — is replaced by a pool of fixed-size **sorted runs**:

  - a staging flush lex-sorts the ring and writes it as one new run
    (O(stage_cap²) fused bools + one row scatter, capacity-independent);

  - a front refill is a *bounded* k-way merge: the first ``front_cap``
    remainder elements of every run plus the main head window are
    lex-sorted by their true ``(time, seq)`` keys and the earliest
    slots are consumed by advancing per-run offsets — O(num_runs ·
    front_cap) work, no put-back, no tag bookkeeping (true seqs make
    the order exact, so the two-tier ``s_evict`` machinery disappears);

  - only when the run pool is exhausted do the runs merge into the
    main array, and the main ring carries ``num_runs × stage_cap``
    physical slack slots so that merge is usually a bounded tail
    append — the O(capacity) rotate+merge compaction fires only when
    the slack is gone, amortized over an entire pool of staged events
    and never on the per-batch path.

  Same bit-exact contract and logical-capacity rule as the other
  families (``capacity`` excludes the slack; front/staging/runs are
  structure, not room).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import ARG_WIDTH, Event

_INF = jnp.float32(jnp.inf)
_I32_MAX = jnp.int32(2**31 - 1)


class HostEventQueue:
    """Binary heap of Events keyed by (time, seq)."""

    def __init__(self):
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = 0
        self.push_count = 0
        self.pop_count = 0

    def push(self, time: float, type_id: int, arg: Any = None) -> Event:
        ev = Event(time=float(time), type_id=int(type_id), arg=arg, seq=self._seq)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._seq += 1
        self.push_count += 1
        return ev

    def push_event(self, ev: Event) -> None:
        """Re-insert an existing event, PRESERVING its seq.

        Used by speculative rollback: re-pushed events must keep their
        original tie-break rank, otherwise they would sort after
        same-timestamp events that were never extracted and execution
        order would diverge from the sequential one.
        """
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._seq = max(self._seq, ev.seq + 1)
        self.push_count += 1

    def pop(self) -> Event:
        self.pop_count += 1
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Event:
        return self._heap[0][2]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class DeviceQueue(NamedTuple):
    """Struct-of-arrays pending-event set (a JAX pytree).

    ``types == -1`` marks a free slot.  ``seq`` is the global insertion
    counter used for deterministic tie-breaking.  ``dropped`` counts
    events lost to capacity overflow (surfaced in the engine run stats).
    """

    times: jnp.ndarray   # f32[capacity]
    types: jnp.ndarray   # i32[capacity], -1 = empty
    args: jnp.ndarray    # f32[capacity, ARG_WIDTH]
    seqs: jnp.ndarray    # i32[capacity]
    size: jnp.ndarray    # i32 scalar
    next_seq: jnp.ndarray  # i32 scalar
    dropped: jnp.ndarray   # i32 scalar, overflow-dropped event count

    @property
    def capacity(self) -> int:
        return self.times.shape[0]


def device_queue_init(capacity: int, arg_width: int = ARG_WIDTH) -> DeviceQueue:
    return DeviceQueue(
        times=jnp.full((capacity,), jnp.inf, jnp.float32),
        types=jnp.full((capacity,), -1, jnp.int32),
        args=jnp.zeros((capacity, arg_width), jnp.float32),
        seqs=jnp.full((capacity,), 2**31 - 1, jnp.int32),
        size=jnp.int32(0),
        next_seq=jnp.int32(0),
        dropped=jnp.int32(0),
    )


def _host_sorted_seed(events, capacity: int, arg_width: int, seqs=None):
    """Shared host-side seed build: the surviving events as columns
    sorted by ``(time, seq)``, plus the logical counters.

    Semantically identical to serial reference pushes — ``seq`` runs
    0..N-1 and events past ``capacity`` are dropped with
    ``size``/``next_seq`` still advancing.  Both ``*_from_host``
    builders split these columns into their own layouts, so the
    reference overflow/seq semantics live in exactly one place.

    ``seqs`` optionally supplies explicit per-event seqs (the sharded
    engine seeds each shard with its slice of the GLOBAL seed, keeping
    the global seq discipline); explicit-seq seeds must fit — the
    global overflow rule was already applied upstream.
    """
    events = list(events)
    n = len(events)
    if seqs is not None:
        if len(seqs) != n:
            raise ValueError(
                f"{len(seqs)} explicit seqs for {n} seed events"
            )
        if n > capacity:
            raise ValueError(
                f"explicit-seq seed of {n} events exceeds capacity "
                f"{capacity}: apply the overflow rule before sharding"
            )
    m = min(n, capacity)
    times = np.full((m,), np.inf, np.float32)
    types = np.full((m,), -1, np.int32)
    args = np.zeros((m, arg_width), np.float32)
    seq_col = np.zeros((m,), np.int32)
    for i, (t, ty, arg) in enumerate(events[:m]):
        times[i] = t
        types[i] = ty
        if arg is not None:
            args[i] = np.asarray(arg, np.float32)
        seq_col[i] = i if seqs is None else int(seqs[i])
    order = np.lexsort((seq_col, times))
    return (times[order], types[order], args[order], seq_col[order], n, m)


def device_queue_from_host(
    events, capacity: int, arg_width: int = ARG_WIDTH
) -> DeviceQueue:
    """Build a seed queue host-side and move it in ONE device_put.

    ``events`` is a sequence of ``(time, type_id, arg)`` with ``arg``
    either ``None`` or an ``f32[arg_width]`` vector.  Semantically
    identical to ``device_queue_push`` applied in order — slot ``i``
    holds event ``i``, ``seq`` runs 0..N-1, events past ``capacity``
    are dropped with ``size``/``next_seq`` still advancing — but costs
    one transfer instead of N jitted dispatches.

    Canonical layout (see module docstring): occupied slots form a
    prefix sorted by (time, seq).  The reference ops are
    layout-independent; the vectorized ops require and preserve it.
    """
    st, sy, sa, ss, n, m = _host_sorted_seed(events, capacity, arg_width)
    times = np.full((capacity,), np.inf, np.float32)
    types = np.full((capacity,), -1, np.int32)
    args = np.zeros((capacity, arg_width), np.float32)
    seqs = np.full((capacity,), 2**31 - 1, np.int32)
    times[:m], types[:m], args[:m], seqs[:m] = st, sy, sa, ss
    return jax.device_put(DeviceQueue(
        times=times,
        types=types,
        args=args,
        seqs=seqs,
        size=np.int32(n),
        next_seq=np.int32(n),
        dropped=np.int32(n - m),
    ))


# ---------------------------------------------------------------------------
# Reference per-event ops (seed semantics; executable specification)
# ---------------------------------------------------------------------------

def device_queue_push(q: DeviceQueue, time, type_id, arg) -> DeviceQueue:
    """Insert one event into the first free slot (pure jnp).

    If the queue is full the event is dropped, the ``dropped`` counter
    increments, and ``size``/``next_seq`` still advance so callers can
    detect overflow (the engine surfaces ``dropped`` in its run stats).
    """
    occupied = q.types >= 0
    # argmin over the boolean mask finds the first False (free) slot.
    slot = jnp.argmin(occupied)
    have_room = q.size < q.capacity
    time = jnp.asarray(time, jnp.float32)
    type_id = jnp.asarray(type_id, jnp.int32)
    arg = jnp.asarray(arg, jnp.float32)

    def do_push(q):
        return q._replace(
            times=q.times.at[slot].set(time),
            types=q.types.at[slot].set(type_id),
            args=q.args.at[slot].set(arg),
            seqs=q.seqs.at[slot].set(q.next_seq),
            size=q.size + 1,
            next_seq=q.next_seq + 1,
        )

    def overflow(q):
        return q._replace(
            size=q.size + 1, next_seq=q.next_seq + 1, dropped=q.dropped + 1
        )

    return jax.lax.cond(have_room, do_push, overflow, q)


def device_queue_push_rows_serial(q: DeviceQueue, rows) -> DeviceQueue:
    """Seed bulk insert: one serial ``device_queue_push`` per row.

    Row layout is ``(time, type, arg...)``; ``type < 0`` rows are
    skipped.  O(rows × capacity) with a serial dependence chain — kept
    as the executable specification for :func:`device_queue_push_rows`
    and :func:`device_queue_fill_rows` (differential tests prove both
    bit-identical to this, the push-rows one including slot placement).
    """
    def body(i, q):
        row = rows[i]
        t, ty = row[0], row[1].astype(jnp.int32)
        return jax.lax.cond(
            ty >= 0,
            lambda q: device_queue_push(q, t, ty, row[2:]),
            lambda q: q,
            q,
        )

    return jax.lax.fori_loop(0, rows.shape[0], body, q)


def device_queue_push_rows(q: DeviceQueue, rows) -> DeviceQueue:
    """Reference bulk insert as ONE scatter pass (layout-independent).

    Bit-identical to :func:`device_queue_push_rows_serial` INCLUDING
    slot placement: serial pushes fill free slots in ascending slot
    order, so the row with insert-rank ``k`` lands in the ``k``-th free
    slot — all destinations are known up front and every column is one
    ``R``-row scatter instead of ``R`` chained O(capacity) argmin/cond
    rounds.  Valid row ``r`` gets ``seq = next_seq + vrank(r)`` and is
    dropped iff ``size + vrank(r) >= capacity`` (``size`` counts ghosts
    — the serial ``have_room`` check at the moment row ``r`` pushes),
    with ``size``/``next_seq`` still advancing and ``dropped`` counted.
    """
    rows = jnp.asarray(rows, jnp.float32)
    C = q.capacity
    t_r = rows[:, 0]
    ty_r = rows[:, 1].astype(jnp.int32)
    arg_r = rows[:, 2:]

    valid = ty_r >= 0
    vrank = _prefix_rank(valid)
    num_valid = jnp.sum(valid).astype(jnp.int32)
    insert = valid & (q.size + vrank < C)
    num_insert = jnp.sum(insert).astype(jnp.int32)
    seq_r = q.next_seq + vrank

    # k-th free slot: rank the free slots by cumsum, invert by scatter.
    # `size >= occupancy` guarantees every inserting row finds a free
    # slot (insert-rank < C - size <= number of free slots).
    free = q.types < 0
    free_rank = jnp.cumsum(free).astype(jnp.int32) - 1
    slot_of_rank = jnp.full((C,), C, jnp.int32).at[
        jnp.where(free, free_rank, C)
    ].set(jnp.arange(C, dtype=jnp.int32), mode="drop")
    irank = _prefix_rank(insert)
    dest = jnp.where(
        insert, slot_of_rank[jnp.clip(irank, 0, C - 1)], C
    )

    return q._replace(
        times=q.times.at[dest].set(t_r, mode="drop"),
        types=q.types.at[dest].set(ty_r, mode="drop"),
        args=q.args.at[dest].set(arg_r, mode="drop"),
        seqs=q.seqs.at[dest].set(seq_r, mode="drop"),
        size=q.size + num_valid,
        next_seq=q.next_seq + num_valid,
        dropped=q.dropped + (num_valid - num_insert),
    )


def _min_key_slot(q: DeviceQueue):
    """Index of the occupied slot with lexicographic-min (time, seq)."""
    occupied = q.types >= 0
    times = jnp.where(occupied, q.times, jnp.inf)
    tmin = jnp.min(times)
    at_min = occupied & (times == tmin)
    seqs = jnp.where(at_min, q.seqs, _I32_MAX)
    slot = jnp.argmin(seqs)
    return slot, tmin


def device_queue_peek(q: DeviceQueue):
    """(time, type, slot) of the earliest event; type=-1 when empty."""
    slot, tmin = _min_key_slot(q)
    empty = q.size <= 0
    t = jnp.where(empty, _INF, tmin)
    ty = jnp.where(empty, jnp.int32(-1), q.types[slot])
    return t, ty, slot


def device_queue_pop(q: DeviceQueue):
    """Remove and return the earliest event.

    Returns ``(q', time, type, arg)``; when empty, type is -1 and the
    queue is unchanged.
    """
    t, ty, slot = device_queue_peek(q)
    arg = q.args[slot]
    nonempty = ty >= 0

    def do_pop(q):
        return q._replace(
            times=q.times.at[slot].set(jnp.inf),
            types=q.types.at[slot].set(-1),
            seqs=q.seqs.at[slot].set(2**31 - 1),
            size=q.size - 1,
        )

    q = jax.lax.cond(nonempty, do_pop, lambda q: q, q)
    return q, t, ty, arg


def device_queue_next_time(q: DeviceQueue):
    """Earliest pending timestamp under the canonical layout (O(1)).

    The occupied prefix is (time, seq)-sorted, so the head slot answers;
    an empty queue holds the ``inf`` sentinel there.
    """
    return q.times[0]


def device_queue_next_time_ref(q: DeviceQueue):
    """Earliest pending timestamp, layout-independent (O(capacity))."""
    return jnp.min(jnp.where(q.types >= 0, q.times, _INF))


def device_queue_extract_ref(q: DeviceQueue, max_len: int, lookaheads,
                             t_cap=None):
    """Reference window extraction: ``max_len`` serial peek/pop rounds.

    The seed engine's loop (paper Fig 2 evaluated one event at a time):
    each round is an O(capacity) masked argmin inside ``lax.cond``, with
    a serial dependence between rounds.  ``t_cap`` optionally starts the
    dynamic window bound below ``inf`` (the run horizon).  Returns
    ``(q', ts, tys, args, length)`` with zero-padding past ``length``.
    Kept as the executable specification for
    :func:`device_queue_extract`.
    """
    ts0 = jnp.zeros((max_len,), jnp.float32)
    tys0 = jnp.zeros((max_len,), jnp.int32)
    args0 = jnp.zeros((max_len, q.args.shape[1]), jnp.float32)

    def body(i, carry):
        queue, ts, tys, args, length, t_max, done = carry
        t, ty, _slot = device_queue_peek(queue)
        can_take = (~done) & (ty >= 0) & (t <= t_max)

        def take(_):
            q2, t2, ty2, arg2 = device_queue_pop(queue)
            ts2 = ts.at[i].set(t2)
            tys2 = tys.at[i].set(ty2)
            args2 = args.at[i].set(arg2)
            t_max2 = jnp.minimum(t_max, t2 + lookaheads[ty2])
            return q2, ts2, tys2, args2, length + 1, t_max2, done

        def skip(_):
            return queue, ts, tys, args, length, t_max, jnp.bool_(True)

        return jax.lax.cond(can_take, take, skip, None)

    cap = _INF if t_cap is None else jnp.asarray(t_cap, jnp.float32)
    init = (q, ts0, tys0, args0, jnp.int32(0), cap, jnp.bool_(False))
    q, ts, tys, args, length, _t_max, _done = jax.lax.fori_loop(
        0, max_len, body, init
    )
    return q, ts, tys, args, length


# ---------------------------------------------------------------------------
# Vectorized single-pass ops
# ---------------------------------------------------------------------------

def _small_lex_perm(ts, sq):
    """Permutation sorting a TINY vector by (ts, sq, index) ascending.

    XLA:CPU sorts are custom calls with large fixed overhead, so for the
    k-element candidate vectors (k = max_batch_len class) the rank of
    each element is computed from all-pairs comparisons (m² tiny bools,
    fully fused) and inverted with an m-element scatter.
    """
    m = ts.shape[0]
    i = jnp.arange(m, dtype=jnp.int32)
    t_lt = ts[:, None] > ts[None, :]
    t_eq = ts[:, None] == ts[None, :]
    s_lt = sq[:, None] > sq[None, :]
    s_eq = sq[:, None] == sq[None, :]
    before = t_lt | (t_eq & s_lt) | (t_eq & s_eq & (i[:, None] > i[None, :]))
    rank = jnp.sum(before, axis=1).astype(jnp.int32)  # unique in [0, m)
    return jnp.zeros((m,), jnp.int32).at[rank].set(i)


def _prefix_rank(mask):
    """Rank of each position among the True positions of a TINY mask
    (-1 where False counts itself out), via all-pairs counting — the
    same avoid-a-scan-thunk reasoning as :func:`_small_lex_perm`."""
    n = mask.shape[0]
    i = jnp.arange(n, dtype=jnp.int32)
    return jnp.sum(
        (i[None, :] <= i[:, None]) & mask[None, :], axis=1
    ).astype(jnp.int32) - 1


def window_prefix_mask(ts, wins, valid, t_cap=None):
    """Vectorized §III-B dynamic-lookahead take rule.

    Given candidates already sorted by ``(time, seq)``, the serial rule
    — take event ``i`` iff every earlier candidate was taken and
    ``t_i <= t_max`` where ``t_max = min over taken j<i of (t_j + l_j)``
    — is *monotone*: once a candidate is rejected no later one can be
    taken.  It therefore reduces to two scans: a shifted (exclusive)
    ``cummin`` over the window bounds ``wins = t + l``, and a prefix-AND
    (via cumsum of rejections) that implements the stop condition.

    ``t_cap`` initializes the dynamic bound below ``inf`` — the run
    horizon (``until``): with it, no event past the cap is ever taken,
    the cross-backend ``t_end`` contract.

    Shared with :func:`repro.core.scheduler.extract_window`, which is
    the host/serial form of the same rule; the differential tests assert
    their equivalence.
    """
    ts = jnp.asarray(ts, jnp.float32)
    wins = jnp.asarray(wins, jnp.float32)
    cap = _INF if t_cap is None else jnp.asarray(t_cap, jnp.float32)
    # Exclusive cummin of the window bounds: t_max before candidate i.
    t_max = jnp.concatenate(
        [jnp.full((1,), jnp.inf, jnp.float32), jax.lax.cummin(wins)[:-1]]
    )
    ok = valid & (ts <= jnp.minimum(t_max, cap))
    # Prefix-AND: no rejection at any earlier position.
    return jnp.cumsum(~ok) == 0


def device_queue_extract(q: DeviceQueue, max_len: int, lookaheads,
                         t_cap=None):
    """Single-pass window extraction (paper Fig 2, fully vectorized).

    Requires the canonical sorted layout (occupied slots form a prefix
    ordered by ``(time, seq)`` — see the module docstring), which makes
    the ``max_len`` earliest events simply the first ``max_len`` slots:
    no reductions, no sort, no serial dependence.  The dynamic lookahead
    rule is applied with :func:`window_prefix_mask`, and all taken slots
    are popped at once by shifting every column left by ``length`` (one
    fused ``dynamic_slice`` per column) — preserving the invariant.

    Bit-identical batch output to :func:`device_queue_extract_ref`
    (lexicographic pop order, tie-breaks, zero-padding) at a constant
    number of data-parallel passes per *batch* instead of
    O(max_len × capacity) serially dependent work.

    Returns ``(q', ts, tys, args, length)``.
    """
    if max_len > q.capacity:
        raise ValueError(
            f"max_len {max_len} exceeds queue capacity {q.capacity}"
        )
    k = max_len
    cap = q.capacity
    num_types = lookaheads.shape[0]
    ts_c = q.times[:k]
    tys_c = q.types[:k]

    valid = tys_c >= 0
    la = lookaheads[jnp.clip(tys_c, 0, num_types - 1)]
    wins = jnp.where(valid, ts_c + la, jnp.inf)
    take = window_prefix_mask(ts_c, wins, valid, t_cap)
    length = jnp.sum(take).astype(jnp.int32)

    ts = jnp.where(take, ts_c, 0.0)
    tys = jnp.where(take, tys_c, 0)
    args = jnp.where(take[:, None], q.args[:k], 0.0)

    # Pop the taken prefix: shift every column left by `length`,
    # refilling the tail with the free-slot sentinels.
    def shift(col, fill):
        pad = jnp.full((k,) + col.shape[1:], fill, col.dtype)
        return jax.lax.dynamic_slice_in_dim(
            jnp.concatenate([col, pad]), length, cap
        )

    q = q._replace(
        times=shift(q.times, jnp.inf),
        types=shift(q.types, -1),
        args=shift(q.args, 0.0),
        seqs=shift(q.seqs, 2**31 - 1),
        size=q.size - length,
    )
    return q, ts, tys, args, length


def device_queue_fill_rows(q: DeviceQueue, rows) -> DeviceQueue:
    """Bulk emit insert: merge a whole ``f32[R, 2+W]`` block at once.

    Row layout is ``(time, type, arg...)``; ``type < 0`` rows are
    skipped.  Requires and preserves the canonical sorted layout: valid
    row ``j`` (the ``r``-th valid row) receives ``seq = next_seq + r``
    — exactly the seq assignment of :func:`device_queue_push_rows` —
    and the surviving rows are merged into the sorted queue in one
    vectorized counting-merge: every merge position is computed from
    all-pairs key comparisons (R·capacity fused bools, no sort, no
    scan), and each queue column is rebuilt with a single gather/select
    pass.  Rows past capacity are dropped with ``size``/``next_seq``
    still advancing and ``dropped`` counted, matching the reference
    overflow semantics.
    """
    rows = jnp.asarray(rows, jnp.float32)
    R = rows.shape[0]
    C = q.capacity
    t_r = rows[:, 0]
    ty_r = rows[:, 1].astype(jnp.int32)
    arg_r = rows[:, 2:]

    valid = ty_r >= 0
    # Rank of each row among the valid rows (R is tiny).
    r_idx = jnp.arange(R, dtype=jnp.int32)
    vrank = _prefix_rank(valid)
    num_valid = jnp.sum(valid).astype(jnp.int32)
    # Serial-push overflow rule: row r inserts iff size + r < capacity
    # (size counts logical pushes, so it may already exceed occupancy).
    insert = valid & (q.size + vrank < C)
    num_insert = jnp.sum(insert).astype(jnp.int32)
    seq_r = q.next_seq + vrank

    # Order the surviving rows by (time, arrival): arrival order equals
    # seq order, and dropped rows are pushed past everything real.
    perm = _small_lex_perm(
        jnp.where(insert, t_r, jnp.inf),
        jnp.where(insert, r_idx, _I32_MAX),
    )
    rt = jnp.where(insert, t_r, jnp.inf)[perm]
    rty = ty_r[perm]
    rarg = arg_r[perm]
    rseq = seq_r[perm]
    rins = insert[perm]

    # Merge positions.  Keys are strictly totally ordered: row seqs are
    # all >= next_seq while queued seqs are all < next_seq, so EVERY
    # equal-time queued event precedes the new row — the count of queued
    # events before row r is therefore a plain searchsorted(side=right)
    # over the sorted times, capped at the occupancy so the (+inf,
    # i32_max) free-slot sentinels are never counted.
    # pos[r] = (#queued events before row r) + r, the second term
    # counting the earlier (sorted, inserting) rows.
    occupancy = jnp.sum(q.types >= 0).astype(jnp.int32)
    older = jnp.minimum(
        jnp.searchsorted(q.times, rt, side="right").astype(jnp.int32),
        occupancy,
    )
    pos = jnp.where(rins, older + r_idx, C)

    # Rebuild each column with one gather pass: output slot i holds
    # sorted row `ins_before[i]` if some row lands at i, else the queued
    # entry shifted right by the rows inserted before it.
    i_idx = jnp.arange(C, dtype=jnp.int32)
    ins_before = jnp.sum(pos[None, :] < i_idx[:, None], axis=1).astype(
        jnp.int32
    )
    is_ins = jnp.sum(pos[None, :] == i_idx[:, None], axis=1) > 0
    src = jnp.where(
        is_ins, C + jnp.clip(ins_before, 0, R - 1),
        jnp.clip(i_idx - ins_before, 0, C - 1),
    )

    def merge(col, rcol):
        return jnp.take(jnp.concatenate([col, rcol]), src, axis=0)

    return q._replace(
        times=merge(q.times, rt),
        types=merge(q.types, rty),
        args=merge(q.args, rarg),
        seqs=merge(q.seqs, rseq),
        size=q.size + num_valid,
        next_seq=q.next_seq + num_valid,
        dropped=q.dropped + (num_valid - num_insert),
    )


# ---------------------------------------------------------------------------
# Two-tier queue: front / staging / main (DESIGN.md §4)
# ---------------------------------------------------------------------------

class TieredDeviceQueue(NamedTuple):
    """Pending-event set split into three tiers (a JAX pytree).

    * ``f_*`` — the **front** tier: ``front_cap`` slots in canonical
      layout (occupied prefix sorted by ``(time, seq)``), holding the
      globally earliest pending events.  Every per-batch operation
      touches only this tier (plus the staging ring), so per-batch cost
      is O(front_cap), independent of ``capacity``.
    * ``s_*`` — the **staging** ring: ``stage_cap`` slots of events that
      sort after the front boundary, in arrival order.  Bulk-merged into
      the main array only when it could overflow or the front drains.
    * ``m_*`` — the **main** array: ``capacity`` slots holding the far
      future as a head-offset ring: the logical (sorted) main tier is
      the ``main_n`` slots starting at ``m_head``.  Refills consume
      from the head without shifting, staging flushes append sorted
      blocks at the tail, and the slots before ``m_head`` are dead
      (stale, NOT sentinel-cleared) until a merge flush compacts the
      ring back to ``m_head = 0``.

    Tier invariant: ``max(front) <= min(staging ∪ main)`` under the
    lexicographic ``(time, seq)`` key.  ``size``/``next_seq``/``dropped``
    follow the reference semantics exactly (``size`` counts logical
    pushes including overflow ghosts); the *logical* capacity is
    ``capacity`` — the front and staging arrays add structure, not room.
    """

    f_times: jnp.ndarray   # f32[front_cap]
    f_types: jnp.ndarray   # i32[front_cap], -1 = empty
    f_args: jnp.ndarray    # f32[front_cap, ARG_WIDTH]
    f_seqs: jnp.ndarray    # i32[front_cap]
    m_times: jnp.ndarray   # f32[capacity]
    m_types: jnp.ndarray   # i32[capacity]
    m_args: jnp.ndarray    # f32[capacity, ARG_WIDTH]
    m_seqs: jnp.ndarray    # i32[capacity]
    s_times: jnp.ndarray   # f32[stage_cap]
    s_types: jnp.ndarray   # i32[stage_cap]
    s_args: jnp.ndarray    # f32[stage_cap, ARG_WIDTH]
    s_seqs: jnp.ndarray    # i32[stage_cap]
    s_evict: jnp.ndarray   # bool[stage_cap], True = evicted from front
    front_n: jnp.ndarray   # i32 scalar, occupied front slots
    main_n: jnp.ndarray    # i32 scalar, occupied main slots
    m_head: jnp.ndarray    # i32 scalar, first logical main slot (ring)
    stage_n: jnp.ndarray   # i32 scalar, occupied staging slots
    size: jnp.ndarray      # i32 scalar, logical pushes (incl. ghosts)
    next_seq: jnp.ndarray  # i32 scalar
    dropped: jnp.ndarray   # i32 scalar

    @property
    def capacity(self) -> int:
        return self.m_times.shape[0]

    @property
    def front_cap(self) -> int:
        return self.f_times.shape[0]

    @property
    def stage_cap(self) -> int:
        return self.s_times.shape[0]


def _ring_unroll(col, fill, head, n, offset=0):
    """Materialize a head-offset ring column's live window at physical
    ``offset``: one O(P) gather (roll by ``head - offset``) with the
    dead slots reset to ``fill``.  Shared by every ring compaction /
    re-centering site — the roll semantics must stay identical."""
    P = col.shape[0]
    i_idx = jnp.arange(P, dtype=jnp.int32)
    rolled = jnp.take(col, (i_idx - offset + head) % P, axis=0)
    live = (i_idx >= offset) & (i_idx < offset + n)
    mask = live if col.ndim == 1 else live[:, None]
    return jnp.where(mask, rolled, fill)


def _sentinel_cols(n: int, arg_width: int):
    return (
        jnp.full((n,), jnp.inf, jnp.float32),
        jnp.full((n,), -1, jnp.int32),
        jnp.zeros((n, arg_width), jnp.float32),
        jnp.full((n,), 2**31 - 1, jnp.int32),
    )


def tiered_queue_init(capacity: int, *, front_cap: int = 256,
                      stage_cap: int = 256,
                      arg_width: int = ARG_WIDTH) -> TieredDeviceQueue:
    front_cap = min(front_cap, capacity)
    ft, fy, fa, fs = _sentinel_cols(front_cap, arg_width)
    mt, my, ma, ms = _sentinel_cols(capacity, arg_width)
    st, sy, sa, ss = _sentinel_cols(stage_cap, arg_width)
    z = jnp.int32(0)
    return TieredDeviceQueue(
        f_times=ft, f_types=fy, f_args=fa, f_seqs=fs,
        m_times=mt, m_types=my, m_args=ma, m_seqs=ms,
        s_times=st, s_types=sy, s_args=sa, s_seqs=ss,
        s_evict=jnp.zeros((stage_cap,), bool),
        front_n=z, main_n=z, m_head=z, stage_n=z, size=z, next_seq=z,
        dropped=z,
    )


def tiered_queue_from_host(events, capacity: int, *, front_cap: int = 256,
                           stage_cap: int = 256,
                           arg_width: int = ARG_WIDTH) -> TieredDeviceQueue:
    """Host-built seed queue, one device_put (cf. device_queue_from_host).

    Events are sorted by ``(time, seq)``; the earliest ``front_cap`` go
    to the front tier, the rest to the main array.  Same logical
    semantics as N serial pushes: ``seq`` runs 0..N-1 and events past
    ``capacity`` are dropped with ``size``/``next_seq`` still advancing.
    """
    front_cap = min(front_cap, capacity)
    times, types, args, seqs, n, m = _host_sorted_seed(
        events, capacity, arg_width
    )
    nf = min(m, front_cap)
    ft = np.full((front_cap,), np.inf, np.float32)
    fy = np.full((front_cap,), -1, np.int32)
    fa = np.zeros((front_cap, arg_width), np.float32)
    fs = np.full((front_cap,), 2**31 - 1, np.int32)
    ft[:nf], fy[:nf], fa[:nf], fs[:nf] = (
        times[:nf], types[:nf], args[:nf], seqs[:nf]
    )
    mt = np.full((capacity,), np.inf, np.float32)
    my = np.full((capacity,), -1, np.int32)
    ma = np.zeros((capacity, arg_width), np.float32)
    ms = np.full((capacity,), 2**31 - 1, np.int32)
    nm = m - nf
    mt[:nm], my[:nm], ma[:nm], ms[:nm] = (
        times[nf:], types[nf:], args[nf:], seqs[nf:]
    )
    st, sy, sa, ss = (np.full((stage_cap,), np.inf, np.float32),
                      np.full((stage_cap,), -1, np.int32),
                      np.zeros((stage_cap, arg_width), np.float32),
                      np.full((stage_cap,), 2**31 - 1, np.int32))
    return jax.device_put(TieredDeviceQueue(
        f_times=ft, f_types=fy, f_args=fa, f_seqs=fs,
        m_times=mt, m_types=my, m_args=ma, m_seqs=ms,
        s_times=st, s_types=sy, s_args=sa, s_seqs=ss,
        s_evict=np.zeros((stage_cap,), bool),
        front_n=np.int32(nf), main_n=np.int32(nm), m_head=np.int32(0),
        stage_n=np.int32(0),
        size=np.int32(n), next_seq=np.int32(n), dropped=np.int32(n - m),
    ))


def tiered_queue_has_pending(q: TieredDeviceQueue):
    """True while any tier holds a real event.

    ``size`` alone is wrong (it counts overflow ghosts), and the front
    head alone is wrong too — the front may be empty while staging/main
    still hold events awaiting a refill.  O(1) from the tier counters.
    """
    return (q.front_n > 0) | (q.stage_n > 0) | (q.main_n > 0)


def tiered_queue_occupancy(q: TieredDeviceQueue):
    """Number of real pending events across all tiers (O(1))."""
    return q.front_n + q.stage_n + q.main_n


def tiered_queue_next_time(q: TieredDeviceQueue):
    """Timestamp of the earliest pending event (``inf`` when empty).

    While the front is non-empty its head is the global minimum (tier
    invariant); a drained front falls back to min(staging, main head) —
    O(stage_cap) for the unsorted ring, still capacity-independent.
    """
    m_min = jnp.where(
        q.main_n > 0,
        jnp.take(q.m_times, jnp.clip(q.m_head, 0, q.capacity - 1)),
        _INF,
    )
    rest = jnp.minimum(jnp.min(q.s_times), m_min)
    return jnp.where(q.front_n > 0, q.f_times[0], rest)


def _flush_stage(q: TieredDeviceQueue) -> TieredDeviceQueue:
    """Bulk-merge the staging ring into the main array (rare path).

    Unlike the emit-row merge, staged keys need lexicographic positions
    AGAINST BOTH TIE DIRECTIONS: a fresh emit's seq exceeds every main
    seq (equal-time main events precede it -> ``searchsorted`` with
    ``side="right"``), while a front-evicted event predates every
    equal-time main event — the ``main >= front`` invariant held while
    it sat in the front, so any equal-time event that reached main has a
    LARGER seq (-> ``side="left"``).  The ``s_evict`` tag records which
    rule applies; no all-pairs seq comparison is needed.  Merge
    positions are unique, so the column rebuild reduces to a scatter
    histogram + exclusive cumsum plus one gather — a linear pass over
    the output, only on the (rarer still) merge fallback; the common
    far-future case is an O(stage_cap) tail append.  Never drops: the
    logical-capacity rule guarantees ``main_n + stage_n <= capacity``.
    """
    S = q.stage_cap
    C = q.capacity
    perm = _small_lex_perm(q.s_times, q.s_seqs)
    st = q.s_times[perm]
    sty = q.s_types[perm]
    sarg = q.s_args[perm]
    sseq = q.s_seqs[perm]
    sev = q.s_evict[perm]
    sval = sty >= 0

    # Fast path: every staged timestamp strictly exceeds the main tail
    # (the overwhelmingly common DES shape — emissions land in the
    # future) and the sorted block fits before the physical end of the
    # ring: one O(stage_cap) dynamic_update_slice at the tail.
    head = jnp.where(q.main_n > 0, q.m_head, 0)
    tail = head + q.main_n
    m_last = jnp.take(q.m_times, jnp.clip(tail - 1, 0, C - 1))
    can_append = (q.main_n == 0) | (st[0] > m_last)
    can_append = can_append & (tail + S <= C)

    def append(q):
        def put(col, scol):
            return jax.lax.dynamic_update_slice_in_dim(col, scol, tail, 0)

        return q._replace(
            m_times=put(q.m_times, st),
            m_types=put(q.m_types, sty),
            m_args=put(q.m_args, sarg),
            m_seqs=put(q.m_seqs, sseq),
            m_head=head,
        )

    def merge_all(q):
        # Rotate the ring back to physical 0 (masking the dead slots
        # before the head and the stale tail), then counting-merge.
        i_idx = jnp.arange(C, dtype=jnp.int32)
        mt = _ring_unroll(q.m_times, jnp.inf, q.m_head, q.main_n)
        my = _ring_unroll(q.m_types, -1, q.m_head, q.main_n)
        ma = _ring_unroll(q.m_args, 0.0, q.m_head, q.main_n)
        ms = _ring_unroll(q.m_seqs, 2**31 - 1, q.m_head, q.main_n)

        older = jnp.where(
            sev,
            jnp.searchsorted(mt, st, side="left").astype(jnp.int32),
            jnp.searchsorted(mt, st, side="right").astype(jnp.int32),
        )
        older = jnp.minimum(older, q.main_n)
        j_idx = jnp.arange(S, dtype=jnp.int32)
        pos = jnp.where(sval, older + j_idx, C)

        # Positions are unique, so the per-slot insert counts reduce to
        # a scatter-histogram + exclusive cumsum — one linear pass over
        # the output instead of a per-slot binary search.
        counts = jnp.zeros((C,), jnp.int32).at[pos].add(1, mode="drop")
        csum = jnp.cumsum(counts)
        ins_before = (csum - counts).astype(jnp.int32)
        is_ins = counts > 0
        src = jnp.where(
            is_ins, C + jnp.clip(ins_before, 0, S - 1),
            jnp.clip(i_idx - ins_before, 0, C - 1),
        )

        def merge(col, scol):
            return jnp.take(jnp.concatenate([col, scol]), src, axis=0)

        return q._replace(
            m_times=merge(mt, st),
            m_types=merge(my, sty),
            m_args=merge(ma, sarg),
            m_seqs=merge(ms, sseq),
            m_head=jnp.int32(0),
        )

    # When the ring is smaller than the staging block the append path
    # can never fire (and would not even trace) — elide it statically.
    if S <= C:
        q = jax.lax.cond(can_append, append, merge_all, q)
    else:
        q = merge_all(q)
    empty_t, empty_y, empty_a, empty_s = _sentinel_cols(S, q.s_args.shape[1])
    return q._replace(
        s_times=empty_t, s_types=empty_y, s_args=empty_a, s_seqs=empty_s,
        s_evict=jnp.zeros((S,), bool),
        main_n=q.main_n + q.stage_n,
        stage_n=jnp.int32(0),
    )


def _refill_front(q: TieredDeviceQueue) -> TieredDeviceQueue:
    """Refill the front tier from the main array (rare-ish path).

    Staging is flushed first (staged keys may precede the main head),
    after which every main element sorts after every front element, so
    the refill is a plain concatenation: front occupied prefix followed
    by the main head.  The main ring just advances ``m_head`` — an
    O(front_cap) gather, no O(capacity) shift.
    """
    q = jax.lax.cond(q.stage_n > 0, _flush_stage, lambda q: q, q)
    F = q.front_cap
    C = q.capacity
    take = jnp.minimum(F - q.front_n, q.main_n)
    i_idx = jnp.arange(F, dtype=jnp.int32)
    src = jnp.where(
        i_idx < q.front_n, i_idx,
        F + jnp.clip(q.m_head + i_idx - q.front_n, 0, C - 1),
    )
    fill_ok = i_idx < q.front_n + take

    def refill(fcol, mcol, fill):
        out = jnp.take(jnp.concatenate([fcol, mcol]), src, axis=0)
        mask = fill_ok if out.ndim == 1 else fill_ok[:, None]
        return jnp.where(mask, out, fill)

    main_n = q.main_n - take
    return q._replace(
        f_times=refill(q.f_times, q.m_times, jnp.inf),
        f_types=refill(q.f_types, q.m_types, -1),
        f_args=refill(q.f_args, q.m_args, 0.0),
        f_seqs=refill(q.f_seqs, q.m_seqs, 2**31 - 1),
        front_n=q.front_n + take,
        main_n=main_n,
        m_head=jnp.where(main_n > 0, q.m_head + take, 0),
    )


def tiered_queue_extract(q: TieredDeviceQueue, max_len: int, lookaheads,
                         t_cap=None):
    """Window extraction from the front tier (paper Fig 2).

    Identical take rule and output as :func:`device_queue_extract`, but
    the candidate read, prefix mask, and shift-left pop all touch only
    the ``front_cap``-sized front tier — O(front_cap) per batch
    regardless of capacity.  When the front has drained below
    ``max_len`` while later tiers still hold events, a ``lax.cond``
    refills it from the main array first (amortized over
    ``(front_cap - max_len) / max_len`` batches).

    Returns ``(q', ts, tys, args, length)``.
    """
    if max_len > q.front_cap:
        raise ValueError(
            f"max_len {max_len} exceeds front tier capacity {q.front_cap}"
        )
    k = max_len
    F = q.front_cap
    num_types = lookaheads.shape[0]

    need_refill = (q.front_n < k) & ((q.stage_n > 0) | (q.main_n > 0))
    q = jax.lax.cond(need_refill, _refill_front, lambda q: q, q)

    ts_c = q.f_times[:k]
    tys_c = q.f_types[:k]
    valid = tys_c >= 0
    la = lookaheads[jnp.clip(tys_c, 0, num_types - 1)]
    wins = jnp.where(valid, ts_c + la, jnp.inf)
    take = window_prefix_mask(ts_c, wins, valid, t_cap)
    length = jnp.sum(take).astype(jnp.int32)

    ts = jnp.where(take, ts_c, 0.0)
    tys = jnp.where(take, tys_c, 0)
    args = jnp.where(take[:, None], q.f_args[:k], 0.0)

    def shift(col, fill):
        pad = jnp.full((k,) + col.shape[1:], fill, col.dtype)
        return jax.lax.dynamic_slice_in_dim(
            jnp.concatenate([col, pad]), length, F
        )

    q = q._replace(
        f_times=shift(q.f_times, jnp.inf),
        f_types=shift(q.f_types, -1),
        f_args=shift(q.f_args, 0.0),
        f_seqs=shift(q.f_seqs, 2**31 - 1),
        front_n=q.front_n - length,
        size=q.size - length,
    )
    return q, ts, tys, args, length


def _default_fill_accounting(q, rows):
    """Reference seq/overflow rule shared by the tiered fills: valid
    row ``r`` gets ``seq = next_seq + vrank(r)`` and survives iff
    ``size + vrank(r) < capacity`` (``size`` counts ghosts).  Returns
    ``(seq_r, insert, counters)`` for :func:`_tiered_fill_finish`."""
    ty_r = rows[:, 1].astype(jnp.int32)
    valid = ty_r >= 0
    vrank = _prefix_rank(valid)
    num_valid = jnp.sum(valid).astype(jnp.int32)
    insert = valid & (q.size + vrank < q.capacity)
    num_insert = jnp.sum(insert).astype(jnp.int32)
    seq_r = q.next_seq + vrank
    counters = dict(
        size=q.size + num_valid,
        next_seq=q.next_seq + num_valid,
        dropped=q.dropped + (num_valid - num_insert),
    )
    return seq_r, insert, counters


def _tiered_fill_finish(q, rows, b_time, seq_r, insert, counters,
                        kernels: str = "xla", b_seq=None):
    """Shared tail of BOTH tiered fill families (the ROADMAP-flagged
    factoring): partition the emit block against the tier boundary,
    counting-merge the near rows into the sorted front (evicting its
    tail to staging when full — the merge output is ``front_cap + R``
    wide, so nothing is lost), append the rest to the staging ring,
    and install the caller-computed counters.

    Works on :class:`TieredDeviceQueue` and :class:`Tiered3DeviceQueue`
    alike (identical ``f_*``/``s_*`` field names); the two-tier
    ``s_evict`` tags are updated iff the queue carries them.  The
    overflow/seq RULE lives with the caller: ``seq_r`` is the per-row
    seq (default ``next_seq + vrank``; the sharded engine supplies
    globally-assigned seqs) and ``insert`` the per-row survive mask —
    only their consequences are applied here, so the trickiest
    accounting exists exactly once.  Row seqs must exceed every queued
    seq (true for fresh emits under both the local and the global seq
    discipline) — the front-merge tie handling relies on it — UNLESS
    ``b_seq`` is given: then the boundary partition and the front-merge
    placement both compare full ``(time, seq)`` lexicographic keys
    (all-pairs against the front, XLA kernels only), which is what lets
    previously *spilled* rows — whose seqs are older than freshly
    queued ones — reabsorb exactly where they belong
    (:func:`tiered3_queue_absorb_rows`).

    ``kernels="pallas"`` computes the front counting-merge with the
    Pallas kernel (:func:`repro.kernels.queue_front.front_merge`) —
    bit-identical output, VMEM-resident on TPU, interpret mode
    elsewhere; the staging appends and counters stay in XLA.
    """
    R = rows.shape[0]
    F = q.front_cap
    t_r = rows[:, 0]
    ty_r = rows[:, 1].astype(jnp.int32)
    arg_r = rows[:, 2:]
    r_idx = jnp.arange(R, dtype=jnp.int32)

    if b_seq is None:
        # Emit seqs all exceed every queued seq, so a timestamp TIE
        # with the boundary already sorts the row after it — the
        # partition is on time alone.
        to_front = insert & (t_r < b_time)
    else:
        # Lex-exact partition for reabsorbed (old-seq) rows.
        to_front = insert & (
            (t_r < b_time) | ((t_r == b_time) & (seq_r < b_seq))
        )
    to_stage = insert & ~to_front

    # --- front merge (output F + R wide: overflow becomes eviction) ---
    FE = F + R
    if kernels == "pallas":
        if b_seq is not None:
            raise ValueError(
                "lex-exact fill (b_seq) is XLA-only; absorb spilled "
                "rows with kernels='xla'"
            )
        from repro.kernels.queue_front import front_merge

        merged_t, merged_y, merged_a, merged_s = front_merge(
            q.f_times, q.f_types, q.f_args, q.f_seqs, q.front_n,
            t_r, ty_r, arg_r, seq_r, to_front,
        )
    else:
        perm = _small_lex_perm(
            jnp.where(to_front, t_r, jnp.inf),
            jnp.where(to_front, seq_r, _I32_MAX),
        )
        rt = jnp.where(to_front, t_r, jnp.inf)[perm]
        rty = ty_r[perm]
        rarg = arg_r[perm]
        rseq = seq_r[perm]
        rins = to_front[perm]

        if b_seq is None:
            # Same strict-total-order shortcut as
            # device_queue_fill_rows: row seqs all exceed queued seqs,
            # so position = searchsorted on time.
            older = jnp.minimum(
                jnp.searchsorted(
                    q.f_times, rt, side="right").astype(jnp.int32),
                q.front_n,
            )
        else:
            # Reabsorbed rows carry OLD seqs: count the occupied front
            # slots strictly lex-before each row (all-pairs, R × F
            # fused bools — boundary-rare, never the per-batch path).
            occ_f = (jnp.arange(F, dtype=jnp.int32) < q.front_n)[None, :]
            lex_lt = (q.f_times[None, :] < rt[:, None]) | (
                (q.f_times[None, :] == rt[:, None])
                & (q.f_seqs[None, :] < rseq[:, None])
            )
            older = jnp.sum(occ_f & lex_lt, axis=1).astype(jnp.int32)
        pos = jnp.where(rins, older + r_idx, FE + R)

        # `pos` ascends over the lex-sorted rows: searchsorted rebuild.
        i_idx = jnp.arange(FE, dtype=jnp.int32)
        ins_before = jnp.searchsorted(
            pos, i_idx, side="left"
        ).astype(jnp.int32)
        is_ins = (
            jnp.searchsorted(pos, i_idx, side="right").astype(jnp.int32)
            > ins_before
        )
        src = jnp.where(
            is_ins, FE + jnp.clip(ins_before, 0, R - 1),
            jnp.clip(i_idx - ins_before, 0, FE - 1),
        )

        def fmerge(col, rcol, fill):
            ext = jnp.concatenate(
                [col, jnp.full((R,) + col.shape[1:], fill, col.dtype),
                 rcol]
            )
            return jnp.take(ext, src, axis=0)

        merged_t = fmerge(q.f_times, rt, jnp.inf)
        merged_y = fmerge(q.f_types, rty, -1)
        merged_a = fmerge(q.f_args, rarg, 0.0)
        merged_s = fmerge(q.f_seqs, rseq, 2**31 - 1)

    n_front = jnp.sum(to_front).astype(jnp.int32)
    occ_after = q.front_n + n_front
    evict_cnt = jnp.maximum(occ_after - F, 0)
    front_n_new = jnp.minimum(occ_after, F)

    # --- staging appends: evicted front tail, then direct rows --------
    SC = q.stage_cap
    e_valid = merged_y[F:] >= 0
    dest_e = jnp.where(e_valid, q.stage_n + r_idx, SC)
    srank = _prefix_rank(to_stage)
    dest_s = jnp.where(to_stage, q.stage_n + evict_cnt + srank, SC)
    n_stage = jnp.sum(to_stage).astype(jnp.int32)

    def stage_put(col, evals, svals):
        col = col.at[dest_e].set(evals, mode="drop")
        return col.at[dest_s].set(svals, mode="drop")

    extra = {}
    if hasattr(q, "s_evict"):
        s_evict = q.s_evict.at[dest_e].set(True, mode="drop")
        extra["s_evict"] = s_evict.at[dest_s].set(False, mode="drop")

    return q._replace(
        f_times=merged_t[:F], f_types=merged_y[:F],
        f_args=merged_a[:F], f_seqs=merged_s[:F],
        s_times=stage_put(q.s_times, merged_t[F:], t_r),
        s_types=stage_put(q.s_types, merged_y[F:], ty_r),
        s_args=stage_put(q.s_args, merged_a[F:], arg_r),
        s_seqs=stage_put(q.s_seqs, merged_s[F:], seq_r),
        front_n=front_n_new,
        stage_n=q.stage_n + evict_cnt + n_stage,
        **counters,
        **extra,
    )


def tiered_queue_fill_rows(q: TieredDeviceQueue, rows) -> TieredDeviceQueue:
    """Per-batch emit insert touching only the front and staging tiers.

    Row layout is ``(time, type, arg...)``; ``type < 0`` rows are
    skipped.  Valid row ``r`` receives ``seq = next_seq + r`` and is
    dropped iff ``size + r >= capacity`` — bit-exact reference overflow
    accounting (``size`` counts ghosts).  Surviving rows whose timestamp
    precedes the tier boundary (the earliest key in staging ∪ main) are
    counting-merged into the sorted front at O(front_cap · R) fused
    bools + O(front_cap) gathers; rows at or past the boundary append to
    the staging ring (:func:`_tiered_fill_finish`, shared with the
    tiered3 fills).  A staging ring that could overflow on this batch
    is first bulk-merged into the main array via the rare
    :func:`_flush_stage` path.

    No O(capacity) work on the common path — this is what makes
    per-batch scheduling cost independent of queue capacity.
    """
    rows = jnp.asarray(rows, jnp.float32)
    R = rows.shape[0]
    C = q.capacity
    if R > q.stage_cap:
        raise ValueError(
            f"emit block of {R} rows exceeds stage_cap {q.stage_cap}"
        )

    # Staging must absorb up to R appends this batch (direct + evicted).
    q = jax.lax.cond(
        q.stage_n + R > q.stage_cap, _flush_stage, lambda q: q, q
    )

    seq_r, insert, counters = _default_fill_accounting(q, rows)

    # Tier boundary: earliest key outside the front.  The main head is
    # read at the ring offset (slots before m_head are dead and must
    # not leak into the boundary).
    m_min = jnp.where(
        q.main_n > 0,
        jnp.take(q.m_times, jnp.clip(q.m_head, 0, C - 1)),
        jnp.inf,
    )
    b_time = jnp.minimum(m_min, jnp.min(q.s_times))
    return _tiered_fill_finish(q, rows, b_time, seq_r, insert, counters)


def tiered_queue_to_flat(q: TieredDeviceQueue) -> DeviceQueue:
    """Canonical flat view of a tiered queue (host-side, for tests).

    Gathers the occupied slots of all three tiers, sorts by
    ``(time, seq)``, and lays them out as a canonical
    :class:`DeviceQueue` with identical logical counters — the flat and
    reference ops' view of the same pending set.
    """
    head, main_n = int(q.m_head), int(q.main_n)
    cols = []
    for pre in ("f", "m", "s"):
        cols.append(tuple(
            np.asarray(getattr(q, f"{pre}_{name}"))
            for name in ("times", "types", "args", "seqs")
        ))
    # Only the live window of the main ring — slots outside
    # [m_head, m_head + main_n) are dead (stale values, not sentinels).
    cols[1] = tuple(c[head:head + main_n] for c in cols[1])
    times = np.concatenate([c[0] for c in cols])
    types = np.concatenate([c[1] for c in cols])
    args = np.concatenate([c[2] for c in cols])
    seqs = np.concatenate([c[3] for c in cols])
    occ = types >= 0
    order = np.lexsort((seqs[occ], times[occ]))
    n = int(occ.sum())
    C = q.capacity
    assert n <= C, "tier occupancy exceeded logical capacity"
    out_t = np.full((C,), np.inf, np.float32)
    out_y = np.full((C,), -1, np.int32)
    out_a = np.zeros((C, q.f_args.shape[1]), np.float32)
    out_s = np.full((C,), 2**31 - 1, np.int32)
    out_t[:n] = times[occ][order]
    out_y[:n] = types[occ][order]
    out_a[:n] = args[occ][order]
    out_s[:n] = seqs[occ][order]
    return DeviceQueue(
        times=jnp.asarray(out_t), types=jnp.asarray(out_y),
        args=jnp.asarray(out_a), seqs=jnp.asarray(out_s),
        size=jnp.asarray(q.size), next_seq=jnp.asarray(q.next_seq),
        dropped=jnp.asarray(q.dropped),
    )


# ---------------------------------------------------------------------------
# Three-tier queue: front / staging / sorted-run log / main (DESIGN.md §4.4)
# ---------------------------------------------------------------------------

def _lex_order(ts, sq):
    """Ascending ``(time, seq)`` permutation for a mid-size vector.

    ONE ``lax.sort`` call with two key operands (lexicographic) and an
    iota payload, instead of the all-pairs rank of
    :func:`_small_lex_perm`: the run-merge vectors are a few thousand
    elements, where m² fused bools stop being free, and XLA:CPU sort
    custom calls have enough fixed overhead that one variadic call
    beats two chained argsorts.
    """
    idx = jnp.arange(ts.shape[0], dtype=jnp.int32)
    _, _, perm = jax.lax.sort((ts, sq, idx), num_keys=2)
    return perm


class Tiered3DeviceQueue(NamedTuple):
    """Pending-event set split into front / staging / run log / main.

    Same front (``f_*``) and staging (``s_*``) tiers as
    :class:`TieredDeviceQueue`; the differences are the third tier and
    the slack reserve:

    * ``r_*`` — the **run log**: ``num_runs`` fixed-size sorted runs of
      ``stage_cap`` slots each.  A staging flush becomes one new run
      (sorted by true ``(time, seq)``); ``r_off``/``r_len`` bound each
      run's live remainder (``r_off`` advances as refills consume the
      run head, so nothing is ever "put back").  The per-run min-time
      summary is ``r_times[i, r_off[i]]``.
    * ``m_*`` — the **main** head-offset ring, physically
      ``capacity + num_runs * stage_cap`` slots: the extra slack lets
      an exhausted run pool usually merge into main as one bounded
      tail append; the O(capacity) rotate+merge compaction only fires
      once the slack itself is gone.

    Because every element's true ``seq`` participates in the run and
    refill merges, no eviction tags are needed: lexicographic
    ``(time, seq)`` order is recovered exactly wherever tiers meet.
    Tier invariant and accounting match :class:`TieredDeviceQueue`:
    ``max(front) <= min(staging ∪ runs ∪ main)``, and the *logical*
    capacity excludes the slack — ``capacity`` is what overflow
    accounting is measured against, bit-identical to the reference.

    Front-tier hot loops come in two implementations selected by the
    ``kernels=`` argument of :func:`tiered3_queue_extract` /
    :func:`tiered3_queue_fill_rows` (surfaced as
    ``DeviceEngine(queue_kernels=...)``): ``"xla"`` — the
    all-pairs-rank + gather shapes tuned for XLA:CPU — or ``"pallas"``
    — :mod:`repro.kernels.queue_front` kernels that keep the window
    extract and the front counting-merge in VMEM on TPU (interpret
    mode elsewhere, bit-identical output).  The queue layout itself is
    implementation-agnostic, which is why the knob rides on the
    functions, not in the pytree.
    """

    f_times: jnp.ndarray   # f32[front_cap]
    f_types: jnp.ndarray   # i32[front_cap], -1 = empty
    f_args: jnp.ndarray    # f32[front_cap, ARG_WIDTH]
    f_seqs: jnp.ndarray    # i32[front_cap]
    m_times: jnp.ndarray   # f32[capacity + num_runs*stage_cap]
    m_types: jnp.ndarray   # i32[...]
    m_args: jnp.ndarray    # f32[..., ARG_WIDTH]
    m_seqs: jnp.ndarray    # i32[...]
    s_times: jnp.ndarray   # f32[stage_cap]
    s_types: jnp.ndarray   # i32[stage_cap]
    s_args: jnp.ndarray    # f32[stage_cap, ARG_WIDTH]
    s_seqs: jnp.ndarray    # i32[stage_cap]
    r_times: jnp.ndarray   # f32[num_runs, stage_cap]
    r_types: jnp.ndarray   # i32[num_runs, stage_cap]
    r_args: jnp.ndarray    # f32[num_runs, stage_cap, ARG_WIDTH]
    r_seqs: jnp.ndarray    # i32[num_runs, stage_cap]
    r_off: jnp.ndarray     # i32[num_runs], consumed prefix of each run
    r_len: jnp.ndarray     # i32[num_runs], written length of each run
    front_n: jnp.ndarray   # i32 scalar
    main_n: jnp.ndarray    # i32 scalar
    m_head: jnp.ndarray    # i32 scalar, first logical main slot (ring)
    stage_n: jnp.ndarray   # i32 scalar
    size: jnp.ndarray      # i32 scalar, logical pushes (incl. ghosts)
    next_seq: jnp.ndarray  # i32 scalar
    dropped: jnp.ndarray   # i32 scalar

    @property
    def main_phys(self) -> int:
        return self.m_times.shape[0]

    @property
    def capacity(self) -> int:
        return self.main_phys - self.num_runs * self.stage_cap

    @property
    def front_cap(self) -> int:
        return self.f_times.shape[0]

    @property
    def stage_cap(self) -> int:
        return self.s_times.shape[0]

    @property
    def num_runs(self) -> int:
        return self.r_times.shape[0]


def tiered3_queue_init(capacity: int, *, front_cap: int = 256,
                       stage_cap: int = 256, num_runs: int = 8,
                       arg_width: int = ARG_WIDTH) -> Tiered3DeviceQueue:
    front_cap = min(front_cap, capacity)
    phys = capacity + num_runs * stage_cap
    ft, fy, fa, fs = _sentinel_cols(front_cap, arg_width)
    mt, my, ma, ms = _sentinel_cols(phys, arg_width)
    st, sy, sa, ss = _sentinel_cols(stage_cap, arg_width)
    z = jnp.int32(0)
    return Tiered3DeviceQueue(
        f_times=ft, f_types=fy, f_args=fa, f_seqs=fs,
        m_times=mt, m_types=my, m_args=ma, m_seqs=ms,
        s_times=st, s_types=sy, s_args=sa, s_seqs=ss,
        r_times=jnp.full((num_runs, stage_cap), jnp.inf, jnp.float32),
        r_types=jnp.full((num_runs, stage_cap), -1, jnp.int32),
        r_args=jnp.zeros((num_runs, stage_cap, arg_width), jnp.float32),
        r_seqs=jnp.full((num_runs, stage_cap), 2**31 - 1, jnp.int32),
        r_off=jnp.zeros((num_runs,), jnp.int32),
        r_len=jnp.zeros((num_runs,), jnp.int32),
        front_n=z, main_n=z, m_head=z, stage_n=z, size=z, next_seq=z,
        dropped=z,
    )


def tiered3_queue_from_host(events, capacity: int, *, front_cap: int = 256,
                            stage_cap: int = 256, num_runs: int = 8,
                            arg_width: int = ARG_WIDTH, seqs=None
                            ) -> Tiered3DeviceQueue:
    """Host-built seed queue, one device_put (cf. tiered_queue_from_host).

    Earliest ``front_cap`` events seed the front, the rest the main
    array at head 0; runs and staging start empty.  Reference overflow
    semantics against the LOGICAL capacity (the slack is structure).

    ``seqs`` optionally supplies explicit global seqs (shard seeding):
    the events must then fit ``capacity`` (the global overflow rule was
    applied upstream) and the counters become shard-local — ``size`` =
    occupancy, ``dropped`` = 0, ``next_seq`` past the largest seq.
    """
    front_cap = min(front_cap, capacity)
    phys = capacity + num_runs * stage_cap
    times, types, args, seq_col, n, m = _host_sorted_seed(
        events, capacity, arg_width, seqs
    )
    nf = min(m, front_cap)
    ft = np.full((front_cap,), np.inf, np.float32)
    fy = np.full((front_cap,), -1, np.int32)
    fa = np.zeros((front_cap, arg_width), np.float32)
    fs = np.full((front_cap,), 2**31 - 1, np.int32)
    ft[:nf], fy[:nf], fa[:nf], fs[:nf] = (
        times[:nf], types[:nf], args[:nf], seq_col[:nf]
    )
    mt = np.full((phys,), np.inf, np.float32)
    my = np.full((phys,), -1, np.int32)
    ma = np.zeros((phys, arg_width), np.float32)
    ms = np.full((phys,), 2**31 - 1, np.int32)
    nm = m - nf
    mt[:nm], my[:nm], ma[:nm], ms[:nm] = (
        times[nf:], types[nf:], args[nf:], seq_col[nf:]
    )
    if seqs is None:
        size, next_seq, dropped = n, n, n - m
    else:
        size = m
        next_seq = int(seq_col.max()) + 1 if m else 0
        dropped = 0
    st, sy, sa, ss = (np.full((stage_cap,), np.inf, np.float32),
                      np.full((stage_cap,), -1, np.int32),
                      np.zeros((stage_cap, arg_width), np.float32),
                      np.full((stage_cap,), 2**31 - 1, np.int32))
    return jax.device_put(Tiered3DeviceQueue(
        f_times=ft, f_types=fy, f_args=fa, f_seqs=fs,
        m_times=mt, m_types=my, m_args=ma, m_seqs=ms,
        s_times=st, s_types=sy, s_args=sa, s_seqs=ss,
        r_times=np.full((num_runs, stage_cap), np.inf, np.float32),
        r_types=np.full((num_runs, stage_cap), -1, np.int32),
        r_args=np.zeros((num_runs, stage_cap, arg_width), np.float32),
        r_seqs=np.full((num_runs, stage_cap), 2**31 - 1, np.int32),
        r_off=np.zeros((num_runs,), np.int32),
        r_len=np.zeros((num_runs,), np.int32),
        front_n=np.int32(nf), main_n=np.int32(nm), m_head=np.int32(0),
        stage_n=np.int32(0),
        size=np.int32(size), next_seq=np.int32(next_seq),
        dropped=np.int32(dropped),
    ))


def _run_mins(q: Tiered3DeviceQueue):
    """Per-run min-time summary: the head of each live remainder
    (``inf`` for consumed/empty runs).  One O(num_runs) gather."""
    S = q.stage_cap
    head = jnp.take_along_axis(
        q.r_times, jnp.clip(q.r_off, 0, S - 1)[:, None], axis=1
    )[:, 0]
    return jnp.where(q.r_len > q.r_off, head, jnp.inf)


def tiered3_queue_has_pending(q: Tiered3DeviceQueue):
    """True while any tier holds a real event (O(num_runs))."""
    return ((q.front_n > 0) | (q.stage_n > 0) | (q.main_n > 0)
            | jnp.any(q.r_len > q.r_off))


def tiered3_queue_occupancy(q: Tiered3DeviceQueue):
    """Number of real pending events across all four tiers."""
    return (q.front_n + q.stage_n + q.main_n
            + jnp.sum(q.r_len - q.r_off).astype(jnp.int32))


def tiered3_queue_next_time(q: Tiered3DeviceQueue):
    """Earliest pending timestamp (``inf`` when empty); O(stage_cap +
    num_runs) on the drained-front fallback, capacity-independent."""
    m_min = jnp.where(
        q.main_n > 0,
        jnp.take(q.m_times, jnp.clip(q.m_head, 0, q.main_phys - 1)),
        _INF,
    )
    rest = jnp.minimum(
        jnp.minimum(jnp.min(q.s_times), jnp.min(_run_mins(q))), m_min
    )
    return jnp.where(q.front_n > 0, q.f_times[0], rest)


def _merge_runs_into_main(q: Tiered3DeviceQueue) -> Tiered3DeviceQueue:
    """Drain the whole run pool into the main ring (rare path).

    The live remainders of every run are lex-sorted by their true
    ``(time, seq)`` keys into one block (O(num_runs · stage_cap ·
    log) — bounded, capacity-independent).  Fast path: when the block's
    minimum strictly exceeds the main tail and the ring's physical
    slack still fits it, ONE tail ``dynamic_update_slice`` lands it.
    Fallback (the only O(capacity) operation in the tiered3 family):
    rotate the ring back to physical 0 and lex-merge — amortized over
    an entire pool (``num_runs × stage_cap`` staged events) per firing.
    Never drops: occupancy <= logical capacity <= physical size.
    """
    R, S, P = q.num_runs, q.stage_cap, q.main_phys
    RL = R * S
    k_idx = jnp.arange(S, dtype=jnp.int32)[None, :]
    live = (k_idx >= q.r_off[:, None]) & (k_idx < q.r_len[:, None])
    bt = jnp.where(live, q.r_times, jnp.inf).reshape(RL)
    by = jnp.where(live, q.r_types, -1).reshape(RL)
    ba = jnp.where(live[:, :, None], q.r_args, 0.0).reshape(
        RL, q.r_args.shape[2])
    bs = jnp.where(live, q.r_seqs, _I32_MAX).reshape(RL)
    order = _lex_order(bt, bs)
    bt, by, ba, bs = bt[order], by[order], ba[order], bs[order]
    run_live = jnp.sum(live).astype(jnp.int32)

    head = jnp.where(q.main_n > 0, q.m_head, 0)
    tail = head + q.main_n
    m_last = jnp.take(q.m_times, jnp.clip(tail - 1, 0, P - 1))
    can_append = ((q.main_n == 0) | (bt[0] > m_last)) & (tail + RL <= P)

    def append(q):
        def put(col, bcol):
            return jax.lax.dynamic_update_slice_in_dim(col, bcol, tail, 0)

        return q._replace(
            m_times=put(q.m_times, bt),
            m_types=put(q.m_types, by),
            m_args=put(q.m_args, ba),
            m_seqs=put(q.m_seqs, bs),
            m_head=head,
        )

    def merge_all(q):
        ct = jnp.concatenate(
            [_ring_unroll(q.m_times, jnp.inf, q.m_head, q.main_n), bt])
        cy = jnp.concatenate(
            [_ring_unroll(q.m_types, -1, q.m_head, q.main_n), by])
        ca = jnp.concatenate(
            [_ring_unroll(q.m_args, 0.0, q.m_head, q.main_n), ba])
        cs = jnp.concatenate(
            [_ring_unroll(q.m_seqs, 2**31 - 1, q.m_head, q.main_n), bs])
        # Real elements <= logical capacity <= P, so truncating the
        # sorted concat to P drops only sentinels.
        perm = _lex_order(ct, cs)[:P]
        return q._replace(
            m_times=ct[perm], m_types=cy[perm], m_args=ca[perm],
            m_seqs=cs[perm], m_head=jnp.int32(0),
        )

    q = jax.lax.cond(can_append, append, merge_all, q)
    return q._replace(
        main_n=q.main_n + run_live,
        r_off=jnp.zeros((R,), jnp.int32),
        r_len=jnp.zeros((R,), jnp.int32),
    )


def _rotate_main(q: Tiered3DeviceQueue) -> Tiered3DeviceQueue:
    """Re-center the sorted main ring — one O(P) gather, no sort.

    The live window moves to start at a margin of up to ``2·stage_cap``
    dead slots, reclaiming BOTH kinds of headroom at once: tail slack
    for far-future appends and head slack for the bounded near-head
    merge (which writes at ``m_head - n_pre``).  Head slack otherwise
    only accrues as refills consume the head — and a front kept full
    by near-head merges never refills, so the flush must be able to
    mint its own headroom.  Amortized over ~stage_cap-many flush
    events per firing.
    """
    P = q.main_phys
    S = q.stage_cap
    # Generous margin (up to a quarter of the ring): head merges can
    # consume ~stage_cap headroom per flush, and each rotate is O(P),
    # so rotating rarely beats rotating tightly.
    margin = jnp.minimum(jnp.maximum(2 * S, P // 4),
                         jnp.maximum(P - q.main_n - S, 0))
    return q._replace(
        m_times=_ring_unroll(q.m_times, jnp.inf, q.m_head, q.main_n,
                             margin),
        m_types=_ring_unroll(q.m_types, -1, q.m_head, q.main_n, margin),
        m_args=_ring_unroll(q.m_args, 0.0, q.m_head, q.main_n, margin),
        m_seqs=_ring_unroll(q.m_seqs, 2**31 - 1, q.m_head, q.main_n,
                            margin),
        m_head=margin,
    )


def _flush_stage_to_run(q: Tiered3DeviceQueue) -> Tiered3DeviceQueue:
    """Drain the staging ring by SPLITTING the sorted block three ways.

    The staged block is lex-sorted once (O(stage_cap²) fused bools),
    then partitioned by where its elements land relative to the main
    ring — real emit mixes contain both near-head re-emits and
    far-future events, so a single-destination flush would almost
    always hit a fallback:

    * **suffix** (times strictly after the main tail): one O(stage_cap)
      gather + ``dynamic_update_slice`` into the ring's physical
      slack — the common far-future path.  When the tail would run off
      the physical end, the sorted ring is first re-centered
      (:func:`_rotate_main` — one O(P) gather, no sort, amortized
      over the whole slack).
    * **prefix** (times strictly before the K-th element past the
      head): counting-merged with the K+stage_cap head window and
      written back as ONE block starting at ``m_head - n_pre`` — the
      already-consumed ring slots are the headroom (re-minted by the
      same re-centering rotate when they run out).  Beyond the write
      range the merged sequence is the old window shifted by exactly
      ``n_pre``, so slot ``head - n_pre + j`` holds element
      ``head + j - n_pre`` either way: nothing past the window is
      touched.  All-pairs strict lex compares on true ``(time, seq)``
      keys — exact, bounded, no sort custom call.  This is the shape
      that made the two-tier flush an O(capacity) lex merge + ring
      compaction.
    * **middle** (neither, or the prefix guard failed): one new sorted
      run in the log (an O(stage_cap) row write).  When it needs a
      slot and every run is occupied, the pool first drains into main
      (:func:`_merge_runs_into_main`), which frees all of them.

    Every leg builds its block with gathers and lands it with one
    ``dynamic_update_slice`` — XLA:CPU executes those as bulk copies,
    where equivalent scatters cost ~100× more per row.  Every leg is
    O(stage_cap·K) worst case — capacity-independent.
    """
    S = q.stage_cap
    P = q.main_phys
    # Head window: K main elements is how far past the head a "near"
    # emit may land and still take the bounded merge (wider blocks use
    # the run log).  A quarter of the stage keeps the all-pairs compare
    # small while covering the emits-just-past-the-window DES shape.
    K = max(min(S, 32), S // 4)
    KS = K + S
    perm = _small_lex_perm(q.s_times, q.s_seqs)
    st = q.s_times[perm]
    sty = q.s_types[perm]
    sarg = q.s_args[perm]
    sseq = q.s_seqs[perm]
    sval = sty >= 0
    s_total = q.stage_n
    j_idx = jnp.arange(S, dtype=jnp.int32)

    def sub_block(offset, count):
        """Sorted sub-range [offset, offset+count) of the staged block
        as its own S-wide block (sentinels past ``count``)."""
        idx = jnp.clip(offset + j_idx, 0, S - 1)
        live = j_idx < count
        return (
            jnp.where(live, st[idx], jnp.inf),
            jnp.where(live, sty[idx], -1),
            jnp.where(live[:, None], sarg[idx], 0.0),
            jnp.where(live, sseq[idx], _I32_MAX),
        )

    # --- suffix: strictly after the main tail -> slack append ---------
    # main_n <= capacity = P - num_runs*S, so after a rotate there is
    # ALWAYS tail room for a stage_cap block.
    head0 = jnp.where(q.main_n > 0, q.m_head, 0)
    m_last = jnp.take(
        q.m_times, jnp.clip(head0 + q.main_n - 1, 0, P - 1))
    after_tail = sval & ((q.main_n == 0) | (st > m_last))
    n_suf = jnp.sum(after_tail).astype(jnp.int32)

    def append_suffix(q):
        q = jax.lax.cond(
            jnp.where(q.main_n > 0, q.m_head, 0) + q.main_n + S > P,
            _rotate_main, lambda q: q, q,
        )
        head1 = jnp.where(q.main_n > 0, q.m_head, 0)
        tail1 = head1 + q.main_n
        bt, by, ba, bs = sub_block(s_total - n_suf, n_suf)

        def put(col, bcol):
            return jax.lax.dynamic_update_slice_in_dim(col, bcol, tail1, 0)

        return q._replace(
            m_times=put(q.m_times, bt),
            m_types=put(q.m_types, by),
            m_args=put(q.m_args, ba),
            m_seqs=put(q.m_seqs, bs),
            m_head=head1,
            main_n=q.main_n + n_suf,
        )

    q = jax.lax.cond(n_suf > 0, append_suffix, lambda q: q, q)

    # --- prefix: strictly inside the head window -> bounded merge -----
    # (reads the post-suffix state: with a short main the window can
    # include just-appended elements; statically elided when the
    # window cannot even fit the ring — tiny-geometry configs, which
    # the run log covers)
    suf_lo = s_total - n_suf
    n_pre = jnp.int32(0)
    head = jnp.where(q.main_n > 0, q.m_head, 0)
    if KS <= P:
        ext_idx = jnp.clip(head + jnp.arange(KS, dtype=jnp.int32), 0, P - 1)
        ext_live = jnp.arange(KS) < q.main_n
        wt = jnp.where(ext_live, q.m_times[ext_idx], jnp.inf)
        ws = jnp.where(ext_live, q.m_seqs[ext_idx], _I32_MAX)
        wy = jnp.where(ext_live, q.m_types[ext_idx], -1)
        wa = jnp.where(ext_live[:, None], q.m_args[ext_idx], 0.0)
        n_pre_want = jnp.sum(
            sval & (j_idx < suf_lo) & (st < wt[K])
        ).astype(jnp.int32)
        # Without head-side headroom (or a window running off the physical
        # end), re-center the ring: rotation moves positions, not values,
        # so the window columns read above stay valid.
        q = jax.lax.cond(
            (n_pre_want > 0)
            & ((head < n_pre_want) | (head - n_pre_want + KS > P)),
            _rotate_main, lambda q: q, q,
        )
        head = jnp.where(q.main_n > 0, q.m_head, 0)
        # Guard again: degenerate geometries (margin clamped below n_pre)
        # still fall through to the run log.
        n_pre = jnp.where(
            (head >= n_pre_want) & (head - n_pre_want + KS <= P),
            n_pre_want, 0)

        def head_merge(q):
            # Counting merge of the prefix (first n_pre sorted entries)
            # with the sorted window: the B-positions come from all-pairs
            # strict lex compares (exact on true (time, seq) keys), the
            # output block from one searchsorted-driven gather per column.
            is_pre = j_idx < n_pre
            bt = jnp.where(is_pre, st, jnp.inf)
            bs = jnp.where(is_pre, sseq, _I32_MAX)
            w_lt_b = (wt[None, :] < bt[:, None]) | (
                (wt[None, :] == bt[:, None]) & (ws[None, :] < bs[:, None])
            )
            # pos_b ascends (B sorted); invalid rows push past the block.
            pos_b = jnp.where(
                is_pre,
                j_idx + jnp.sum(w_lt_b, axis=1).astype(jnp.int32),
                KS + S,
            )
            i_idx = jnp.arange(KS, dtype=jnp.int32)
            ins_before = jnp.searchsorted(
                pos_b, i_idx, side="right").astype(jnp.int32)
            is_ins = ins_before > jnp.searchsorted(
                pos_b, i_idx, side="left").astype(jnp.int32)
            src = jnp.where(
                is_ins, KS + jnp.clip(ins_before - 1, 0, S - 1),
                jnp.clip(i_idx - ins_before, 0, KS - 1),
            )
            start = head - n_pre

            def merge_put(col, wcol, bcol):
                merged = jnp.take(jnp.concatenate([wcol, bcol]), src, axis=0)
                return jax.lax.dynamic_update_slice_in_dim(
                    col, merged, start, 0)

            return q._replace(
                m_times=merge_put(q.m_times, wt, st),
                m_types=merge_put(q.m_types, wy, sty),
                m_args=merge_put(q.m_args, wa, sarg),
                m_seqs=merge_put(q.m_seqs, ws, sseq),
                m_head=start,
                main_n=q.main_n + n_pre,
            )

        q = jax.lax.cond(n_pre > 0, head_merge, lambda q: q, q)

    # --- middle: whatever neither leg could place -> one sorted run ---
    n_mid = s_total - n_suf - n_pre

    def to_run(q):
        q = jax.lax.cond(
            jnp.all(q.r_len > q.r_off), _merge_runs_into_main,
            lambda q: q, q,
        )
        slot = jnp.argmax(q.r_off >= q.r_len)  # first free run
        bt, by, ba, bs = sub_block(n_pre, n_mid)
        return q._replace(
            r_times=q.r_times.at[slot].set(bt),
            r_types=q.r_types.at[slot].set(by),
            r_args=q.r_args.at[slot].set(ba),
            r_seqs=q.r_seqs.at[slot].set(bs),
            r_off=q.r_off.at[slot].set(0),
            r_len=q.r_len.at[slot].set(n_mid),
        )

    q = jax.lax.cond(n_mid > 0, to_run, lambda q: q, q)

    empty_t, empty_y, empty_a, empty_s = _sentinel_cols(
        S, q.s_args.shape[1])
    return q._replace(
        s_times=empty_t, s_types=empty_y, s_args=empty_a, s_seqs=empty_s,
        stage_n=jnp.int32(0),
    )



def _runs_intersect_refill(q: Tiered3DeviceQueue):
    """True iff some run holds an element the next MAIN-ONLY refill
    would need: the main-only path takes the next
    ``min(front_cap - front_n, main_n)`` main elements, so a run
    matters only if its min key could precede the last of those.  A
    dormant far-future run (e.g. stragglers parked during warmup)
    then costs nothing: refills keep streaming from main and the run
    is consulted again only once the clock reaches it.  Strict time
    comparison — a tie falls back to the exact k-way merge.
    """
    take = jnp.minimum(q.front_cap - q.front_n, q.main_n)
    last_idx = jnp.clip(q.m_head + take - 1, 0, q.main_phys - 1)
    last_t = jnp.take(q.m_times, last_idx)
    # Empty main (take == 0) must still drain live runs.
    return jnp.min(_run_mins(q)) <= jnp.where(take > 0, last_t, jnp.inf)


def _refill_front3_windowed(w: int):
    """Front refill — always bounded, never O(capacity).

    Staging is flushed first (append / head merge / run).  With no run
    intersecting the take, the refill is the two-tier O(front_cap)
    main-head gather (:func:`_refill_main_only`); otherwise the
    bounded k-way merge (:func:`_refill_kway`) with its take capped at
    the static ``w`` — see there for why small top-ups win.
    """
    def refill(q):
        q = jax.lax.cond(
            q.stage_n > 0, _flush_stage_to_run, lambda q: q, q)
        return jax.lax.cond(
            _runs_intersect_refill(q),
            lambda q: _refill_kway(q, w), _refill_main_only, q,
        )

    return refill


def _refill_main_only(q: Tiered3DeviceQueue) -> Tiered3DeviceQueue:
    """Refill with an empty run pool (the common case once far-future
    flushes append straight to main): every main element sorts after
    every front element, so the refill is the two-tier O(front_cap)
    gather — no sort at all.  The main ring just advances ``m_head``.
    """
    F = q.front_cap
    P = q.main_phys
    take = jnp.minimum(F - q.front_n, q.main_n)
    i_idx = jnp.arange(F, dtype=jnp.int32)
    src = jnp.where(
        i_idx < q.front_n, i_idx,
        F + jnp.clip(q.m_head + i_idx - q.front_n, 0, P - 1),
    )
    fill_ok = i_idx < q.front_n + take

    def refill(fcol, mcol, fill):
        out = jnp.take(jnp.concatenate([fcol, mcol]), src, axis=0)
        mask = fill_ok if out.ndim == 1 else fill_ok[:, None]
        return jnp.where(mask, out, fill)

    main_n = q.main_n - take
    return q._replace(
        f_times=refill(q.f_times, q.m_times, jnp.inf),
        f_types=refill(q.f_types, q.m_types, -1),
        f_args=refill(q.f_args, q.m_args, 0.0),
        f_seqs=refill(q.f_seqs, q.m_seqs, 2**31 - 1),
        front_n=q.front_n + take,
        main_n=main_n,
        m_head=jnp.where(main_n > 0, q.m_head + take, 0),
    )


def _refill_kway(q: Tiered3DeviceQueue, w: int | None = None
                 ) -> Tiered3DeviceQueue:
    """Refill against a live run pool: the bounded k-way merge.

    The candidate set is the first ``w`` live elements of every run
    plus the main head window — (num_runs + 1) · w candidates,
    lex-ordered by their true ``(time, seq)`` keys with the all-pairs
    rank (fused bools; an XLA:CPU sort custom call would cost more
    than the whole merge).  The earliest ``min(front_cap - front_n,
    w)`` fill the front; each source just advances its head offset by
    the number taken (runs: ``r_off``; main: ``m_head``), so nothing
    is written back.  Any element outside a candidate window has ``w``
    same-source elements ahead of it, so it can never be among the
    earliest ``need <= w`` — the windows lose nothing.

    The engine calls this with a SMALL ``w`` (a few batch windows):
    topping the front up incrementally keeps N² at a few hundred
    squared — effectively free — where one full-front refill would
    need an N that forces a real sort.  O(num_runs · w²) per refill,
    independent of capacity.
    """
    F, R, S, P = q.front_cap, q.num_runs, q.stage_cap, q.main_phys
    W = F if w is None else min(w, F)
    N = (R + 1) * W

    widx = q.r_off[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]
    rvalid = widx < q.r_len[:, None]
    wc = jnp.clip(widx, 0, S - 1)
    ct_r = jnp.where(rvalid, jnp.take_along_axis(q.r_times, wc, axis=1),
                     jnp.inf)
    cy_r = jnp.take_along_axis(q.r_types, wc, axis=1)
    ca_r = jnp.take_along_axis(q.r_args, wc[:, :, None], axis=1)
    cs_r = jnp.where(rvalid, jnp.take_along_axis(q.r_seqs, wc, axis=1),
                     _I32_MAX)

    midx = jnp.clip(q.m_head + jnp.arange(W, dtype=jnp.int32), 0, P - 1)
    mvalid = jnp.arange(W) < q.main_n
    ct_m = jnp.where(mvalid, q.m_times[midx], jnp.inf)
    cy_m = q.m_types[midx]
    ca_m = q.m_args[midx]
    cs_m = jnp.where(mvalid, q.m_seqs[midx], _I32_MAX)

    ct = jnp.concatenate([ct_r.reshape(R * W), ct_m])
    cy = jnp.concatenate([cy_r.reshape(R * W), cy_m])
    ca = jnp.concatenate([ca_r.reshape(R * W, -1), ca_m])
    cs = jnp.concatenate([cs_r.reshape(R * W), cs_m])
    src = jnp.concatenate([
        jnp.repeat(jnp.arange(R, dtype=jnp.int32), W),
        jnp.full((W,), R, jnp.int32),
    ])
    valid = jnp.concatenate([rvalid.reshape(R * W), mvalid])

    order = _small_lex_perm(ct, cs)
    ct, cy, ca, cs = ct[order], cy[order], ca[order], cs[order]
    src, valid = src[order], valid[order]

    need = jnp.minimum(F - q.front_n, W)
    # Valid candidates form a sorted prefix (sentinels are lex-max), so
    # the take mask is a prefix too — the taken block lands in front
    # slots [front_n, front_n + taken) already sorted.
    take = (jnp.arange(N) < need) & valid
    taken = jnp.sum(take).astype(jnp.int32)
    counts = jnp.zeros((R + 2,), jnp.int32).at[
        jnp.where(take, src, R + 1)
    ].add(1, mode="drop")

    main_taken = counts[R]
    main_n = q.main_n - main_taken
    i_idx = jnp.arange(F, dtype=jnp.int32)
    srcF = jnp.where(
        i_idx < q.front_n, i_idx,
        F + jnp.clip(i_idx - q.front_n, 0, N - 1),
    )
    fill_ok = i_idx < q.front_n + taken

    def refill(fcol, ccol, fill):
        out = jnp.take(jnp.concatenate([fcol, ccol]), srcF, axis=0)
        mask = fill_ok if out.ndim == 1 else fill_ok[:, None]
        return jnp.where(mask, out, fill)

    return q._replace(
        f_times=refill(q.f_times, ct, jnp.inf),
        f_types=refill(q.f_types, cy, -1),
        f_args=refill(q.f_args, ca, 0.0),
        f_seqs=refill(q.f_seqs, cs, 2**31 - 1),
        front_n=q.front_n + taken,
        r_off=q.r_off + counts[:R],
        main_n=main_n,
        m_head=jnp.where(main_n > 0, q.m_head + main_taken, 0),
    )


def tiered3_queue_peek_front(q: Tiered3DeviceQueue, k: int):
    """Shard-aware entry point: the queue's ``k`` earliest events.

    Refills the front exactly as :func:`tiered3_queue_extract` would
    (the bounded :func:`_refill_front3_windowed` path), then returns
    the first ``k`` front slots WITHOUT popping — free slots read as
    the ``(inf, -1, 0, i32_max)`` sentinels.  The sharded engine merges
    these candidate heads across shards to reconstruct the exact
    global §III-B window, then pops each shard's taken prefix with
    :func:`tiered3_queue_pop_prefix`.

    Returns ``(q', ts, tys, args, seqs)``.
    """
    if k > q.front_cap:
        raise ValueError(
            f"peek width {k} exceeds front tier capacity {q.front_cap}"
        )
    F = q.front_cap
    need_refill = (q.front_n < k) & (
        (q.stage_n > 0) | (q.main_n > 0) | jnp.any(q.r_len > q.r_off)
    )
    # Small k-way top-ups (a few windows' worth) keep the live-run
    # merge in all-pairs territory; the empty-pool path still refills
    # the whole front in one gather.
    q = jax.lax.cond(
        need_refill, _refill_front3_windowed(min(F, 4 * k)),
        lambda q: q, q,
    )
    return q, q.f_times[:k], q.f_types[:k], q.f_args[:k], q.f_seqs[:k]


def tiered3_queue_pop_prefix(q: Tiered3DeviceQueue, length, k: int
                             ) -> Tiered3DeviceQueue:
    """Pop the first ``length`` (<= static ``k``) front events: shift
    every front column left by ``length`` (one fused ``dynamic_slice``
    per column, exactly the :func:`tiered3_queue_extract` pop).  The
    caller must have established ``length <= front_n`` via
    :func:`tiered3_queue_peek_front` — taken candidates are always a
    valid front prefix."""
    F = q.front_cap

    def shift(col, fill):
        pad = jnp.full((k,) + col.shape[1:], fill, col.dtype)
        return jax.lax.dynamic_slice_in_dim(
            jnp.concatenate([col, pad]), length, F
        )

    return q._replace(
        f_times=shift(q.f_times, jnp.inf),
        f_types=shift(q.f_types, -1),
        f_args=shift(q.f_args, 0.0),
        f_seqs=shift(q.f_seqs, 2**31 - 1),
        front_n=q.front_n - length,
        size=q.size - length,
    )


def tiered3_queue_extract(q: Tiered3DeviceQueue, max_len: int, lookaheads,
                          t_cap=None, kernels: str = "xla", bound=None):
    """Window extraction from the front tier (paper Fig 2).

    Identical take rule and output as :func:`tiered_queue_extract`;
    the drained-front refill is the bounded path of
    :func:`_refill_front3_windowed` instead of a staging flush into
    main.  Composed from the shard-aware halves — refill+read
    (:func:`tiered3_queue_peek_front`) and prefix pop
    (:func:`tiered3_queue_pop_prefix`) — so the sharded engine's split
    extraction shares every line with the single-queue path the
    differential suites pin.

    ``kernels="pallas"`` runs the post-refill hot loop (§III-B take
    rule + prefix pop) as one Pallas kernel
    (:func:`repro.kernels.queue_front.window_extract`) — bit-identical
    output, front columns stay in VMEM on TPU, interpret mode
    elsewhere.  The bounded refill itself stays in XLA (it is the rare
    amortized path, not the per-batch one).

    ``bound`` optionally caps the candidate set at a lexicographic
    ``(time, seq)`` key: only events strictly lex-BEFORE it are
    eligible.  This is the spill policy's ordering fence — while a
    spilled event is held host-side, nothing at or past its key may
    execute — and since the eligible set is a lex prefix of the sorted
    candidates, the §III-B take rule sees it as the queue simply
    ending earlier (XLA kernels only).

    Returns ``(q', ts, tys, args, length)``.
    """
    if max_len > q.front_cap:
        raise ValueError(
            f"max_len {max_len} exceeds front tier capacity {q.front_cap}"
        )
    k = max_len
    num_types = lookaheads.shape[0]

    if kernels == "pallas":
        if bound is not None:
            raise ValueError(
                "lex-bounded extraction (spill) is XLA-only; use "
                "queue_kernels='xla'"
            )
        from repro.kernels.queue_front import window_extract

        q, _ts_c, _tys_c, _args_c, _seqs_c = tiered3_queue_peek_front(q, k)
        (ts, tys, args, length,
         nf_t, nf_y, nf_a, nf_s) = window_extract(
            q.f_times, q.f_types, q.f_args, q.f_seqs,
            lookaheads, t_cap, k=k,
        )
        q = q._replace(
            f_times=nf_t, f_types=nf_y, f_args=nf_a, f_seqs=nf_s,
            front_n=q.front_n - length,
            size=q.size - length,
        )
        return q, ts, tys, args, length

    q, ts_c, tys_c, args_c, seqs_c = tiered3_queue_peek_front(q, k)
    valid = tys_c >= 0
    if bound is not None:
        b_t, b_s = bound
        valid = valid & (
            (ts_c < b_t) | ((ts_c == b_t) & (seqs_c < b_s))
        )
    la = lookaheads[jnp.clip(tys_c, 0, num_types - 1)]
    wins = jnp.where(valid, ts_c + la, jnp.inf)
    take = window_prefix_mask(ts_c, wins, valid, t_cap)
    length = jnp.sum(take).astype(jnp.int32)

    ts = jnp.where(take, ts_c, 0.0)
    tys = jnp.where(take, tys_c, 0)
    args = jnp.where(take[:, None], args_c, 0.0)

    q = tiered3_queue_pop_prefix(q, length, k)
    return q, ts, tys, args, length


def _tiered3_boundary(q: Tiered3DeviceQueue):
    """Earliest key outside the front tier: min over staging, the run
    summaries, and the main ring head (read at the ring offset — slots
    before ``m_head`` are dead and must not leak into the boundary)."""
    m_min = jnp.where(
        q.main_n > 0,
        jnp.take(q.m_times, jnp.clip(q.m_head, 0, q.main_phys - 1)),
        jnp.inf,
    )
    return jnp.minimum(
        jnp.minimum(m_min, jnp.min(q.s_times)), jnp.min(_run_mins(q))
    )


def _lex_min_pair(t1, s1, t2, s2):
    """Lexicographic min of two ``(time, seq)`` keys (elementwise)."""
    t = jnp.minimum(t1, t2)
    s = jnp.minimum(
        jnp.where(t1 == t, s1, _I32_MAX),
        jnp.where(t2 == t, s2, _I32_MAX),
    )
    return t, s


def _tiered3_boundary_key(q: Tiered3DeviceQueue):
    """Lexicographic ``(time, seq)`` form of :func:`_tiered3_boundary`:
    the earliest full key outside the front tier.  Needed wherever the
    time-only boundary is ambiguous — reabsorbing spilled rows whose
    seqs are older than queued ones (:func:`tiered3_queue_absorb_rows`).
    O(stage_cap + num_runs)."""
    s_t = jnp.min(q.s_times)
    s_s = jnp.min(jnp.where(
        (q.s_times == s_t) & (q.s_types >= 0), q.s_seqs, _I32_MAX
    ))
    r_heads_t = _run_mins(q)
    r_heads_s = jnp.where(
        q.r_len > q.r_off,
        jnp.take_along_axis(
            q.r_seqs, jnp.clip(q.r_off, 0, q.stage_cap - 1)[:, None],
            axis=1,
        )[:, 0],
        _I32_MAX,
    )
    r_t = jnp.min(r_heads_t)
    r_s = jnp.min(jnp.where(r_heads_t == r_t, r_heads_s, _I32_MAX))
    m_idx = jnp.clip(q.m_head, 0, q.main_phys - 1)
    m_t = jnp.where(q.main_n > 0, jnp.take(q.m_times, m_idx), _INF)
    m_s = jnp.where(q.main_n > 0, jnp.take(q.m_seqs, m_idx), _I32_MAX)
    t, s = _lex_min_pair(s_t, s_s, r_t, r_s)
    return _lex_min_pair(t, s, m_t, m_s)


def tiered3_queue_next_key(q: Tiered3DeviceQueue):
    """Full ``(time, seq)`` key of the earliest pending event —
    ``(inf, i32_max)`` when empty.  The lex refinement of
    :func:`tiered3_queue_next_time`, used by the spill policy's
    while-loop guard (no event at or past the spilled bound may run
    before the spill reabsorbs)."""
    b_t, b_s = _tiered3_boundary_key(q)
    t = jnp.where(q.front_n > 0, q.f_times[0], b_t)
    s = jnp.where(q.front_n > 0, q.f_seqs[0], b_s)
    return t, s


def _tiered3_preflush(q: Tiered3DeviceQueue, R: int) -> Tiered3DeviceQueue:
    """Make room for up to ``R`` staging appends (direct + evicted)
    before a fill, via the bounded run-log flush."""
    if R > q.stage_cap:
        raise ValueError(
            f"emit block of {R} rows exceeds stage_cap {q.stage_cap}"
        )
    return jax.lax.cond(
        q.stage_n + R > q.stage_cap, _flush_stage_to_run, lambda q: q, q
    )


def tiered3_queue_fill_rows(q: Tiered3DeviceQueue, rows,
                            kernels: str = "xla") -> Tiered3DeviceQueue:
    """Per-batch emit insert touching only the front and staging tiers.

    Same partition and accounting as :func:`tiered_queue_fill_rows`
    (the shared :func:`_tiered_fill_finish`; boundary now spans staging
    ∪ runs ∪ main; drop rule unchanged: valid row ``r`` is a ghost iff
    ``size + r >= capacity``), but the pre-flush when staging could
    overflow writes one sorted run (O(stage_cap),
    capacity-independent) instead of merging into main — near-full
    near-head pressure no longer touches an O(capacity) path on any
    per-batch route.  No eviction tags: runs keep true seqs and every
    downstream merge is a true ``(time, seq)`` lex sort.
    """
    rows = jnp.asarray(rows, jnp.float32)
    q = _tiered3_preflush(q, rows.shape[0])
    seq_r, insert, counters = _default_fill_accounting(q, rows)
    return _tiered_fill_finish(
        q, rows, _tiered3_boundary(q), seq_r, insert, counters,
        kernels=kernels,
    )


def tiered3_queue_fill_rows_tagged(q: Tiered3DeviceQueue, rows, seqs,
                                   insert, kernels: str = "xla"
                                   ) -> Tiered3DeviceQueue:
    """Shard-aware emit insert: seqs and survival are decided UPSTREAM.

    The sharded engine assigns seqs from ONE global counter across all
    shards and applies the reference overflow rule against the GLOBAL
    logical capacity, then routes each row to its destination shard —
    so this entry point takes ``seqs`` (i32[R], must exceed every seq
    already queued in any shard) and ``insert`` (bool[R], the rows this
    shard actually absorbs: globally surviving AND routed here) instead
    of deriving them from the local counters.  Rows outside ``insert``
    are ignored entirely (ghost accounting lives in the engine's global
    counters), so the local ``size`` tracks real occupancy and
    ``dropped`` stays 0 on shard queues.  Merge mechanics are byte-for-
    byte the single-queue path (:func:`_tiered_fill_finish`).
    """
    rows = jnp.asarray(rows, jnp.float32)
    seqs = jnp.asarray(seqs, jnp.int32)
    q = _tiered3_preflush(q, rows.shape[0])
    insert = insert & (rows[:, 1] >= 0)
    n_ins = jnp.sum(insert).astype(jnp.int32)
    counters = dict(
        size=q.size + n_ins,
        next_seq=jnp.maximum(
            q.next_seq, jnp.max(jnp.where(insert, seqs + 1, 0))
        ),
        dropped=q.dropped,
    )
    return _tiered_fill_finish(
        q, rows, _tiered3_boundary(q), seqs, insert, counters,
        kernels=kernels,
    )


def tiered3_queue_absorb_rows(q: Tiered3DeviceQueue, rows, seqs,
                              insert=None) -> Tiered3DeviceQueue:
    """Absorb out-of-band rows carrying externally assigned seqs.

    Two callers: the overflow='spill' policy reabsorbing previously
    spilled rows at a segment boundary, and the streaming ingest path
    absorbing arrival blocks (DESIGN.md §10).  Unlike fresh emits, the
    rows' seqs may be OLDER than seqs queued after them, so both the
    boundary partition and the front-merge placement must compare full
    lexicographic ``(time, seq)`` keys — the ``b_seq`` mode of
    :func:`_tiered_fill_finish`.  Counters follow the occupancy
    discipline of the tagged fill (``size`` = real occupancy,
    ``dropped`` untouched, ``next_seq`` maxed past every absorbed seq);
    the caller guarantees the inserted rows fit (occupancy + inserted
    <= capacity) — absorption never drops.

    ``insert`` optionally masks rows (ANDed with ``type >= 0``): the
    streamed admission path uses a traced ``[lo, hi)`` prefix mask so
    one jitted absorb serves any admitted-row count.

    Host-driven (segment boundaries, off the hot path): rows are
    chunked to ``stage_cap`` so each chunk satisfies the preflush
    contract.  Row layout ``(time, type, arg...)``; ``type < 0`` rows
    are skipped.
    """
    rows = jnp.asarray(rows, jnp.float32)
    seqs = jnp.asarray(seqs, jnp.int32)
    S = q.stage_cap
    for start in range(0, int(rows.shape[0]), S):
        chunk = rows[start:start + S]
        chunk_seqs = seqs[start:start + S]
        q = _tiered3_preflush(q, int(chunk.shape[0]))
        insert_c = chunk[:, 1] >= 0
        if insert is not None:
            insert_c = insert_c & jnp.asarray(insert)[start:start + S]
        n_ins = jnp.sum(insert_c).astype(jnp.int32)
        counters = dict(
            size=q.size + n_ins,
            next_seq=jnp.maximum(
                q.next_seq,
                jnp.max(jnp.where(insert_c, chunk_seqs + 1, 0)),
            ),
            dropped=q.dropped,
        )
        b_t, b_s = _tiered3_boundary_key(q)
        q = _tiered_fill_finish(
            q, chunk, b_t, chunk_seqs, insert_c, counters, b_seq=b_s
        )
    return q


def tiered3_queue_to_flat(q: Tiered3DeviceQueue) -> DeviceQueue:
    """Canonical flat view of a tiered3 queue (host-side, for tests)."""
    head, main_n = int(q.m_head), int(q.main_n)
    off = np.asarray(q.r_off)
    rlen = np.asarray(q.r_len)
    parts = []
    for pre in ("f", "s"):
        parts.append(tuple(
            np.asarray(getattr(q, f"{pre}_{name}"))
            for name in ("times", "types", "args", "seqs")
        ))
    mcols = tuple(
        np.asarray(getattr(q, f"m_{name}"))[head:head + main_n]
        for name in ("times", "types", "args", "seqs")
    )
    parts.append(mcols)
    for i in range(q.num_runs):
        parts.append(tuple(
            np.asarray(getattr(q, f"r_{name}"))[i, off[i]:rlen[i]]
            for name in ("times", "types", "args", "seqs")
        ))
    times = np.concatenate([p[0] for p in parts])
    types = np.concatenate([p[1] for p in parts])
    args = np.concatenate([p[2] for p in parts])
    seqs = np.concatenate([p[3] for p in parts])
    occ = types >= 0
    order = np.lexsort((seqs[occ], times[occ]))
    n = int(occ.sum())
    C = q.capacity
    assert n <= C, "tier occupancy exceeded logical capacity"
    out_t = np.full((C,), np.inf, np.float32)
    out_y = np.full((C,), -1, np.int32)
    out_a = np.zeros((C, q.f_args.shape[1]), np.float32)
    out_s = np.full((C,), 2**31 - 1, np.int32)
    out_t[:n] = times[occ][order]
    out_y[:n] = types[occ][order]
    out_a[:n] = args[occ][order]
    out_s[:n] = seqs[occ][order]
    return DeviceQueue(
        times=jnp.asarray(out_t), types=jnp.asarray(out_y),
        args=jnp.asarray(out_a), seqs=jnp.asarray(out_s),
        size=jnp.asarray(q.size), next_seq=jnp.asarray(q.next_seq),
        dropped=jnp.asarray(q.dropped),
    )
