"""Pending-event set: host binary heap + device-resident array queue.

The paper's runtime mechanism reads the set of future events in
non-decreasing timestamp order (§III-B).  Two implementations:

* :class:`HostEventQueue` — a classic binary heap over
  :class:`repro.core.events.Event`, used by the paper-faithful host
  scheduler and by the serving engine's host control plane.

* :class:`DeviceEventQueue` — a fixed-capacity struct-of-arrays queue
  whose operations are pure jnp (usable inside ``lax.while_loop``), used
  by the fully on-device scheduler.

Device queue layout
-------------------
``types == -1`` marks a free slot, and free slots always hold the
sentinel key ``(time=+inf, seq=i32_max)`` so they order after every real
event.  ``seq`` is the global insertion counter used for deterministic
``(time, seq)`` lexicographic pop order.  ``size`` counts *logical*
pushes (it keeps incrementing past ``capacity`` on overflow so callers
can detect it); ``dropped`` counts events lost to overflow.

Two families of operations are provided:

* **Reference ops** (seed semantics, layout-independent, O(capacity)
  work *per event* with a serial dependence chain):
  :func:`device_queue_peek`, :func:`device_queue_pop`,
  :func:`device_queue_push`, :func:`device_queue_push_rows`,
  :func:`device_queue_extract_ref`.  Pop is a masked argmin; push is a
  first-free-slot scatter.  Kept as the executable specification for
  differential tests.

* **Vectorized single-pass ops**, which require and preserve the
  *canonical layout*: occupied slots form a prefix of the arrays,
  ordered by ``(time, seq)`` (:func:`device_queue_from_host` builds it;
  an empty queue has it trivially).  With the pending set kept sorted,
  every per-batch interaction is a constant number of fused
  data-parallel passes — no sorts, no reductions, no serial chains:

  - :func:`device_queue_extract` reads the lookahead window directly
    from the first ``max_batch_len`` slots, evaluates the §III-B
    dynamic-lookahead take rule as a shifted ``cummin`` + prefix mask
    (:func:`window_prefix_mask` — the rule is monotone on time-sorted
    candidates, so no serial scan is needed), and pops all taken slots
    by shifting each column left with one ``dynamic_slice``.

  - :func:`device_queue_fill_rows` merges a whole emit block at once:
    merge positions come from all-pairs key comparisons
    (rows × capacity fused bools, a counting merge), and each column is
    rebuilt with a single gather/select pass.

  Both reproduce the reference ops' ``(time, seq)`` pop order and
  overflow behaviour bit-exactly; the two families must not be
  interleaved on one queue (the reference pushes do not maintain the
  canonical layout).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import ARG_WIDTH, Event

_INF = jnp.float32(jnp.inf)
_I32_MAX = jnp.int32(2**31 - 1)


class HostEventQueue:
    """Binary heap of Events keyed by (time, seq)."""

    def __init__(self):
        self._heap: list[tuple[float, int, Event]] = []
        self._seq = 0
        self.push_count = 0
        self.pop_count = 0

    def push(self, time: float, type_id: int, arg: Any = None) -> Event:
        ev = Event(time=float(time), type_id=int(type_id), arg=arg, seq=self._seq)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._seq += 1
        self.push_count += 1
        return ev

    def push_event(self, ev: Event) -> None:
        ev = dataclasses.replace(ev, seq=self._seq)
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        self._seq += 1
        self.push_count += 1

    def pop(self) -> Event:
        self.pop_count += 1
        return heapq.heappop(self._heap)[2]

    def peek(self) -> Event:
        return self._heap[0][2]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


class DeviceQueue(NamedTuple):
    """Struct-of-arrays pending-event set (a JAX pytree).

    ``types == -1`` marks a free slot.  ``seq`` is the global insertion
    counter used for deterministic tie-breaking.  ``dropped`` counts
    events lost to capacity overflow (surfaced in the engine run stats).
    """

    times: jnp.ndarray   # f32[capacity]
    types: jnp.ndarray   # i32[capacity], -1 = empty
    args: jnp.ndarray    # f32[capacity, ARG_WIDTH]
    seqs: jnp.ndarray    # i32[capacity]
    size: jnp.ndarray    # i32 scalar
    next_seq: jnp.ndarray  # i32 scalar
    dropped: jnp.ndarray   # i32 scalar, overflow-dropped event count

    @property
    def capacity(self) -> int:
        return self.times.shape[0]


def device_queue_init(capacity: int, arg_width: int = ARG_WIDTH) -> DeviceQueue:
    return DeviceQueue(
        times=jnp.full((capacity,), jnp.inf, jnp.float32),
        types=jnp.full((capacity,), -1, jnp.int32),
        args=jnp.zeros((capacity, arg_width), jnp.float32),
        seqs=jnp.full((capacity,), 2**31 - 1, jnp.int32),
        size=jnp.int32(0),
        next_seq=jnp.int32(0),
        dropped=jnp.int32(0),
    )


def device_queue_from_host(
    events, capacity: int, arg_width: int = ARG_WIDTH
) -> DeviceQueue:
    """Build a seed queue host-side and move it in ONE device_put.

    ``events`` is a sequence of ``(time, type_id, arg)`` with ``arg``
    either ``None`` or an ``f32[arg_width]`` vector.  Semantically
    identical to ``device_queue_push`` applied in order — slot ``i``
    holds event ``i``, ``seq`` runs 0..N-1, events past ``capacity``
    are dropped with ``size``/``next_seq`` still advancing — but costs
    one transfer instead of N jitted dispatches.
    """
    events = list(events)
    n = len(events)
    m = min(n, capacity)
    times = np.full((capacity,), np.inf, np.float32)
    types = np.full((capacity,), -1, np.int32)
    args = np.zeros((capacity, arg_width), np.float32)
    seqs = np.full((capacity,), 2**31 - 1, np.int32)
    for i, (t, ty, arg) in enumerate(events[:m]):
        times[i] = t
        types[i] = ty
        if arg is not None:
            args[i] = np.asarray(arg, np.float32)
        seqs[i] = i
    # Canonical layout (see module docstring): occupied slots form a
    # prefix sorted by (time, seq).  The reference ops are
    # layout-independent; the vectorized ops require and preserve it.
    order = np.lexsort((seqs[:m], times[:m]))
    times[:m] = times[order]
    types[:m] = types[order]
    args[:m] = args[order]
    seqs[:m] = seqs[order]
    return jax.device_put(DeviceQueue(
        times=times,
        types=types,
        args=args,
        seqs=seqs,
        size=np.int32(n),
        next_seq=np.int32(n),
        dropped=np.int32(n - m),
    ))


# ---------------------------------------------------------------------------
# Reference per-event ops (seed semantics; executable specification)
# ---------------------------------------------------------------------------

def device_queue_push(q: DeviceQueue, time, type_id, arg) -> DeviceQueue:
    """Insert one event into the first free slot (pure jnp).

    If the queue is full the event is dropped, the ``dropped`` counter
    increments, and ``size``/``next_seq`` still advance so callers can
    detect overflow (the engine surfaces ``dropped`` in its run stats).
    """
    occupied = q.types >= 0
    # argmin over the boolean mask finds the first False (free) slot.
    slot = jnp.argmin(occupied)
    have_room = q.size < q.capacity
    time = jnp.asarray(time, jnp.float32)
    type_id = jnp.asarray(type_id, jnp.int32)
    arg = jnp.asarray(arg, jnp.float32)

    def do_push(q):
        return q._replace(
            times=q.times.at[slot].set(time),
            types=q.types.at[slot].set(type_id),
            args=q.args.at[slot].set(arg),
            seqs=q.seqs.at[slot].set(q.next_seq),
            size=q.size + 1,
            next_seq=q.next_seq + 1,
        )

    def overflow(q):
        return q._replace(
            size=q.size + 1, next_seq=q.next_seq + 1, dropped=q.dropped + 1
        )

    return jax.lax.cond(have_room, do_push, overflow, q)


def device_queue_push_rows(q: DeviceQueue, rows) -> DeviceQueue:
    """Reference bulk insert: one serial ``device_queue_push`` per row.

    Row layout is ``(time, type, arg...)``; ``type < 0`` rows are
    skipped.  O(rows × capacity) with a serial dependence chain — kept
    as the executable specification for :func:`device_queue_fill_rows`.
    """
    def body(i, q):
        row = rows[i]
        t, ty = row[0], row[1].astype(jnp.int32)
        return jax.lax.cond(
            ty >= 0,
            lambda q: device_queue_push(q, t, ty, row[2:]),
            lambda q: q,
            q,
        )

    return jax.lax.fori_loop(0, rows.shape[0], body, q)


def _min_key_slot(q: DeviceQueue):
    """Index of the occupied slot with lexicographic-min (time, seq)."""
    occupied = q.types >= 0
    times = jnp.where(occupied, q.times, jnp.inf)
    tmin = jnp.min(times)
    at_min = occupied & (times == tmin)
    seqs = jnp.where(at_min, q.seqs, _I32_MAX)
    slot = jnp.argmin(seqs)
    return slot, tmin


def device_queue_peek(q: DeviceQueue):
    """(time, type, slot) of the earliest event; type=-1 when empty."""
    slot, tmin = _min_key_slot(q)
    empty = q.size <= 0
    t = jnp.where(empty, _INF, tmin)
    ty = jnp.where(empty, jnp.int32(-1), q.types[slot])
    return t, ty, slot


def device_queue_pop(q: DeviceQueue):
    """Remove and return the earliest event.

    Returns ``(q', time, type, arg)``; when empty, type is -1 and the
    queue is unchanged.
    """
    t, ty, slot = device_queue_peek(q)
    arg = q.args[slot]
    nonempty = ty >= 0

    def do_pop(q):
        return q._replace(
            times=q.times.at[slot].set(jnp.inf),
            types=q.types.at[slot].set(-1),
            seqs=q.seqs.at[slot].set(2**31 - 1),
            size=q.size - 1,
        )

    q = jax.lax.cond(nonempty, do_pop, lambda q: q, q)
    return q, t, ty, arg


def device_queue_extract_ref(q: DeviceQueue, max_len: int, lookaheads):
    """Reference window extraction: ``max_len`` serial peek/pop rounds.

    The seed engine's loop (paper Fig 2 evaluated one event at a time):
    each round is an O(capacity) masked argmin inside ``lax.cond``, with
    a serial dependence between rounds.  Returns
    ``(q', ts, tys, args, length)`` with zero-padding past ``length``.
    Kept as the executable specification for
    :func:`device_queue_extract`.
    """
    ts0 = jnp.zeros((max_len,), jnp.float32)
    tys0 = jnp.zeros((max_len,), jnp.int32)
    args0 = jnp.zeros((max_len, q.args.shape[1]), jnp.float32)

    def body(i, carry):
        queue, ts, tys, args, length, t_max, done = carry
        t, ty, _slot = device_queue_peek(queue)
        can_take = (~done) & (ty >= 0) & (t <= t_max)

        def take(_):
            q2, t2, ty2, arg2 = device_queue_pop(queue)
            ts2 = ts.at[i].set(t2)
            tys2 = tys.at[i].set(ty2)
            args2 = args.at[i].set(arg2)
            t_max2 = jnp.minimum(t_max, t2 + lookaheads[ty2])
            return q2, ts2, tys2, args2, length + 1, t_max2, done

        def skip(_):
            return queue, ts, tys, args, length, t_max, jnp.bool_(True)

        return jax.lax.cond(can_take, take, skip, None)

    init = (q, ts0, tys0, args0, jnp.int32(0), _INF, jnp.bool_(False))
    q, ts, tys, args, length, _t_max, _done = jax.lax.fori_loop(
        0, max_len, body, init
    )
    return q, ts, tys, args, length


# ---------------------------------------------------------------------------
# Vectorized single-pass ops
# ---------------------------------------------------------------------------

def _small_lex_perm(ts, sq):
    """Permutation sorting a TINY vector by (ts, sq, index) ascending.

    XLA:CPU sorts are custom calls with large fixed overhead, so for the
    k-element candidate vectors (k = max_batch_len class) the rank of
    each element is computed from all-pairs comparisons (m² tiny bools,
    fully fused) and inverted with an m-element scatter.
    """
    m = ts.shape[0]
    i = jnp.arange(m, dtype=jnp.int32)
    t_lt = ts[:, None] > ts[None, :]
    t_eq = ts[:, None] == ts[None, :]
    s_lt = sq[:, None] > sq[None, :]
    s_eq = sq[:, None] == sq[None, :]
    before = t_lt | (t_eq & s_lt) | (t_eq & s_eq & (i[:, None] > i[None, :]))
    rank = jnp.sum(before, axis=1).astype(jnp.int32)  # unique in [0, m)
    return jnp.zeros((m,), jnp.int32).at[rank].set(i)


def window_prefix_mask(ts, wins, valid):
    """Vectorized §III-B dynamic-lookahead take rule.

    Given candidates already sorted by ``(time, seq)``, the serial rule
    — take event ``i`` iff every earlier candidate was taken and
    ``t_i <= t_max`` where ``t_max = min over taken j<i of (t_j + l_j)``
    — is *monotone*: once a candidate is rejected no later one can be
    taken.  It therefore reduces to two scans: a shifted (exclusive)
    ``cummin`` over the window bounds ``wins = t + l``, and a prefix-AND
    (via cumsum of rejections) that implements the stop condition.

    Shared with :func:`repro.core.scheduler.extract_window`, which is
    the host/serial form of the same rule; the differential tests assert
    their equivalence.
    """
    ts = jnp.asarray(ts, jnp.float32)
    wins = jnp.asarray(wins, jnp.float32)
    # Exclusive cummin of the window bounds: t_max before candidate i.
    t_max = jnp.concatenate(
        [jnp.full((1,), jnp.inf, jnp.float32), jax.lax.cummin(wins)[:-1]]
    )
    ok = valid & (ts <= t_max)
    # Prefix-AND: no rejection at any earlier position.
    return jnp.cumsum(~ok) == 0


def device_queue_extract(q: DeviceQueue, max_len: int, lookaheads):
    """Single-pass window extraction (paper Fig 2, fully vectorized).

    Requires the canonical sorted layout (occupied slots form a prefix
    ordered by ``(time, seq)`` — see the module docstring), which makes
    the ``max_len`` earliest events simply the first ``max_len`` slots:
    no reductions, no sort, no serial dependence.  The dynamic lookahead
    rule is applied with :func:`window_prefix_mask`, and all taken slots
    are popped at once by shifting every column left by ``length`` (one
    fused ``dynamic_slice`` per column) — preserving the invariant.

    Bit-identical batch output to :func:`device_queue_extract_ref`
    (lexicographic pop order, tie-breaks, zero-padding) at a constant
    number of data-parallel passes per *batch* instead of
    O(max_len × capacity) serially dependent work.

    Returns ``(q', ts, tys, args, length)``.
    """
    if max_len > q.capacity:
        raise ValueError(
            f"max_len {max_len} exceeds queue capacity {q.capacity}"
        )
    k = max_len
    cap = q.capacity
    num_types = lookaheads.shape[0]
    ts_c = q.times[:k]
    tys_c = q.types[:k]

    valid = tys_c >= 0
    la = lookaheads[jnp.clip(tys_c, 0, num_types - 1)]
    wins = jnp.where(valid, ts_c + la, jnp.inf)
    take = window_prefix_mask(ts_c, wins, valid)
    length = jnp.sum(take).astype(jnp.int32)

    ts = jnp.where(take, ts_c, 0.0)
    tys = jnp.where(take, tys_c, 0)
    args = jnp.where(take[:, None], q.args[:k], 0.0)

    # Pop the taken prefix: shift every column left by `length`,
    # refilling the tail with the free-slot sentinels.
    def shift(col, fill):
        pad = jnp.full((k,) + col.shape[1:], fill, col.dtype)
        return jax.lax.dynamic_slice_in_dim(
            jnp.concatenate([col, pad]), length, cap
        )

    q = q._replace(
        times=shift(q.times, jnp.inf),
        types=shift(q.types, -1),
        args=shift(q.args, 0.0),
        seqs=shift(q.seqs, 2**31 - 1),
        size=q.size - length,
    )
    return q, ts, tys, args, length


def device_queue_fill_rows(q: DeviceQueue, rows) -> DeviceQueue:
    """Bulk emit insert: merge a whole ``f32[R, 2+W]`` block at once.

    Row layout is ``(time, type, arg...)``; ``type < 0`` rows are
    skipped.  Requires and preserves the canonical sorted layout: valid
    row ``j`` (the ``r``-th valid row) receives ``seq = next_seq + r``
    — exactly the seq assignment of :func:`device_queue_push_rows` —
    and the surviving rows are merged into the sorted queue in one
    vectorized counting-merge: every merge position is computed from
    all-pairs key comparisons (R·capacity fused bools, no sort, no
    scan), and each queue column is rebuilt with a single gather/select
    pass.  Rows past capacity are dropped with ``size``/``next_seq``
    still advancing and ``dropped`` counted, matching the reference
    overflow semantics.
    """
    rows = jnp.asarray(rows, jnp.float32)
    R = rows.shape[0]
    C = q.capacity
    t_r = rows[:, 0]
    ty_r = rows[:, 1].astype(jnp.int32)
    arg_r = rows[:, 2:]

    valid = ty_r >= 0
    # Rank of each row among the valid rows, via all-pairs counting (R
    # is tiny; avoids a scan thunk per engine-loop iteration).
    r_idx = jnp.arange(R, dtype=jnp.int32)
    vrank = jnp.sum(
        (r_idx[None, :] <= r_idx[:, None]) & valid[None, :], axis=1
    ).astype(jnp.int32) - 1
    num_valid = jnp.sum(valid).astype(jnp.int32)
    # Serial-push overflow rule: row r inserts iff size + r < capacity
    # (size counts logical pushes, so it may already exceed occupancy).
    insert = valid & (q.size + vrank < C)
    num_insert = jnp.sum(insert).astype(jnp.int32)
    seq_r = q.next_seq + vrank

    # Order the surviving rows by (time, arrival): arrival order equals
    # seq order, and dropped rows are pushed past everything real.
    perm = _small_lex_perm(
        jnp.where(insert, t_r, jnp.inf),
        jnp.where(insert, r_idx, _I32_MAX),
    )
    rt = jnp.where(insert, t_r, jnp.inf)[perm]
    rty = ty_r[perm]
    rarg = arg_r[perm]
    rseq = seq_r[perm]
    rins = insert[perm]

    # Merge positions.  Keys are strictly totally ordered: row seqs are
    # all >= next_seq while queued seqs are all < next_seq, so EVERY
    # equal-time queued event precedes the new row — the count of queued
    # events before row r is therefore a plain searchsorted(side=right)
    # over the sorted times, capped at the occupancy so the (+inf,
    # i32_max) free-slot sentinels are never counted.
    # pos[r] = (#queued events before row r) + r, the second term
    # counting the earlier (sorted, inserting) rows.
    occupancy = jnp.sum(q.types >= 0).astype(jnp.int32)
    older = jnp.minimum(
        jnp.searchsorted(q.times, rt, side="right").astype(jnp.int32),
        occupancy,
    )
    pos = jnp.where(rins, older + r_idx, C)

    # Rebuild each column with one gather pass: output slot i holds
    # sorted row `ins_before[i]` if some row lands at i, else the queued
    # entry shifted right by the rows inserted before it.
    i_idx = jnp.arange(C, dtype=jnp.int32)
    ins_before = jnp.sum(pos[None, :] < i_idx[:, None], axis=1).astype(
        jnp.int32
    )
    is_ins = jnp.sum(pos[None, :] == i_idx[:, None], axis=1) > 0
    src = jnp.where(
        is_ins, C + jnp.clip(ins_before, 0, R - 1),
        jnp.clip(i_idx - ins_before, 0, C - 1),
    )

    def merge(col, rcol):
        return jnp.take(jnp.concatenate([col, rcol]), src, axis=0)

    return q._replace(
        times=merge(q.times, rt),
        types=merge(q.types, rty),
        args=merge(q.args, rarg),
        seqs=merge(q.seqs, rseq),
        size=q.size + num_valid,
        next_seq=q.next_seq + num_valid,
        dropped=q.dropped + (num_valid - num_insert),
    )
