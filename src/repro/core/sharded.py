"""Sharded device engine: lookahead-synchronized multi-queue execution.

PARSIR-style conservative PDES (PAPERS.md) scales past one processor by
partitioning the pending set across engines and letting each run ahead
only as far as a lookahead-bounded horizon.  :class:`ShardedDeviceEngine`
brings that structure to the on-device runtime: entities are partitioned
across ``shards`` per-shard :class:`~repro.core.queue.Tiered3DeviceQueue`
pending sets, each super-step synchronizes the shard clocks under a
shared conservative horizon, and cross-shard emissions travel through
fixed-capacity exchange blocks merged into the destination queues with
the same bounded counting-merge primitives the single queue uses (no
sorts, no scatters — the XLA:CPU traps, DESIGN.md §4.4).

The horizon, honestly
---------------------
Every super-step:

1. **peek** — each shard surfaces its ``max_batch_len`` earliest events
   (:func:`~repro.core.queue.tiered3_queue_peek_front`: the tiered3
   front tier after its bounded refill), O(front_cap) per shard.
2. **merge** — the ``shards × max_batch_len`` candidate heads are
   lex-ordered by their true global ``(time, seq)`` keys (all-pairs
   rank — the candidate set is tiny) and the §III-B dynamic-lookahead
   take rule (:func:`~repro.core.queue.window_prefix_mask`) runs over
   the first ``max_batch_len`` of the merged order.  Because every
   pending event is among its own shard's ``max_batch_len`` earliest
   whenever it is among the ``max_batch_len`` globally earliest, this
   reconstructs EXACTLY the window the single-queue engine would
   extract.  The window's dynamic bound ``min over taken (t_j + l_j)``
   is the conservative synchronization horizon; it is bounded below by
   ``min_i(next_time_i) + min_lookahead`` — the classic conservative
   floor (no shard can receive a cross-shard event below it) — but the
   merged evaluation is exact where the floor alone would under- or
   over-take.
3. **pop** — the take set is a prefix of the merged order, so each
   shard's taken events are a prefix of its own candidates; shard ``i``
   pops its count with one
   :func:`~repro.core.queue.tiered3_queue_pop_prefix` shift.
4. **dispatch** — the merged window runs through the identical
   composed-batch dispatch path as :class:`~repro.core.engine
   .DeviceEngine` (switch or vmapped entity runs), so the state update
   is bit-identical.
5. **exchange** — emitted rows get seqs from ONE global counter
   (``next_seq + vrank``, the reference rule) and the global overflow
   rule (ghost iff ``size + vrank >= capacity``, ``size`` counting
   ghosts) — both computed BEFORE routing, so accounting cannot depend
   on the partition.  Each destination shard then absorbs its routed
   rows from the fixed ``max_batch_len × max_emit``-row exchange block
   via :func:`~repro.core.queue.tiered3_queue_fill_rows_tagged` — the
   single-queue counting-merge fill with seqs/survival supplied.

Because each super-step reproduces the single-queue window exactly —
same events, same order, same batch grouping, same seqs, same ghosts —
the sharded run is bit-identical to ``queue_mode="tiered3"`` with one
queue: final state, executed (time, seq) sequence, ``dropped``,
``final_time``, and even ``batches``.  The executable contract lives in
``tests/test_sharded_engine.py`` and the shared parity harness
(``tests/_parity.py``).

Compilation shape
-----------------
The shard queues are a TUPLE of :class:`Tiered3DeviceQueue` pytrees and
the per-shard legs (peek, pop, exchange fill) are an unrolled Python
loop, so each shard's buffers thread through the ``while_loop`` carry
as separate arrays that XLA updates IN PLACE — per-super-step cost
stays bounded (capacity-independent) like the single queue's.  Two
tempting alternatives are wrong at scale and were measured so:
``lax.scan`` over stacked shards compiles the machinery once (~4×
faster compile at N=4) but its xs/ys slicing re-materializes every
shard's capacity-sized leaves every super-step — O(N·capacity) memcpy
per batch, ~45–100× slower at 64k and GROWING with capacity; ``vmap``
additionally lowers the rare-path ``lax.cond``s to select pairs that
execute both branches (including the O(capacity) ring rotate) for
every shard every step.  Compile time is therefore linear in
``shards`` (~7 s per shard on CPU) — the price of bounded runtime.

Routing
-------
``shard_fn(tys, args) -> i32[rows]`` maps each emitted event to a
shard.  The default routes by ``arg[0]`` — the entity index of
entity-parallel types (``@prog.entity_handler`` puts the entity id
there) and the conventional routing slot of emitting types (PHOLD's
destination LP, the serving scenario's request id) — reduced mod
``shards``.  Any deterministic routing is CORRECT (parity never depends
on the partition, only load balance does); results of a custom
``shard_fn`` are reduced mod ``shards`` so no row can be lost to an
out-of-range destination.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import validate as _validate
from repro.core.engine import DeviceEngine
from repro.core.events import ARG_WIDTH
from repro.core.validate import FAULT_CLOCK
from repro.core.queue import (
    DeviceQueue,
    Tiered3DeviceQueue,
    _prefix_rank,
    _small_lex_perm,
    tiered3_queue_absorb_rows,
    tiered3_queue_fill_rows_tagged,
    tiered3_queue_from_host,
    tiered3_queue_has_pending,
    tiered3_queue_next_key,
    tiered3_queue_next_time,
    tiered3_queue_occupancy,
    tiered3_queue_peek_front,
    tiered3_queue_pop_prefix,
    tiered3_queue_to_flat,
    window_prefix_mask,
)

__all__ = ["ShardedDeviceEngine", "ShardedQueue", "sharded_queue_to_flat"]


class ShardedQueue(NamedTuple):
    """The sharded pending set (a JAX pytree): N per-shard tiered3
    queues plus the GLOBAL logical counters.

    The global counters carry the reference overflow/seq semantics —
    ``size`` counts logical pushes including ghosts, ``next_seq`` is
    the one seq counter all shards share, ``dropped`` the global ghost
    count — while each shard's local ``size`` tracks only its real
    occupancy (shard-local ``dropped`` stays 0; see
    :func:`~repro.core.queue.tiered3_queue_fill_rows_tagged`).  The
    logical capacity is the single-queue ``capacity`` (each shard can
    physically hold all of it, so routing skew never causes drops the
    single queue would not have had).
    """

    shards: tuple[Tiered3DeviceQueue, ...]
    size: jnp.ndarray      # i32 scalar, global logical pushes (+ghosts)
    next_seq: jnp.ndarray  # i32 scalar, global seq counter
    dropped: jnp.ndarray   # i32 scalar, global overflow drops

    @property
    def num_shards(self) -> int:
        return len(self.shards)

    @property
    def capacity(self) -> int:
        return self.shards[0].capacity

    def shard(self, i: int) -> Tiered3DeviceQueue:
        return self.shards[i]


def sharded_queue_to_flat(sq: ShardedQueue) -> DeviceQueue:
    """Canonical flat view of a sharded queue (host-side, for tests).

    Gathers every shard's occupied slots, sorts by the global
    ``(time, seq)`` key, and lays them out as one canonical
    :class:`~repro.core.queue.DeviceQueue` carrying the GLOBAL
    counters — directly comparable to the single-queue flat views.
    """
    cols = []
    for i in range(sq.num_shards):
        flat = tiered3_queue_to_flat(sq.shard(i))
        occ = np.asarray(flat.types) >= 0
        cols.append((np.asarray(flat.times)[occ], np.asarray(flat.types)[occ],
                     np.asarray(flat.args)[occ], np.asarray(flat.seqs)[occ]))
    times = np.concatenate([c[0] for c in cols])
    types = np.concatenate([c[1] for c in cols])
    args = np.concatenate([c[2] for c in cols])
    seqs = np.concatenate([c[3] for c in cols])
    order = np.lexsort((seqs, times))
    n = times.shape[0]
    C = sq.capacity
    assert n <= C, "sharded occupancy exceeded global logical capacity"
    out_t = np.full((C,), np.inf, np.float32)
    out_y = np.full((C,), -1, np.int32)
    out_a = np.zeros((C, args.shape[1]), np.float32)
    out_s = np.full((C,), 2**31 - 1, np.int32)
    out_t[:n], out_y[:n], out_a[:n], out_s[:n] = (
        times[order], types[order], args[order], seqs[order]
    )
    return DeviceQueue(
        times=jnp.asarray(out_t), types=jnp.asarray(out_y),
        args=jnp.asarray(out_a), seqs=jnp.asarray(out_s),
        size=jnp.asarray(sq.size), next_seq=jnp.asarray(sq.next_seq),
        dropped=jnp.asarray(sq.dropped),
    )


@dataclasses.dataclass
class ShardedDeviceEngine(DeviceEngine):
    """Multi-queue device engine, bit-identical to the single queue.

    Preferred entry point: ``repro.api.SimProgram.build(
    backend="device", shards=N)``.  Direct usage mirrors
    :class:`~repro.core.engine.DeviceEngine`::

        eng = ShardedDeviceEngine(registry, shards=4, capacity=65536,
                                  max_batch_len=8)
        queue = eng.initial_queue(events)     # -> ShardedQueue
        state, queue, stats = eng.run(state0, queue)

    All :class:`DeviceEngine` knobs apply per shard (each shard is a
    full tiered3 queue with the same ``front_cap``/``stage_cap``/
    ``num_runs`` geometry); ``queue_mode`` must remain ``"tiered3"``
    (the per-shard pending-set implementation this engine is built
    on).  ``shard_fn`` customizes event routing (module docstring) —
    it must be a pure jnp function of ``(tys, args)``; its result is
    reduced mod ``shards``.  The queue argument to :meth:`run` is
    donated exactly as in the parent.
    """

    shards: int = 2
    shard_fn: Callable | None = None

    def __post_init__(self, use_vectorized_queue):
        if self.queue_mode != "tiered3":
            raise ValueError(
                f"ShardedDeviceEngine requires queue_mode='tiered3' "
                f"(got {self.queue_mode!r}): the per-shard pending sets "
                "are tiered3 queues"
            )
        if self.shards < 1:
            raise ValueError(f"shards must be >= 1, got {self.shards}")
        if self.overflow == "spill":
            raise ValueError(
                "overflow='spill' is not supported on the sharded engine "
                "yet: the spill fence is a single-queue lex bound "
                "(use overflow='drop' or 'error')"
            )
        super().__post_init__(use_vectorized_queue)

    @classmethod
    def from_program(cls, program, *, shards: int = 2,
                     shard_fn: Callable | None = None,
                     queue_mode: str = "tiered3",
                     capacity: int | None = None,
                     front_cap: int | None = None,
                     stage_cap: int | None = None,
                     num_runs: int | None = None,
                     dispatch_mode: str = "switch",
                     hot_words=None,
                     queue_kernels: str = "xla",
                     validate: str = "off",
                     overflow: str = "drop",
                     t_end: float = float("inf")) -> "ShardedDeviceEngine":
        """Construct the sharded device backend from a frozen SimProgram
        (cf. :meth:`DeviceEngine.from_program`; the entity→shard mapping
        falls out of the entity-handler ``arg[0]`` convention unless a
        ``shard_fn`` overrides it)."""
        cfg = program.config
        return cls(
            program.device_registry(),
            max_batch_len=cfg.max_batch_len,
            capacity=cfg.capacity if capacity is None else capacity,
            max_emit=cfg.max_emit,
            t_end=t_end,
            queue_mode=queue_mode,
            front_cap=front_cap,
            stage_cap=stage_cap,
            num_runs=num_runs,
            dispatch_mode=dispatch_mode,
            hot_words=hot_words,
            queue_kernels=queue_kernels,
            validate=validate,
            overflow=overflow,
            entity_handlers=program.device_entity_handlers() or None,
            shards=shards,
            shard_fn=shard_fn,
        )

    # -- routing ------------------------------------------------------------
    def _shard_of(self, tys, args):
        """Destination shard per row, always in ``[0, shards)``."""
        if self.shard_fn is not None:
            dest = jnp.asarray(self.shard_fn(tys, args), jnp.int32)
        else:
            dest = jnp.abs(args[:, 0].astype(jnp.int32))
        return dest % jnp.int32(self.shards)

    # -- queue construction -------------------------------------------------
    def initial_queue(self, events) -> ShardedQueue:
        """Partition the seed across shards under the GLOBAL seq and
        overflow rules: event ``i`` keeps seq ``i`` and is a ghost iff
        ``i >= capacity`` (the reference ``from_host`` semantics),
        THEN the survivors are routed — so the seed is bit-equivalent
        to the single queue's regardless of the partition."""
        events = list(events)
        n = len(events)
        C = self.capacity
        survivors = events[:C]
        if survivors:
            tys = jnp.asarray([ty for (_, ty, _) in survivors], jnp.int32)
            args = np.zeros((len(survivors), ARG_WIDTH), np.float32)
            for i, (_, _, arg) in enumerate(survivors):
                if arg is not None:
                    args[i] = np.asarray(arg, np.float32)
            dest = np.asarray(self._shard_of(tys, jnp.asarray(args)))
        else:
            dest = np.zeros((0,), np.int32)
        shard_qs = []
        for s in range(self.shards):
            mine = np.flatnonzero(dest == s)
            shard_qs.append(tiered3_queue_from_host(
                [survivors[i] for i in mine], C,
                front_cap=self.front_cap, stage_cap=self.stage_cap,
                num_runs=self.num_runs, seqs=mine,
            ))
        return ShardedQueue(
            shards=tuple(shard_qs),
            size=jnp.int32(n),
            next_seq=jnp.int32(n),
            dropped=jnp.int32(n - len(survivors)),
        )

    # -- run accounting -----------------------------------------------------
    def queue_occupancy(self, queue):
        """Real pending-event count summed across shards."""
        return sum(
            (tiered3_queue_occupancy(q) for q in queue.shards),
            jnp.int32(0),
        )

    def _cheap_fault_bits(self, queue):
        return _validate.sharded_fault_bits(queue)

    def absorb_rows(self, sq, rows, seqs, insert):
        """Absorb stream-arrival rows where ``insert`` is set: route
        through ``shard_fn`` like any exchange, absorb per shard under
        the full lex key, and advance the GLOBAL counters (``size`` by
        the inserted count — the occupancy discipline; ``dropped``
        untouched).  Caller guarantees the masked rows fit globally."""
        rows = jnp.asarray(rows, jnp.float32)
        seqs = jnp.asarray(seqs, jnp.int32)
        insert = jnp.asarray(insert) & (rows[:, 1] >= 0)
        dest = self._shard_of(rows[:, 1].astype(jnp.int32), rows[:, 2:])
        shard_qs = tuple(
            tiered3_queue_absorb_rows(q, rows, seqs,
                                      insert=insert & (dest == i))
            for i, q in enumerate(sq.shards)
        )
        n_ins = jnp.sum(insert).astype(jnp.int32)
        return ShardedQueue(
            shards=shard_qs,
            size=sq.size + n_ins,
            next_seq=jnp.maximum(
                sq.next_seq, jnp.max(jnp.where(insert, seqs + 1, 0))
            ),
            dropped=sq.dropped,
        )

    # -- main loop ----------------------------------------------------------
    def _run(self, state, queue, t_end, max_batches, stats0):
        k = self.max_batch_len
        N = self.shards
        num_types = len(self.registry)
        lookaheads = self._lookaheads
        validate_on = self.validate != "off"
        # Streamed-arrival admission fence (DESIGN.md §10): carried
        # structurally, exactly as in the single-queue engine — closed
        # runs compile a fence-free loop.
        fenced = "bound_t" in stats0
        I32_MAX = jnp.int32(2**31 - 1)

        def cond(carry):
            state, sq, stats = carry
            del state
            pending = jnp.any(jnp.stack(
                [tiered3_queue_has_pending(q) for q in sq.shards]
            ))
            next_t = jnp.min(jnp.stack(
                [tiered3_queue_next_time(q) for q in sq.shards]
            ))
            ok = (
                pending
                & (stats["batches"] < max_batches)
                & (next_t <= t_end)
            )
            if validate_on:
                ok = ok & (stats["fault_word"] == 0)
            if self.overflow == "error":
                ok = ok & (sq.dropped == 0)
            if fenced:
                # The globally earliest pending (time, seq) must be
                # lex-below the bound, else the segment ends and the
                # host absorbs the next arrival block first.
                keys = [tiered3_queue_next_key(q) for q in sq.shards]
                kt = jnp.stack([t for t, _ in keys])
                ks = jnp.stack([s for _, s in keys])
                nk_t = jnp.min(kt)
                nk_s = jnp.min(jnp.where(kt == nk_t, ks, I32_MAX))
                below = (nk_t < stats["bound_t"]) | (
                    (nk_t == stats["bound_t"])
                    & (nk_s < stats["bound_seq"])
                )
                ok = ok & below
            return ok

        def body(carry):
            state, sq, stats = carry

            # 1. peek: each shard's earliest k events (bounded refill).
            # Unrolled per shard — NOT a scan/vmap — so each shard's
            # capacity-sized buffers thread the while-loop carry as
            # separate in-place arrays (module docstring: scan's xs/ys
            # slicing would copy O(N·capacity) per super-step).
            peeked = [tiered3_queue_peek_front(q, k) for q in sq.shards]
            qs = [p[0] for p in peeked]
            cts = jnp.concatenate([p[1] for p in peeked])
            ctys = jnp.concatenate([p[2] for p in peeked])
            cargs = jnp.concatenate([p[3] for p in peeked])
            cseqs = jnp.concatenate([p[4] for p in peeked])
            csrc = jnp.repeat(jnp.arange(N, dtype=jnp.int32), k)

            # 2. merge + exact global window (the horizon evaluation).
            order = _small_lex_perm(cts, cseqs)[:k]
            ts_c = cts[order]
            tys_c = ctys[order]
            args_c = cargs[order]
            src_c = csrc[order]
            valid = tys_c >= 0
            if fenced:
                # Candidates at/past the admission bound are invisible
                # this super-step; they form a suffix of the lex-merged
                # order, so the §III-B prefix take rule is unaffected.
                seqs_c = cseqs[order]
                valid = valid & (
                    (ts_c < stats["bound_t"])
                    | ((ts_c == stats["bound_t"])
                       & (seqs_c < stats["bound_seq"]))
                )
            la = lookaheads[jnp.clip(tys_c, 0, num_types - 1)]
            wins = jnp.where(valid, ts_c + la, jnp.inf)
            take = window_prefix_mask(ts_c, wins, valid, t_end)
            length = jnp.sum(take).astype(jnp.int32)

            ts = jnp.where(take, ts_c, 0.0)
            tys = jnp.where(take, tys_c, 0)
            args = jnp.where(take[:, None], args_c, 0.0)

            # 3. pop each shard's taken prefix.
            qs = [
                tiered3_queue_pop_prefix(
                    qs[i],
                    jnp.sum(take & (src_c == i)).astype(jnp.int32),
                    k,
                )
                for i in range(N)
            ]

            # 4. dispatch: the parent's composed-batch path, verbatim.
            state, emits = self._dispatch_window(state, ts, tys, args,
                                                 length)

            # 5. global seq + overflow accounting (reference rule; the
            # insert-time size is POST-extract, as in the single queue).
            ty_r = emits[:, 1].astype(jnp.int32)
            valid_r = ty_r >= 0
            vrank = _prefix_rank(valid_r)
            num_valid = jnp.sum(valid_r).astype(jnp.int32)
            size_mid = sq.size - length
            insert = valid_r & (size_mid + vrank < self.capacity)
            num_insert = jnp.sum(insert).astype(jnp.int32)
            seq_r = sq.next_seq + vrank

            # 6. exchange: route rows; each shard absorbs its slice of
            # the fixed R-row exchange block.
            dest = self._shard_of(ty_r, emits[:, 2:])
            qs = [
                tiered3_queue_fill_rows_tagged(
                    qs[i], emits, seq_r, insert & (dest == i),
                    kernels=self.queue_kernels,
                )
                for i in range(N)
            ]

            sq = ShardedQueue(
                shards=tuple(qs),
                size=size_mid + num_valid,
                next_seq=sq.next_seq + num_valid,
                dropped=sq.dropped + (num_valid - num_insert),
            )
            last_t = ts[jnp.maximum(length - 1, 0)]
            prev_time = stats["time"]
            new_stats = {
                "batches": stats["batches"] + 1,
                "events": stats["events"] + length,
                "emitted": stats["emitted"] + num_valid,
                "time": jnp.maximum(stats["time"], last_t),
            }
            if self._track_word_counts:
                code = self.codec.encode_jnp(tys, length)
                new_stats["word_counts"] = \
                    stats["word_counts"].at[code].add(1)
            if fenced:
                new_stats["bound_t"] = stats["bound_t"]
                new_stats["bound_seq"] = stats["bound_seq"]
            if validate_on:
                bits = self._cheap_fault_bits(sq)
                bits = bits | jnp.where(
                    (length > 0) & (ts[0] < prev_time),
                    jnp.int32(FAULT_CLOCK), jnp.int32(0),
                )
                # Word only — the faulting step is reconstructed from
                # ``batches`` at exit (see DeviceEngine.run).
                new_stats["fault_word"] = stats["fault_word"] | bits
            return state, sq, new_stats

        return jax.lax.while_loop(cond, body, (state, queue, stats0))
