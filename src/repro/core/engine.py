"""Host-runtime facade and the fully on-device DES engine.

This is the BACKEND layer: models should be defined once with
:class:`repro.api.SimProgram` and compiled here via
``prog.build(backend=..., ...)`` (DESIGN.md §1.1) — both classes below
expose ``from_program`` constructors for that path.  Direct
construction remains supported for benchmarks and tests that probe one
runtime mechanism.

Two runtimes (DESIGN.md §2):

* **Host runtime** (paper-faithful): :class:`Simulator` drives a Python
  event loop over a binary heap, dispatching pre-composed jitted batch
  programs — the direct analogue of the paper's function-pointer
  dispatch.

* **Device runtime** (TPU-native adaptation): :class:`DeviceEngine`
  compiles the ENTIRE simulation — queue, lookahead-window extraction,
  Horner encoding, batch dispatch — into one XLA program built around
  ``lax.while_loop`` + ``lax.switch``.  Every composed batch body is a
  contiguous fragment inside that module, so XLA applies cross-event
  optimization exactly as clang does in the paper, and there are zero
  host round-trips during the run.

Per-batch scheduling cost is selected by ``queue_mode`` (DESIGN.md §4):

* ``"tiered3"`` (default) — the log-structured third tier (DESIGN.md
  §4.4): staging flushes become bounded sorted runs and front refills
  a bounded k-way merge, so no per-batch path is O(capacity) even at
  >=90% occupancy; the one O(capacity) compaction amortizes over an
  entire run pool.  Serves every regime including near-full 64k+
  scenarios, which is why it is the default (promoted after soaking in
  the serving scenarios since PR 4).
* ``"tiered"`` — two-tier queue; per-batch work touches only the small
  front/staging tiers, so scheduling overhead is independent of queue
  capacity on the common path (the staging flush merge is still
  O(capacity) under near-full, near-head re-emit pressure).
* ``"flat"`` — the PR-1 single-array vectorized ops: a constant number
  of data-parallel passes, but the emit merge is O(capacity) per batch.
* ``"reference"`` — seed semantics for differential testing and the
  overhead benchmark: extraction is the serial per-event argmin chain
  (the executable spec), inserts the one-pass
  :func:`device_queue_push_rows` (bit-identical to the serial seed
  pushes INCLUDING slot placement; the serial chain survives as
  ``device_queue_push_rows_serial``, exercised by the differential
  tests).

The queue argument to :meth:`DeviceEngine.run` is DONATED to the jitted
program (its buffers are reused for the output queue), so a queue value
must not be reused after being passed to ``run`` — rebuild it with
:meth:`DeviceEngine.initial_queue` or use the returned queue.

Single-type-run windows can additionally bypass the sequential switch
branch: event types listed in ``entity_handlers`` are dispatched through
``vmap`` over entity slices of the state
(:func:`repro.core.vectorize.make_masked_run_handler`) — the
serving-style data-parallel win, now available on the device engine.

On-device emit convention: handlers marked with ``@emits_events`` return
``(state, emits)`` with ``emits: f32[max_emit, 2 + ARG_WIDTH]`` rows of
``(absolute_time, type, arg...)``; ``type == -1`` marks unused slots.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import DenseCodec, PaperCodec, make_codec
from repro.core.composer import (
    EagerComposer,
    LazyComposer,
    build_fused_dispatcher,
    build_masked_dispatcher,
    build_switch_dispatcher,
)
from repro.core.events import ARG_WIDTH, EventRegistry
from repro.core.queue import (
    DeviceQueue,
    HostEventQueue,
    Tiered3DeviceQueue,
    TieredDeviceQueue,
    _prefix_rank,
    device_queue_extract,
    device_queue_extract_ref,
    device_queue_fill_rows,
    device_queue_from_host,
    device_queue_next_time,
    device_queue_next_time_ref,
    device_queue_push_rows,
    tiered3_queue_absorb_rows,
    tiered3_queue_extract,
    tiered3_queue_fill_rows,
    tiered3_queue_fill_rows_tagged,
    tiered3_queue_from_host,
    tiered3_queue_has_pending,
    tiered3_queue_next_key,
    tiered3_queue_next_time,
    tiered3_queue_occupancy,
    tiered_queue_extract,
    tiered_queue_fill_rows,
    tiered_queue_from_host,
    tiered_queue_has_pending,
    tiered_queue_next_time,
    tiered_queue_occupancy,
)
from repro.core import validate as _validate
from repro.core.validate import FAULT_CLOCK, FAULT_OVERFLOW, EngineFaultError
from repro.core.scheduler import (
    ConservativeScheduler,
    RunStats,
    SpeculativeScheduler,
    run_unbatched,
)
from repro.core.vectorize import make_masked_run_handler


class Simulator:
    """Host-runtime facade over registry + queue + scheduler.

    Backend layer: prefer defining models once with
    :class:`repro.api.SimProgram` and compiling via
    ``prog.build(backend="host", ...)`` — the same definition then also
    runs on the device engine.
    """

    @classmethod
    def from_program(cls, program, *, composer: str = "lazy",
                     state_spec=None, arg_spec=None) -> "Simulator":
        """Construct the host backend from a frozen SimProgram, with the
        program's scheduled initial events already queued."""
        cfg = program.config
        sim = cls(
            program.host_registry(),
            max_batch_len=cfg.max_batch_len,
            codec=cfg.codec,
            composer=composer,
            state_spec=state_spec,
            arg_spec=arg_spec,
        )
        for (t, type_id, arg) in program.scheduled_events():
            sim.queue.push(t, type_id, arg)
        return sim

    def __init__(self, registry: EventRegistry, *, max_batch_len: int = 4,
                 codec: str = "dense", composer: str = "lazy",
                 state_spec=None, arg_spec=None):
        registry.freeze()
        self.registry = registry
        self.codec = make_codec(codec, len(registry), max_batch_len)
        if composer == "lazy":
            self.composer = LazyComposer(registry, self.codec)
        elif composer == "eager":
            self.composer = EagerComposer(
                registry, self.codec, state_spec=state_spec, arg_spec=arg_spec
            )
        else:
            raise ValueError(f"unknown composer {composer!r}")
        self.queue = HostEventQueue()

    def schedule(self, time: float, type_name: str, arg: Any = None):
        et = self.registry[type_name]
        return self.queue.push(time, et.type_id, arg)

    def run(self, state, *, mode: str = "conservative",
            max_events: int | None = None) -> tuple[Any, RunStats]:
        if mode == "conservative":
            sched = ConservativeScheduler(self.registry, self.composer)
            return sched.run(state, self.queue, max_events=max_events)
        if mode == "speculative":
            sched = SpeculativeScheduler(self.registry, self.composer)
            return sched.run(state, self.queue, max_events=max_events)
        if mode == "unbatched":
            return run_unbatched(
                self.registry, state, self.queue, max_events=max_events
            )
        raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# On-device engine
# ---------------------------------------------------------------------------

# Default hot-set width for dispatch_mode="fused" without declared
# hot_words, and the num_batches ceiling for carrying the per-word
# batch-count histogram in the run stats (beyond it the i32[num_batches]
# carry would dominate the loop state for pathological alphabets).
_DEFAULT_HOT_W = 32
_WORD_COUNT_LIMIT = 4096


@dataclasses.dataclass
class DeviceEngine:
    """Builder for the single-program on-device simulation.

    Preferred entry point: ``repro.api.SimProgram.build(
    backend="device", ...)``, which constructs this class via
    :meth:`from_program` and wraps the run/queue lifecycle in a
    re-runnable ``CompiledSim``.  Direct usage::

        eng = DeviceEngine(registry, max_batch_len=4, capacity=1024)
        queue = eng.initial_queue([(t, type_id, arg_vec), ...])
        final_state, final_queue, stats = eng.run(state0, queue,
                                                  max_batches=10_000)

    ``eng.run`` is jitted once; repeat calls with same-shaped inputs are
    pure device execution.  The queue argument is donated (consumed) —
    build a fresh one per run or chain the returned queue.  Run stats
    include ``dropped``, the number of emitted events lost to
    queue-capacity overflow.

    ``queue_mode`` selects the pending-set implementation:
    ``"tiered3"`` (default: log-structured run tier with bounded
    worst-case per-batch cost at any occupancy/capacity),
    ``"tiered"`` (two-tier: capacity-independent per-batch cost on the
    common path only), ``"flat"`` (PR-1 single-array vectorized ops),
    or ``"reference"`` (seed semantics: serial-spec extraction + the
    bit-identical one-pass bulk insert).  For multi-queue execution
    see :class:`repro.core.sharded.ShardedDeviceEngine`.
    ``front_cap``/``stage_cap`` size the tiered queues' front tier and
    staging ring and ``num_runs`` the tiered3 run pool; the defaults
    scale with ``max_batch_len`` and ``max_emit`` and are clamped to
    valid ranges.

    ``dispatch_mode`` selects how an extracted window reaches its
    handlers (DESIGN.md §7; all three are bit-identical):

    * ``"switch"`` (default) — one ``lax.switch`` over ALL composed
      batch words; maximal cross-event scope, compile cost Σ Tᵏ.
    * ``"masked"`` — the generic per-lane masked path (per-handler
      scope; O(T·max_batch_len) compile, no cross-event optimization).
    * ``"fused"`` — two-level: the top-W *hot* words (``hot_words``,
      or a profiled histogram via
      :func:`repro.core.composer.hot_words_from_counts`; default: the
      first ``32`` dense codes) run as straight-line super-procedures
      behind a bounded W+1-way switch, everything else falls back to
      the masked path.  W-linear compile, hot windows keep the full
      cross-event scope.

    ``queue_kernels`` selects the tiered3 front-tier hot-loop
    implementation: ``"xla"`` (default — the all-pairs-rank + gather
    shapes tuned for XLA:CPU) or ``"pallas"`` (Pallas kernels in
    ``repro.kernels.queue_front`` keeping the window extract and the
    front counting-merge in VMEM; interpret mode off-TPU, bit-identical
    output, requires ``queue_mode="tiered3"``).

    ``entity_handlers`` maps a type_id to an entity-local handler
    ``(entity_state, t, arg) -> entity_state`` over slices of the state
    pytree (leading axis = entity).  When an extracted window is a
    single-type run of such a type, the engine dispatches it as one
    ``vmap`` over the touched entities (``arg[0]`` is the entity index)
    instead of the sequential switch branch.  The registered sequential
    handler must match the local handler's semantics — it still serves
    mixed windows.  Entity-parallel types must not emit events, and a
    window must not contain two events for the same entity.
    """

    registry: EventRegistry
    max_batch_len: int = 4
    capacity: int = 1024
    max_emit: int = 2
    t_end: float = float("inf")
    queue_mode: str = "tiered3"
    front_cap: int | None = None
    stage_cap: int | None = None
    num_runs: int | None = None
    dispatch_mode: str = "switch"
    hot_words: Any = None
    queue_kernels: str = "xla"
    entity_handlers: Mapping[int, Callable] | None = None
    validate: str = "off"
    overflow: str = "drop"
    # Removed 2024-era flag; kept as an InitVar so old call sites get a
    # pointer at queue_mode instead of a generic unexpected-kwarg error.
    use_vectorized_queue: dataclasses.InitVar[Any] = None

    def __post_init__(self, use_vectorized_queue):
        if use_vectorized_queue is not None:
            raise TypeError(
                "DeviceEngine(use_vectorized_queue=...) was removed; "
                "pass queue_mode='flat' (True) or queue_mode="
                "'reference' (False) instead — or build through "
                "repro.api.SimProgram.build(backend='device', "
                "queue_mode=...)."
            )
        self.registry.freeze()
        if self.queue_mode not in ("tiered", "tiered3", "flat",
                                   "reference"):
            raise ValueError(
                f"unknown queue_mode {self.queue_mode!r}; expected "
                "'tiered', 'tiered3', 'flat', or 'reference'"
            )
        if self.dispatch_mode not in ("switch", "masked", "fused"):
            raise ValueError(
                f"unknown dispatch_mode {self.dispatch_mode!r}; expected "
                "'switch', 'masked', or 'fused'"
            )
        if self.hot_words is not None and self.dispatch_mode != "fused":
            raise ValueError(
                "hot_words only applies to dispatch_mode='fused' "
                f"(got dispatch_mode={self.dispatch_mode!r})"
            )
        if self.queue_kernels not in ("xla", "pallas"):
            raise ValueError(
                f"unknown queue_kernels {self.queue_kernels!r}; expected "
                "'xla' or 'pallas'"
            )
        if self.queue_kernels == "pallas" and self.queue_mode != "tiered3":
            raise ValueError(
                "queue_kernels='pallas' requires queue_mode='tiered3' "
                f"(got {self.queue_mode!r}): the Pallas kernels implement "
                "the tiered3 front-tier hot loops"
            )
        if self.validate not in ("off", "cheap", "full"):
            raise ValueError(
                f"unknown validate {self.validate!r}; expected "
                "'off', 'cheap', or 'full'"
            )
        if self.overflow not in ("drop", "error", "spill"):
            raise ValueError(
                f"unknown overflow {self.overflow!r}; expected "
                "'drop', 'error', or 'spill'"
            )
        if self.overflow == "spill" and self.queue_mode != "tiered3":
            raise ValueError(
                "overflow='spill' requires queue_mode='tiered3' (got "
                f"{self.queue_mode!r}): spilled rows reabsorb through "
                "the tiered3 tagged-fill path"
            )
        if self.overflow == "spill" and self.queue_kernels != "xla":
            raise ValueError(
                "overflow='spill' requires queue_kernels='xla': the "
                "lex-bounded extraction fence is XLA-only"
            )
        # Tier sizing: the rare O(capacity) paths (front refill, staging
        # flush) amortize over ~front_cap/max_batch_len resp.
        # ~stage_cap/emit_rows batches, so both tiers default to many
        # multiples of the per-batch quanta.
        emit_rows = self.max_batch_len * self.max_emit
        if self.front_cap is None:
            self.front_cap = max(256, 8 * self.max_batch_len)
        self.front_cap = min(max(self.front_cap, self.max_batch_len),
                             self.capacity)
        if self.stage_cap is None:
            self.stage_cap = max(256, 8 * emit_rows)
        self.stage_cap = max(self.stage_cap, emit_rows)
        # Run pool: one compaction per num_runs*stage_cap staged events.
        if self.num_runs is None:
            self.num_runs = 8
        self.num_runs = max(self.num_runs, 1)
        self.codec = DenseCodec(len(self.registry), self.max_batch_len)
        # The full-enumeration switch is always available (it is the
        # "switch"-mode path and the attribute contract benchmarks
        # probe); building it only constructs Python closures — nothing
        # is traced until a mode actually dispatches through it.
        self.dispatch = build_switch_dispatcher(
            self.registry, self.codec, max_emit=self.max_emit
        )
        self._dispatch_masked = None
        self._dispatch_fused = None
        if self.dispatch_mode == "masked":
            self._dispatch_masked = build_masked_dispatcher(
                self.registry, self.codec, max_emit=self.max_emit
            )
        elif self.dispatch_mode == "fused":
            hot = self.hot_words
            if hot is None:
                # No profile declared: bake the first W dense codes
                # (shortest words first — deterministic, and small
                # alphabets degenerate to the full switch).  Real
                # deployments should pass profiled hot_words
                # (composer.hot_words_from_counts over a prior run's
                # ``word_counts``).
                hot = [
                    self.codec.decode(c)
                    for c in range(min(self.codec.num_batches,
                                       _DEFAULT_HOT_W))
                ]
            self._dispatch_fused = build_fused_dispatcher(
                self.registry, self.codec, hot, max_emit=self.max_emit
            )
            self.hot_words = self._dispatch_fused.hot_words
        # Per-word batch histogram in the run stats (hot-word profiling
        # + benchmarks/batch_counts.py), gated so a pathological
        # alphabet cannot blow up the while-loop carry.
        self._track_word_counts = (
            self.codec.num_batches <= _WORD_COUNT_LIMIT
        )
        self._lookaheads = self.registry.lookaheads()
        if self.entity_handlers:
            entity_types = sorted(self.entity_handlers)
            for ty in entity_types:
                if not 0 <= ty < len(self.registry):
                    raise ValueError(
                        f"entity_handlers key {ty} is not a registered "
                        f"type id (registry has {len(self.registry)} types)"
                    )
                if self.registry[ty].returns_events:
                    raise ValueError(
                        f"entity-parallel type {self.registry[ty].name!r} "
                        "must not emit events"
                    )
            branch_of_type = [-1] * len(self.registry)
            for i, ty in enumerate(entity_types):
                branch_of_type[ty] = i
            self._run_branch_of_type = jnp.asarray(branch_of_type, jnp.int32)
            self._run_branches = [
                make_masked_run_handler(self.entity_handlers[ty])
                for ty in entity_types
            ]
        else:
            self._run_branch_of_type = None
            self._run_branches = []
        # The queue (arg 1) is donated: repeat runs reuse its
        # capacity-sized buffers in place instead of copying them.  The
        # state is NOT donated — callers routinely feed one initial
        # state to several engines (and donation of a shared buffer
        # would poison the caller's copy).  `max_batches` and the stats
        # carry are TRACED arguments: segmented execution re-enters the
        # same compiled loop with a new cumulative batch target and the
        # previous segment's stats, so checkpoint cadence never forces
        # a recompile.
        self._run_jit = jax.jit(self._run, donate_argnums=(1,))

    @classmethod
    def from_program(cls, program, *, queue_mode: str = "tiered3",
                     capacity: int | None = None,
                     front_cap: int | None = None,
                     stage_cap: int | None = None,
                     num_runs: int | None = None,
                     dispatch_mode: str = "switch",
                     hot_words=None,
                     queue_kernels: str = "xla",
                     validate: str = "off",
                     overflow: str = "drop",
                     t_end: float = float("inf")) -> "DeviceEngine":
        """Construct the device backend from a frozen SimProgram.

        The program supplies the adapted registry (delay-relative emits
        rewritten to the absolute-time on-device convention), the
        entity-parallel dispatch table, and the shared Config knobs;
        per-backend kwargs stay here.  ``max_emit`` intentionally has
        no override: the program's handler adapters bake the emit-row
        shape from ``Config.max_emit``, so a differing engine width
        could never run.
        """
        cfg = program.config
        return cls(
            program.device_registry(),
            max_batch_len=cfg.max_batch_len,
            capacity=cfg.capacity if capacity is None else capacity,
            max_emit=cfg.max_emit,
            t_end=t_end,
            queue_mode=queue_mode,
            front_cap=front_cap,
            stage_cap=stage_cap,
            num_runs=num_runs,
            dispatch_mode=dispatch_mode,
            hot_words=hot_words,
            queue_kernels=queue_kernels,
            validate=validate,
            overflow=overflow,
            entity_handlers=program.device_entity_handlers() or None,
        )

    # -- queue construction -------------------------------------------------
    def initial_queue(
        self, events
    ) -> DeviceQueue | TieredDeviceQueue | Tiered3DeviceQueue:
        # Built host-side, one device_put (None args become zero vectors).
        if self.queue_mode == "tiered":
            return tiered_queue_from_host(
                events, self.capacity, front_cap=self.front_cap,
                stage_cap=self.stage_cap,
            )
        if self.queue_mode == "tiered3":
            return tiered3_queue_from_host(
                events, self.capacity, front_cap=self.front_cap,
                stage_cap=self.stage_cap, num_runs=self.num_runs,
            )
        return device_queue_from_host(events, self.capacity)

    def initial_queue_spill(self, events):
        """Seed split for ``overflow='spill'``: the lex-earliest
        ``capacity`` events seed the queue with their original
        input-order seqs; the rest start life in the host spill pool
        (instead of being dropped as ghosts).  Returns ``(queue,
        spill_rows, spill_seqs)`` — the rows in device emit layout
        ``(time, type, arg...)``, ready for
        :func:`tiered3_queue_absorb_rows`.
        """
        if self.queue_mode != "tiered3":
            raise ValueError("overflow='spill' requires queue_mode='tiered3'")
        events = list(events)
        n = len(events)
        if n <= self.capacity:
            return (self.initial_queue(events),
                    np.zeros((0, 2 + ARG_WIDTH), np.float32),
                    np.zeros((0,), np.int32))
        order = sorted(range(n), key=lambda i: (float(events[i][0]), i))
        keep = sorted(order[:self.capacity])
        spill = sorted(order[self.capacity:])
        q = tiered3_queue_from_host(
            [events[i] for i in keep], self.capacity,
            front_cap=self.front_cap, stage_cap=self.stage_cap,
            num_runs=self.num_runs, seqs=keep,
        )
        # Spilled events own seqs too: the counter must already be past
        # every seed seq, queued or spilled.
        q = q._replace(next_seq=jnp.int32(n))
        rows = np.zeros((len(spill), 2 + ARG_WIDTH), np.float32)
        for j, i in enumerate(spill):
            t, ty, arg = events[i]
            rows[j, 0] = t
            rows[j, 1] = ty
            if arg is not None:
                rows[j, 2:] = np.asarray(arg, np.float32)
        return q, rows, np.asarray(spill, np.int32)

    # -- extraction (paper Fig 2) --------------------------------------------
    def _extract(self, queue, t_cap=None, bound=None):
        if self.queue_mode == "tiered":
            return tiered_queue_extract(
                queue, self.max_batch_len, self._lookaheads, t_cap
            )
        if self.queue_mode == "tiered3":
            return tiered3_queue_extract(
                queue, self.max_batch_len, self._lookaheads, t_cap,
                kernels=self.queue_kernels, bound=bound,
            )
        if self.queue_mode == "flat":
            return device_queue_extract(
                queue, self.max_batch_len, self._lookaheads, t_cap
            )
        return device_queue_extract_ref(
            queue, self.max_batch_len, self._lookaheads, t_cap
        )

    # -- dispatch -------------------------------------------------------------
    def _dispatch_window(self, state, ts, tys, args, length):
        """Dispatch one extracted window; returns (state, emits).

        The composed path is selected by ``dispatch_mode``; all three
        execute the identical handler sequence for any window, so the
        choice never changes results (parity-pinned).
        """
        def switch_path(state):
            if self.dispatch_mode == "masked":
                return self._dispatch_masked(state, ts, tys, args, length)
            code = self.codec.encode_jnp(tys, length)
            if self.dispatch_mode == "fused":
                return self._dispatch_fused(
                    code, state, ts, tys, args, length
                )
            return self.dispatch(code, state, ts, tys, args)

        if not self._run_branches:
            return switch_path(state)

        lane = jnp.arange(self.max_batch_len)
        in_window = lane < length
        branch = self._run_branch_of_type[
            jnp.clip(tys[0], 0, len(self.registry) - 1)
        ]
        is_run = (
            (length > 0)
            & (branch >= 0)
            & jnp.all(jnp.where(in_window, tys == tys[0], True))
        )

        def run_path(state):
            entity_ids = args[:, 0].astype(jnp.int32)
            state = jax.lax.switch(
                jnp.maximum(branch, 0), self._run_branches,
                state, ts, args, entity_ids, in_window,
            )
            return state, self.dispatch.empty_emits()

        return jax.lax.cond(is_run, run_path, switch_path, state)

    # -- run accounting -------------------------------------------------------
    def initial_run_stats(self):
        """The stats carry threaded through the while-loop.

        Segmented execution hands the PREVIOUS segment's stats back in,
        so cumulative counters (``batches``, ``events``, ``emitted``,
        ``time``, the fault word, the spill buffer) survive segment
        boundaries and a segmented run is bit-identical to an
        unsegmented one by construction.
        """
        stats = {
            "batches": jnp.int32(0),
            "events": jnp.int32(0),
            "emitted": jnp.int32(0),
            "time": jnp.float32(0.0),
        }
        if self._track_word_counts:
            stats["word_counts"] = jnp.zeros(
                (self.codec.num_batches,), jnp.int32
            )
        if self.validate != "off":
            # Only the WORD rides the carry.  The faulting step is not
            # tracked on device: a set bit freezes the loop guard, so
            # at exit the step is recoverable from ``batches`` alone
            # (see ``run``) — one fewer carried scalar, which matters
            # because every extra carry leaf is another launch-bound
            # copy/fusion kernel per super-step on CPU.
            stats["fault_word"] = jnp.int32(0)
        if self.overflow == "spill":
            rows = self.dispatch.empty_emits()
            stats["spill_rows"] = jnp.asarray(rows)
            stats["spill_seqs"] = jnp.zeros((rows.shape[0],), jnp.int32)
            stats["spill_n"] = jnp.int32(0)
            stats["bound_t"] = jnp.float32(jnp.inf)
            stats["bound_seq"] = jnp.int32(2**31 - 1)
        return stats

    def queue_occupancy(self, queue):
        """Real pending-event count (conservation-law accounting)."""
        if self.queue_mode == "tiered3":
            return tiered3_queue_occupancy(queue)
        if self.queue_mode == "tiered":
            return tiered_queue_occupancy(queue)
        return jnp.sum(queue.types >= 0).astype(jnp.int32)

    def absorb_rows(self, queue, rows, seqs, insert):
        """Absorb externally keyed rows (stream arrivals) where
        ``insert`` is set.  Caller guarantees the masked rows fit;
        seqs come from the run's reserved arrival range (DESIGN.md
        §10), so absorbed rows land at their pre-seeded lex rank."""
        if self.queue_mode != "tiered3":
            raise ValueError(
                f"absorb_rows requires queue_mode='tiered3', got "
                f"{self.queue_mode!r}"
            )
        return tiered3_queue_absorb_rows(queue, rows, seqs, insert=insert)

    def _cheap_fault_bits(self, queue):
        """O(front) per-super-step invariant bits for this queue mode."""
        if self.queue_mode == "tiered3":
            return _validate.tiered3_fault_bits(
                queue, local=(self.overflow == "spill")
            )
        if self.queue_mode == "tiered":
            return _validate.tiered_fault_bits(queue)
        if self.queue_mode == "flat":
            return _validate.flat_fault_bits(queue, sorted_layout=True)
        return _validate.flat_fault_bits(queue, sorted_layout=False)

    def _spill_insert(self, queue, emits, stats):
        """Insert the emit rows that fit; divert the rest to the
        host-bound spill buffer carried in the stats.

        Every valid row — queued or spilled — draws its seq from the
        one global counter, so a reabsorbed row keeps its exact place
        in the total ``(time, seq)`` order.  Returns ``(queue, delta)``
        with ``delta`` the spill-related stats updates.  The loop guard
        stops the segment as soon as ``spill_n > 0``, so at most one
        batch ever writes the buffer before the host drains it.
        """
        R = emits.shape[0]
        valid = emits[:, 1] >= 0
        vrank = _prefix_rank(valid)
        num_valid = jnp.sum(valid).astype(jnp.int32)
        base_seq = queue.next_seq
        seq_r = base_seq + vrank
        occ = tiered3_queue_occupancy(queue)
        fits = valid & (occ + vrank < jnp.int32(self.capacity))
        spilled = valid & ~fits
        queue = tiered3_queue_fill_rows_tagged(
            queue, emits, seq_r, fits, kernels=self.queue_kernels
        )
        # The tagged fill advances next_seq only past INSERTED rows;
        # spilled rows still own theirs.
        queue = queue._replace(next_seq=base_seq + num_valid)
        srank = _prefix_rank(spilled)
        dst = jnp.where(spilled, srank, jnp.int32(R))
        n_spill = jnp.sum(spilled).astype(jnp.int32)
        s_t = jnp.where(spilled, emits[:, 0], jnp.inf)
        min_t = jnp.min(s_t)
        min_s = jnp.min(jnp.where(
            spilled & (emits[:, 0] == min_t), seq_r, jnp.int32(2**31 - 1)
        ))
        # Tighten the execution fence to the lex-earliest outstanding
        # spilled key: nothing at or past it may run before reabsorb.
        take = (min_t < stats["bound_t"]) | (
            (min_t == stats["bound_t"]) & (min_s < stats["bound_seq"])
        )
        delta = {
            "spill_rows": stats["spill_rows"].at[dst].set(
                emits, mode="drop"
            ),
            "spill_seqs": stats["spill_seqs"].at[dst].set(
                seq_r, mode="drop"
            ),
            "spill_n": stats["spill_n"] + n_spill,
            "bound_t": jnp.where(take, min_t, stats["bound_t"]),
            "bound_seq": jnp.where(take, min_s, stats["bound_seq"]),
        }
        return queue, delta

    # -- main loop ------------------------------------------------------------
    def _run(self, state, queue, t_end, max_batches, stats0):
        inserts = {
            "tiered": tiered_queue_fill_rows,
            "tiered3": lambda q, rows: tiered3_queue_fill_rows(
                q, rows, kernels=self.queue_kernels
            ),
            "flat": device_queue_fill_rows,
            "reference": device_queue_push_rows,
        }
        insert = inserts[self.queue_mode]

        # Loop while events are actually pending.  `queue.size` alone is
        # wrong here: it counts overflow-dropped ghosts, which would spin
        # the loop forever on an empty queue after an overflow.  The
        # tiered check is refill-aware (the front may be empty while
        # staging/main still hold events); under the canonical sorted
        # layout the head slot answers in O(1); the reference layout
        # needs the full occupancy mask.
        if self.queue_mode == "tiered":
            has_pending = tiered_queue_has_pending
            next_time = tiered_queue_next_time
        elif self.queue_mode == "tiered3":
            has_pending = tiered3_queue_has_pending
            next_time = tiered3_queue_next_time
        elif self.queue_mode == "flat":
            has_pending = lambda queue: queue.types[0] >= 0
            next_time = device_queue_next_time
        else:
            has_pending = lambda queue: jnp.any(queue.types >= 0)
            next_time = device_queue_next_time_ref

        # `t_end` is a traced value, so one compiled program serves every
        # horizon.  The contract (shared with the host schedulers): the
        # dynamic extraction window is capped at t_end, so exactly the
        # events with timestamp <= t_end execute — later ones stay
        # queued — identically on every backend.  `max_batches` is
        # cumulative against the carried stats, which is what makes a
        # segmented run re-enter this loop mid-count.
        validate_on = self.validate != "off"
        spill = self.overflow == "spill"
        # The admission fence: nothing at or past the lex-earliest
        # OUTSTANDING external key — a spilled row awaiting reabsorb,
        # or the next unabsorbed stream arrival — may execute.  Spill
        # mode always carries the bound; a streamed run injects
        # ``bound_t``/``bound_seq`` into the incoming stats, and the
        # carry STRUCTURE is part of the jit cache key, so closed runs
        # compile a fence-free loop at zero cost.
        fenced = spill or "bound_t" in stats0
        if fenced and self.queue_mode != "tiered3":
            raise ValueError(
                "the admission fence (overflow='spill' / streamed "
                "arrivals) requires queue_mode='tiered3', got "
                f"{self.queue_mode!r}"
            )

        def cond(carry):
            state, queue, stats = carry
            del state
            ok = (
                has_pending(queue)
                & (stats["batches"] < max_batches)
                & (next_time(queue) <= t_end)
            )
            if validate_on:
                # Fail-fast without host sync: a set bit freezes the
                # loop at the faulting super-step.
                ok = ok & (stats["fault_word"] == 0)
            if self.overflow == "error":
                ok = ok & (queue.dropped == 0)
            if fenced:
                nk_t, nk_s = tiered3_queue_next_key(queue)
                below = (nk_t < stats["bound_t"]) | (
                    (nk_t == stats["bound_t"])
                    & (nk_s < stats["bound_seq"])
                )
                ok = ok & below
            if spill:
                ok = ok & (stats["spill_n"] == 0)
            return ok

        def body(carry):
            state, queue, stats = carry
            if fenced:
                queue, ts, tys, args, length = self._extract(
                    queue, t_end,
                    bound=(stats["bound_t"], stats["bound_seq"]),
                )
            else:
                queue, ts, tys, args, length = self._extract(queue, t_end)
            prev_time = stats["time"]
            state, emits = self._dispatch_window(state, ts, tys, args, length)
            if spill:
                queue, spill_delta = self._spill_insert(queue, emits, stats)
            else:
                queue = insert(queue, emits)
            last_t = ts[jnp.maximum(length - 1, 0)]
            new_stats = {
                "batches": stats["batches"] + 1,
                "events": stats["events"] + length,
                "emitted": stats["emitted"]
                + jnp.sum(emits[:, 1] >= 0).astype(jnp.int32),
                "time": jnp.maximum(stats["time"], last_t),
            }
            if self._track_word_counts:
                # Per-word histogram (XLA CSEs the encode against the
                # dispatch path's — same pure computation).
                code = self.codec.encode_jnp(tys, length)
                new_stats["word_counts"] = stats["word_counts"].at[code].add(1)
            if spill:
                new_stats.update(spill_delta)
            elif fenced:
                # Fence-only carry: the bound is host-set between
                # segments and rides the loop unchanged.
                new_stats["bound_t"] = stats["bound_t"]
                new_stats["bound_seq"] = stats["bound_seq"]
            if validate_on:
                bits = self._cheap_fault_bits(queue)
                bits = bits | jnp.where(
                    (length > 0) & (ts[0] < prev_time),
                    jnp.int32(FAULT_CLOCK), jnp.int32(0),
                )
                new_stats["fault_word"] = stats["fault_word"] | bits
            return state, queue, new_stats

        return jax.lax.while_loop(cond, body, (state, queue, stats0))

    def run(self, state,
            queue: DeviceQueue | TieredDeviceQueue | Tiered3DeviceQueue,
            *, max_batches: int = 1 << 30, t_end: float | None = None,
            stats: Mapping | None = None):
        """Run to completion (or ``max_batches`` / horizon ``t_end``).

        ``t_end`` and ``max_batches`` override the engine defaults per
        call without recompiling (both are traced arguments): the
        extraction window is capped at t_end, so exactly the events
        with timestamp <= t_end execute and later ones stay queued.

        ``stats`` resumes a previous (segmented) run: pass the stats a
        prior ``run`` returned and the loop continues its cumulative
        counters — ``max_batches`` then caps the TOTAL batch count, not
        this call's increment.

        Stats carry ``word_counts`` (i32[num_batches], batches per
        Horner word — the fused-dispatch profiling source) whenever the
        code space is small enough to track, plus the fault word /
        spill buffer when ``validate`` / ``overflow='spill'`` enable
        them.

        With ``validate != 'off'`` a set fault bit raises
        :class:`EngineFaultError` naming the first violated invariant
        and super-step; with ``overflow='error'`` the first dropped
        event does the same.
        """
        t_end = self.t_end if t_end is None else t_end
        if stats is None:
            stats0 = self.initial_run_stats()
        else:
            # "dropped" is surfaced on the way out (it lives on the
            # queue, not in the loop carry) — strip it on the way in.
            stats0 = {k: v for k, v in stats.items() if k != "dropped"}
        if self.validate != "off":
            # Entry audit: a queue corrupted BETWEEN segments (bad
            # restore, host-side mutation) would otherwise have its
            # poisoned front extracted on the first super-step, before
            # the in-loop bits (computed post-insert) ever see it.
            # Folding the incoming queue's bits into the carry makes
            # the loop guard trip before any event executes.
            # Jitted (and cached): eagerly the ~30 small ops dispatch
            # one by one at ~100x the cost of a single compiled call,
            # which would dominate the whole auditor's overhead.
            entry_fn = self.__dict__.get("_entry_bits_jit")
            if entry_fn is None:
                entry_fn = jax.jit(self._cheap_fault_bits)
                self._entry_bits_jit = entry_fn
            stats0 = dict(stats0)
            stats0["fault_word"] = stats0["fault_word"] | jnp.int32(
                entry_fn(queue))
        state, queue, stats = self._run_jit(
            state, queue, jnp.float32(t_end), jnp.int32(max_batches), stats0
        )
        stats = dict(stats)
        stats["dropped"] = queue.dropped
        if self.overflow == "error" and int(queue.dropped) > 0:
            raise EngineFaultError(
                FAULT_OVERFLOW, int(stats["batches"]),
                detail=(f"{int(queue.dropped)} event(s) overflowed the "
                        f"capacity-{self.capacity} queue"),
            )
        if self.validate != "off" and int(stats["fault_word"]) != 0:
            # The guard freezes the loop the moment the word sets, so
            # the word can only have been set by the LAST executed
            # super-step (batches - 1), or — when no super-step ran at
            # all — by the entry audit on the incoming queue (batches).
            final_b = int(stats["batches"])
            entry_b = int(stats0["batches"])
            raise EngineFaultError(
                int(stats["fault_word"]),
                final_b - 1 if final_b > entry_b else final_b,
            )
        if self.validate == "full":
            # Segment-boundary audit: each ``run`` call is one segment,
            # so the O(capacity) cross-tier sweep runs off the hot path.
            _validate.raise_on_findings(
                _validate.full_audit(
                    queue, local=(self.overflow == "spill")
                ),
                step=int(stats["batches"]),
            )
        return state, queue, stats

    def lower_run(self, state_spec, queue_spec):
        """AOT lowering hook (used by tests and the dry-run).

        Lowers the same jitted function as :meth:`run`, so the AOT
        executable keeps the documented queue-donation semantics.
        """
        t_spec = jax.ShapeDtypeStruct((), jnp.float32)
        mb_spec = jax.ShapeDtypeStruct((), jnp.int32)
        stats_spec = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype),
            self.initial_run_stats(),
        )
        return self._run_jit.lower(
            state_spec, queue_spec, t_spec, mb_spec, stats_spec
        )
