"""Simulator facade and the fully on-device DES engine.

Two runtimes (DESIGN.md §2):

* **Host runtime** (paper-faithful): :class:`Simulator` drives a Python
  event loop over a binary heap, dispatching pre-composed jitted batch
  programs — the direct analogue of the paper's function-pointer
  dispatch.

* **Device runtime** (TPU-native adaptation): :func:`run_on_device`
  compiles the ENTIRE simulation — queue, lookahead-window extraction,
  Horner encoding, batch dispatch — into one XLA program built around
  ``lax.while_loop`` + ``lax.switch``.  Every composed batch body is a
  contiguous fragment inside that module, so XLA applies cross-event
  optimization exactly as clang does in the paper, and there are zero
  host round-trips during the run.

On-device emit convention: handlers marked with ``@emits_events`` return
``(state, emits)`` with ``emits: f32[max_emit, 2 + ARG_WIDTH]`` rows of
``(absolute_time, type, arg...)``; ``type == -1`` marks unused slots.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.codec import DenseCodec, PaperCodec, make_codec
from repro.core.composer import (
    EagerComposer,
    LazyComposer,
    build_switch_dispatcher,
)
from repro.core.events import ARG_WIDTH, EventRegistry
from repro.core.queue import (
    DeviceQueue,
    HostEventQueue,
    device_queue_init,
    device_queue_peek,
    device_queue_pop,
    device_queue_push,
    device_queue_push_rows,
)
from repro.core.scheduler import (
    ConservativeScheduler,
    RunStats,
    SpeculativeScheduler,
    run_unbatched,
)


class Simulator:
    """User-facing facade over registry + queue + scheduler."""

    def __init__(self, registry: EventRegistry, *, max_batch_len: int = 4,
                 codec: str = "dense", composer: str = "lazy",
                 state_spec=None, arg_spec=None):
        registry.freeze()
        self.registry = registry
        self.codec = make_codec(codec, len(registry), max_batch_len)
        if composer == "lazy":
            self.composer = LazyComposer(registry, self.codec)
        elif composer == "eager":
            self.composer = EagerComposer(
                registry, self.codec, state_spec=state_spec, arg_spec=arg_spec
            )
        else:
            raise ValueError(f"unknown composer {composer!r}")
        self.queue = HostEventQueue()

    def schedule(self, time: float, type_name: str, arg: Any = None):
        et = self.registry[type_name]
        return self.queue.push(time, et.type_id, arg)

    def run(self, state, *, mode: str = "conservative",
            max_events: int | None = None) -> tuple[Any, RunStats]:
        if mode == "conservative":
            sched = ConservativeScheduler(self.registry, self.composer)
            return sched.run(state, self.queue, max_events=max_events)
        if mode == "speculative":
            sched = SpeculativeScheduler(self.registry, self.composer)
            return sched.run(state, self.queue, max_events=max_events)
        if mode == "unbatched":
            return run_unbatched(
                self.registry, state, self.queue, max_events=max_events
            )
        raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# On-device engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DeviceEngine:
    """Builder for the single-program on-device simulation.

    Usage::

        eng = DeviceEngine(registry, max_batch_len=4, capacity=1024)
        queue = eng.initial_queue([(t, type_id, arg_vec), ...])
        final_state, final_queue, stats = eng.run(state0, queue,
                                                  max_batches=10_000)

    ``eng.run`` is jitted once; repeat calls with same-shaped inputs are
    pure device execution.
    """

    registry: EventRegistry
    max_batch_len: int = 4
    capacity: int = 1024
    max_emit: int = 2
    t_end: float = float("inf")

    def __post_init__(self):
        self.registry.freeze()
        self.codec = DenseCodec(len(self.registry), self.max_batch_len)
        self.dispatch = build_switch_dispatcher(
            self.registry, self.codec, max_emit=self.max_emit
        )
        self._lookaheads = self.registry.lookaheads()
        self._run_jit = jax.jit(self._run, static_argnames=("max_batches",))

    # -- queue construction -------------------------------------------------
    def initial_queue(self, events) -> DeviceQueue:
        q = device_queue_init(self.capacity)
        for (t, ty, arg) in events:
            arg = jnp.zeros((ARG_WIDTH,), jnp.float32) if arg is None else (
                jnp.asarray(arg, jnp.float32)
            )
            q = device_queue_push(q, t, ty, arg)
        return q

    # -- extraction (paper Fig 2, in lax) ------------------------------------
    def _extract(self, queue: DeviceQueue):
        max_len = self.max_batch_len
        la = self._lookaheads

        ts0 = jnp.zeros((max_len,), jnp.float32)
        tys0 = jnp.zeros((max_len,), jnp.int32)
        args0 = jnp.zeros((max_len, ARG_WIDTH), jnp.float32)

        def body(i, carry):
            queue, ts, tys, args, length, t_max, done = carry
            t, ty, _slot = device_queue_peek(queue)
            can_take = (~done) & (ty >= 0) & (t <= t_max)

            def take(_):
                q2, t2, ty2, arg2 = device_queue_pop(queue)
                ts2 = ts.at[i].set(t2)
                tys2 = tys.at[i].set(ty2)
                args2 = args.at[i].set(arg2)
                t_max2 = jnp.minimum(t_max, t2 + la[ty2])
                return q2, ts2, tys2, args2, length + 1, t_max2, done

            def skip(_):
                return queue, ts, tys, args, length, t_max, jnp.bool_(True)

            return jax.lax.cond(can_take, take, skip, None)

        init = (queue, ts0, tys0, args0, jnp.int32(0), _inf_f32(), jnp.bool_(False))
        queue, ts, tys, args, length, _t_max, _done = jax.lax.fori_loop(
            0, max_len, body, init
        )
        return queue, ts, tys, args, length

    # -- main loop ------------------------------------------------------------
    def _run(self, state, queue: DeviceQueue, *, max_batches: int):
        def cond(carry):
            state, queue, stats = carry
            del state
            return (queue.size > 0) & (stats["batches"] < max_batches) & (
                stats["time"] <= self.t_end
            )

        def body(carry):
            state, queue, stats = carry
            queue, ts, tys, args, length = self._extract(queue)
            code = self.codec.encode_jnp(tys, length)
            state, emits = self.dispatch(code, state, ts, tys, args)
            queue = device_queue_push_rows(queue, emits)
            last_t = ts[jnp.maximum(length - 1, 0)]
            stats = {
                "batches": stats["batches"] + 1,
                "events": stats["events"] + length,
                "time": jnp.maximum(stats["time"], last_t),
            }
            return state, queue, stats

        stats0 = {
            "batches": jnp.int32(0),
            "events": jnp.int32(0),
            "time": jnp.float32(0.0),
        }
        return jax.lax.while_loop(cond, body, (state, queue, stats0))

    def run(self, state, queue: DeviceQueue, *, max_batches: int = 1 << 30):
        state, queue, stats = self._run_jit(state, queue, max_batches=max_batches)
        return state, queue, stats

    def lower_run(self, state_spec, queue_spec, *, max_batches: int = 1 << 30):
        """AOT lowering hook (used by tests and the dry-run)."""
        return jax.jit(self._run, static_argnames=("max_batches",)).lower(
            state_spec, queue_spec, max_batches=max_batches
        )


def _inf_f32():
    return jnp.float32(jnp.inf)
