"""Batch-identifier codecs (paper §III-A).

The paper interprets the event alphabet Σ as the digits of a number
system and identifies a batch (a word of Σ*) with the natural number it
represents, evaluated with a Horner scheme.  Because the digit 0 would be
absorbed at the most-significant end ("aba would have the same id as
ba"), the paper introduces an explicit ν ("no event") digit, at the cost
of redundant codes: with |Σ| event types and maximum batch length n,
``B = Σ_{i=1..n} (|Σ|+1)^i`` codes are enumerated, of which

    redundant(|Σ|, n) = B - Σ_{i=1..n} |Σ|^i

never correspond to a ν-free batch the scheduler can emit (58 % at
|Σ|=5, n=5 — §IV.C).

Two codecs are provided:

* :class:`PaperCodec` — the faithful reproduction: base ``|Σ|+1``,
  digit 0 = ν, real types are 1-based, identifiers enumerated densely
  over all words *including* redundant ν-containing ones.

* :class:`DenseCodec` — the improvement the paper lists as future work
  ("a refined enumeration scheme could eliminate these redundant
  batches"): a bijective base-|Σ| numbering over ν-free words only.
  ``id(word of length k) = offset(k) + Σ_i digit_i·|Σ|^i`` with 0-based
  digits and ``offset(k) = Σ_{j=1..k-1}|Σ|^j``.  Exactly
  ``Σ_{i=1..n}|Σ|^i`` codes, zero redundancy, and the ids are contiguous
  — directly usable as ``lax.switch`` branch indices on device.

Both codecs are evaluated identically in Python (host scheduler /
compile-time composition) and in jnp (on-device scheduler), mirroring the
paper's requirement that the scheme be "efficiently evaluated both during
runtime and compile-time".
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np


def geometric_sum(base: int, n: int) -> int:
    """Σ_{i=1..n} base^i  (number of non-empty words up to length n)."""
    if base == 1:
        return n
    return (base ** (n + 1) - base) // (base - 1)


def paper_batch_count(num_types: int, max_len: int) -> int:
    """B from §III-A: all words over Σν up to length n (excluding ε)."""
    return geometric_sum(num_types + 1, max_len)


def dense_batch_count(num_types: int, max_len: int) -> int:
    """ν-free word count: Σ_{i=1..n} |Σ|^i."""
    return geometric_sum(num_types, max_len)


def redundant_batch_count(num_types: int, max_len: int) -> int:
    """§IV.C: codes composed by the paper scheme that are never used."""
    return paper_batch_count(num_types, max_len) - dense_batch_count(
        num_types, max_len
    )


@dataclasses.dataclass(frozen=True)
class PaperCodec:
    """Paper-faithful Horner codec over Σν (digit 0 = ν)."""

    num_types: int
    max_len: int

    @property
    def base(self) -> int:
        return self.num_types + 1

    @property
    def num_batches(self) -> int:
        return paper_batch_count(self.num_types, self.max_len)

    # ids are 1-based in the enumeration (0 encodes the empty word ε which
    # the scheduler never emits); we keep the paper's convention that the
    # enumeration covers 1..B.
    def encode(self, type_ids: Sequence[int]) -> int:
        """Horner scheme: first event of the batch is the least
        significant digit, so decode() pops handlers in execution order
        (paper Alg. 1 appends eventHandlers[id mod base - 1] first)."""
        if not 1 <= len(type_ids) <= self.max_len:
            raise ValueError(f"batch length must be in [1, {self.max_len}]")
        code = 0
        for t in reversed(type_ids):
            if not 0 <= t < self.num_types:
                raise ValueError(f"type id {t} out of range")
            code = code * self.base + (t + 1)
        return code

    def decode(self, code: int) -> list[int]:
        """Inverse of encode; skips ν digits exactly like GENBATCH."""
        if code <= 0:
            raise ValueError("code must be positive (0 is the empty word)")
        out = []
        while code:
            digit = code % self.base
            if digit > 0:  # "check for ν-event"
                out.append(digit - 1)
            code //= self.base
        return out

    def enumerate_codes(self):
        """All codes 1..B in order, paper Alg. 1 ENUMERATEBATCHES.

        Many decode to the same ν-free word (the redundancy of §IV.C);
        callers that want each *distinct* batch exactly once should use
        DenseCodec instead.
        """
        # The paper enumerates ids over words up to length max_len, i.e.
        # codes up to base^max_len - 1 plus the length-max_len words; the
        # total count is B. Codes are simply 1..B in the mixed-length
        # numbering (base^(max_len+1) overshoots; B is exact).
        return range(1, self.num_batches + 1)

    # -- jnp evaluation (on-device Horner) --------------------------------
    def encode_jnp(self, padded_types, length):
        """Horner evaluation on device.

        padded_types: i32[max_len] with type ids (entries >= length are
        ignored); length: i32 scalar. Returns i32 code.
        """
        base = jnp.int32(self.base)
        idx = jnp.arange(self.max_len - 1, -1, -1)
        code = jnp.int32(0)
        for i in range(self.max_len):
            pos = self.max_len - 1 - i  # walk from last slot to first
            valid = pos < length
            digit = jnp.where(valid, padded_types[pos] + 1, 0)
            code = jnp.where(valid, code * base + digit, code)
        del idx
        return code


@dataclasses.dataclass(frozen=True)
class DenseCodec:
    """Bijective, redundancy-free codec (paper §IV.D future work).

    ids are 0-based and contiguous in [0, Σ_{i=1..n}|Σ|^i), grouped by
    length: all length-1 batches first, then length-2, etc.  Within a
    length group the word is read as a base-|Σ| number with the FIRST
    event as the least significant digit (same execution-order convention
    as PaperCodec).
    """

    num_types: int
    max_len: int

    @property
    def base(self) -> int:
        return self.num_types

    @property
    def num_batches(self) -> int:
        return dense_batch_count(self.num_types, self.max_len)

    def offset(self, length: int) -> int:
        """Start id of the length-`length` group."""
        return geometric_sum(self.num_types, length - 1)

    def encode(self, type_ids: Sequence[int]) -> int:
        k = len(type_ids)
        if not 1 <= k <= self.max_len:
            raise ValueError(f"batch length must be in [1, {self.max_len}]")
        code = 0
        for t in reversed(type_ids):
            if not 0 <= t < self.num_types:
                raise ValueError(f"type id {t} out of range")
            code = code * self.base + t
        return self.offset(k) + code

    def decode(self, code: int) -> list[int]:
        if not 0 <= code < self.num_batches:
            raise ValueError(f"code {code} out of range")
        length = 1
        while code >= self.offset(length) + self.base ** length:
            length += 1
        rem = code - self.offset(length)
        out = []
        for _ in range(length):
            out.append(rem % self.base)
            rem //= self.base
        return out

    def enumerate_codes(self):
        return range(self.num_batches)

    def enumerate_words(self):
        """Yield (code, word) for every distinct batch, in id order."""
        for code in self.enumerate_codes():
            yield code, self.decode(code)

    # -- jnp evaluation ----------------------------------------------------
    def encode_jnp(self, padded_types, length):
        """On-device encode: i32[max_len] types + i32 length -> i32 id.

        Evaluated with a fixed-length unrolled Horner loop (max_len is a
        compile-time constant, so this is `max_len` fused selects/mads —
        the "efficiently evaluated at runtime" property of §III-A).
        """
        base = jnp.int32(self.base)
        code = jnp.int32(0)
        for i in range(self.max_len - 1, -1, -1):
            valid = i < length
            code = jnp.where(valid, code * base + padded_types[i], code)
        # offset(length) = (base^length - base) / (base - 1), computed
        # branch-free for the handful of possible lengths.
        offs = jnp.asarray(
            [self.offset(k) if k >= 1 else 0 for k in range(self.max_len + 1)],
            dtype=jnp.int32,
        )
        return offs[length] + code


def make_codec(kind: str, num_types: int, max_len: int):
    if kind == "paper":
        return PaperCodec(num_types, max_len)
    if kind == "dense":
        return DenseCodec(num_types, max_len)
    raise ValueError(f"unknown codec kind {kind!r}")
