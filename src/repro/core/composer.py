"""Compile-time batch composition (paper §III-A, Alg. 1).

The paper composes, *during compilation*, one contiguous procedure per
batch identifier by concatenating the registered event handlers' bodies,
so the compiler optimizes across events.  The JAX equivalent: for each
batch word ``w = [t0, t1, ...]`` we build a Python closure that applies
the handlers sequentially and hand it to ``jax.jit`` — tracing inlines
all handler bodies into ONE jaxpr/HLO module, which XLA then optimizes as
a contiguous code fragment (cross-event DCE, fusion, CSE).  That is the
paper's mechanism with XLA in the role of clang.

Three composition strategies:

* :class:`EagerComposer` — paper-faithful: ALL batch programs are
  composed and AOT-compiled (``.lower().compile()``) up front, exactly
  like the C++ template instantiation.  Compile time grows with the
  batch count (reproduced as the Fig-4 benchmark).
* :class:`LazyComposer` — the paper's §IV.D JIT idea: programs are
  composed up front (cheap) but compiled on first dispatch and cached,
  so only batches that actually occur pay compilation cost.
* :func:`build_switch_dispatcher` — the TPU-native runtime: a single
  program containing ``lax.switch`` over every composed batch, used by
  the fully on-device scheduler (no host round-trip per batch).

Handlers follow the conventions of :mod:`repro.core.events`.  Emitted
events are buffered and returned to the caller *after* the whole batch
has run — the paper's §IV.D "postponing the scheduling of all new events
to the end of a batch execution" optimization (always on here; the
unbatched baseline in benchmarks/ inserts eagerly).  Each buffered
emission carries the in-batch index of its emitting event, so schedulers
anchor the new event at the emitter's timestamp — results never depend
on how events were grouped into batches.
"""

from __future__ import annotations

import time as _time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.events import ARG_WIDTH, EventRegistry, normalize_handler_result
from repro.core.codec import DenseCodec, PaperCodec, make_codec


# ---------------------------------------------------------------------------
# Host-side batch programs
# ---------------------------------------------------------------------------

def compose_word_fn(registry: EventRegistry, word: Sequence[int]) -> Callable:
    """Concatenate the handlers of ``word`` into one traceable function.

    Returns ``fn(state, ts, args) -> (state, emitted)`` where ``ts`` is a
    length-``len(word)`` sequence of timestamps and ``args`` the matching
    handler arguments.  ``emitted`` is the Python list of events created
    by any handler, in execution order (deferred scheduling, §IV.D), as
    ``(src, delay, type_id, arg)`` tuples where ``src`` is the index
    within the batch of the emitting event — schedulers anchor the new
    event at ``ts[src] + delay``, so emission times do not depend on how
    events were grouped into batches.
    """
    types = [registry[t] for t in word]

    def batch_fn(state, ts, args):
        emitted = []
        for i, et in enumerate(types):
            result = et.handler(state, ts[i], args[i])
            state, new = normalize_handler_result(
                result, returns_events=et.returns_events
            )
            emitted.extend((i, delay, ty, a) for (delay, ty, a) in new)
        return state, emitted

    batch_fn.__name__ = "batch_" + "_".join(t.name for t in types)
    return batch_fn


class _ComposerBase:
    """Shared bookkeeping for host-side composers."""

    def __init__(self, registry: EventRegistry, codec):
        if not registry.frozen:
            registry.freeze()
        self.registry = registry
        self.codec = codec
        self._programs: dict[int, Callable] = {}   # code -> jitted fn
        self._words: dict[int, tuple[int, ...]] = {}
        self.compile_seconds: dict[int, float] = {}
        self.trace_count = 0

    def word_for(self, code: int) -> tuple[int, ...]:
        if code not in self._words:
            self._words[code] = tuple(self.codec.decode(code))
        return self._words[code]

    def _build(self, code: int) -> Callable:
        word = self.word_for(code)
        fn = compose_word_fn(self.registry, word)
        # Timestamps are traced values (donated by the scheduler); the
        # batch structure itself is baked into the program — exactly the
        # paper's "batch = compiled contiguous procedure".
        jfn = jax.jit(fn)
        self.trace_count += 1
        return jfn

    def program(self, code: int) -> Callable:
        if code not in self._programs:
            t0 = _time.perf_counter()
            self._programs[code] = self._build(code)
            self.compile_seconds[code] = _time.perf_counter() - t0
        return self._programs[code]

    def execute(self, code: int, state, ts, args):
        """Run batch ``code``; returns (state, emitted_events)."""
        return self.program(code)(state, ts, args)

    @property
    def num_composed(self) -> int:
        return len(self._programs)

    @classmethod
    def from_program(cls, program, **kwargs):
        """Construct from a frozen SimProgram: the host-adapted registry
        plus a codec sized by the program's Config."""
        registry = program.host_registry()
        cfg = program.config
        codec = make_codec(cfg.codec, len(registry), cfg.max_batch_len)
        return cls(registry, codec, **kwargs)


class EagerComposer(_ComposerBase):
    """Paper-faithful: compose + AOT-compile every batch up front.

    ``state_spec``/``arg_spec`` are ShapeDtypeStruct pytrees describing
    one state and one handler argument; they let us `.lower().compile()`
    without touching device memory (same trick as the multi-pod dry-run).
    """

    def __init__(self, registry, codec, *, state_spec=None, arg_spec=None,
                 aot: bool = True):
        super().__init__(registry, codec)
        self.aot = aot and state_spec is not None
        self.state_spec = state_spec
        self.arg_spec = arg_spec
        self.total_compile_seconds = 0.0
        t0 = _time.perf_counter()
        for code in codec.enumerate_codes():
            word = self.word_for(code)
            if not word:
                continue  # redundant ν-only code (PaperCodec)
            if self.aot:
                self._programs[code] = self._aot_build(code, word)
            else:
                self._programs[code] = self._build(code)
        self.total_compile_seconds = _time.perf_counter() - t0

    def _aot_build(self, code, word):
        fn = compose_word_fn(self.registry, word)
        k = len(word)
        ts_spec = [jax.ShapeDtypeStruct((), jnp.float32)] * k
        args_spec = [self.arg_spec] * k
        t0 = _time.perf_counter()
        compiled = jax.jit(fn).lower(self.state_spec, ts_spec, args_spec).compile()
        self.compile_seconds[code] = _time.perf_counter() - t0
        self.trace_count += 1
        return compiled

    def execute(self, code, state, ts, args):
        prog = self._programs[code]
        if self.aot:
            return prog(state, list(ts), list(args))
        return prog(state, ts, args)


class LazyComposer(_ComposerBase):
    """Beyond-paper (§IV.D): compile batches on first occurrence only."""
    # program() already builds lazily; nothing else needed.


# ---------------------------------------------------------------------------
# On-device dispatcher (TPU-native runtime, DESIGN.md §2)
# ---------------------------------------------------------------------------

def build_switch_dispatcher(
    registry: EventRegistry,
    codec: DenseCodec,
    *,
    max_emit: int = 2,
):
    """One traceable function dispatching over ALL composed batches.

    The returned ``dispatch(code, state, ts, types, args)`` contains a
    ``lax.switch`` whose branch ``c`` is the composed program of batch
    word ``decode(c)``.  All branches share the padded signature

        ts:    f32[max_len]          event timestamps
        types: i32[max_len]          event type ids (engine bookkeeping)
        args:  f32[max_len, ARG_WIDTH]

    and return ``(state, emits)`` with
    ``emits: f32[max_len * max_emit, 2 + ARG_WIDTH]`` rows of
    ``(time, type, arg...)``; ``type == -1`` marks an empty slot.

    On-device handlers must follow the fixed-record convention
    (DESIGN.md §6.3): ``handler(state, t, arg) -> state`` or
    ``(state, emits_f32[max_emit, 2+ARG_WIDTH])``.

    Because every branch lives in one XLA module, XLA optimizes each
    batch body as a contiguous fragment — the paper's cross-event scope —
    while the simulation main loop never leaves the device.
    """
    if not isinstance(codec, DenseCodec):
        raise TypeError(
            "on-device dispatch requires the DenseCodec (contiguous ids); "
            "the PaperCodec's redundant ids would blow up the switch."
        )
    if not registry.frozen:
        registry.freeze()
    max_len = codec.max_len
    emit_rows = max_len * max_emit
    emit_width = 2 + ARG_WIDTH

    def _empty_emits():
        e = jnp.zeros((emit_rows, emit_width), jnp.float32)
        return e.at[:, 1].set(-1.0)

    def make_branch(word):
        types = [registry[t] for t in word]

        def branch(state, ts, args):
            emits = _empty_emits()
            for i, et in enumerate(types):
                result = et.handler(state, ts[i], args[i])
                if et.returns_events:
                    state, new = result
                    new = jnp.asarray(new, jnp.float32)
                    if new.shape != (max_emit, emit_width):
                        raise ValueError(
                            f"on-device handler {et.name} must emit "
                            f"f32[{max_emit}, {emit_width}], got {new.shape}"
                        )
                    emits = jax.lax.dynamic_update_slice(
                        emits, new, (i * max_emit, 0)
                    )
                else:
                    state = result
            return state, emits

        return branch

    branches = []
    for code, word in codec.enumerate_words():
        del code
        branches.append(make_branch(word))

    def dispatch(code, state, ts, types, args):
        del types  # engine bookkeeping only; the word is baked per branch
        return jax.lax.switch(code, branches, state, ts, args)

    dispatch.num_batches = codec.num_batches
    dispatch.max_len = max_len
    dispatch.max_emit = max_emit
    dispatch.emit_rows = emit_rows
    dispatch.emit_width = emit_width
    # Layout helper for callers (e.g. the engine's vmapped run path and
    # the bulk scatter insert) that need a no-emission block.
    dispatch.empty_emits = _empty_emits
    return dispatch
