"""Compile-time batch composition (paper §III-A, Alg. 1).

The paper composes, *during compilation*, one contiguous procedure per
batch identifier by concatenating the registered event handlers' bodies,
so the compiler optimizes across events.  The JAX equivalent: for each
batch word ``w = [t0, t1, ...]`` we build a Python closure that applies
the handlers sequentially and hand it to ``jax.jit`` — tracing inlines
all handler bodies into ONE jaxpr/HLO module, which XLA then optimizes as
a contiguous code fragment (cross-event DCE, fusion, CSE).  That is the
paper's mechanism with XLA in the role of clang.

Three composition strategies:

* :class:`EagerComposer` — paper-faithful: ALL batch programs are
  composed and AOT-compiled (``.lower().compile()``) up front, exactly
  like the C++ template instantiation.  Compile time grows with the
  batch count (reproduced as the Fig-4 benchmark).
* :class:`LazyComposer` — the paper's §IV.D JIT idea: programs are
  composed up front (cheap) but compiled on first dispatch and cached,
  so only batches that actually occur pay compilation cost.
* :func:`build_switch_dispatcher` — the TPU-native runtime: a single
  program containing ``lax.switch`` over every composed batch, used by
  the fully on-device scheduler (no host round-trip per batch).

On-device dispatch additionally comes in two specialized shapes
(DESIGN.md §7, selected by ``DeviceEngine(dispatch_mode=...)``):

* :func:`build_masked_dispatcher` — the generic per-handler-scope
  baseline: one masked per-lane ``lax.switch`` over the T event types
  (plus a no-op leg) per window lane.  Compile cost is O(T · max_len)
  regardless of the batch-word count, but XLA sees each handler alone —
  no cross-event scope.
* :func:`build_fused_dispatcher` — the two-level composition-
  specialized path: the top-W *hot* batch words are AOT-composed into
  straight-line "super-procedures" (no masks, no per-type legs —
  handlers inlined back-to-back exactly like the full switch's
  branches, so XLA fuses/DCEs across event boundaries), reached
  through a bounded ``lax.switch`` over W+1 branches via a
  code→slot lookup table; every other word falls back to the masked
  path.  Compile cost is W-linear (guarded by
  ``benchmarks/compile_times.py``), and because hot branches, full-
  switch branches, and the masked path all execute the identical
  handler sequence, all three modes are bit-identical
  (``tests/_parity.py``).

Handlers follow the conventions of :mod:`repro.core.events`.  Emitted
events are buffered and returned to the caller *after* the whole batch
has run — the paper's §IV.D "postponing the scheduling of all new events
to the end of a batch execution" optimization (always on here; the
unbatched baseline in benchmarks/ inserts eagerly).  Each buffered
emission carries the in-batch index of its emitting event, so schedulers
anchor the new event at the emitter's timestamp — results never depend
on how events were grouped into batches.
"""

from __future__ import annotations

import time as _time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import ARG_WIDTH, EventRegistry, normalize_handler_result
from repro.core.codec import DenseCodec, PaperCodec, make_codec


# ---------------------------------------------------------------------------
# Host-side batch programs
# ---------------------------------------------------------------------------

def compose_word_fn(registry: EventRegistry, word: Sequence[int]) -> Callable:
    """Concatenate the handlers of ``word`` into one traceable function.

    Returns ``fn(state, ts, args) -> (state, emitted)`` where ``ts`` is a
    length-``len(word)`` sequence of timestamps and ``args`` the matching
    handler arguments.  ``emitted`` is the Python list of events created
    by any handler, in execution order (deferred scheduling, §IV.D), as
    ``(src, delay, type_id, arg)`` tuples where ``src`` is the index
    within the batch of the emitting event — schedulers anchor the new
    event at ``ts[src] + delay``, so emission times do not depend on how
    events were grouped into batches.
    """
    types = [registry[t] for t in word]

    def batch_fn(state, ts, args):
        emitted = []
        for i, et in enumerate(types):
            result = et.handler(state, ts[i], args[i])
            state, new = normalize_handler_result(
                result, returns_events=et.returns_events
            )
            emitted.extend((i, delay, ty, a) for (delay, ty, a) in new)
        return state, emitted

    batch_fn.__name__ = "batch_" + "_".join(t.name for t in types)
    return batch_fn


class _ComposerBase:
    """Shared bookkeeping for host-side composers."""

    def __init__(self, registry: EventRegistry, codec):
        if not registry.frozen:
            registry.freeze()
        self.registry = registry
        self.codec = codec
        self._programs: dict[int, Callable] = {}   # code -> jitted fn
        self._words: dict[int, tuple[int, ...]] = {}
        self.compile_seconds: dict[int, float] = {}
        self.trace_count = 0
        # Per-word execution histogram (code -> dispatch count): the
        # host-side profiling source for hot-word selection
        # (:func:`hot_words_from_counts`); the device engine keeps the
        # equivalent histogram in its run stats (``word_counts``).
        self.execute_counts: dict[int, int] = {}

    def word_for(self, code: int) -> tuple[int, ...]:
        if code not in self._words:
            self._words[code] = tuple(self.codec.decode(code))
        return self._words[code]

    def _build(self, code: int) -> Callable:
        word = self.word_for(code)
        fn = compose_word_fn(self.registry, word)
        # Timestamps are traced values (donated by the scheduler); the
        # batch structure itself is baked into the program — exactly the
        # paper's "batch = compiled contiguous procedure".
        jfn = jax.jit(fn)
        self.trace_count += 1
        return jfn

    def program(self, code: int) -> Callable:
        if code not in self._programs:
            t0 = _time.perf_counter()
            self._programs[code] = self._build(code)
            self.compile_seconds[code] = _time.perf_counter() - t0
        return self._programs[code]

    def execute(self, code: int, state, ts, args):
        """Run batch ``code``; returns (state, emitted_events)."""
        self.execute_counts[code] = self.execute_counts.get(code, 0) + 1
        return self.program(code)(state, ts, args)

    @property
    def num_composed(self) -> int:
        return len(self._programs)

    @classmethod
    def from_program(cls, program, **kwargs):
        """Construct from a frozen SimProgram: the host-adapted registry
        plus a codec sized by the program's Config."""
        registry = program.host_registry()
        cfg = program.config
        codec = make_codec(cfg.codec, len(registry), cfg.max_batch_len)
        return cls(registry, codec, **kwargs)


class EagerComposer(_ComposerBase):
    """Paper-faithful: compose + AOT-compile every batch up front.

    ``state_spec``/``arg_spec`` are ShapeDtypeStruct pytrees describing
    one state and one handler argument; they let us `.lower().compile()`
    without touching device memory (same trick as the multi-pod dry-run).
    """

    def __init__(self, registry, codec, *, state_spec=None, arg_spec=None,
                 aot: bool = True):
        super().__init__(registry, codec)
        self.aot = aot and state_spec is not None
        self.state_spec = state_spec
        self.arg_spec = arg_spec
        self.total_compile_seconds = 0.0
        t0 = _time.perf_counter()
        for code in codec.enumerate_codes():
            word = self.word_for(code)
            if not word:
                continue  # redundant ν-only code (PaperCodec)
            if self.aot:
                self._programs[code] = self._aot_build(code, word)
            else:
                self._programs[code] = self._build(code)
        self.total_compile_seconds = _time.perf_counter() - t0

    def _aot_build(self, code, word):
        fn = compose_word_fn(self.registry, word)
        k = len(word)
        ts_spec = [jax.ShapeDtypeStruct((), jnp.float32)] * k
        args_spec = [self.arg_spec] * k
        t0 = _time.perf_counter()
        compiled = jax.jit(fn).lower(self.state_spec, ts_spec, args_spec).compile()
        self.compile_seconds[code] = _time.perf_counter() - t0
        self.trace_count += 1
        return compiled

    def execute(self, code, state, ts, args):
        self.execute_counts[code] = self.execute_counts.get(code, 0) + 1
        prog = self._programs[code]
        if self.aot:
            return prog(state, list(ts), list(args))
        return prog(state, ts, args)


class LazyComposer(_ComposerBase):
    """Beyond-paper (§IV.D): compile batches on first occurrence only."""
    # program() already builds lazily; nothing else needed.


# ---------------------------------------------------------------------------
# On-device dispatchers (TPU-native runtime, DESIGN.md §2 and §7)
# ---------------------------------------------------------------------------

def _emit_layout(max_len: int, max_emit: int):
    """Shared on-device emit-block layout: ``emits`` is
    ``f32[max_len * max_emit, 2 + ARG_WIDTH]`` rows of
    ``(time, type, arg...)``, event ``i`` owning rows
    ``[i*max_emit, (i+1)*max_emit)``; ``type == -1`` marks empty slots.
    Every dispatcher flavor writes this exact layout, which is what
    makes them interchangeable (and bit-comparable) to the engine."""
    emit_rows = max_len * max_emit
    emit_width = 2 + ARG_WIDTH

    def empty_emits():
        e = jnp.zeros((emit_rows, emit_width), jnp.float32)
        return e.at[:, 1].set(-1.0)

    return emit_rows, emit_width, empty_emits


def make_word_branch(registry: EventRegistry, word: Sequence[int], *,
                     max_emit: int, emit_width: int,
                     empty_emits: Callable) -> Callable:
    """The composed straight-line program of one batch word: handlers
    applied back-to-back with no masks or per-type legs, each emitting
    into its own fixed row block — the paper's contiguous batch
    procedure.  Used verbatim as a full-switch branch AND as a fused
    hot-word super-procedure."""
    types = [registry[t] for t in word]

    def branch(state, ts, args):
        emits = empty_emits()
        for i, et in enumerate(types):
            result = et.handler(state, ts[i], args[i])
            if et.returns_events:
                state, new = result
                new = jnp.asarray(new, jnp.float32)
                if new.shape != (max_emit, emit_width):
                    raise ValueError(
                        f"on-device handler {et.name} must emit "
                        f"f32[{max_emit}, {emit_width}], got {new.shape}"
                    )
                emits = jax.lax.dynamic_update_slice(
                    emits, new, (i * max_emit, 0)
                )
            else:
                state = result
        return state, emits

    return branch


def _require_dense(codec, what: str):
    if not isinstance(codec, DenseCodec):
        raise TypeError(
            f"{what} requires the DenseCodec (contiguous ids); "
            "the PaperCodec's redundant ids would blow up the switch."
        )


def build_switch_dispatcher(
    registry: EventRegistry,
    codec: DenseCodec,
    *,
    max_emit: int = 2,
):
    """One traceable function dispatching over ALL composed batches.

    The returned ``dispatch(code, state, ts, types, args)`` contains a
    ``lax.switch`` whose branch ``c`` is the composed program of batch
    word ``decode(c)``.  All branches share the padded signature

        ts:    f32[max_len]          event timestamps
        types: i32[max_len]          event type ids (engine bookkeeping)
        args:  f32[max_len, ARG_WIDTH]

    and return ``(state, emits)`` with
    ``emits: f32[max_len * max_emit, 2 + ARG_WIDTH]`` rows of
    ``(time, type, arg...)``; ``type == -1`` marks an empty slot.

    On-device handlers must follow the fixed-record convention
    (DESIGN.md §6.3): ``handler(state, t, arg) -> state`` or
    ``(state, emits_f32[max_emit, 2+ARG_WIDTH])``.

    Because every branch lives in one XLA module, XLA optimizes each
    batch body as a contiguous fragment — the paper's cross-event scope —
    while the simulation main loop never leaves the device.
    """
    _require_dense(codec, "on-device dispatch")
    if not registry.frozen:
        registry.freeze()
    max_len = codec.max_len
    emit_rows, emit_width, _empty_emits = _emit_layout(max_len, max_emit)

    branches = []
    for code, word in codec.enumerate_words():
        del code
        branches.append(make_word_branch(
            registry, word, max_emit=max_emit, emit_width=emit_width,
            empty_emits=_empty_emits,
        ))

    def dispatch(code, state, ts, types, args):
        del types  # engine bookkeeping only; the word is baked per branch
        return jax.lax.switch(code, branches, state, ts, args)

    dispatch.num_batches = codec.num_batches
    dispatch.max_len = max_len
    dispatch.max_emit = max_emit
    dispatch.emit_rows = emit_rows
    dispatch.emit_width = emit_width
    # Layout helper for callers (e.g. the engine's vmapped run path and
    # the bulk scatter insert) that need a no-emission block.
    dispatch.empty_emits = _empty_emits
    return dispatch


def build_masked_dispatcher(
    registry: EventRegistry,
    codec: DenseCodec,
    *,
    max_emit: int = 2,
):
    """The generic masked window path: per-handler compiler scope.

    ``dispatch(state, ts, types, args, length) -> (state, emits)``
    applies, for each lane ``i < max_len``, a masked ``lax.switch`` over
    the T registered handlers plus a no-op leg (selected for padding
    lanes ``i >= length``).  Emitting handlers write their rows at
    ``i * max_emit`` — byte-identical emit layout to the composed word
    branches, and the handler sequence for any window is identical too,
    so this path is bit-equivalent to the full switch while compiling
    only O(T · max_len) handler bodies instead of Σ Tᵏ.

    This is the XLA analog of the paper's per-handler dispatch baseline
    (each handler is optimized alone; no cross-event scope) and the
    fallback leg of :func:`build_fused_dispatcher`.
    """
    _require_dense(codec, "on-device dispatch")
    if not registry.frozen:
        registry.freeze()
    max_len = codec.max_len
    num_types = len(registry)
    emit_rows, emit_width, _empty_emits = _emit_layout(max_len, max_emit)

    def make_lane_legs(i):
        def make_leg(et):
            def leg(state, emits, ts, args):
                result = et.handler(state, ts[i], args[i])
                if et.returns_events:
                    state, new = result
                    new = jnp.asarray(new, jnp.float32)
                    if new.shape != (max_emit, emit_width):
                        raise ValueError(
                            f"on-device handler {et.name} must emit "
                            f"f32[{max_emit}, {emit_width}], got {new.shape}"
                        )
                    emits = jax.lax.dynamic_update_slice(
                        emits, new, (i * max_emit, 0)
                    )
                else:
                    state = result
                return state, emits

            return leg

        def noop(state, emits, ts, args):
            del ts, args
            return state, emits

        return [make_leg(registry[t]) for t in range(num_types)] + [noop]

    lane_legs = [make_lane_legs(i) for i in range(max_len)]

    def dispatch(state, ts, types, args, length):
        emits = _empty_emits()
        for i in range(max_len):
            idx = jnp.where(
                jnp.int32(i) < length,
                jnp.clip(types[i], 0, num_types - 1),
                jnp.int32(num_types),
            )
            state, emits = jax.lax.switch(
                idx, lane_legs[i], state, emits, ts, args
            )
        return state, emits

    dispatch.num_batches = codec.num_batches
    dispatch.max_len = max_len
    dispatch.max_emit = max_emit
    dispatch.emit_rows = emit_rows
    dispatch.emit_width = emit_width
    dispatch.empty_emits = _empty_emits
    return dispatch


def build_fused_dispatcher(
    registry: EventRegistry,
    codec: DenseCodec,
    hot_words: Sequence[Sequence[int]],
    *,
    max_emit: int = 2,
):
    """Two-level composition-specialized dispatch (DESIGN.md §7).

    The W declared/profiled *hot* batch words are composed into
    straight-line super-procedures (:func:`make_word_branch` — the same
    fused bodies the full switch uses, so XLA optimizes across event
    boundaries, the paper's §III scope win) and reached through a
    bounded ``lax.switch`` over W+1 branches: an ``i32[num_batches]``
    lookup table maps each Horner code to its hot slot, with slot W —
    every non-hot word — falling back to the generic masked path
    (:func:`build_masked_dispatcher`).

    ``dispatch(code, state, ts, types, args, length) -> (state, emits)``.
    Compile cost is W-linear plus the constant masked fallback
    (``benchmarks/compile_times.py`` guards this); results are
    bit-identical to both other modes for every window, hot or not.

    Attributes: ``hot_words`` (the deduplicated tuple actually baked
    in), ``num_hot``, ``hot_slot_table`` (the numpy code→slot table;
    slot ``num_hot`` = fallback), plus the shared layout attrs.
    """
    _require_dense(codec, "fused dispatch")
    if not registry.frozen:
        registry.freeze()
    max_len = codec.max_len
    num_types = len(registry)
    emit_rows, emit_width, _empty_emits = _emit_layout(max_len, max_emit)

    seen: dict[tuple[int, ...], None] = {}
    for w in hot_words:
        word = tuple(int(t) for t in w)
        if not 1 <= len(word) <= max_len:
            raise ValueError(
                f"hot word {word} has length {len(word)}; expected "
                f"1..{max_len} (= max_batch_len)"
            )
        for t in word:
            if not 0 <= t < num_types:
                raise ValueError(
                    f"hot word {word} names type id {t}; registry has "
                    f"{num_types} types"
                )
        seen.setdefault(word, None)
    hot = tuple(seen)

    fallback = build_masked_dispatcher(registry, codec, max_emit=max_emit)

    def make_hot(word):
        branch = make_word_branch(
            registry, word, max_emit=max_emit, emit_width=emit_width,
            empty_emits=_empty_emits,
        )

        def hot_branch(state, ts, types, args, length):
            del types, length  # the word (and its length) is baked in
            return branch(state, ts, args)

        return hot_branch

    def fallback_branch(state, ts, types, args, length):
        return fallback(state, ts, types, args, length)

    branches = [make_hot(w) for w in hot] + [fallback_branch]

    table = np.full((codec.num_batches,), len(hot), np.int32)
    for slot, word in enumerate(hot):
        table[codec.encode(list(word))] = slot
    table_j = jnp.asarray(table)

    def dispatch(code, state, ts, types, args, length):
        slot = table_j[jnp.clip(code, 0, codec.num_batches - 1)]
        return jax.lax.switch(slot, branches, state, ts, types, args,
                              length)

    dispatch.hot_words = hot
    dispatch.num_hot = len(hot)
    dispatch.hot_slot_table = table
    dispatch.num_batches = codec.num_batches
    dispatch.max_len = max_len
    dispatch.max_emit = max_emit
    dispatch.emit_rows = emit_rows
    dispatch.emit_width = emit_width
    dispatch.empty_emits = _empty_emits
    return dispatch


def hot_words_from_counts(counts, codec, top_w: int):
    """Top-W batch words by observed frequency — the profile half of
    "profile or statically declare".

    ``counts`` is either the device engine's per-word histogram
    (``RunResult.word_counts`` / run stats ``word_counts``, an array
    over dense codes) or a host composer's ``execute_counts`` dict.
    Returns a list of word tuples suitable for
    ``DeviceEngine(hot_words=...)`` / ``build(..., hot_words=...)``;
    ties break toward the smaller code so the selection is
    deterministic.  Words never observed are never selected.
    """
    if hasattr(counts, "items"):
        pairs = list(counts.items())
    else:
        pairs = list(enumerate(np.asarray(counts).reshape(-1).tolist()))
    ranked = sorted(
        ((int(n), int(code)) for code, n in pairs if int(n) > 0),
        key=lambda p: (-p[0], p[1]),
    )
    return [tuple(codec.decode(code)) for _, code in ranked[:int(top_w)]]
