"""Same-type run vectorization (TPU-native cross-event optimization).

DESIGN.md §2: a batch that is a *run* of the same event type over
independent entities can be executed as ``vmap(handler)`` instead of a
sequential concatenation — the data-parallel analogue of the paper's
cross-event scalar optimization, and the natural mapping onto the TPU's
VPU/MXU.  The C++ setting of the paper has no equivalent; here it is the
single biggest win for the serving engine (decoding many sequences in
one fused step).

An event type opts in by being *entity-parallel safe*: its handler can be
expressed as a function over an entity slice of the state,

    local_handler(entity_state, t, arg) -> entity_state

with no cross-entity interaction.  ``make_run_handler`` lifts it to a
whole-run handler ``(state, ts, args, entity_ids) -> state`` using
``vmap`` + scatter, which the serving engine dispatches when the
extracted window is a single-type run.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def make_run_handler(local_handler: Callable, *, state_axis: int = 0):
    """Lift an entity-local handler to a vectorized run handler.

    ``state`` must be a pytree whose leaves carry the entity dimension at
    ``state_axis``.  ``entity_ids: i32[k]`` selects the rows the run's
    events touch; ``ts: f32[k]``, ``args`` batched likewise.  Duplicate
    entity ids within one run are NOT allowed (they would race); callers
    guarantee it — the serving engine's windows contain at most one
    decode event per sequence by construction.
    """

    vh = jax.vmap(local_handler, in_axes=(state_axis, 0, 0), out_axes=state_axis)

    def run_handler(state, ts, args, entity_ids):
        take = lambda leaf: jnp.take(leaf, entity_ids, axis=state_axis)
        sub = jax.tree.map(take, state)
        sub = vh(sub, ts, args)

        def put(leaf, new):
            return leaf.at[entity_ids].set(new) if state_axis == 0 else (
                jnp.moveaxis(
                    jnp.moveaxis(leaf, state_axis, 0).at[entity_ids].set(
                        jnp.moveaxis(new, state_axis, 0)
                    ),
                    0,
                    state_axis,
                )
            )

        return jax.tree.map(put, state, sub)

    return run_handler


def is_single_type_run(type_ids) -> bool:
    """Host-side check that an extracted window is a same-type run."""
    ids = list(type_ids)
    return len(ids) > 0 and all(t == ids[0] for t in ids)
