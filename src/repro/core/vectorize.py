"""Same-type run vectorization (TPU-native cross-event optimization).

DESIGN.md §2: a batch that is a *run* of the same event type over
independent entities can be executed as ``vmap(handler)`` instead of a
sequential concatenation — the data-parallel analogue of the paper's
cross-event scalar optimization, and the natural mapping onto the TPU's
VPU/MXU.  The C++ setting of the paper has no equivalent; here it is the
single biggest win for the serving engine (decoding many sequences in
one fused step).

An event type opts in by being *entity-parallel safe*: its handler can be
expressed as a function over an entity slice of the state,

    local_handler(entity_state, t, arg) -> entity_state

with no cross-entity interaction.  ``make_run_handler`` lifts it to a
whole-run handler ``(state, ts, args, entity_ids) -> state`` using
``vmap`` + scatter, which the serving engine dispatches when the
extracted window is a single-type run.  ``make_masked_run_handler`` is
the fixed-shape variant used by the on-device engine
(``DeviceEngine(entity_handlers=...)``), where windows are padded to
``max_batch_len`` and a lane mask marks the real events.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def make_run_handler(local_handler: Callable, *, state_axis: int = 0):
    """Lift an entity-local handler to a vectorized run handler.

    ``state`` must be a pytree whose leaves carry the entity dimension at
    ``state_axis``.  ``entity_ids: i32[k]`` selects the rows the run's
    events touch; ``ts: f32[k]``, ``args`` batched likewise.  Duplicate
    entity ids within one run are NOT allowed (they would race); callers
    guarantee it — the serving engine's windows contain at most one
    decode event per sequence by construction.
    """

    vh = jax.vmap(local_handler, in_axes=(state_axis, 0, 0), out_axes=state_axis)

    def run_handler(state, ts, args, entity_ids):
        take = lambda leaf: jnp.take(leaf, entity_ids, axis=state_axis)
        sub = jax.tree.map(take, state)
        sub = vh(sub, ts, args)

        def put(leaf, new):
            return leaf.at[entity_ids].set(new) if state_axis == 0 else (
                jnp.moveaxis(
                    jnp.moveaxis(leaf, state_axis, 0).at[entity_ids].set(
                        jnp.moveaxis(new, state_axis, 0)
                    ),
                    0,
                    state_axis,
                )
            )

        return jax.tree.map(put, state, sub)

    return run_handler


def make_masked_run_handler(local_handler: Callable, *, state_axis: int = 0):
    """Like :func:`make_run_handler`, for fixed-shape padded windows.

    The on-device engine extracts windows padded to ``max_batch_len``;
    ``mask: bool[k]`` marks the lanes that hold real events.  Masked-out
    lanes gather entity 0 (result discarded) and scatter nowhere (their
    scatter index is pushed out of range and dropped), so padding can
    never perturb the state.  Duplicate entity ids among *real* lanes
    remain the caller's responsibility, as in :func:`make_run_handler`.
    """

    vh = jax.vmap(local_handler, in_axes=(state_axis, 0, 0), out_axes=state_axis)
    _DROP = jnp.int32(2**31 - 1)

    def run_handler(state, ts, args, entity_ids, mask):
        gather_ids = jnp.where(mask, entity_ids, 0)
        take = lambda leaf: jnp.take(leaf, gather_ids, axis=state_axis)
        sub = jax.tree.map(take, state)
        sub = vh(sub, ts, args)
        scatter_ids = jnp.where(mask, entity_ids, _DROP)

        def put(leaf, new):
            if state_axis == 0:
                return leaf.at[scatter_ids].set(new, mode="drop")
            moved = jnp.moveaxis(leaf, state_axis, 0)
            updated = moved.at[scatter_ids].set(
                jnp.moveaxis(new, state_axis, 0), mode="drop"
            )
            return jnp.moveaxis(updated, 0, state_axis)

        return jax.tree.map(put, state, sub)

    return run_handler


def is_single_type_run(type_ids) -> bool:
    """Host-side check that an extracted window is a same-type run."""
    ids = list(type_ids)
    return len(ids) > 0 and all(t == ids[0] for t in ids)
