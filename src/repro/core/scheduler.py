"""Batch selection and execution (paper §III-B).

Extraction rule (paper Fig 2): iterate over the future events in time
order, maintaining the dynamic lookahead window
``t_max = min over extracted e of (t_e + l_e)``.  An event is extracted
while its timestamp does not exceed the current ``t_max`` and the batch
is shorter than the configured maximum length.  The extracted word is
encoded with the Horner codec and the corresponding pre-composed batch
program is executed.

Schedulers:

* :class:`ConservativeScheduler` — the paper's runtime mechanism
  (host-driven; correct by construction).
* :func:`run_unbatched`    — one-event-at-a-time baseline, as in common
  sequential simulators (used for the §IV.B overhead measurement and the
  Fig-3 speedup denominators).
* :class:`SpeculativeScheduler` — the paper's §IV.D future-work variant:
  extract optimistically past the lookahead window, snapshot the state,
  and roll back if an emitted event lands inside the executed window.

Emission anchoring: handlers emit ``(delay, type, arg)`` and the new
event is scheduled at ``t_emitter + delay`` (the composer tags each
emission with its in-batch source index), identically across the
batched, unbatched, and speculative paths.  Emissions whose type is
negative are ν-rows (unused slots of the fixed-record convention that
``repro.api.SimProgram`` compiles portable handlers to) and are skipped
everywhere — including the speculative violation predicate.

All three run entry points accept ``t_end``: a batch (or event) is
started only while the earliest pending event's timestamp is <= t_end,
the same horizon contract as ``DeviceEngine.run``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

import numpy as np

from repro.core.composer import _ComposerBase
from repro.core.events import Event, EventRegistry
from repro.core.queue import HostEventQueue, window_prefix_mask


@dataclasses.dataclass
class RunStats:
    events_executed: int = 0
    batches_executed: int = 0
    rollbacks: int = 0
    final_time: float = 0.0
    batch_length_hist: dict[int, int] = dataclasses.field(default_factory=dict)

    def record_batch(self, length: int) -> None:
        self.batches_executed += 1
        self.events_executed += length
        self.batch_length_hist[length] = self.batch_length_hist.get(length, 0) + 1

    @property
    def mean_batch_length(self) -> float:
        if not self.batches_executed:
            return 0.0
        return self.events_executed / self.batches_executed


def extract_window(
    queue: HostEventQueue,
    registry: EventRegistry,
    max_len: int,
    t_cap: float = float("inf"),
) -> list[Event]:
    """Pop the maximal runnable prefix under the dynamic lookahead window.

    ``t_cap`` starts the dynamic bound below ``inf`` — the run horizon:
    no event with a later timestamp is extracted.  This is the serial
    form of the take rule; the vectorized form shared with the device
    queue is :func:`repro.core.queue.window_prefix_mask` (and
    :func:`extract_window_presorted` below), and the differential tests
    assert their equivalence.
    """
    batch: list[Event] = []
    t_max = t_cap
    while queue and len(batch) < max_len:
        head = queue.peek()
        if head.time > t_max:
            break
        batch.append(queue.pop())
        la = registry[head.type_id].lookahead
        t_max = min(t_max, head.time + la)
    return batch


def extract_window_presorted(
    events: list[Event],
    registry: EventRegistry,
    max_len: int,
) -> int:
    """Length of the runnable prefix of an already-(time, seq)-sorted list.

    Host-side entry point to the same vectorized take rule the device
    queue uses (:func:`repro.core.queue.window_prefix_mask`): the §III-B
    extraction condition is monotone on sorted candidates, so it reduces
    to a shifted cummin + prefix mask — no serial scan needed.
    """
    if not events:
        return 0
    cand = events[:max_len]
    ts = np.asarray([ev.time for ev in cand], np.float32)
    wins = np.asarray(
        [ev.time + registry[ev.type_id].lookahead for ev in cand], np.float32
    )
    valid = jnp.ones((len(cand),), bool)
    take = window_prefix_mask(ts, wins, valid)
    return int(jnp.sum(take))


class ConservativeScheduler:
    """Paper §III-B: lookahead-window batches over a host event queue."""

    def __init__(self, registry: EventRegistry, composer: _ComposerBase,
                 *, check_causality: bool = False):
        self.registry = registry
        self.composer = composer
        self.max_len = composer.codec.max_len
        self.check_causality = check_causality

    @classmethod
    def from_program(cls, program, *, composer: _ComposerBase | None = None,
                     check_causality: bool = False):
        """Construct from a frozen SimProgram (host-adapted registry)."""
        from repro.core.composer import LazyComposer

        composer = composer or LazyComposer.from_program(program)
        return cls(program.host_registry(), composer,
                   check_causality=check_causality)

    def run(self, state, queue: HostEventQueue, *,
            max_events: int | None = None,
            max_batches: int | None = None,
            t_end: float = float("inf")) -> tuple[Any, RunStats]:
        stats = RunStats()
        budget = float("inf") if max_events is None else max_events
        b_budget = float("inf") if max_batches is None else max_batches
        while (queue and stats.events_executed < budget
               and stats.batches_executed < b_budget
               and queue.peek().time <= t_end):
            batch = extract_window(queue, self.registry, self.max_len,
                                   t_cap=t_end)
            if not batch:  # cannot happen: first event is always extractable
                break
            word = [ev.type_id for ev in batch]
            code = self.composer.codec.encode(word)
            ts = [jnp.float32(ev.time) for ev in batch]
            args = [ev.arg for ev in batch]
            state, emitted = self.composer.execute(code, state, ts, args)
            # Deferred scheduling (§IV.D): emissions buffered during the
            # batch are inserted only now, anchored at the EMITTING
            # event's timestamp (same as unbatched execution, so results
            # do not depend on how events were grouped into batches).
            last_t = batch[-1].time
            for (src, delay, type_id, arg) in emitted:
                ty = int(type_id)
                if ty < 0:
                    continue  # ν-row (unused fixed-record slot)
                t_new = float(batch[src].time) + float(delay)
                if self.check_causality and t_new < last_t:
                    raise RuntimeError(
                        f"causality violation: event type {ty} emitted "
                        f"at {t_new} < batch end {last_t}; lookahead too "
                        "large for this model"
                    )
                queue.push(t_new, ty, arg)
            stats.record_batch(len(batch))
            stats.final_time = last_t
        return state, stats


def run_unbatched(
    registry: EventRegistry,
    state,
    queue: HostEventQueue,
    *,
    jit_handlers: bool = True,
    max_events: int | None = None,
    max_batches: int | None = None,
    t_end: float = float("inf"),
) -> tuple[Any, RunStats]:
    """One-by-one execution, the common sequential DES baseline.

    Each handler is individually jitted (that is what a production JAX
    DES without cross-event batching would do) so the comparison against
    batched execution isolates the *cross-event* optimization, not
    jit-vs-python overhead.
    """
    stats = RunStats()
    progs = {}
    for et in registry:
        progs[et.type_id] = jax.jit(et.handler) if jit_handlers else et.handler
    budget = float("inf") if max_events is None else max_events
    if max_batches is not None:  # one event per "batch" here
        budget = min(budget, max_batches)
    while (queue and stats.events_executed < budget
           and queue.peek().time <= t_end):
        ev = queue.pop()
        et = registry[ev.type_id]
        result = progs[ev.type_id](state, jnp.float32(ev.time), ev.arg)
        if et.returns_events:
            state, emitted = result
            for (delay, type_id, arg) in emitted:
                ty = int(type_id)
                if ty < 0:
                    continue  # ν-row (unused fixed-record slot)
                queue.push(ev.time + float(delay), ty, arg)
        else:
            state = result
        stats.record_batch(1)
        stats.final_time = ev.time
    return state, stats


class SpeculativeScheduler:
    """Optimistic batches with rollback (paper §IV.D future work).

    Events are extracted up to ``max_len`` ignoring the lookahead window
    (but still in timestamp order).  The state pytree is snapshotted
    before the batch; if the batch emits an event whose timestamp falls
    *before* the timestamp of the last event executed in the batch, the
    causality constraint may have been violated, so the batch is rolled
    back and re-executed conservatively one event at a time.

    Snapshot/restore is O(state) but on-device (no transfers): JAX arrays
    are immutable, so the "snapshot" is just keeping the old pytree alive
    — rollback is free unless the batch committed, which makes this a
    particularly cheap Time-Warp on immutable arrays.
    """

    def __init__(self, registry: EventRegistry, composer: _ComposerBase,
                 *, window_slack: float = float("inf")):
        self.registry = registry
        self.composer = composer
        self.max_len = composer.codec.max_len
        # How far past t_max we are willing to speculate.
        self.window_slack = window_slack

    @classmethod
    def from_program(cls, program, *, composer: _ComposerBase | None = None,
                     window_slack: float = float("inf")):
        """Construct from a frozen SimProgram (host-adapted registry)."""
        from repro.core.composer import LazyComposer

        composer = composer or LazyComposer.from_program(program)
        return cls(program.host_registry(), composer,
                   window_slack=window_slack)

    def _extract_speculative(self, queue: HostEventQueue,
                             t_cap: float = float("inf")):
        batch: list[Event] = []
        t_max = float("inf")
        while queue and len(batch) < self.max_len:
            head = queue.peek()
            # Speculation may run past the lookahead window (by
            # window_slack) but never past the run horizon t_cap.
            if head.time > min(t_max + self.window_slack, t_cap):
                break
            batch.append(queue.pop())
            la = self.registry[head.type_id].lookahead
            t_max = min(t_max, head.time + la)
        return batch, t_max

    def run(self, state, queue: HostEventQueue, *,
            max_events: int | None = None,
            max_batches: int | None = None,
            t_end: float = float("inf")) -> tuple[Any, RunStats]:
        stats = RunStats()
        budget = float("inf") if max_events is None else max_events
        b_budget = float("inf") if max_batches is None else max_batches
        while (queue and stats.events_executed < budget
               and stats.batches_executed < b_budget
               and queue.peek().time <= t_end):
            batch, t_max = self._extract_speculative(queue, t_cap=t_end)
            word = [ev.type_id for ev in batch]
            code = self.composer.codec.encode(word)
            ts = [jnp.float32(ev.time) for ev in batch]
            args = [ev.arg for ev in batch]
            snapshot = state  # immutable pytree: snapshot is a reference
            state_new, emitted = self.composer.execute(code, state, ts, args)
            last_t = batch[-1].time
            # Causality check, per emission: the new event lands at
            # t_new = t_emitter + delay; if any event with a LATER
            # timestamp already executed in this batch, that event ran
            # without seeing the emission and the batch must roll back.
            # Ties are safe — the emission gets a later seq, so ordering
            # matches sequential execution.  (The seed expression's
            # or/and precedence collapsed to "batch_end + delay <
            # batch_end", which can never fire for delay >= 0 and fires
            # spuriously for negative delays anchored at the wrong
            # event.)
            del t_max
            violated = any(
                int(_ty) >= 0
                and float(batch[src].time) + float(delay) < last_t
                for (src, delay, _ty, _a) in emitted
            )
            if violated:
                # Rollback: restore snapshot, requeue, replay one by one.
                stats.rollbacks += 1
                state = snapshot
                for ev in batch:
                    queue.push_event(ev)
                for _ in range(len(batch)):
                    ev = queue.pop()
                    et = self.registry[ev.type_id]
                    result = et.handler(state, jnp.float32(ev.time), ev.arg)
                    if et.returns_events:
                        state, new = result
                        for (delay, ty, a) in new:
                            if int(ty) < 0:
                                continue  # ν-row
                            queue.push(ev.time + float(delay), int(ty), a)
                    else:
                        state = result
                    stats.record_batch(1)
                    stats.final_time = ev.time
                continue
            state = state_new
            for (src, delay, type_id, arg) in emitted:
                if int(type_id) < 0:
                    continue  # ν-row
                queue.push(
                    float(batch[src].time) + float(delay), int(type_id), arg
                )
            stats.record_batch(len(batch))
            stats.final_time = last_t
        return state, stats
