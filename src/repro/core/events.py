"""Event types, lookahead, and the event record format.

The paper (§III) assumes the modeler registers *function pointers to all
event handlers in a constant array*.  `EventRegistry` is that array: an
ordered, immutable-after-freeze list of event types, each pairing a pure
JAX handler with a per-type *lookahead* (the minimum delta between an
event's execution time and the earliest timestamp of any event it may
create — §III-B).

Handlers are pure functions over the simulation state:

    handler(state: PyTree, t: f32 scalar, arg: PyTree) -> state
        or -> (state, new_events)

where ``new_events`` (optional) is a list of ``(delay, type_id, arg)``
tuples with ``delay >= lookahead`` of the handler's type — the engine
checks this invariant in debug mode, mirroring the causality requirement
of conservative PDES that the paper leans on.

Static-shape adaptation (DESIGN.md §6.3): on-device events are fixed
records ``(time: f32, type: i32, arg: f32[ARG_WIDTH])``; rich payloads
live in the state PyTree.
"""

from __future__ import annotations

import dataclasses
import functools
import inspect
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

# Width of the inline argument vector carried by on-device events.
ARG_WIDTH = 4

# Reserved type id for the ν-event ("no event", §III-A).  In the
# paper-faithful codec the digit 0 is ν and real types are 1-based.
NU_EVENT = -1


@dataclasses.dataclass(frozen=True)
class EventType:
    """One character of the event alphabet Σ."""

    type_id: int            # dense index into the registry (0-based)
    name: str
    handler: Callable       # (state, t, arg) -> state | (state, events)
    lookahead: float        # l_e >= 0; np.inf allowed (never blocks)
    returns_events: bool    # whether handler returns (state, new_events)

    def __call__(self, state, t, arg):
        return self.handler(state, t, arg)


@dataclasses.dataclass(frozen=True)
class Event:
    """A host-side scheduled event instance."""

    time: float
    type_id: int
    arg: Any = None
    # Monotonic sequence number used as a tie-breaker so that events with
    # equal timestamps execute in schedule order (deterministic runs).
    seq: int = 0

    def key(self):
        return (self.time, self.seq)


def _handler_returns_events(handler: Callable) -> bool:
    """Best-effort detection of the (state, events) return convention.

    Handlers may declare it explicitly via a ``returns_events`` attribute
    (set by the ``@emits_events`` decorator); otherwise we assume the
    plain state-only convention.
    """
    return bool(getattr(handler, "returns_events", False))


def emits_events(handler: Callable) -> Callable:
    """Decorator marking a handler as returning ``(state, new_events)``.

    Returns a wrapper carrying ``returns_events = True`` instead of
    mutating ``handler`` in place: ``functools.partial`` objects, bound
    methods, and builtins reject attribute assignment, and mutating a
    shared callable would silently mark every other registration of it.
    The wrapped callable stays reachable via ``__wrapped__``.
    """

    @functools.wraps(handler)
    def wrapper(*args, **kwargs):
        return handler(*args, **kwargs)

    wrapper.returns_events = True
    return wrapper


class EventRegistry:
    """The ordered array of event handlers (the alphabet Σ).

    The registry is frozen before batch composition; its order defines
    the digit values of the Horner codec, so it must not change between
    compilation and runtime — the same constraint the paper places on
    its constant function-pointer array.
    """

    def __init__(self):
        self._types: list[EventType] = []
        self._by_name: dict[str, EventType] = {}
        self._frozen = False

    # -- registration -----------------------------------------------------
    def register(
        self,
        name: str,
        handler: Callable,
        *,
        lookahead: float = float("inf"),
    ) -> EventType:
        if self._frozen:
            raise RuntimeError(
                "EventRegistry is frozen; register all event types before "
                "composing batches (paper §III-A: constant handler array)."
            )
        if name in self._by_name:
            raise ValueError(f"event type {name!r} already registered")
        if lookahead < 0:
            raise ValueError("lookahead must be >= 0")
        et = EventType(
            type_id=len(self._types),
            name=name,
            handler=handler,
            lookahead=float(lookahead),
            returns_events=_handler_returns_events(handler),
        )
        self._types.append(et)
        self._by_name[name] = et
        return et

    def event_type(self, fn: Callable | None = None, *, name=None, lookahead=float("inf")):
        """Decorator form: ``@registry.event_type(lookahead=1.0)``."""
        def wrap(f):
            self.register(name or f.__name__, f, lookahead=lookahead)
            return f
        if fn is not None:
            return wrap(fn)
        return wrap

    def freeze(self) -> "EventRegistry":
        self._frozen = True
        return self

    # -- introspection ----------------------------------------------------
    def __len__(self) -> int:
        return len(self._types)

    def __iter__(self):
        return iter(self._types)

    def __getitem__(self, idx) -> EventType:
        if isinstance(idx, str):
            return self._by_name[idx]
        return self._types[idx]

    @property
    def frozen(self) -> bool:
        return self._frozen

    @property
    def names(self) -> list[str]:
        return [t.name for t in self._types]

    def lookaheads(self) -> jnp.ndarray:
        """Per-type lookahead vector (f32), inf-safe, for device use."""
        la = [t.lookahead for t in self._types]
        return jnp.asarray(la, dtype=jnp.float32)

    def any_returns_events(self) -> bool:
        return any(t.returns_events for t in self._types)


def normalize_handler_result(result, *, returns_events: bool):
    """Canonicalize a handler result to ``(state, list_of_new_events)``."""
    if returns_events:
        state, new_events = result
        return state, list(new_events)
    return result, []
