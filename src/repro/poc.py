"""The paper's synthetic proof-of-concept model (§IV.A).

Two event types over a global scalar ``sum``:

* ``Increment`` — K iterations of ``sum += sum + 1`` (paper: K = 1e6),
  i.e. ``sum <- 2*sum + 1``, a computationally intensive loop whose
  result is only observable through the final value of ``sum``.
* ``Set`` — ``sum <- 10``, a constant store.

When a batch contains ``Increment`` followed (eventually) by ``Set``, the
increment loop is dead code *within the batch's contiguous program* and
the compiler removes it — clang in the paper, XLA here (the ``while`` op
vanishes from the optimized HLO; asserted in tests/test_poc_hlo.py).

State is a single uint32 (C++ unsigned overflow semantics = wraparound,
matching the paper's native arithmetic).  Neither event schedules new
events (§IV.A), so any lookahead is valid; the paper uses a lookahead of
1e6 so every batch reaches the maximum length.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import EventRegistry

SET_VALUE = 10
PAPER_ITERS = 1_000_000     # paper §IV.A
DEFAULT_ITERS = 100_000     # container default (single-core CPU; DESIGN §6.4)


def increment_body(sum_, iters: int):
    """K iterations of ``sum += sum + 1`` as a lax.fori_loop."""
    return jax.lax.fori_loop(
        0, iters, lambda _i, s: s * jnp.uint32(2) + jnp.uint32(1), sum_
    )


def build_registry(iters: int = DEFAULT_ITERS,
                   lookahead: float = 1_000_000.0) -> EventRegistry:
    """Registry with the paper's two event types.

    Handlers follow the (state, t, arg) -> state convention; ``state`` is
    the global uint32 ``sum``.  ``arg`` is unused (the PoC events carry
    no payload).
    """
    reg = EventRegistry()

    def increment(state, t, arg):
        del t, arg
        return increment_body(state, iters)

    def set_(state, t, arg):
        del state, t, arg
        return jnp.uint32(SET_VALUE)

    reg.register("Increment", increment, lookahead=lookahead)
    reg.register("Set", set_, lookahead=lookahead)
    return reg.freeze()


def build_program(iters: int = DEFAULT_ITERS,
                  lookahead: float = 1_000_000.0,
                  config=None):
    """The PoC model as a :class:`repro.api.SimProgram` — the same two
    handlers, declared once and compilable to every runtime."""
    from repro.core.program import Config, SimProgram

    prog = SimProgram("poc", config=config or Config(max_batch_len=4))

    @prog.handler("Increment", lookahead=lookahead)
    def increment(state, t, arg):
        del t, arg
        return increment_body(state, iters)

    @prog.handler("Set", lookahead=lookahead)
    def set_(state, t, arg):
        del state, t, arg
        return jnp.uint32(SET_VALUE)

    return prog


INCREMENT, SET = 0, 1  # type ids, in registration order


def initial_state():
    return jnp.uint32(0)


def schedule_poc_events(num_events: int, p_set: float, seed: int):
    """§IV.B workload: one event per integer time step, type ~ Bernoulli.

    Returns a list of (time, type_id) pairs.
    """
    rng = np.random.default_rng(seed)
    types = np.where(rng.random(num_events) < p_set, SET, INCREMENT)
    return [(float(t), int(ty)) for t, ty in enumerate(types)]


def reference_final_sum(types, iters: int) -> int:
    """Pure-Python oracle for the final value of ``sum`` (mod 2^32)."""
    s = 0
    mask = (1 << 32) - 1
    for ty in types:
        if ty == SET:
            s = SET_VALUE
        else:
            # 2^K * s + (2^K - 1) mod 2^32 (closed form of K doublings).
            twoK = pow(2, iters, 1 << 32)
            s = (twoK * s + twoK - 1) & mask
    return s


def s_max(n: int, p_i: float) -> float:
    """Analytic maximum speedup (paper Corollary 1)."""
    if p_i <= 0.0:
        return float(n)
    if p_i >= 1.0:
        return 1.0
    return n * (1.0 - p_i) / (1.0 - p_i ** n)
