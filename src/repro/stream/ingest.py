"""Host→device arrival feeding: the double-buffered :class:`StreamFeeder`.

The feeder sits between an :class:`~repro.stream.source.ArrivalSource`
and the segment loop in :meth:`repro.core.program.CompiledSim.run`.  A
daemon thread pulls blocks from the source, validates them, assigns
seqs from the run's reserved range, and stages both the host copy and a
``jax.device_put`` device copy into a depth-2 queue — so while the
engine executes the active segment (releasing the GIL inside XLA), the
NEXT arrival block's generation and host→device transfer overlap with
device compute.  ``prefetch=False`` degrades to synchronous in-line
feeding (the bench baseline for measuring that overlap).

Determinism: the feeder never *decides* anything — which rows are
admitted, shed, or spilled is chosen by the segment loop from the
cursor, the horizon, and queue occupancy, all of which are independent
of thread timing.  Prefetching only changes WHEN a block's bytes reach
the device, never what they contain.

Seq discipline (the equivalence keystone): the run reserves seqs
``seq0 .. seq0+len(source)`` upfront by advancing the queue's global
``next_seq`` before the first batch, and the feeder labels global row
``j`` with seq ``seq0 + j``.  An arrival therefore occupies exactly the
(time, seq) rank it would have had as the ``j``-th pre-seeded event,
even under timestamp ties with events emitted mid-run (which draw seqs
past the reserved range).  Shed rows leave harmless seq gaps.
"""

from __future__ import annotations

import queue as _queue
import threading

import numpy as np

import jax

from repro.stream.source import EMIT_WIDTH, ArrivalSource

_I32_MAX = 2**31 - 1

#: blocks staged ahead of the consumer: the active block + one standby
_DEPTH = 2


class StreamFeeder:
    """Cursor-tracking, optionally prefetching view over an arrival source.

    The consumer (the segment loop) sees a flat row stream addressed by
    a global ``cursor`` (row index into the source) and interacts at
    block granularity:

    - :meth:`next_key` — the (time, seq) lex key of the next unconsumed
      arrival, or ``(inf, 2**31-1)`` when exhausted.  This is the
      admission fence fed to the engine: no event at/after this key may
      execute before the arrival is absorbed.
    - :meth:`admissible` — how many rows of the *current block* have
      ``time <= t_end`` (arrivals past the horizon are never consumed).
    - :meth:`device_block` / :meth:`host_slice` — the staged device
      arrays (for the jitted masked absorb) or a host copy of the next
      ``k`` rows (for the spill pool).
    - :meth:`advance` — commit consumption of ``k`` rows.
    """

    def __init__(
        self,
        source: ArrivalSource,
        seq0: int,
        *,
        start: int = 0,
        prefetch: bool = True,
        to_device: bool = True,
    ):
        self.source = source
        self.seq0 = int(seq0)
        self.n = len(source)
        if not 0 <= start <= self.n:
            raise ValueError(f"start cursor {start} outside [0, {self.n}]")
        self.cursor = int(start)
        self.prefetch = bool(prefetch)
        self.to_device = bool(to_device)
        self._cur = None  # active block dict: c0, rows, n [, dev_rows, dev_seqs]
        self._off = 0  # rows of the active block already consumed
        self._prod_last_t = -np.inf  # producer-side monotonicity watermark
        self._err = None
        self._stop = threading.Event()
        self._thread = None
        source.seek(self.cursor)
        self._gen = source.blocks()
        self._c0_next = self.cursor  # producer-side global index of next block
        if self.prefetch:
            self._q = _queue.Queue(maxsize=_DEPTH)
            self._thread = threading.Thread(
                target=self._pump, name="repro-stream-feeder", daemon=True
            )
            self._thread.start()

    # -- producer side ----------------------------------------------------

    def _make_block(self, c0: int, rows: np.ndarray) -> dict:
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or rows.shape[1] != EMIT_WIDTH:
            raise ValueError(
                f"arrival block must be (block, {EMIT_WIDTH}), got {rows.shape}"
            )
        n = min(rows.shape[0], self.n - c0)
        if n and not np.all(rows[:n, 1] >= 0):
            raise ValueError(
                "padding (type < 0) row inside the real prefix of an "
                "arrival block — only the tail may be padding"
            )
        if np.any(rows[n:, 1] >= 0):
            raise ValueError(
                f"arrival source produced more than its advertised "
                f"len()={self.n} real rows"
            )
        if n:
            t = rows[:n, 0]
            if t[0] < self._prod_last_t or np.any(np.diff(t) < 0):
                raise ValueError(
                    "arrival times must be nondecreasing within and "
                    "across blocks"
                )
            self._prod_last_t = float(t[n - 1])
        blk = {"c0": int(c0), "rows": rows, "n": int(n)}
        if self.to_device:
            seqs = (self.seq0 + c0 + np.arange(rows.shape[0])).astype(np.int32)
            blk["dev_rows"] = jax.device_put(rows)
            blk["dev_seqs"] = jax.device_put(seqs)
        return blk

    def _next_block_sync(self):
        rows = next(self._gen, None)
        if rows is None:
            return None
        blk = self._make_block(self._c0_next, rows)
        self._c0_next += rows.shape[0]
        return blk

    def _pump(self):
        try:
            while not self._stop.is_set():
                blk = self._next_block_sync()
                while not self._stop.is_set():
                    try:
                        self._q.put(blk, timeout=0.1)
                        break
                    except _queue.Full:
                        continue
                if blk is None:
                    return
        except BaseException as e:  # surfaced on the consumer thread
            self._err = e
            while not self._stop.is_set():
                try:
                    self._q.put(None, timeout=0.1)
                    return
                except _queue.Full:
                    continue

    # -- consumer side ----------------------------------------------------

    def _ensure(self):
        """Return the active block, fetching until it covers ``cursor``."""
        while self._cur is None or self._off >= self._cur["n"]:
            if self.cursor >= self.n:
                return None
            blk = self._q.get() if self.prefetch else self._next_block_sync()
            if blk is None:
                if self._err is not None:
                    err, self._err = self._err, None
                    raise err
                raise ValueError(
                    f"arrival source exhausted at row "
                    f"{self._cur['c0'] + self._cur['n'] if self._cur else 0} "
                    f"but advertised len()={self.n}"
                )
            self._cur = blk
            self._off = self.cursor - blk["c0"]
            if not 0 <= self._off <= blk["rows"].shape[0]:
                raise ValueError(
                    f"arrival block at row {blk['c0']} does not cover "
                    f"cursor {self.cursor}"
                )
        return self._cur

    def has_pending(self) -> bool:
        return self.cursor < self.n

    def next_key(self):
        """(time, seq) lex key of the next arrival — the admission fence."""
        blk = self._ensure()
        if blk is None:
            return (float("inf"), _I32_MAX)
        return (float(blk["rows"][self._off, 0]), self.seq0 + self.cursor)

    def next_time(self) -> float:
        return self.next_key()[0]

    def admissible(self, t_end: float) -> int:
        """Rows of the active block at/under the horizon (``time <= t_end``)."""
        blk = self._ensure()
        if blk is None:
            return 0
        t = blk["rows"][self._off : blk["n"], 0]
        return int(np.searchsorted(t, np.float32(t_end), side="right"))

    def device_block(self):
        """``(dev_rows, dev_seqs, offset)`` of the active block.

        The consumer absorbs rows ``[offset, offset+k)`` with a masked
        insert and then calls ``advance(k)``.
        """
        blk = self._ensure()
        if blk is None or not self.to_device:
            raise RuntimeError("no device-staged arrival block available")
        return blk["dev_rows"], blk["dev_seqs"], self._off

    def host_slice(self, k: int):
        """Host copy of the next ``k`` rows and their seqs (spill pool)."""
        blk = self._ensure()
        if blk is None or k > blk["n"] - self._off:
            raise RuntimeError(f"host_slice({k}) exceeds the active block")
        rows = np.array(blk["rows"][self._off : self._off + k], np.float32)
        seqs = (self.seq0 + self.cursor + np.arange(k)).astype(np.int32)
        return rows, seqs

    def advance(self, k: int) -> None:
        """Commit consumption (admitted, spilled, or shed) of ``k`` rows."""
        k = int(k)
        if k < 0 or (k > 0 and (self._cur is None or self._off + k > self._cur["n"])):
            raise ValueError(f"advance({k}) outside the active block")
        self.cursor += k
        self._off += k

    def close(self) -> None:
        self._stop.set()
        if self._thread is not None:
            # unblock a producer waiting on a full queue
            try:
                while True:
                    self._q.get_nowait()
            except _queue.Empty:
                pass
            self._thread.join(timeout=5.0)
            self._thread = None

    def __enter__(self) -> "StreamFeeder":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
