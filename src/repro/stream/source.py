"""Arrival sources: deterministic host-side generators of arrival blocks.

An :class:`ArrivalSource` yields fixed-size blocks of arrival rows in
the portable emit-row layout (``(time, type, arg0..arg3)``, float32,
width ``EMIT_WIDTH``) with **host-assigned arrival times**.  Rows with
``type < 0`` are padding; real rows must carry nondecreasing times
within and across blocks — the feeder enforces this at consume time.

All sources are seeded and fully deterministic: iterating ``blocks()``
twice, or regenerating after :meth:`ArrivalSource.seek`, reproduces the
identical rows bit-for-bit.  Determinism is what lets checkpoint/resume
store only a row *cursor* instead of buffered arrival data, and what
makes the closed-vs-open equivalence tests meaningful.

Synthetic generators:

- :class:`PoissonSource` — homogeneous Poisson arrivals (exp gaps).
- :class:`BurstySource` — on/off modulated Poisson (bursts of
  ``burst_len`` closely spaced arrivals separated by idle gaps).
- :class:`DiurnalSource` — sinusoidally rate-modulated arrivals
  (a "time-of-day" curve).

All three support ``grid=`` quantization: arrival times snap to
multiples of a grid step while staying strictly increasing, which keeps
float32 arithmetic exact when a scenario's event times live on the same
grid (the serving admission scenario uses a 0.25 grid).

Bounded-memory traces: :class:`TraceWriter` streams blocks to disk,
:class:`TraceReader` replays them block-at-a-time via ``np.fromfile``
with an explicit offset — memory use is one block regardless of trace
length.
"""

from __future__ import annotations

import json
import os
from typing import Callable, Iterator, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.events import ARG_WIDTH

EMIT_WIDTH = 2 + ARG_WIDTH

#: default rows per arrival block (one host→device transfer + absorb)
DEFAULT_BLOCK = 256


def _pad_block(rows: np.ndarray, block_size: int) -> np.ndarray:
    """Pad a partial block to ``block_size`` rows with type=-1 rows."""
    n = rows.shape[0]
    if n == block_size:
        return rows
    out = np.zeros((block_size, EMIT_WIDTH), np.float32)
    out[:, 1] = -1.0
    out[:n] = rows
    return out


@runtime_checkable
class ArrivalSource(Protocol):
    """Protocol for arrival streams consumed by ``run(arrivals=...)``.

    ``blocks()`` returns a *fresh* iterator over fixed-size float32
    blocks of shape ``(block_size, EMIT_WIDTH)``; rows with ``type < 0``
    are padding (only the final block may be partial).  ``len(source)``
    is the total number of real arrival rows.  ``seek(cursor)`` makes
    the next ``blocks()`` iterator start at row ``cursor`` (block-
    aligned padding applies from there) — used by checkpoint resume.
    """

    block_size: int

    def __len__(self) -> int: ...

    def blocks(self) -> Iterator[np.ndarray]: ...

    def seek(self, cursor: int) -> None: ...


class _SyntheticSource:
    """Shared machinery for seeded synthetic generators.

    Subclasses implement ``_gaps(rng, idx0, m, carry)`` drawing the
    inter-arrival gaps for rows ``idx0..idx0+m`` from a single
    sequential RNG stream; the base class turns gaps into nondecreasing
    float32 times (optionally grid-quantized), fills args, and chunks
    into fixed blocks.  Generation is block-at-a-time — memory use is
    O(block_size) regardless of ``n``, so a million-row trace streams
    straight to disk.  ``seek`` regenerates from row 0 and discards —
    O(cursor) work, but always in block-sized vectorized numpy.
    Chunking is identical on every iteration (full blocks from row 0),
    so the generated rows are bit-reproducible regardless of how the
    RNG's draws are consumed.
    """

    def __init__(
        self,
        n: int,
        seed: int = 0,
        t0: float = 0.0,
        type_id: int = 0,
        block_size: int = DEFAULT_BLOCK,
        grid: Optional[float] = None,
        arg_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None,
    ):
        if n < 0:
            raise ValueError(f"n must be >= 0, got {n}")
        if block_size <= 0:
            raise ValueError(f"block_size must be positive, got {block_size}")
        if grid is not None and grid <= 0:
            raise ValueError(f"grid must be positive, got {grid}")
        self.n = int(n)
        self.seed = int(seed)
        self.t0 = float(t0)
        self.type_id = int(type_id)
        self.block_size = int(block_size)
        self.grid = None if grid is None else float(grid)
        self.arg_fn = arg_fn
        self._cursor = 0

    def __len__(self) -> int:
        return self.n

    def seek(self, cursor: int) -> None:
        if not 0 <= cursor <= self.n:
            raise ValueError(f"cursor {cursor} outside [0, {self.n}]")
        self._cursor = int(cursor)

    def _init_carry(self):
        return None

    def _gaps(self, rng: np.random.Generator, idx0: int, m: int, carry):
        """Return ``(gaps, carry)`` for global rows ``idx0..idx0+m``."""
        raise NotImplementedError

    def _iter_rows(self) -> Iterator[np.ndarray]:
        """Yield real rows in block-sized chunks, starting at row 0.

        Times accumulate in float64 across chunks (cast to float32 per
        row), or on an exact int64 grid index when ``grid`` is set:
        each gap quantizes to >= 1 grid step, so grid times are
        float32-exact multiples and strictly increasing.
        """
        rng = np.random.default_rng(self.seed)
        carry = self._init_carry()
        idx_acc = np.int64(0)
        t_acc = float(self.t0)
        bs = self.block_size
        produced = 0
        while produced < self.n:
            m = min(bs, self.n - produced)
            gaps, carry = self._gaps(rng, produced, m, carry)
            gaps = np.asarray(gaps, np.float64)
            if self.grid is not None:
                steps = np.maximum(1, np.rint(gaps / self.grid).astype(np.int64))
                idx = idx_acc + np.cumsum(steps)
                idx_acc = idx[-1]
                t = np.float32(self.t0) + (idx * self.grid).astype(np.float32)
            else:
                acc = t_acc + np.cumsum(gaps)
                t_acc = float(acc[-1])
                t = acc.astype(np.float32)
            rows = np.zeros((m, EMIT_WIDTH), np.float32)
            rows[:, 0] = t
            rows[:, 1] = np.float32(self.type_id)
            gidx = produced + np.arange(m, dtype=np.int64)
            if self.arg_fn is not None:
                args = np.asarray(self.arg_fn(gidx), np.float32)
                if args.shape != (m, ARG_WIDTH):
                    raise ValueError(
                        f"arg_fn must return shape ({m}, {ARG_WIDTH}), "
                        f"got {args.shape}"
                    )
                rows[:, 2:] = args
            else:
                rows[:, 2] = gidx.astype(np.float32)
            yield rows
            produced += m

    def blocks(self) -> Iterator[np.ndarray]:
        bs = self.block_size
        skip = self._cursor
        buf = np.zeros((0, EMIT_WIDTH), np.float32)
        for chunk in self._iter_rows():
            if skip >= chunk.shape[0]:
                skip -= chunk.shape[0]
                continue
            if skip:
                chunk = chunk[skip:]
                skip = 0
            buf = chunk if buf.shape[0] == 0 else np.concatenate([buf, chunk])
            while buf.shape[0] >= bs:
                yield np.ascontiguousarray(buf[:bs])
                buf = buf[bs:]
        if buf.shape[0]:
            yield _pad_block(np.ascontiguousarray(buf), bs)


class PoissonSource(_SyntheticSource):
    """Homogeneous Poisson arrivals at ``rate`` events per unit time."""

    def __init__(self, rate: float, n: int, **kw):
        if rate <= 0:
            raise ValueError(f"rate must be positive, got {rate}")
        super().__init__(n, **kw)
        self.rate = float(rate)

    def _gaps(self, rng, idx0, m, carry):
        return rng.exponential(1.0 / self.rate, m), carry


class BurstySource(_SyntheticSource):
    """On/off Poisson: bursts of ``burst_len`` arrivals at ``burst_rate``
    separated by idle gaps at ``idle_rate`` — the adversarial pattern for
    queue occupancy (a whole burst can land inside one lookahead window).
    """

    def __init__(
        self,
        burst_rate: float,
        idle_rate: float,
        burst_len: int,
        n: int,
        **kw,
    ):
        if burst_rate <= 0 or idle_rate <= 0:
            raise ValueError("burst_rate and idle_rate must be positive")
        if burst_len <= 0:
            raise ValueError(f"burst_len must be positive, got {burst_len}")
        super().__init__(n, **kw)
        self.burst_rate = float(burst_rate)
        self.idle_rate = float(idle_rate)
        self.burst_len = int(burst_len)

    def _gaps(self, rng, idx0, m, carry):
        u = rng.exponential(1.0, m)
        idx = idx0 + np.arange(m)
        first_of_burst = (idx % self.burst_len) == 0
        mean = np.where(first_of_burst, 1.0 / self.idle_rate, 1.0 / self.burst_rate)
        return u * mean, carry


class DiurnalSource(_SyntheticSource):
    """Sinusoidally rate-modulated arrivals: the instantaneous rate is
    ``base_rate * (1 + amplitude * sin(2*pi*t/period))`` evaluated at the
    previous arrival (a deterministic rate-modulated stream, not an
    exact nonhomogeneous-Poisson thinning — good enough for a synthetic
    load curve and exactly reproducible).
    """

    def __init__(
        self,
        base_rate: float,
        n: int,
        amplitude: float = 0.5,
        period: float = 64.0,
        **kw,
    ):
        if base_rate <= 0:
            raise ValueError(f"base_rate must be positive, got {base_rate}")
        if not 0 <= amplitude < 1:
            raise ValueError(f"amplitude must be in [0, 1), got {amplitude}")
        if period <= 0:
            raise ValueError(f"period must be positive, got {period}")
        super().__init__(n, **kw)
        self.base_rate = float(base_rate)
        self.amplitude = float(amplitude)
        self.period = float(period)

    def _init_carry(self):
        return float(self.t0)

    def _gaps(self, rng, idx0, m, carry):
        u = rng.exponential(1.0, m)
        gaps = np.empty(m, np.float64)
        t = carry
        two_pi = 2.0 * np.pi
        for i in range(m):
            rate = self.base_rate * (
                1.0 + self.amplitude * np.sin(two_pi * t / self.period)
            )
            gaps[i] = u[i] / rate
            t += gaps[i]
        return gaps, t


# ---------------------------------------------------------------------------
# On-disk traces
# ---------------------------------------------------------------------------

_MAGIC = b"REPRO-TRACE-V1\n"
_HEADER_BYTES = 256


class TraceWriter:
    """Streams arrival blocks to disk in bounded memory.

    File layout: a fixed 256-byte header (magic + JSON metadata, padded
    with spaces) followed by raw little-endian float32 rows.  The row
    count in the header is finalized on :meth:`close`, so a writer can
    stream an unknown-length source.  Use as a context manager.
    """

    def __init__(self, path: str, meta: Optional[dict] = None):
        self.path = str(path)
        self.meta = dict(meta or {})
        self._rows = 0
        self._fh = open(self.path, "wb")
        self._write_header()

    def _write_header(self) -> None:
        payload = dict(self.meta)
        payload["rows"] = self._rows
        payload["width"] = EMIT_WIDTH
        body = _MAGIC + json.dumps(payload, sort_keys=True).encode()
        if len(body) >= _HEADER_BYTES:
            raise ValueError("trace metadata too large for header")
        self._fh.write(body.ljust(_HEADER_BYTES, b" "))

    def write_block(self, rows: np.ndarray) -> int:
        """Append the real (type >= 0) rows of a block; returns count."""
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or rows.shape[1] != EMIT_WIDTH:
            raise ValueError(f"expected (*, {EMIT_WIDTH}) rows, got {rows.shape}")
        real = rows[rows[:, 1] >= 0]
        self._fh.write(np.ascontiguousarray(real, "<f4").tobytes())
        self._rows += real.shape[0]
        return real.shape[0]

    def close(self) -> None:
        if self._fh is None:
            return
        self._fh.seek(0)
        self._write_header()
        self._fh.close()
        self._fh = None

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class TraceReader:
    """Bounded-memory block reader for :class:`TraceWriter` files.

    Reads one block at a time via ``np.fromfile`` at an explicit byte
    offset — a million-row trace costs one block of host memory.
    Implements the :class:`ArrivalSource` protocol.
    """

    def __init__(self, path: str, block_size: int = DEFAULT_BLOCK):
        if block_size <= 0:
            raise ValueError(f"block_size must be positive, got {block_size}")
        self.path = str(path)
        self.block_size = int(block_size)
        self._cursor = 0
        with open(self.path, "rb") as fh:
            head = fh.read(_HEADER_BYTES)
        if not head.startswith(_MAGIC):
            raise ValueError(f"{path}: not a repro trace file")
        self.meta = json.loads(head[len(_MAGIC) :].decode())
        if self.meta.get("width") != EMIT_WIDTH:
            raise ValueError(
                f"{path}: row width {self.meta.get('width')} != {EMIT_WIDTH}"
            )
        self.n = int(self.meta["rows"])
        size = os.path.getsize(self.path) - _HEADER_BYTES
        if size < self.n * EMIT_WIDTH * 4:
            raise ValueError(f"{path}: truncated trace ({size} data bytes)")

    def __len__(self) -> int:
        return self.n

    def seek(self, cursor: int) -> None:
        if not 0 <= cursor <= self.n:
            raise ValueError(f"cursor {cursor} outside [0, {self.n}]")
        self._cursor = int(cursor)

    def blocks(self) -> Iterator[np.ndarray]:
        bs = self.block_size
        pos = self._cursor
        with open(self.path, "rb") as fh:
            while pos < self.n:
                take = min(bs, self.n - pos)
                fh.seek(_HEADER_BYTES + pos * EMIT_WIDTH * 4)
                flat = np.fromfile(fh, "<f4", take * EMIT_WIDTH)
                rows = flat.astype(np.float32).reshape(take, EMIT_WIDTH)
                yield _pad_block(rows, bs)
                pos += take


def source_events(source: ArrivalSource) -> list:
    """Materialize a source as ``(time, type, args)`` seed tuples.

    This is the closed-system reference path: pre-seed the entire trace
    into the initial queue and run to quiescence.  Tests compare this
    against streaming the same source.  Loads the whole trace — use
    only for traces that fit in host memory.
    """
    out = []
    source.seek(0)
    for block in source.blocks():
        for row in block:
            if row[1] < 0:
                continue
            out.append(
                (float(row[0]), int(row[1]), tuple(float(a) for a in row[2:]))
            )
    return out
