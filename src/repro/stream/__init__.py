"""Open-system ingestion: host→device arrival streams (DESIGN.md §10).

Every run used to be a CLOSED system — events seeded once, capacity
fixed at build time, the engine drained to quiescence.  This package
makes the pending set OPEN: an :class:`~repro.stream.source
.ArrivalSource` produces fixed-size arrival blocks in the portable
emit-row layout, and :class:`~repro.stream.ingest.StreamFeeder`
double-buffers them host→device while the engine runs, absorbing each
block at segment boundaries under the lexicographic admission fence —
the same conservative-window discipline the spill policy uses, so a
streamed run is bit-identical to pre-seeding the whole trace.

Entry point: ``CompiledSim.run(arrivals=source, backpressure=...)``
(see :meth:`repro.core.program.CompiledSim.run`).
"""

from repro.stream.source import (
    ArrivalSource,
    BurstySource,
    DiurnalSource,
    PoissonSource,
    TraceReader,
    TraceWriter,
    source_events,
)
from repro.stream.ingest import StreamFeeder

__all__ = [
    "ArrivalSource",
    "BurstySource",
    "DiurnalSource",
    "PoissonSource",
    "StreamFeeder",
    "TraceReader",
    "TraceWriter",
    "source_events",
]
