"""Gradient compression for the data-parallel all-reduce.

int8 uniform quantization with per-leaf scales and an ERROR-FEEDBACK
buffer (the residual of each quantization is added to the next step's
gradient — 1-bit-Adam-style memory compensation, which keeps convergence
within noise of fp32 all-reduce).

The compressed collective itself is expressed with ``shard_map`` +
``psum``: each DP shard quantizes its local gradient to int8, the psum
accumulates in int32 (no overflow below 2^23 replicas), and the result
is dequantized — 4x less ICI traffic than fp32, 2x less than bf16.

On this single-device container the wrapper degrades to the identity
collective but the quantize/dequantize path (and the error-feedback
recursion) is exercised by unit tests; the dry-run's multi-device mesh
lowers the real psum.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x):
    """x fp -> (int8 q, f32 scale); symmetric per-tensor scaling."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_residual(x):
    """(quantized-representable part, residual error) of x."""
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s)
    return deq, x.astype(jnp.float32) - deq


def error_feedback_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def apply_error_feedback(grads, ef_state):
    """g' = g + e_{t-1}; returns (compensated grads, fn to get new e)."""
    comp = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                        grads, ef_state)
    return comp


def compressed_psum_gradients(grads, mesh, dp_axes):
    """All-reduce-mean gradients over the DP axes with int8 payload.

    Must be called INSIDE a shard_map over ``mesh`` (grads are the local
    per-shard values).  Accumulation is int32 -> exact sum of the int8
    codes; dequantization uses the max scale psum'd alongside (scales
    are psum-maxed so every shard dequantizes identically).
    """
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]

    def reduce_leaf(g):
        q, s = quantize_int8(g)
        s = jax.lax.pmax(s, dp_axes)          # common scale
        # re-quantize against the common scale for exactness
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / s),
                     -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), dp_axes)
        return (total.astype(jnp.float32) * s / n).astype(jnp.float32)

    return jax.tree.map(reduce_leaf, grads)
