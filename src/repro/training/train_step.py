"""The training step: loss -> grad -> AdamW, with microbatching + remat.

``make_train_step`` returns a pure ``(state, batch) -> (state, metrics)``
suitable for ``jax.jit`` under a mesh.  Gradient accumulation runs as a
``lax.scan`` over microbatches (bounding activation memory to one
microbatch), with fp32 accumulators; the optimizer applies once per
global step.  Remat (full ``nothing_saveable`` per scanned layer) is on
by default for the large train shapes.

TrainState is a plain dict pytree: {"params", "opt", ["ef"]} — the
error-feedback buffer appears only when gradient compression is on.
"""

from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import LM
from repro.training.optim import AdamWConfig, adamw_init, adamw_update


def init_train_state(model: LM, key, *, compression: bool = False):
    params = model.init(key)
    state = {"params": params, "opt": adamw_init(params)}
    if compression:
        from repro.training.compression import error_feedback_init
        state["ef"] = error_feedback_init(params)
    return state


def _split_microbatches(batch, num_micro: int):
    """Split the global batch into scan-able microbatches, STRIDED.

    ``x.reshape(num_micro, per, ...)`` would place the DP-sharded batch
    dim onto the microbatch axis — every microbatch then lives on one
    data shard and GSPMD replicates the whole forward pass (a 16x
    executed-FLOP regression caught by the HLO cost model; EXPERIMENTS
    §Perf).  Reshaping to [per, num_micro] and swapping axes assigns
    element (m, k) = global index m + num_micro·k: each microbatch takes
    one slice from EVERY data shard, so the batch dim stays sharded.

    m_rope 'positions' [3, B, T] split along dim 1.
    """
    def split(x, axis=0):
        b = x.shape[axis]
        if b % num_micro:
            raise ValueError(f"batch {b} not divisible by {num_micro}")
        per = b // num_micro
        new = x.shape[:axis] + (per, num_micro) + x.shape[axis + 1:]
        return jnp.moveaxis(x.reshape(new), axis + 1, 0)

    def split_leaf(path, x):
        name = jax.tree_util.keystr(path)
        if "positions" in name and x.ndim == 3:
            return split(x, axis=1)
        return split(x, axis=0)

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch)
    return jax.tree_util.tree_unflatten(
        treedef, [split_leaf(p, l) for p, l in flat])


def make_train_step(model: LM, opt_cfg: AdamWConfig, *,
                    num_microbatches: int = 1, remat: bool = True):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, micro):
        return model.loss(params, micro, remat=remat)

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(state, batch):
        params = state["params"]
        if num_microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            micros = _split_microbatches(batch, num_microbatches)

            def acc_step(carry, micro):
                loss_acc, grads_acc = carry
                loss, grads = grad_fn(params, micro)
                grads_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32),
                    grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_step, (jnp.float32(0.0), zero_grads), micros)
            inv = 1.0 / num_microbatches
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)

        if "ef" in state:
            from repro.training.compression import (
                apply_error_feedback, compress_residual)
            grads = apply_error_feedback(grads, state["ef"])
            pairs = jax.tree.map(compress_residual, grads)
            grads = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_ef = jax.tree.map(lambda p: p[1], pairs,
                                  is_leaf=lambda x: isinstance(x, tuple))

        params, opt, metrics = adamw_update(opt_cfg, params, grads,
                                            state["opt"])
        new_state = {"params": params, "opt": opt}
        if "ef" in state:
            new_state["ef"] = new_ef
        metrics["loss"] = loss
        return new_state, metrics

    return train_step
