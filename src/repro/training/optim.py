"""Optimizer: AdamW with mixed-precision discipline + LR schedules.

Hand-rolled (no optax dependency): params may be bf16; first/second
moments and the update math are fp32; weight decay is decoupled.  The
optimizer state pytree mirrors the param tree, so the FSDP shardings of
the params apply leaf-for-leaf to ``m`` and ``v`` (ZeRO-style sharded
optimizer state for free under GSPMD).

Schedules: cosine (default) and WSD (warmup-stable-decay), the MiniCPM
schedule the minicpm-2b config calls for.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"      # constant | cosine | wsd
    warmup_steps: int = 100
    total_steps: int = 10_000
    stable_frac: float = 0.9      # WSD: fraction of steps at peak lr


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        mult = jnp.float32(1.0)
    elif cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps) /
                     jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                     0.0, 1.0)
        mult = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "wsd":
        # Warmup -> Stable (peak lr) -> exponential-ish Decay tail.
        stable_end = cfg.warmup_steps + cfg.stable_frac * (
            cfg.total_steps - cfg.warmup_steps)
        t = jnp.clip((step - stable_end) /
                     jnp.maximum(cfg.total_steps - stable_end, 1), 0.0, 1.0)
        mult = jnp.where(step < stable_end, 1.0, 0.5 ** (t * 10.0))
    else:
        raise ValueError(cfg.schedule)
    return cfg.lr * warm * mult


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


_NO_DECAY_TOKENS = ("norm", "scale", "bias", "decay_base", "bonus_u",
                    "dt_bias", "A_log", "mix")


def _decay_mask(path: str) -> bool:
    return not any(tok in path for tok in _NO_DECAY_TOKENS)


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule_lr(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])

    new_p, new_m, new_v = [], [], []
    for (path, p), g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        pathstr = jax.tree_util.keystr(path)
        gf = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * jnp.square(gf)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if _decay_mask(pathstr):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        pnew = p.astype(jnp.float32) - lr * update
        new_p.append(pnew.astype(p.dtype))
        new_m.append(m)
        new_v.append(v)

    unflatten = jax.tree_util.tree_unflatten
    params = unflatten(treedef, new_p)
    opt_state = {
        "m": unflatten(treedef, new_m),
        "v": unflatten(treedef, new_v),
        "step": step,
    }
    return params, opt_state, {"lr": lr, "grad_norm": gnorm}
