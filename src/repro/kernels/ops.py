"""Jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile through Mosaic (``interpret=False``); on any
other backend (this CPU container) they run in interpret mode, which
executes the kernel body faithfully for correctness validation.  The
models call these through ``attn_impl='pallas'``; layout translation
from the models' [B,T,H,D] to the kernels' [B,H,T,D] happens here.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.rwkv6_scan import rwkv6_scan_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """q: [B,T,H,D]; k/v: [B,S,KV,D] (model layout) -> [B,T,H,D]."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_pallas(qt, kt, vt, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=_interpret())
    return o.transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k_cache, v_cache, lengths, *, block_k: int = 512):
    """q: [B,H,D]; caches: [B,S,KV,D] (model layout) -> [B,H,D]."""
    kt = k_cache.transpose(0, 2, 1, 3)
    vt = v_cache.transpose(0, 2, 1, 3)
    return decode_attention_pallas(q, kt, vt, lengths, block_k=block_k,
                                   interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def rwkv6_scan(r, k, v, logw, u, *, chunk: int = 64):
    """r/k/v/logw: [B,H,T,K]; u: [H,K] -> [B,H,T,K] fp32."""
    return rwkv6_scan_pallas(r, k, v, logw, u, chunk=chunk,
                             interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk", "block_i"))
def mamba_scan(xdt, dt, bc, cc, a, *, chunk: int = 32,
               block_i: int = 256):
    """Selective scan: xdt/dt [B,T,I]; bc/cc [B,T,N]; a [I,N] -> fp32."""
    return mamba_scan_pallas(xdt, dt, bc, cc, a, chunk=chunk,
                             block_i=block_i, interpret=_interpret())
