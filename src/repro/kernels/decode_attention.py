"""Pallas TPU decode attention: one query token vs. a long KV cache.

Decode is HBM-bandwidth-bound (the cache read dominates); the kernel
streams the cache through VMEM in ``block_k`` chunks with the running
softmax state in scratch, exactly one pass over K and V.  Per-sequence
valid lengths live in SMEM (scalar prefetch) so padded cache tail blocks
are masked, and blocks entirely past the length are skipped — for
mixed-length continuous-batching this prunes the tail reads.

Layout contract: q [B, H, D]; k/v caches [B, KV, S, D]; lengths i32[B].
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._pallas_compat import CompilerParams

_NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, block_k: int, group: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    length = len_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [G, D]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)          # [bk, D]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale    # [G, bk]
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (group, block_k), 1)
        s = jnp.where(k_pos < length, s, _NEG_INF)
        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_prev * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    # Skip cache blocks entirely past this sequence's length.
    pl.when(ki * block_k < length)(_compute)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, lengths, *,
                            scale: float | None = None,
                            block_k: int = 512, interpret: bool = True):
    """q: [B,H,D]; caches [B,KV,S,D]; lengths i32[B] -> [B,H,D].

    Grid: (B, KV, S/block_k); each (b, kv) step processes the G = H/KV
    query heads of that KV group together (one cache read serves the
    whole group — the GQA bandwidth saving, realized in VMEM).
    """
    B, H, D = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_k = min(block_k, S)
    nk = -(-S // block_k)
    Sp = nk * block_k
    if Sp != S:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    qg = q.reshape(B, KV, G, D)

    kernel = functools.partial(_decode_kernel, scale=scale,
                               block_k=block_k, group=G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, kv, ki, *_: (b, kv, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, kv, ki, *_: (b, kv, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, kv, ki, *_: (b, kv, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, kv, ki, *_: (b, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    return out.reshape(B, H, D)
